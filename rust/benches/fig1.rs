//! Regenerates the paper's Figure 1 (large-weight byte-position
//! histogram, pre-WOT ~uniform / post-WOT empty in positions 0..6).

use zsecc::harness::fig1;
use zsecc::model::manifest::list_models;

fn main() {
    let artifacts = zsecc::artifacts_dir();
    if !artifacts.join("index.json").exists() {
        println!("fig1: no artifacts (run `make artifacts`)");
        return;
    }
    let models = list_models(&artifacts).unwrap();
    let figs = fig1::run(&artifacts, &models).unwrap();
    println!("{}", fig1::render(&figs));
    for f in &figs {
        println!(
            "  {}: pre-WOT positions roughly uniform (tol 50%): {} (paper Fig 1: ~uniform)",
            f.model,
            fig1::is_roughly_uniform(&f.pre_wot, 0.5)
        );
    }
}
