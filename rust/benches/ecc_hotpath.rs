//! Hot-path microbenchmarks: ECC block encode/decode/scrub throughput
//! per strategy, syndrome computation, fault injection, dequantization.
//!
//! This is the §Perf ledger for Layer 3: the paper's latency claim is
//! that in-place decoding adds only wiring on top of standard SEC-DED —
//! in software that translates to "in-place decode GB/s within ~1.1x of
//! (72,64) SEC-DED decode GB/s", checked here.

use zsecc::ecc::strategy_by_name;
use zsecc::memory::{FaultInjector, FaultModel};
use zsecc::quant::dequantize_into;
use zsecc::util::rng::Rng;
use zsecc::util::timer::bench;

fn wot_weights(n: usize, seed: u64) -> Vec<i8> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            if i % 8 == 7 {
                (rng.below(256) as i64 - 128) as i8
            } else {
                (rng.below(128) as i64 - 64) as i8
            }
        })
        .collect()
}

fn ext_weights(n: usize, seed: u64) -> Vec<i8> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            if i % 16 == 15 {
                (rng.below(256) as i64 - 128) as i8
            } else {
                (rng.below(64) as i64 - 32) as i8
            }
        })
        .collect()
}

fn main() {
    const N: usize = 1 << 20; // 1 MiB of weights — a VGG16_s-scale buffer
    println!("== ecc_hotpath: {} weight bytes per op ==", N);
    let w8 = wot_weights(N, 1);
    let w16 = ext_weights(N, 2);
    let mut out = vec![0i8; N];

    for name in ["faulty", "zero", "ecc", "in-place", "bch16"] {
        let s = strategy_by_name(name).unwrap();
        let w = if name == "bch16" { &w16 } else { &w8 };
        // encode
        let r = bench(&format!("{name}: encode"), || {
            let enc = s.encode(w).unwrap();
            std::hint::black_box(&enc);
        });
        println!("    -> {}", r.throughput_str(N));
        // decode clean
        let enc = s.encode(w).unwrap();
        let r = bench(&format!("{name}: decode (clean)"), || {
            s.decode(std::hint::black_box(&enc), &mut out);
        });
        println!("    -> {}", r.throughput_str(N));
        // decode with sparse faults (1e-4: the realistic scrub-path load)
        let mut enc_f = enc.clone();
        FaultInjector::new(FaultModel::Uniform, 3).inject(&mut enc_f, 1e-4);
        let r = bench(&format!("{name}: decode (rate 1e-4)"), || {
            s.decode(std::hint::black_box(&enc_f), &mut out);
        });
        println!("    -> {}", r.throughput_str(N));
        // scrub
        let r = bench(&format!("{name}: scrub (rate 1e-4)"), || {
            let mut e = enc_f.clone();
            s.scrub(&mut e);
            std::hint::black_box(&e);
        });
        println!("    -> {}", r.throughput_str(N));
    }

    // latency-claim check: in-place vs conventional SEC-DED decode
    {
        let ecc = strategy_by_name("ecc").unwrap();
        let inp = strategy_by_name("in-place").unwrap();
        let enc_e = ecc.encode(&w8).unwrap();
        let enc_i = inp.encode(&w8).unwrap();
        let re = bench("claim: secded(72,64) decode", || {
            ecc.decode(std::hint::black_box(&enc_e), &mut out);
        });
        let ri = bench("claim: in-place(64,57) decode", || {
            inp.decode(std::hint::black_box(&enc_i), &mut out);
        });
        let ratio = ri.ns_per_iter / re.ns_per_iter;
        println!(
            "    -> in-place / secded decode time ratio = {ratio:.3} (paper: wiring only; target <= ~1.1)"
        );
    }

    // fault injection + dequantization (the rest of the scrub epoch)
    {
        let s = strategy_by_name("in-place").unwrap();
        let enc = s.encode(&w8).unwrap();
        let r = bench("fault injection (rate 1e-3)", || {
            let mut e = enc.clone();
            let mut inj = FaultInjector::new(FaultModel::Uniform, 7);
            inj.inject(&mut e, 1e-3);
            std::hint::black_box(&e);
        });
        println!("    -> {}", r.throughput_str(N));
        let layers = vec![zsecc::model::Layer {
            name: "w".into(),
            shape: vec![N],
            offset: 0,
            size: N,
            scale: 0.01,
            scale_prewot: 0.01,
        }];
        let mut f = vec![0f32; N];
        let r = bench("dequantize (per-layer scale)", || {
            dequantize_into(std::hint::black_box(&w8), &layers, &mut f);
        });
        println!("    -> {}", r.throughput_str(N));
    }
}
