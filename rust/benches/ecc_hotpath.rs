//! Hot-path microbenchmarks: ECC block encode/decode/scrub throughput
//! per strategy, syndrome computation, fault injection, dequantization,
//! and the sharded store's parallel scrub+decode scaling.
//!
//! This is the §Perf ledger for Layer 3: the paper's latency claim is
//! that in-place decoding adds only wiring on top of standard SEC-DED —
//! in software that translates to "in-place decode GB/s within ~1.1x of
//! (72,64) SEC-DED decode GB/s", checked here. The sharded section
//! checks the serving claim instead: with >= 4 workers the sharded
//! store's scrub+decode epoch must run >= 2x the single-worker rate.
//!
//! `--json` appends one machine-readable record (for the BENCH_*.json
//! trajectory) after the human-readable output.

use zsecc::ecc::strategy_by_name;
use zsecc::memory::{FaultInjector, FaultModel, ShardedBank};
use zsecc::quant::dequantize_into;
use zsecc::util::cli::Args;
use zsecc::util::json::{arr, num, obj, s};
use zsecc::util::rng::Rng;
use zsecc::util::timer::bench;

fn wot_weights(n: usize, seed: u64) -> Vec<i8> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            if i % 8 == 7 {
                (rng.below(256) as i64 - 128) as i8
            } else {
                (rng.below(128) as i64 - 64) as i8
            }
        })
        .collect()
}

fn ext_weights(n: usize, seed: u64) -> Vec<i8> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            if i % 16 == 15 {
                (rng.below(256) as i64 - 128) as i8
            } else {
                (rng.below(64) as i64 - 32) as i8
            }
        })
        .collect()
}

fn main() {
    let args = Args::from_env().unwrap_or_default();
    const N: usize = 1 << 20; // 1 MiB of weights — a VGG16_s-scale buffer
    println!("== ecc_hotpath: {} weight bytes per op ==", N);
    let w8 = wot_weights(N, 1);
    let w16 = ext_weights(N, 2);
    let mut out = vec![0i8; N];
    // (name, GB/s) pairs for the --json record
    let mut records: Vec<(String, f64)> = Vec::new();
    let gbps = |ns_per_iter: f64| N as f64 / ns_per_iter;

    for name in ["faulty", "zero", "ecc", "in-place", "bch16"] {
        let s = strategy_by_name(name).unwrap();
        let w = if name == "bch16" { &w16 } else { &w8 };
        // encode
        let r = bench(&format!("{name}: encode"), || {
            let enc = s.encode(w).unwrap();
            std::hint::black_box(&enc);
        });
        println!("    -> {}", r.throughput_str(N));
        records.push((format!("{name}/encode"), gbps(r.ns_per_iter)));
        // decode clean
        let enc = s.encode(w).unwrap();
        let r = bench(&format!("{name}: decode (clean)"), || {
            s.decode(std::hint::black_box(&enc), &mut out);
        });
        println!("    -> {}", r.throughput_str(N));
        records.push((format!("{name}/decode_clean"), gbps(r.ns_per_iter)));
        // decode with sparse faults (1e-4: the realistic scrub-path load)
        let mut enc_f = enc.clone();
        FaultInjector::new(FaultModel::Uniform, 3).inject(&mut enc_f, 1e-4);
        let r = bench(&format!("{name}: decode (rate 1e-4)"), || {
            s.decode(std::hint::black_box(&enc_f), &mut out);
        });
        println!("    -> {}", r.throughput_str(N));
        records.push((format!("{name}/decode_1e-4"), gbps(r.ns_per_iter)));
        // scrub
        let r = bench(&format!("{name}: scrub (rate 1e-4)"), || {
            let mut e = enc_f.clone();
            s.scrub(&mut e);
            std::hint::black_box(&e);
        });
        println!("    -> {}", r.throughput_str(N));
        records.push((format!("{name}/scrub_1e-4"), gbps(r.ns_per_iter)));
    }

    // latency-claim check: in-place vs conventional SEC-DED decode
    let claim_ratio = {
        let ecc = strategy_by_name("ecc").unwrap();
        let inp = strategy_by_name("in-place").unwrap();
        let enc_e = ecc.encode(&w8).unwrap();
        let enc_i = inp.encode(&w8).unwrap();
        let re = bench("claim: secded(72,64) decode", || {
            ecc.decode(std::hint::black_box(&enc_e), &mut out);
        });
        let ri = bench("claim: in-place(64,57) decode", || {
            inp.decode(std::hint::black_box(&enc_i), &mut out);
        });
        let ratio = ri.ns_per_iter / re.ns_per_iter;
        println!(
            "    -> in-place / secded decode time ratio = {ratio:.3} (paper: wiring only; target <= ~1.1)"
        );
        ratio
    };

    // fault injection + dequantization (the rest of the scrub epoch)
    {
        let s = strategy_by_name("in-place").unwrap();
        let enc = s.encode(&w8).unwrap();
        let r = bench("fault injection (rate 1e-3)", || {
            let mut e = enc.clone();
            let mut inj = FaultInjector::new(FaultModel::Uniform, 7);
            inj.inject(&mut e, 1e-3);
            std::hint::black_box(&e);
        });
        println!("    -> {}", r.throughput_str(N));
        let layers = vec![zsecc::model::Layer {
            name: "w".into(),
            shape: vec![N],
            offset: 0,
            size: N,
            scale: 0.01,
            scale_prewot: 0.01,
        }];
        let mut f = vec![0f32; N];
        let r = bench("dequantize (per-layer scale)", || {
            dequantize_into(std::hint::black_box(&w8), &layers, &mut f);
        });
        println!("    -> {}", r.throughput_str(N));
        records.push(("dequantize".into(), gbps(r.ns_per_iter)));
    }

    // sharded store: one scrub+decode epoch over the 1 MiB in-place
    // image, swept over the worker-pool size (32 shards).
    const SHARDS: usize = 32;
    println!("== sharded store: in-place, {SHARDS} shards, scrub+decode epoch ==");
    let mut sharded: Vec<(usize, f64)> = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let mut sb =
            ShardedBank::new(strategy_by_name("in-place").unwrap(), &w8, SHARDS, workers)
                .unwrap();
        sb.inject(FaultModel::Uniform, 1e-4, 5);
        let r = bench(&format!("sharded scrub+decode ({workers} workers)"), || {
            sb.scrub();
            sb.read(&mut out);
        });
        // 2 passes over the image per iteration (scrub + decode)
        println!("    -> {}", r.throughput_str(2 * N));
        sharded.push((workers, 2.0 * N as f64 / r.ns_per_iter));
    }
    let base = sharded[0].1;
    for &(workers, g) in &sharded {
        records.push((format!("sharded_scrub_decode/{workers}w"), g));
        if workers >= 4 {
            println!(
                "    -> {workers} workers vs 1: {:.2}x (target >= 2x at 4 workers)",
                g / base
            );
        }
    }

    if args.bool("json") {
        let rec = obj(vec![
            ("bench", s("ecc_hotpath")),
            ("bytes_per_op", num(N as f64)),
            ("inplace_vs_secded_decode_ratio", num(claim_ratio)),
            ("shards", num(SHARDS as f64)),
            (
                "sharded_speedup_4w",
                num(sharded.iter().find(|r| r.0 == 4).map(|r| r.1 / base).unwrap_or(0.0)),
            ),
            (
                "gbps",
                obj(records
                    .iter()
                    .map(|(k, v)| (k.as_str(), num(*v)))
                    .collect()),
            ),
            (
                "sharded_workers",
                arr(sharded.iter().map(|&(w, _)| num(w as f64))),
            ),
            (
                "sharded_gbps",
                arr(sharded.iter().map(|&(_, g)| num(g))),
            ),
        ]);
        println!("{rec}");
    }
}
