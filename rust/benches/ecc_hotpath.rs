//! Hot-path microbenchmarks: ECC block encode/decode/scrub throughput
//! per strategy, syndrome computation, fault injection, dequantization,
//! and the sharded store's parallel scrub+decode scaling.
//!
//! This is the §Perf ledger for Layer 3: the paper's latency claim is
//! that in-place decoding adds only wiring on top of standard SEC-DED —
//! in software that translates to "in-place decode GB/s within ~1.1x of
//! (72,64) SEC-DED decode GB/s", checked here. The sharded section
//! checks the serving claim instead: with >= 4 workers the sharded
//! store's scrub+decode epoch must run >= 2x the single-worker rate.
//!
//! `--json` appends one machine-readable record (for the BENCH_*.json
//! trajectory) after the human-readable output; `--out FILE` appends
//! the same record to FILE (the repo-root `BENCH_ecc.json` ledger is a
//! JSON-lines file of these records); `--n BYTES` overrides the buffer
//! size (rounded up to whole 512-byte tiles; CI uses a synthetic small
//! size, the default is a VGG16_s-scale 1 MiB).

use zsecc::ecc::strategy_by_name;
use zsecc::memory::{FaultInjector, FaultModel, ShardedBank};
use zsecc::quant::dequantize_into;
use zsecc::util::cli::Args;
use zsecc::util::json::{arr, num, obj, s};
use zsecc::util::rng::Rng;
use zsecc::util::timer::bench;

fn wot_weights(n: usize, seed: u64) -> Vec<i8> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            if i % 8 == 7 {
                (rng.below(256) as i64 - 128) as i8
            } else {
                (rng.below(128) as i64 - 64) as i8
            }
        })
        .collect()
}

fn ext_weights(n: usize, seed: u64) -> Vec<i8> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            if i % 16 == 15 {
                (rng.below(256) as i64 - 128) as i8
            } else {
                (rng.below(64) as i64 - 32) as i8
            }
        })
        .collect()
}

fn main() {
    let args = Args::from_env().unwrap_or_default();
    // 1 MiB of weights (a VGG16_s-scale buffer) unless --n overrides;
    // rounded up to whole tiles so every strategy's block size divides.
    // A malformed --n must not silently bench the default size — the
    // ledger record would be mislabeled.
    let n = args.usize_or("n", 1 << 20).expect("--n expects a byte count");
    let n = n.max(512).div_ceil(512) * 512;
    println!("== ecc_hotpath: {} weight bytes per op ==", n);
    let w8 = wot_weights(n, 1);
    let w16 = ext_weights(n, 2);
    let mut out = vec![0i8; n];
    // (name, GB/s) pairs for the --json record
    let mut records: Vec<(String, f64)> = Vec::new();
    let gbps = |ns_per_iter: f64| n as f64 / ns_per_iter;

    for name in ["faulty", "zero", "ecc", "in-place", "bch16"] {
        let s = strategy_by_name(name).unwrap();
        let w = if name == "bch16" { &w16 } else { &w8 };
        // encode
        let r = bench(&format!("{name}: encode"), || {
            let enc = s.encode(w).unwrap();
            std::hint::black_box(&enc);
        });
        println!("    -> {}", r.throughput_str(n));
        records.push((format!("{name}/encode"), gbps(r.ns_per_iter)));
        // decode clean
        let enc = s.encode(w).unwrap();
        let r = bench(&format!("{name}: decode (clean)"), || {
            s.decode(std::hint::black_box(&enc), &mut out);
        });
        println!("    -> {}", r.throughput_str(n));
        records.push((format!("{name}/decode_clean"), gbps(r.ns_per_iter)));
        // decode with sparse faults (1e-4: the realistic scrub-path load)
        let mut enc_f = enc.clone();
        FaultInjector::new(FaultModel::Uniform, 3).inject(&mut enc_f, 1e-4);
        let r = bench(&format!("{name}: decode (rate 1e-4)"), || {
            s.decode(std::hint::black_box(&enc_f), &mut out);
        });
        println!("    -> {}", r.throughput_str(n));
        records.push((format!("{name}/decode_1e-4"), gbps(r.ns_per_iter)));
        // scrub
        let r = bench(&format!("{name}: scrub (rate 1e-4)"), || {
            let mut e = enc_f.clone();
            s.scrub(&mut e);
            std::hint::black_box(&e);
        });
        println!("    -> {}", r.throughput_str(n));
        records.push((format!("{name}/scrub_1e-4"), gbps(r.ns_per_iter)));
    }

    // tile engine: clean-buffer decode throughput, scalar span vs the
    // word-parallel tiled span, per strategy. The clean path is the
    // overwhelmingly common case at realistic fault rates; the tiled
    // form proves a whole 512-byte tile clean with one OR-reduction
    // and degrades decode to a copy (plus sign restore for in-place).
    println!("== tile engine: clean-buffer decode, scalar vs tiled ==");
    let mut tile_records: Vec<(String, f64, f64)> = Vec::new();
    for name in ["faulty", "zero", "ecc", "in-place", "bch16"] {
        let s = strategy_by_name(name).unwrap();
        let w = if name == "bch16" { &w16 } else { &w8 };
        let enc = s.encode(w).unwrap();
        let rs = bench(&format!("{name}: decode_span scalar (clean)"), || {
            s.decode_span(
                std::hint::black_box(&enc.data),
                std::hint::black_box(&enc.oob),
                &mut out,
            );
        });
        let rt = bench(&format!("{name}: decode_span tiled  (clean)"), || {
            s.decode_span_tiled(
                std::hint::black_box(&enc.data),
                std::hint::black_box(&enc.oob),
                &mut out,
            );
        });
        println!(
            "    -> scalar {} | tiled {} | speedup {:.2}x",
            rs.throughput_str(n),
            rt.throughput_str(n),
            rs.ns_per_iter / rt.ns_per_iter
        );
        tile_records.push((name.to_string(), gbps(rs.ns_per_iter), gbps(rt.ns_per_iter)));
    }

    // latency-claim check: in-place vs conventional SEC-DED decode
    let claim_ratio = {
        let ecc = strategy_by_name("ecc").unwrap();
        let inp = strategy_by_name("in-place").unwrap();
        let enc_e = ecc.encode(&w8).unwrap();
        let enc_i = inp.encode(&w8).unwrap();
        let re = bench("claim: secded(72,64) decode", || {
            ecc.decode(std::hint::black_box(&enc_e), &mut out);
        });
        let ri = bench("claim: in-place(64,57) decode", || {
            inp.decode(std::hint::black_box(&enc_i), &mut out);
        });
        let ratio = ri.ns_per_iter / re.ns_per_iter;
        println!(
            "    -> in-place / secded decode time ratio = {ratio:.3} (paper: wiring only; target <= ~1.1)"
        );
        ratio
    };

    // fault injection + dequantization (the rest of the scrub epoch)
    {
        let s = strategy_by_name("in-place").unwrap();
        let enc = s.encode(&w8).unwrap();
        let r = bench("fault injection (rate 1e-3)", || {
            let mut e = enc.clone();
            let mut inj = FaultInjector::new(FaultModel::Uniform, 7);
            inj.inject(&mut e, 1e-3);
            std::hint::black_box(&e);
        });
        println!("    -> {}", r.throughput_str(n));
        let layers = vec![zsecc::model::Layer {
            name: "w".into(),
            shape: vec![n],
            offset: 0,
            size: n,
            scale: 0.01,
            scale_prewot: 0.01,
        }];
        let mut f = vec![0f32; n];
        let r = bench("dequantize (per-layer scale)", || {
            dequantize_into(std::hint::black_box(&w8), &layers, &mut f);
        });
        println!("    -> {}", r.throughput_str(n));
        records.push(("dequantize".into(), gbps(r.ns_per_iter)));
    }

    // sharded store: one scrub+decode epoch over the 1 MiB in-place
    // image, swept over the worker-pool size (32 shards).
    const SHARDS: usize = 32;
    println!("== sharded store: in-place, {SHARDS} shards, scrub+decode epoch ==");
    let mut sharded: Vec<(usize, f64)> = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let mut sb =
            ShardedBank::new(strategy_by_name("in-place").unwrap(), &w8, SHARDS, workers)
                .unwrap();
        sb.inject(FaultModel::Uniform, 1e-4, 5);
        let r = bench(&format!("sharded scrub+decode ({workers} workers)"), || {
            sb.scrub();
            sb.read(&mut out);
        });
        // 2 passes over the image per iteration (scrub + decode)
        println!("    -> {}", r.throughput_str(2 * n));
        sharded.push((workers, 2.0 * n as f64 / r.ns_per_iter));
    }
    let base = sharded[0].1;
    for &(workers, g) in &sharded {
        records.push((format!("sharded_scrub_decode/{workers}w"), g));
        if workers >= 4 {
            println!(
                "    -> {workers} workers vs 1: {:.2}x (target >= 2x at 4 workers)",
                g / base
            );
        }
    }

    if args.bool("json") || args.str_opt("out").is_some() {
        // tile section: per-strategy clean-decode GB/s, scalar vs tiled
        let tile_flat: Vec<(String, f64)> = tile_records
            .iter()
            .flat_map(|(name, sc, ti)| {
                [
                    (format!("{name}/scalar"), *sc),
                    (format!("{name}/tiled"), *ti),
                ]
            })
            .collect();
        let rec = obj(vec![
            ("bench", s("ecc_hotpath")),
            ("bytes_per_op", num(n as f64)),
            (
                "tile",
                obj(tile_flat
                    .iter()
                    .map(|(k, v)| (k.as_str(), num(*v)))
                    .collect()),
            ),
            ("inplace_vs_secded_decode_ratio", num(claim_ratio)),
            ("shards", num(SHARDS as f64)),
            (
                "sharded_speedup_4w",
                num(sharded.iter().find(|r| r.0 == 4).map(|r| r.1 / base).unwrap_or(0.0)),
            ),
            (
                "gbps",
                obj(records
                    .iter()
                    .map(|(k, v)| (k.as_str(), num(*v)))
                    .collect()),
            ),
            (
                "sharded_workers",
                arr(sharded.iter().map(|&(w, _)| num(w as f64))),
            ),
            (
                "sharded_gbps",
                arr(sharded.iter().map(|&(_, g)| num(g))),
            ),
        ]);
        if args.bool("json") {
            println!("{rec}");
        }
        if let Some(path) = args.str_opt("out") {
            // append one JSON-lines record to the perf ledger
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .unwrap_or_else(|e| panic!("cannot open ledger {path}: {e}"));
            writeln!(f, "{rec}").expect("ledger write failed");
            println!("appended record to {path}");
        }
    }
}
