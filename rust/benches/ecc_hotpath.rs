//! Hot-path microbenchmarks: ECC block encode/decode/scrub throughput
//! per strategy, syndrome computation, fault injection, dequantization,
//! the sharded store's parallel scrub+decode scaling, and the `pool`
//! section — persistent-pool vs scoped-spawn scrub-pass latency at
//! shard counts {4, 16, 64} plus the steady-state
//! allocations-per-scrub-tick gauge (arena misses; target 0).
//!
//! This is the §Perf ledger for Layer 3: the paper's latency claim is
//! that in-place decoding adds only wiring on top of standard SEC-DED —
//! in software that translates to "in-place decode GB/s within ~1.1x of
//! (72,64) SEC-DED decode GB/s", checked here. The sharded section
//! checks the serving claim instead: with >= 4 workers the sharded
//! store's scrub+decode epoch must run >= 2x the single-worker rate.
//!
//! The `guards` section prices the compute-path protection: guarded
//! (ABFT checksummed / range-supervised) vs unguarded dense-head
//! forward throughput, plus the raw envelope-clamp scan rate.
//!
//! The `recovery` section prices the MILR tier: the zero-redundancy
//! milr probe decode, the block-localizing outcome decode at a sparse
//! fault rate, and the algebraic least-squares solve in µs per
//! recovered block (eight blocks solved jointly on a dense head).
//!
//! The `fleet` section prices the process-wide scrub arbiter:
//! `FleetArbitration::plan` per wakeup at N models × S shards with
//! every shard due (worst-case demand width).
//!
//! The `closedloop` section prices the wear aging process the
//! closed-loop accuracy simulation drives every tick: `Wear::advance`
//! and `Wear::strike_positions` at the saturated stuck population.
//!
//! `--json` appends one machine-readable record (for the BENCH_*.json
//! trajectory) after the human-readable output; `--out FILE` appends
//! the same record to FILE (the repo-root `BENCH_ecc.json` ledger is a
//! JSON-lines file of these records); `--n BYTES` overrides the buffer
//! size (rounded up to whole 512-byte tiles; CI uses a synthetic small
//! size, the default is a VGG16_s-scale 1 MiB).

use zsecc::ecc::{strategy_by_name, Encoded, Protection};
use zsecc::harness::scrubsim;
use zsecc::memory::{plan_shards, pool, FaultInjector, FaultModel, ShardedBank};
use zsecc::quant::dequantize_into;
use zsecc::util::cli::Args;
use zsecc::util::json::{arr, num, obj, s, Json};
use zsecc::util::rng::Rng;
use zsecc::util::timer::bench;

fn wot_weights(n: usize, seed: u64) -> Vec<i8> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            if i % 8 == 7 {
                (rng.below(256) as i64 - 128) as i8
            } else {
                (rng.below(128) as i64 - 64) as i8
            }
        })
        .collect()
}

fn ext_weights(n: usize, seed: u64) -> Vec<i8> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            if i % 16 == 15 {
                (rng.below(256) as i64 - 128) as i8
            } else {
                (rng.below(64) as i64 - 32) as i8
            }
        })
        .collect()
}

/// One scrub pass fanned out the pre-pool way: fresh scoped threads,
/// round-robin buckets — the baseline the persistent pool is measured
/// against (`memory::pool::run_jobs_scoped` drives the same shape for
/// plain closures; this variant carries the shard span splitting).
fn scoped_scrub(
    strategy: &dyn Protection,
    enc: &mut Encoded,
    ranges: &[(usize, usize)],
    workers: usize,
) {
    let (data_len, oob_len) = (enc.data.len(), enc.oob.len());
    let mut jobs = Vec::with_capacity(ranges.len());
    let mut d_rest: &mut [u8] = &mut enc.data;
    let mut o_rest: &mut [u8] = &mut enc.oob;
    let (mut d_off, mut o_off) = (0usize, 0usize);
    for &(s, e) in ranges {
        let (_, oe) = strategy.oob_window(s, e, data_len, oob_len);
        let (d_win, d_next) = d_rest.split_at_mut(e - d_off);
        let (o_win, o_next) = o_rest.split_at_mut(oe - o_off);
        jobs.push((d_win, o_win));
        d_rest = d_next;
        o_rest = o_next;
        d_off = e;
        o_off = oe;
    }
    let nw = workers.min(jobs.len()).max(1);
    let mut buckets: Vec<Vec<_>> = (0..nw).map(|_| Vec::new()).collect();
    for (k, job) in jobs.into_iter().enumerate() {
        buckets[k % nw].push(job);
    }
    std::thread::scope(|scope| {
        for bucket in buckets {
            scope.spawn(move || {
                for (d, o) in bucket {
                    strategy.scrub_span_tiled(d, o);
                }
            });
        }
    });
}

/// Closed-loop multi-producer front-door throughput (million req/s)
/// for the lock-free slab ring: P producers push fire-and-forget
/// requests (response receivers dropped, so fan-out is a cheap failed
/// send) while a dispatcher thread drains sealed batches and recycles
/// slabs. The executor is free, so this isolates the ingress cost —
/// reserve/write/seal against lock/enqueue in [`locked_ingress_mreqs`].
fn ring_ingress_mreqs(producers: usize, secs: f64) -> f64 {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::mpsc::channel;
    use std::sync::Arc;
    use std::time::{Duration, Instant};
    use zsecc::coordinator::{IngressRing, Response, RingConfig};

    let ring = Arc::new(IngressRing::new(RingConfig {
        depth: 64,
        cap: 32,
        dim: 8,
        max_wait: Duration::from_millis(1),
    }));
    let dispatcher = {
        let r = ring.clone();
        std::thread::spawn(move || {
            while let Some(batch) = r.next_sealed() {
                for slot in 0..batch.count() {
                    let lane = batch.take_lane(slot);
                    let _ = lane.resp.send(Response {
                        id: lane.id,
                        pred: 0,
                        latency: lane.submitted.elapsed(),
                    });
                }
            }
        })
    };
    let stop = AtomicBool::new(false);
    let mut pushed = 0u64;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for p in 0..producers {
            let ring = &ring;
            let stop = &stop;
            handles.push(scope.spawn(move || {
                let img = vec![0f32; 8];
                let (tx, rx) = channel();
                drop(rx); // fire-and-forget: response sends fail cheaply
                let mut n = 0u64;
                let mut id = (p as u64) << 32;
                while !stop.load(Ordering::Relaxed) {
                    match ring.push(id, &img, tx.clone()) {
                        Ok(()) => {
                            n += 1;
                            id += 1;
                        }
                        Err(_) => std::thread::yield_now(),
                    }
                }
                n
            }));
        }
        std::thread::sleep(Duration::from_secs_f64(secs));
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            pushed += h.join().unwrap();
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    ring.close();
    dispatcher.join().unwrap();
    pushed as f64 / elapsed / 1e6
}

/// The locked baseline for [`ring_ingress_mreqs`]: same closed-loop
/// producers and free executor, front door swapped for the
/// Mutex+Condvar [`zsecc::coordinator::Batcher`]. The batcher queue is
/// unbounded, so producers self-throttle (an occasional `len()` probe)
/// to keep the comparison memory-bounded without adding a lock
/// acquisition to every push.
fn locked_ingress_mreqs(producers: usize, secs: f64) -> f64 {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::mpsc::channel;
    use std::sync::Arc;
    use std::time::{Duration, Instant};
    use zsecc::coordinator::{BatchPolicy, Batcher, Request, Response};

    let b = Arc::new(Batcher::new(BatchPolicy {
        max_batch: 32,
        max_wait: Duration::from_millis(1),
    }));
    let consumer = {
        let b = b.clone();
        std::thread::spawn(move || {
            while let Some(batch) = b.next_batch() {
                for req in batch {
                    let _ = req.resp.send(Response {
                        id: req.id,
                        pred: 0,
                        latency: req.submitted.elapsed(),
                    });
                }
            }
        })
    };
    let stop = AtomicBool::new(false);
    let mut pushed = 0u64;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for p in 0..producers {
            let b = &b;
            let stop = &stop;
            handles.push(scope.spawn(move || {
                let img = vec![0f32; 8];
                let (tx, rx) = channel();
                drop(rx);
                let mut n = 0u64;
                let mut id = (p as u64) << 32;
                while !stop.load(Ordering::Relaxed) {
                    if n % 256 == 0 && b.len() > 8192 {
                        std::thread::yield_now();
                        continue;
                    }
                    let req = Request {
                        id,
                        image: img.clone(),
                        submitted: Instant::now(),
                        resp: tx.clone(),
                    };
                    if b.push(req).is_err() {
                        break;
                    }
                    n += 1;
                    id += 1;
                }
                n
            }));
        }
        std::thread::sleep(Duration::from_secs_f64(secs));
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            pushed += h.join().unwrap();
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    b.close();
    consumer.join().unwrap();
    pushed as f64 / elapsed / 1e6
}

fn main() {
    let args = Args::from_env().unwrap_or_default();
    // 1 MiB of weights (a VGG16_s-scale buffer) unless --n overrides;
    // rounded up to whole tiles so every strategy's block size divides.
    // A malformed --n must not silently bench the default size — the
    // ledger record would be mislabeled.
    let n = args.usize_or("n", 1 << 20).expect("--n expects a byte count");
    let n = n.max(512).div_ceil(512) * 512;
    println!("== ecc_hotpath: {} weight bytes per op ==", n);
    let w8 = wot_weights(n, 1);
    let w16 = ext_weights(n, 2);
    let mut out = vec![0i8; n];
    // (name, GB/s) pairs for the --json record
    let mut records: Vec<(String, f64)> = Vec::new();
    let gbps = |ns_per_iter: f64| n as f64 / ns_per_iter;

    for name in ["faulty", "zero", "ecc", "in-place", "bch16"] {
        let s = strategy_by_name(name).unwrap();
        let w = if name == "bch16" { &w16 } else { &w8 };
        // encode
        let r = bench(&format!("{name}: encode"), || {
            let enc = s.encode(w).unwrap();
            std::hint::black_box(&enc);
        });
        println!("    -> {}", r.throughput_str(n));
        records.push((format!("{name}/encode"), gbps(r.ns_per_iter)));
        // decode clean
        let enc = s.encode(w).unwrap();
        let r = bench(&format!("{name}: decode (clean)"), || {
            s.decode(std::hint::black_box(&enc), &mut out);
        });
        println!("    -> {}", r.throughput_str(n));
        records.push((format!("{name}/decode_clean"), gbps(r.ns_per_iter)));
        // decode with sparse faults (1e-4: the realistic scrub-path load)
        let mut enc_f = enc.clone();
        FaultInjector::new(FaultModel::Uniform, 3).inject(&mut enc_f, 1e-4);
        let r = bench(&format!("{name}: decode (rate 1e-4)"), || {
            s.decode(std::hint::black_box(&enc_f), &mut out);
        });
        println!("    -> {}", r.throughput_str(n));
        records.push((format!("{name}/decode_1e-4"), gbps(r.ns_per_iter)));
        // scrub
        let r = bench(&format!("{name}: scrub (rate 1e-4)"), || {
            let mut e = enc_f.clone();
            s.scrub(&mut e);
            std::hint::black_box(&e);
        });
        println!("    -> {}", r.throughput_str(n));
        records.push((format!("{name}/scrub_1e-4"), gbps(r.ns_per_iter)));
    }

    // tile engine: clean-buffer decode throughput, scalar span vs the
    // word-parallel tiled span, per strategy. The clean path is the
    // overwhelmingly common case at realistic fault rates; the tiled
    // form proves a whole 512-byte tile clean with one OR-reduction
    // and degrades decode to a copy (plus sign restore for in-place).
    println!("== tile engine: clean-buffer decode, scalar vs tiled ==");
    let mut tile_records: Vec<(String, f64, f64)> = Vec::new();
    for name in ["faulty", "zero", "ecc", "in-place", "bch16"] {
        let s = strategy_by_name(name).unwrap();
        let w = if name == "bch16" { &w16 } else { &w8 };
        let enc = s.encode(w).unwrap();
        let rs = bench(&format!("{name}: decode_span scalar (clean)"), || {
            s.decode_span(
                std::hint::black_box(&enc.data),
                std::hint::black_box(&enc.oob),
                &mut out,
            );
        });
        let rt = bench(&format!("{name}: decode_span tiled  (clean)"), || {
            s.decode_span_tiled(
                std::hint::black_box(&enc.data),
                std::hint::black_box(&enc.oob),
                &mut out,
            );
        });
        println!(
            "    -> scalar {} | tiled {} | speedup {:.2}x",
            rs.throughput_str(n),
            rt.throughput_str(n),
            rs.ns_per_iter / rt.ns_per_iter
        );
        tile_records.push((name.to_string(), gbps(rs.ns_per_iter), gbps(rt.ns_per_iter)));
    }

    // latency-claim check: in-place vs conventional SEC-DED decode
    let claim_ratio = {
        let ecc = strategy_by_name("ecc").unwrap();
        let inp = strategy_by_name("in-place").unwrap();
        let enc_e = ecc.encode(&w8).unwrap();
        let enc_i = inp.encode(&w8).unwrap();
        let re = bench("claim: secded(72,64) decode", || {
            ecc.decode(std::hint::black_box(&enc_e), &mut out);
        });
        let ri = bench("claim: in-place(64,57) decode", || {
            inp.decode(std::hint::black_box(&enc_i), &mut out);
        });
        let ratio = ri.ns_per_iter / re.ns_per_iter;
        println!(
            "    -> in-place / secded decode time ratio = {ratio:.3} (paper: wiring only; target <= ~1.1)"
        );
        ratio
    };

    // fault injection + dequantization (the rest of the scrub epoch)
    {
        let s = strategy_by_name("in-place").unwrap();
        let enc = s.encode(&w8).unwrap();
        let r = bench("fault injection (rate 1e-3)", || {
            let mut e = enc.clone();
            let mut inj = FaultInjector::new(FaultModel::Uniform, 7);
            inj.inject(&mut e, 1e-3);
            std::hint::black_box(&e);
        });
        println!("    -> {}", r.throughput_str(n));
        let layers = vec![zsecc::model::Layer {
            name: "w".into(),
            shape: vec![n],
            offset: 0,
            size: n,
            scale: 0.01,
            scale_prewot: 0.01,
        }];
        let mut f = vec![0f32; n];
        let r = bench("dequantize (per-layer scale)", || {
            dequantize_into(std::hint::black_box(&w8), &layers, &mut f);
        });
        println!("    -> {}", r.throughput_str(n));
        records.push(("dequantize".into(), gbps(r.ns_per_iter)));
    }

    // sharded store: one scrub+decode epoch over the 1 MiB in-place
    // image, swept over the worker-pool size (32 shards).
    const SHARDS: usize = 32;
    println!("== sharded store: in-place, {SHARDS} shards, scrub+decode epoch ==");
    let mut sharded: Vec<(usize, f64)> = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let mut sb =
            ShardedBank::new(strategy_by_name("in-place").unwrap(), &w8, SHARDS, workers)
                .unwrap();
        sb.inject(FaultModel::Uniform, 1e-4, 5);
        let r = bench(&format!("sharded scrub+decode ({workers} workers)"), || {
            sb.scrub();
            sb.read(&mut out);
        });
        // 2 passes over the image per iteration (scrub + decode)
        println!("    -> {}", r.throughput_str(2 * n));
        sharded.push((workers, 2.0 * n as f64 / r.ns_per_iter));
    }
    let base = sharded[0].1;
    for &(workers, g) in &sharded {
        records.push((format!("sharded_scrub_decode/{workers}w"), g));
        if workers >= 4 {
            println!(
                "    -> {workers} workers vs 1: {:.2}x (target >= 2x at 4 workers)",
                g / base
            );
        }
    }

    // persistent pool vs scoped spawn: one scrub pass over the in-place
    // image at growing shard counts. A clean-ish image makes the scrub
    // work itself nearly free (tile clean proof), so this isolates the
    // orchestration cost — parked-worker enqueue vs per-pass
    // spawn/join. The gap must widen with the shard count.
    const POOL_WORKERS: usize = 4;
    println!("== pool: scrub pass, scoped spawn vs persistent pool ({POOL_WORKERS} workers) ==");
    let mut pool_rows: Vec<(usize, f64, f64)> = Vec::new(); // (shards, scoped ns, pool ns)
    for shards in [4usize, 16, 64] {
        let s = strategy_by_name("in-place").unwrap();
        let mut enc = s.encode(&w8).unwrap();
        FaultInjector::new(FaultModel::Uniform, 5).inject(&mut enc, 1e-4);
        let ranges = plan_shards(enc.data.len(), s.block_bytes(), shards);
        let rs = bench(&format!("scoped scrub ({shards} shards)"), || {
            scoped_scrub(s.as_ref(), &mut enc, &ranges, POOL_WORKERS);
        });
        let mut sb =
            ShardedBank::new(strategy_by_name("in-place").unwrap(), &w8, shards, POOL_WORKERS)
                .unwrap();
        sb.inject(FaultModel::Uniform, 1e-4, 5);
        let rp = bench(&format!("pool scrub   ({shards} shards)"), || {
            sb.scrub();
        });
        println!(
            "    -> scoped {} | pool {} | pool speedup {:.2}x",
            rs.throughput_str(n),
            rp.throughput_str(n),
            rs.ns_per_iter / rp.ns_per_iter
        );
        pool_rows.push((shards, rs.ns_per_iter, rp.ns_per_iter));
    }
    let pool_speedup_64 = match pool_rows.iter().find(|r| r.0 == 64) {
        Some(r) => r.1 / r.2,
        None => 0.0,
    };

    // steady-state allocations per scrub tick: one serving epoch =
    // scrub + fused decode→dequant refresh with scratch leased from
    // the worker arenas. After warmup the arena satisfies every lease,
    // so the per-tick allocation count (arena misses) must be 0.
    let allocs_per_tick = {
        let mut sb =
            ShardedBank::new(strategy_by_name("in-place").unwrap(), &w8, 64, POOL_WORKERS)
                .unwrap();
        let layers = vec![zsecc::model::Layer {
            name: "w".into(),
            shape: vec![n],
            offset: 0,
            size: n,
            scale: 0.01,
            scale_prewot: 0.01,
        }];
        let mut f = vec![0f32; n];
        for _ in 0..3 {
            sb.scrub();
            sb.decode_dequant_all(&layers, &mut f);
        }
        let (_, m0) = pool::arena_stats();
        let ticks = 10u32;
        for _ in 0..ticks {
            sb.scrub();
            sb.decode_dequant_all(&layers, &mut f);
        }
        let (_, m1) = pool::arena_stats();
        let a = (m1 - m0) as f64 / f64::from(ticks);
        println!("    -> steady-state arena allocations per scrub tick: {a:.1} (target 0)");
        a
    };

    // adaptive scrub scheduling: hotspot-migration scenario at equal
    // scrub bandwidth, fixed vs adaptive residuals. Deterministic
    // counts (virtual time), so the record is machine-independent; the
    // bench-regression guard gates only the tile/pool throughput.
    let (sched_fixed, sched_adaptive) = {
        let cfg = scrubsim::SimConfig::default();
        let scenario = scrubsim::Scenario::hotspot_migration(7);
        let fixed = scrubsim::run_sim(&cfg, &scenario, zsecc::memory::ScrubPolicy::Fixed)
            .expect("scrubsim fixed");
        let adaptive = scrubsim::run_sim(&cfg, &scenario, zsecc::memory::ScrubPolicy::Adaptive)
            .expect("scrubsim adaptive");
        println!("== sched: hotspot-migration scenario, {} passes each ==", fixed.scrub_passes);
        println!(
            "    -> residual uncorrectable blocks: fixed {} | adaptive {} ({})",
            fixed.residual_uncorrectable,
            adaptive.residual_uncorrectable,
            if adaptive.residual_uncorrectable < fixed.residual_uncorrectable {
                "adaptive wins"
            } else {
                "NO WIN"
            }
        );
        (fixed, adaptive)
    };

    // fleet arbitration: FleetArbitration::plan overhead per wakeup at
    // N models x S shards with every shard due — the worst-case demand
    // set, so deferral bookkeeping, the two-class sort, and the greedy
    // fit all run at full width. Prices the arbiter a serving process
    // pays per wakeup; ledger-only, not a regression gate.
    let fleet_rows: Vec<(usize, usize, f64)> = {
        use std::time::Duration;
        use zsecc::memory::{FleetArbitration, SchedulerConfig, ScrubScheduler};
        println!("== fleet: arbitration plan() per wakeup, all shards due ==");
        let tick = Duration::from_secs(1);
        let mut rows = Vec::new();
        for &(nmodels, shards) in &[(2usize, 16usize), (8, 32), (16, 64)] {
            let shard_bits = 32 * 1024u64;
            // budget = half the due demand: both grant classes and the
            // deficit books stay busy at every wakeup
            let budget = (nmodels * shards) as u64 / 2 * shard_bits;
            let mut fleet = FleetArbitration::new(Some(budget), 4);
            let scheds: Vec<ScrubScheduler> = (0..nmodels)
                .map(|_| {
                    ScrubScheduler::new(
                        SchedulerConfig::fixed(tick),
                        &vec![shard_bits; shards],
                        Duration::ZERO,
                    )
                })
                .collect();
            let slots: Vec<usize> = (0..nmodels).map(|_| fleet.register(shards)).collect();
            let refs: Vec<(usize, &ScrubScheduler)> =
                slots.iter().copied().zip(scheds.iter()).collect();
            let now = tick * 2; // every deadline passed: all shards due
            let r = bench(&format!("plan ({nmodels} models x {shards} shards)"), || {
                let g = fleet.plan(std::hint::black_box(&refs), now);
                std::hint::black_box(&g);
            });
            let due = (nmodels * shards) as f64;
            println!(
                "    -> {:.1} us/wakeup | {:.0} ns per due shard",
                r.ns_per_iter / 1e3,
                r.ns_per_iter / due
            );
            rows.push((nmodels, shards, r.ns_per_iter));
        }
        rows
    };

    // closed-loop wear process: the per-tick overhead the aging model
    // adds to the accuracy simulation — advance() (stuck-at accrual)
    // and strike_positions() (stuck re-assert scan over the full stuck
    // set + transient draws) against the n-byte in-place image, priced
    // at the saturated stuck population (the steady-state worst case:
    // every tick walks the whole stuck map). Ledger-only, not a
    // regression gate.
    let (wear_advance_us, wear_strike_us, wear_strikes, wear_stuck) = {
        use zsecc::memory::{Wear, WearParams};
        println!("== closedloop: wear process per-tick cost (saturated stuck set) ==");
        let sb = ShardedBank::new(strategy_by_name("in-place").unwrap(), &w8, 32, 1).unwrap();
        let total_bits = sb.total_bits();
        let mut wear = Wear::new(WearParams::default(), 7).unwrap();
        // default params reach the stuck cap around tick ~600
        // (size-independent: both cap and per-tick budget scale with
        // total_bits); past the cap every advance() is O(1)
        for _ in 0..1000 {
            wear.advance(total_bits);
        }
        let ra = bench("wear: advance (at stuck cap)", || {
            wear.advance(std::hint::black_box(total_bits));
        });
        let strikes = wear.strike_positions(sb.image()).len();
        let rs = bench("wear: strike_positions", || {
            let p = wear.strike_positions(std::hint::black_box(sb.image()));
            std::hint::black_box(&p);
        });
        println!(
            "    -> advance {:.2} us/tick | strikes {:.1} us/tick ({} positions, {} stuck)",
            ra.ns_per_iter / 1e3,
            rs.ns_per_iter / 1e3,
            strikes,
            wear.stuck_cells()
        );
        (
            ra.ns_per_iter / 1e3,
            rs.ns_per_iter / 1e3,
            strikes,
            wear.stuck_cells(),
        )
    };

    // compute-path guards: the guarded software executor's dense-head
    // forward under each guard mode vs the unguarded pass (same model,
    // same inputs, no faults — the steady-state serve cost), plus the
    // raw envelope-clamp scan over an n-byte activation plane.
    let (guard_gmacs, guard_full_ratio, guard_clamp_gbps) = {
        use zsecc::runtime::guard::{ComputeFaults, DenseModel, Envelope, GuardMode, GuardReport};
        const GDIMS: &[(usize, usize)] = &[(256, 64), (64, 16)];
        const GBATCH: usize = 32;
        let nw: usize = GDIMS.iter().map(|&(r, c)| r * c).sum();
        let mut rng = Rng::new(11);
        let w: Vec<f32> = (0..nw).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        let mut model = DenseModel::from_flat(&w, GDIMS).unwrap();
        let x: Vec<f32> = (0..GBATCH * GDIMS[0].0)
            .map(|_| (rng.f64() * 2.0 - 1.0) as f32)
            .collect();
        model.calibrate(&x, GBATCH, 0.05);
        let macs = (GBATCH * nw) as f64;
        let faults = ComputeFaults::default();
        println!("== guards: dense head {GBATCH}x256x64x16, guarded vs unguarded forward ==");
        let mut time_mode = |mode: GuardMode| {
            let r = bench(&format!("forward ({})", mode.tag()), || {
                let mut report = GuardReport::default();
                let y = model.forward_guarded(
                    std::hint::black_box(&x),
                    GBATCH,
                    mode,
                    &faults,
                    &mut report,
                );
                std::hint::black_box((&y, &report));
            });
            println!("    -> {:.2} GMAC/s", macs / r.ns_per_iter);
            r.ns_per_iter
        };
        let t_off = time_mode(GuardMode::Off);
        let t_range = time_mode(GuardMode::Range);
        let t_abft = time_mode(GuardMode::Abft);
        let t_full = time_mode(GuardMode::Full);
        println!(
            "    -> overhead vs off: range {:.2}x | abft {:.2}x | full {:.2}x",
            t_range / t_off,
            t_abft / t_off,
            t_full / t_off
        );
        let env = Envelope::new(-1.0, 1.0);
        let mut plane = vec![0.5f32; (n / 4).max(1)];
        let rc = bench("range clamp scan (clean plane)", || {
            std::hint::black_box(env.clamp_count(std::hint::black_box(&mut plane)));
        });
        println!("    -> {}", rc.throughput_str(plane.len() * 4));
        let clamp_gbps = (plane.len() * 4) as f64 / rc.ns_per_iter;
        (
            [macs / t_off, macs / t_range, macs / t_abft, macs / t_full],
            t_full / t_off,
            clamp_gbps,
        )
    };

    // recovery tier: the milr probe (zero-redundancy clean proof), the
    // block-localizing outcome decode at a sparse fault rate, and the
    // algebraic solve itself — µs per recovered block, eight blocks
    // solved jointly (8 unknowns per column system) on a dense head.
    let (milr_probe_gbps, milr_outcome_gbps, solve_us_per_block) = {
        use zsecc::ecc::QuantGrid;
        use zsecc::model::{recover_blocks, DenseShape, RecoverySet};
        use zsecc::runtime::guard::DenseModel;
        let s = strategy_by_name("milr").unwrap();
        let enc = s.encode(&w8).unwrap();
        println!("== recovery: milr probe + outcome decode + algebraic solve ==");
        let r = bench("milr: decode (clean probe)", || {
            s.decode(std::hint::black_box(&enc), &mut out);
        });
        println!("    -> {}", r.throughput_str(n));
        let probe_gbps = gbps(r.ns_per_iter);
        let mut enc_f = enc.clone();
        FaultInjector::new(FaultModel::Uniform, 3).inject(&mut enc_f, 1e-4);
        let ro = bench("milr: decode_range_outcome (rate 1e-4)", || {
            let o = s.decode_range_outcome(
                std::hint::black_box(&enc_f),
                0,
                enc_f.data.len(),
                &mut out,
            );
            std::hint::black_box(&o);
        });
        println!("    -> {}", ro.throughput_str(n));
        let cols = 16usize;
        let rows = n / cols;
        let scale = 0.02f32;
        let wf: Vec<f32> = w8.iter().map(|&v| v as f32 * scale).collect();
        let model = DenseModel::from_flat(&wf, &[(rows, cols)]).unwrap();
        let mut rng = Rng::new(777);
        let batch = 32usize;
        let x: Vec<f32> = (0..batch * rows)
            .map(|_| (rng.f64() * 2.0 - 1.0) as f32)
            .collect();
        let set = RecoverySet::capture(&model, &["head".to_string()], &x, batch);
        let shapes = vec![DenseShape {
            name: "head".into(),
            offset: 0,
            rows,
            cols,
            scale,
        }];
        // eight even blocks: rows 0..7 of columns 0..7, so every column
        // system carries 8 joint unknowns — the worst supported shape
        // for this batch size short of underdetermination
        let blocks: Vec<usize> = (0..8).map(|i| 2 * i).collect();
        let rs = bench("milr: recover_blocks (8 joint blocks)", || {
            let o = recover_blocks(
                &set,
                &shapes,
                std::hint::black_box(&w8),
                &blocks,
                8,
                QuantGrid::WOT8,
            );
            std::hint::black_box(&o);
        });
        let us = rs.ns_per_iter / 1e3 / blocks.len() as f64;
        println!("    -> {us:.1} us per recovered block");
        (probe_gbps, gbps(ro.ns_per_iter), us)
    };

    // serving ingress: closed-loop multi-producer front-door
    // throughput, lock-free slab ring vs the mutex batcher, free
    // executor (batch 32 both ways). The ring's reserve/write/seal
    // path must hold its lead once producers contend on the front
    // door (target: ring >= locked at 4 producers).
    const PRODUCERS: [usize; 5] = [1, 2, 4, 8, 16];
    println!("== serving ingress: ring vs locked, closed-loop producers (batch 32) ==");
    let ingress_secs = 0.3;
    let mut ring_mreqs: Vec<f64> = Vec::new();
    let mut locked_mreqs: Vec<f64> = Vec::new();
    for &p in &PRODUCERS {
        let rg = ring_ingress_mreqs(p, ingress_secs);
        let lk = locked_ingress_mreqs(p, ingress_secs);
        println!(
            "    -> {p:>2} producers: ring {rg:>6.2} Mreq/s | locked {lk:>6.2} Mreq/s | {:.2}x",
            rg / lk
        );
        ring_mreqs.push(rg);
        locked_mreqs.push(lk);
    }
    let ring_vs_locked_4p = {
        let i = PRODUCERS.iter().position(|&p| p == 4).unwrap();
        ring_mreqs[i] / locked_mreqs[i]
    };
    println!("    -> ring/locked at 4 producers: {ring_vs_locked_4p:.2}x (target >= 1x)");

    if args.bool("json") || args.str_opt("out").is_some() {
        // tile section: per-strategy clean-decode GB/s, scalar vs tiled
        let tile_flat: Vec<(String, f64)> = tile_records
            .iter()
            .flat_map(|(name, sc, ti)| {
                [
                    (format!("{name}/scalar"), *sc),
                    (format!("{name}/tiled"), *ti),
                ]
            })
            .collect();
        let rec = obj(vec![
            ("bench", s("ecc_hotpath")),
            ("bytes_per_op", num(n as f64)),
            (
                "tile",
                obj(tile_flat
                    .iter()
                    .map(|(k, v)| (k.as_str(), num(*v)))
                    .collect()),
            ),
            ("inplace_vs_secded_decode_ratio", num(claim_ratio)),
            (
                "sched",
                obj(vec![
                    ("scenario", s("migrate")),
                    ("scrub_passes", num(sched_fixed.scrub_passes as f64)),
                    (
                        "fixed_residual_uncorrectable",
                        num(sched_fixed.residual_uncorrectable as f64),
                    ),
                    (
                        "adaptive_residual_uncorrectable",
                        num(sched_adaptive.residual_uncorrectable as f64),
                    ),
                    (
                        "fixed_residual_wrong_weights",
                        num(sched_fixed.residual_wrong_weights as f64),
                    ),
                    (
                        "adaptive_residual_wrong_weights",
                        num(sched_adaptive.residual_wrong_weights as f64),
                    ),
                    (
                        "adaptive_wins",
                        Json::Bool(
                            sched_adaptive.residual_uncorrectable
                                < sched_fixed.residual_uncorrectable,
                        ),
                    ),
                ]),
            ),
            (
                "fleet",
                obj(vec![
                    (
                        "combos",
                        arr(fleet_rows.iter().map(|&(m, sh, _)| s(&format!("{m}x{sh}")))),
                    ),
                    (
                        "plan_us_per_wakeup",
                        arr(fleet_rows.iter().map(|&(_, _, ns)| num(ns / 1e3))),
                    ),
                    (
                        "ns_per_due_shard",
                        arr(fleet_rows.iter().map(|&(m, sh, ns)| num(ns / (m * sh) as f64))),
                    ),
                ]),
            ),
            (
                "closedloop",
                obj(vec![
                    ("wear_advance_us_per_tick", num(wear_advance_us)),
                    ("wear_strike_us_per_tick", num(wear_strike_us)),
                    ("wear_strikes_per_tick", num(wear_strikes as f64)),
                    ("wear_stuck_cells", num(wear_stuck as f64)),
                ]),
            ),
            (
                "guards",
                obj(vec![
                    ("batch", num(32.0)),
                    ("dims", s("256x64x16")),
                    ("unguarded_gmacs", num(guard_gmacs[0])),
                    ("range_gmacs", num(guard_gmacs[1])),
                    ("abft_gmacs", num(guard_gmacs[2])),
                    ("full_gmacs", num(guard_gmacs[3])),
                    ("full_overhead_ratio", num(guard_full_ratio)),
                    ("clamp_gbps", num(guard_clamp_gbps)),
                ]),
            ),
            (
                "recovery",
                obj(vec![
                    ("milr_probe_decode_gbps", num(milr_probe_gbps)),
                    ("milr_outcome_decode_gbps", num(milr_outcome_gbps)),
                    ("solve_us_per_block", num(solve_us_per_block)),
                ]),
            ),
            (
                "serving",
                obj(vec![(
                    "ingress",
                    obj(vec![
                        ("producers", arr(PRODUCERS.iter().map(|&p| num(p as f64)))),
                        ("ring_mreqs", arr(ring_mreqs.iter().map(|&v| num(v)))),
                        ("locked_mreqs", arr(locked_mreqs.iter().map(|&v| num(v)))),
                        ("ring_vs_locked_4p", num(ring_vs_locked_4p)),
                    ]),
                )]),
            ),
            (
                "pool",
                obj(vec![
                    ("workers", num(POOL_WORKERS as f64)),
                    ("shards", arr(pool_rows.iter().map(|r| num(r.0 as f64)))),
                    ("scoped_gbps", arr(pool_rows.iter().map(|r| num(gbps(r.1))))),
                    ("pool_gbps", arr(pool_rows.iter().map(|r| num(gbps(r.2))))),
                    ("speedup_64_shards", num(pool_speedup_64)),
                    ("allocs_per_scrub_tick", num(allocs_per_tick)),
                ]),
            ),
            ("shards", num(SHARDS as f64)),
            (
                "sharded_speedup_4w",
                num(sharded.iter().find(|r| r.0 == 4).map(|r| r.1 / base).unwrap_or(0.0)),
            ),
            (
                "gbps",
                obj(records
                    .iter()
                    .map(|(k, v)| (k.as_str(), num(*v)))
                    .collect()),
            ),
            (
                "sharded_workers",
                arr(sharded.iter().map(|&(w, _)| num(w as f64))),
            ),
            (
                "sharded_gbps",
                arr(sharded.iter().map(|&(_, g)| num(g))),
            ),
        ]);
        if args.bool("json") {
            println!("{rec}");
        }
        if let Some(path) = args.str_opt("out") {
            // append one JSON-lines record to the perf ledger
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .unwrap_or_else(|e| panic!("cannot open ledger {path}: {e}"));
            writeln!(f, "{rec}").expect("ledger write failed");
            println!("appended record to {path}");
        }
    }
}
