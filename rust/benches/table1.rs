//! Regenerates the paper's Table 1 (accuracy + weight distribution of
//! the 8-bit quantized zoo). `ZSECC_NO_REMEASURE=1` skips the PJRT
//! re-measurement for a fast structural run.

use zsecc::harness::table1;
use zsecc::model::manifest::list_models;
use zsecc::util::timer::time_once;

fn main() {
    let artifacts = zsecc::artifacts_dir();
    if !artifacts.join("index.json").exists() {
        println!("table1: no artifacts at {} (run `make artifacts`)", artifacts.display());
        return;
    }
    let models = list_models(&artifacts).unwrap();
    let remeasure = std::env::var("ZSECC_NO_REMEASURE").is_err();
    let (rows, secs) = time_once(|| table1::run(&artifacts, &models, remeasure).unwrap());
    println!("{}", table1::render(&rows));
    println!("(generated in {secs:.1}s; paper analogue: Table 1)");
    // the paper's headline observation: small weights dominate
    for r in &rows {
        println!(
            "  {}: {:.2}% of weights in [-64, 63] (paper: >99% for ImageNet CNNs)",
            r.model,
            (r.band0 + r.band1) * 100.0
        );
    }
}
