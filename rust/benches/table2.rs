//! Regenerates the paper's Table 2 (the headline fault-injection grid).
//!
//! Full paper grid: 3 models x 4 strategies x 4 rates x 10 trials.
//! Env knobs: ZSECC_TRIALS (default 10), ZSECC_MODELS (comma list),
//! ZSECC_RATES (comma list). `cargo bench` runs the full grid.

use zsecc::harness::table2;
use zsecc::util::timer::time_once;

fn main() {
    let artifacts = zsecc::artifacts_dir();
    if !artifacts.join("index.json").exists() {
        println!("table2: no artifacts at {} (run `make artifacts`)", artifacts.display());
        return;
    }
    let mut cfg = table2::Config::default();
    if let Ok(t) = std::env::var("ZSECC_TRIALS") {
        cfg.trials = t.parse().expect("ZSECC_TRIALS");
    }
    if let Ok(m) = std::env::var("ZSECC_MODELS") {
        cfg.models = m.split(',').map(String::from).collect();
    }
    if let Ok(r) = std::env::var("ZSECC_RATES") {
        cfg.rates = r.split(',').map(|x| x.parse().unwrap()).collect();
    }
    let (t2, secs) = time_once(|| table2::run(&artifacts, &cfg, true).unwrap());
    println!("{}", t2.render(&cfg));
    println!("shape checks (paper's qualitative claims):");
    let mut all_ok = true;
    for (name, ok) in t2.shape_checks(&cfg) {
        all_ok &= ok;
        println!("  [{}] {}", if ok { "ok" } else { "FAIL" }, name);
    }
    println!(
        "(full grid in {secs:.1}s; {} cells x {} trials; all shape checks {})",
        t2.cells.len(),
        cfg.trials,
        if all_ok { "PASS" } else { "FAIL" }
    );
    // machine-readable dump for EXPERIMENTS.md bookkeeping
    std::fs::write(
        artifacts.join("table2.report.json"),
        t2.to_json().to_string(),
    )
    .ok();
}
