//! Regenerates the paper's Figure 4 (accuracy before/after throttling
//! during WOT — the gap closes and the int8 baseline is recovered).

use zsecc::harness::fig34;
use zsecc::model::manifest::list_models;

fn main() {
    let artifacts = zsecc::artifacts_dir();
    if !artifacts.join("index.json").exists() {
        println!("fig4: no artifacts (run `make artifacts`)");
        return;
    }
    let models = list_models(&artifacts).unwrap();
    let logs = fig34::run(&artifacts, &models).unwrap();
    println!("{}", fig34::render_fig4(&logs));
    for (name, ok) in fig34::shape_checks(&logs) {
        if name.contains("Fig4") {
            println!("  [{}] {}", if ok { "ok" } else { "FAIL" }, name);
        }
    }
}
