//! Serving benchmark: coordinator throughput/latency under open-loop
//! Poisson load, swept over the batching policy — first with a mock
//! executor (pure coordinator overhead), then a closed-loop
//! multi-producer sweep over the ingress front door, then the real
//! PJRT model when artifacts exist.
//!
//! Flags: `--ingress ring|locked|both` (default both) selects the
//! front door(s) under test; `--producers N` pins the producer sweep
//! to one count instead of {1, 2, 4, 8, 16}; `--quick` shrinks drive
//! times and skips the real-model section (the CI smoke runs
//! `--ingress ring --producers 4 --quick`); `--scrub-policy
//! fixed|adaptive` selects the scrub scheduling policy of the
//! real-model section (BENCH_ecc.json records the scheduler's
//! fixed-vs-adaptive comparison in its `sched` section; this flag lets
//! the serving latency numbers be taken under either policy too).

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use zsecc::coordinator::server::BatchExec;
use zsecc::coordinator::{BatchPolicy, IngressPolicy, Server, ServerConfig};
use zsecc::memory::ScrubPolicy;
use zsecc::model::EvalSet;
use zsecc::util::cli::Args;
use zsecc::util::rng::Rng;
use zsecc::util::stats::Series;

struct Mock {
    batch: usize,
    dim: usize,
    /// Simulated per-batch compute (models a fixed-cost accelerator call).
    cost: Duration,
}

impl BatchExec for Mock {
    fn batch(&self) -> usize {
        self.batch
    }
    fn input_dim(&self) -> usize {
        self.dim
    }
    fn exec(&mut self, _images: &[f32], count: usize) -> anyhow::Result<Vec<usize>> {
        std::thread::sleep(self.cost);
        Ok(vec![0; count])
    }
    fn refresh(&mut self, _w: &[f32]) -> anyhow::Result<()> {
        Ok(())
    }
}

fn drive(srv: &Server, dim: usize, rps: f64, secs: f64, seed: u64) -> (f64, Series) {
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut lat = Series::default();
    let mut answered = 0u64;
    let img = vec![0f32; dim];
    while t0.elapsed().as_secs_f64() < secs {
        if let Ok(rx) = srv.submit(img.clone()) {
            pending.push(rx);
        }
        pending.retain(|rx| match rx.try_recv() {
            Ok(resp) => {
                lat.push(resp.latency.as_secs_f64() * 1e3);
                answered += 1;
                false
            }
            Err(_) => true,
        });
        std::thread::sleep(Duration::from_secs_f64(rng.exp(rps)));
    }
    for rx in pending {
        if let Ok(resp) = rx.recv_timeout(Duration::from_secs(60)) {
            lat.push(resp.latency.as_secs_f64() * 1e3);
            answered += 1;
        }
    }
    (answered as f64 / t0.elapsed().as_secs_f64(), lat)
}

/// Closed-loop multi-producer throughput (million answered req/s)
/// through the full server with a zero-cost mock executor: each
/// producer keeps a bounded window of in-flight requests and counts
/// completed responses, so the number is end-to-end (submit → batch →
/// exec → fan-out), dominated by the selected ingress front door.
fn producer_sweep(pol: IngressPolicy, producers: usize, secs: f64) -> anyhow::Result<f64> {
    const WINDOW: usize = 64;
    let cfg = ServerConfig {
        strategy: "faulty".into(),
        policy: BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(1),
        },
        scrub_interval: None,
        fault_rate_per_interval: 0.0,
        fault_seed: 0,
        ingress: pol,
        ring_depth: 64,
        ..ServerConfig::default()
    };
    let srv = Server::start_with(
        move || {
            Ok(Box::new(Mock {
                batch: 32,
                dim: 8,
                cost: Duration::ZERO,
            }) as Box<dyn BatchExec>)
        },
        8,
        &cfg,
        None,
    )?;
    let stop = AtomicBool::new(false);
    let mut answered = 0u64;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..producers {
            let srv = &srv;
            let stop = &stop;
            handles.push(scope.spawn(move || {
                let img = vec![0f32; 8];
                let mut window = std::collections::VecDeque::with_capacity(WINDOW);
                let mut n = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    match srv.try_submit(img.clone()) {
                        Ok(rx) => window.push_back(rx),
                        Err(_) => std::thread::yield_now(), // ring backpressure
                    }
                    if window.len() >= WINDOW {
                        let rx = window.pop_front().unwrap();
                        if rx.recv_timeout(Duration::from_secs(10)).is_ok() {
                            n += 1;
                        }
                    }
                }
                for rx in window {
                    if rx.recv_timeout(Duration::from_secs(10)).is_ok() {
                        n += 1;
                    }
                }
                n
            }));
        }
        std::thread::sleep(Duration::from_secs_f64(secs));
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            answered += h.join().unwrap();
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    srv.shutdown();
    Ok(answered as f64 / elapsed / 1e6)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().unwrap_or_default();
    let scrub_policy = ScrubPolicy::parse(&args.str_or("scrub-policy", "adaptive"))?;
    let quick = args.bool("quick");
    let ingress_arg = args.str_or("ingress", "both");
    let fronts: Vec<IngressPolicy> = match ingress_arg.as_str() {
        "both" => vec![IngressPolicy::Ring, IngressPolicy::Locked],
        other => vec![IngressPolicy::parse(other)?],
    };
    let drive_secs = if quick { 0.5 } else { 2.0 };
    let policy_grid: &[(usize, u64)] = if quick {
        &[(32, 5)]
    } else {
        &[(1, 0), (8, 2), (32, 5), (32, 20), (128, 5)]
    };
    for &front in &fronts {
        println!(
            "== serving bench: coordinator overhead (mock executor, 2ms/batch, ingress={}) ==",
            front.tag()
        );
        println!(
            "{:<32} {:>10} {:>10} {:>10} {:>10}",
            "policy", "req/s", "mean ms", "p50 ms", "p99 ms"
        );
        for &(max_batch, wait_ms) in policy_grid {
            let cfg = ServerConfig {
                strategy: "faulty".into(),
                policy: BatchPolicy {
                    max_batch,
                    max_wait: Duration::from_millis(wait_ms),
                },
                scrub_interval: None,
                fault_rate_per_interval: 0.0,
                fault_seed: 0,
                ingress: front,
                ring_depth: 8,
                ..ServerConfig::default()
            };
            let srv = Server::start_with(
                move || {
                    Ok(Box::new(Mock {
                        batch: max_batch,
                        dim: 8,
                        cost: Duration::from_millis(2),
                    }) as Box<dyn BatchExec>)
                },
                8,
                &cfg,
                None,
            )?;
            let (rps, lat) = drive(&srv, 8, 2000.0, drive_secs, 42);
            println!(
                "{:<32} {:>10.0} {:>10.2} {:>10.2} {:>10.2}",
                format!("batch<={max_batch} wait={wait_ms}ms"),
                rps,
                lat.mean(),
                lat.p(50.0),
                lat.p(99.0)
            );
            srv.shutdown();
        }
    }

    // Closed-loop producer sweep over the ingress front door: the
    // ring's lock-free reserve/write/seal path against the mutex
    // batcher as producer contention grows.
    let producer_counts: Vec<usize> = match args.usize_or("producers", 0)? {
        0 => vec![1, 2, 4, 8, 16],
        p => vec![p],
    };
    let sweep_secs = if quick { 0.3 } else { 1.0 };
    println!("== serving bench: closed-loop producer sweep (mock executor, free exec) ==");
    for &p in &producer_counts {
        for &front in &fronts {
            let mreqs = producer_sweep(front, p, sweep_secs)?;
            println!("ingress={:<8} producers={:<3} {:>8.3} Mreq/s", front.tag(), p, mreqs);
        }
    }

    if quick {
        println!("\n(real-model serving bench skipped: --quick)");
        return Ok(());
    }
    let artifacts = zsecc::artifacts_dir();
    if artifacts.join("index.json").exists() {
        println!(
            "\n== serving bench: real PJRT model (squeezenet_s, in-place, live faults, {} scrub, ingress={}) ==",
            scrub_policy.tag(),
            fronts[0].tag()
        );
        println!(
            "{:<32} {:>10} {:>10} {:>10} {:>10}",
            "policy", "req/s", "mean ms", "p50 ms", "p99 ms"
        );
        let ds = EvalSet::load(&artifacts.join("dataset.eval.bin"))?;
        for (max_batch, wait_ms) in [(1usize, 0u64), (32, 5), (256, 10)] {
            let cfg = ServerConfig {
                strategy: "in-place".into(),
                policy: BatchPolicy {
                    max_batch,
                    max_wait: Duration::from_millis(wait_ms),
                },
                scrub_interval: Some(Duration::from_millis(250)),
                scrub_policy,
                fault_rate_per_interval: 1e-6,
                fault_seed: 1,
                ingress: fronts[0],
                ring_depth: 8,
                ..ServerConfig::default()
            };
            let srv = Server::start_pjrt(&artifacts, "squeezenet_s", &cfg)?;
            let (rps, lat) = drive(&srv, ds.dim, 500.0, 4.0, 7);
            println!(
                "{:<32} {:>10.0} {:>10.2} {:>10.2} {:>10.2}",
                format!("batch<={max_batch} wait={wait_ms}ms"),
                rps,
                lat.mean(),
                lat.p(50.0),
                lat.p(99.0)
            );
            println!("  metrics: {}", srv.metrics.report());
            srv.shutdown();
        }
    } else {
        println!("\n(real-model serving bench skipped: no artifacts)");
    }
    Ok(())
}
