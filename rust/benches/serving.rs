//! Serving benchmark: coordinator throughput/latency under open-loop
//! Poisson load, swept over the batching policy — first with a mock
//! executor (pure coordinator overhead), then over the real PJRT model
//! when artifacts exist. `--scrub-policy fixed|adaptive` selects the
//! scrub scheduling policy of the real-model section (BENCH_ecc.json
//! records the scheduler's fixed-vs-adaptive comparison in its `sched`
//! section; this flag lets the serving latency numbers be taken under
//! either policy too).

use std::time::{Duration, Instant};

use zsecc::coordinator::server::BatchExec;
use zsecc::coordinator::{BatchPolicy, Server, ServerConfig};
use zsecc::memory::ScrubPolicy;
use zsecc::model::EvalSet;
use zsecc::util::cli::Args;
use zsecc::util::rng::Rng;
use zsecc::util::stats::Series;

struct Mock {
    batch: usize,
    dim: usize,
    /// Simulated per-batch compute (models a fixed-cost accelerator call).
    cost: Duration,
}

impl BatchExec for Mock {
    fn batch(&self) -> usize {
        self.batch
    }
    fn input_dim(&self) -> usize {
        self.dim
    }
    fn exec(&mut self, _images: &[f32], count: usize) -> anyhow::Result<Vec<usize>> {
        std::thread::sleep(self.cost);
        Ok(vec![0; count])
    }
    fn refresh(&mut self, _w: &[f32]) -> anyhow::Result<()> {
        Ok(())
    }
}

fn drive(srv: &Server, dim: usize, rps: f64, secs: f64, seed: u64) -> (f64, Series) {
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut lat = Series::default();
    let mut answered = 0u64;
    let img = vec![0f32; dim];
    while t0.elapsed().as_secs_f64() < secs {
        if let Ok(rx) = srv.submit(img.clone()) {
            pending.push(rx);
        }
        pending.retain(|rx| match rx.try_recv() {
            Ok(resp) => {
                lat.push(resp.latency.as_secs_f64() * 1e3);
                answered += 1;
                false
            }
            Err(_) => true,
        });
        std::thread::sleep(Duration::from_secs_f64(rng.exp(rps)));
    }
    for rx in pending {
        if let Ok(resp) = rx.recv_timeout(Duration::from_secs(60)) {
            lat.push(resp.latency.as_secs_f64() * 1e3);
            answered += 1;
        }
    }
    (answered as f64 / t0.elapsed().as_secs_f64(), lat)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().unwrap_or_default();
    let scrub_policy = ScrubPolicy::parse(&args.str_or("scrub-policy", "adaptive"))?;
    println!("== serving bench: coordinator overhead (mock executor, 2ms/batch) ==");
    println!(
        "{:<32} {:>10} {:>10} {:>10} {:>10}",
        "policy", "req/s", "mean ms", "p50 ms", "p99 ms"
    );
    for (max_batch, wait_ms) in [(1usize, 0u64), (8, 2), (32, 5), (32, 20), (128, 5)] {
        let cfg = ServerConfig {
            strategy: "faulty".into(),
            policy: BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(wait_ms),
            },
            scrub_interval: None,
            fault_rate_per_interval: 0.0,
            fault_seed: 0,
            ..ServerConfig::default()
        };
        let srv = Server::start_with(
            move || {
                Ok(Box::new(Mock {
                    batch: max_batch,
                    dim: 8,
                    cost: Duration::from_millis(2),
                }) as Box<dyn BatchExec>)
            },
            8,
            &cfg,
            None,
        )?;
        let (rps, lat) = drive(&srv, 8, 2000.0, 2.0, 42);
        println!(
            "{:<32} {:>10.0} {:>10.2} {:>10.2} {:>10.2}",
            format!("batch<={max_batch} wait={wait_ms}ms"),
            rps,
            lat.mean(),
            lat.p(50.0),
            lat.p(99.0)
        );
        srv.shutdown();
    }

    let artifacts = zsecc::artifacts_dir();
    if artifacts.join("index.json").exists() {
        println!(
            "\n== serving bench: real PJRT model (squeezenet_s, in-place, live faults, {} scrub) ==",
            scrub_policy.tag()
        );
        println!(
            "{:<32} {:>10} {:>10} {:>10} {:>10}",
            "policy", "req/s", "mean ms", "p50 ms", "p99 ms"
        );
        let ds = EvalSet::load(&artifacts.join("dataset.eval.bin"))?;
        for (max_batch, wait_ms) in [(1usize, 0u64), (32, 5), (256, 10)] {
            let cfg = ServerConfig {
                strategy: "in-place".into(),
                policy: BatchPolicy {
                    max_batch,
                    max_wait: Duration::from_millis(wait_ms),
                },
                scrub_interval: Some(Duration::from_millis(250)),
                scrub_policy,
                fault_rate_per_interval: 1e-6,
                fault_seed: 1,
                ..ServerConfig::default()
            };
            let srv = Server::start_pjrt(&artifacts, "squeezenet_s", &cfg)?;
            let (rps, lat) = drive(&srv, ds.dim, 500.0, 4.0, 7);
            println!(
                "{:<32} {:>10.0} {:>10.2} {:>10.2} {:>10.2}",
                format!("batch<={max_batch} wait={wait_ms}ms"),
                rps,
                lat.mean(),
                lat.p(50.0),
                lat.p(99.0)
            );
            println!("  metrics: {}", srv.metrics.report());
            srv.shutdown();
        }
    } else {
        println!("\n(real-model serving bench skipped: no artifacts)");
    }
    Ok(())
}
