//! Ablation benches: QATT vs ADMM, code strength (SEC-DED vs BCH-16 at
//! zero space), burst-fault sensitivity, scrub-interval study.

use zsecc::harness::ablation;

fn main() -> anyhow::Result<()> {
    let artifacts = zsecc::artifacts_dir();
    match ablation::render_admm_vs_qatt(&artifacts) {
        Ok(s) => println!("{s}"),
        Err(e) => println!("(QATT-vs-ADMM skipped: {e})"),
    }

    let rates = [1e-4, 3e-4, 1e-3, 3e-3, 1e-2];
    let rows = ablation::code_strength(&rates, 64 * 512, 5)?;
    println!("{}", ablation::render_code_strength(&rows));

    let brows = ablation::burst(&[1, 2, 3, 4], 1e-3, 64 * 512, 5)?;
    println!("{}", ablation::render_burst(&brows, 1e-3));

    let srows = ablation::scrub_study(&[1, 2, 4, 8, 16, 32], 2e-4, 64 * 256)?;
    println!("{}", ablation::render_scrub(&srows, 2e-4));

    // Campaign engine over the full fault-model set (adaptive trials,
    // parallel cells) — also a wall-clock smoke of the worker fan-out.
    let sweep = ablation::fault_model_campaign(1e-3, 64 * 512, 4)?;
    println!("{}", ablation::render_fault_models(&sweep, 1e-3));
    Ok(())
}
