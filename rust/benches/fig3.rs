//! Regenerates the paper's Figure 3 (large-value count in positions
//! 0..6 before throttling, per WOT training step — decays to ~0).

use zsecc::harness::fig34;
use zsecc::model::manifest::list_models;

fn main() {
    let artifacts = zsecc::artifacts_dir();
    if !artifacts.join("index.json").exists() {
        println!("fig3: no artifacts (run `make artifacts`)");
        return;
    }
    let models = list_models(&artifacts).unwrap();
    let logs = fig34::run(&artifacts, &models).unwrap();
    println!("{}", fig34::render_fig3(&logs));
    for (name, ok) in fig34::shape_checks(&logs) {
        if name.contains("Fig3") {
            println!("  [{}] {}", if ok { "ok" } else { "FAIL" }, name);
        }
    }
}
