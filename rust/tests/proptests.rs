//! Property-based tests (proptest is unavailable offline; this file
//! carries a small in-tree property-testing harness: seeded random case
//! generation with on-failure seed reporting, plus a shrink-lite retry
//! at smaller sizes).
//!
//! Properties covered: Hsiao/in-place/BCH code laws (roundtrip, single-
//! correct, double-detect/correct), parity detection, strategy encode/
//! decode laws over arbitrary WOT-satisfying buffers, JSON roundtrip for
//! arbitrary values, PRNG distinct-sampling laws.

use zsecc::ecc::{all_strategies, strategy_by_name, DecodeStats, Encoded};
use zsecc::util::json::Json;
use zsecc::util::rng::Rng;

// ------------------------------------------------------ mini-framework --

/// Run `prop` on `cases` random inputs; on failure, retry the same seed
/// at smaller sizes to report a smaller counterexample.
fn check<F: Fn(&mut Rng, usize) -> Result<(), String>>(name: &str, cases: u64, prop: F) {
    let base = 0xC0FFEE ^ cases;
    for c in 0..cases {
        let seed = base.wrapping_add(c.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, 64) {
            // shrink-lite: same seed, smaller sizes
            for size in [1usize, 2, 4, 8, 16, 32] {
                let mut r2 = Rng::new(seed);
                if let Err(m2) = prop(&mut r2, size) {
                    panic!("property '{name}' failed (seed {seed:#x}, size {size}): {m2}");
                }
            }
            panic!("property '{name}' failed (seed {seed:#x}, size 64): {msg}");
        }
    }
}

fn wot_weights(rng: &mut Rng, nblocks: usize) -> Vec<i8> {
    (0..nblocks * 8)
        .map(|i| {
            if i % 8 == 7 {
                (rng.below(256) as i64 - 128) as i8
            } else {
                (rng.below(128) as i64 - 64) as i8
            }
        })
        .collect()
}

fn ext_weights(rng: &mut Rng, nblocks: usize) -> Vec<i8> {
    (0..nblocks * 16)
        .map(|i| {
            if i % 16 == 15 {
                (rng.below(256) as i64 - 128) as i8
            } else {
                (rng.below(64) as i64 - 32) as i8
            }
        })
        .collect()
}

// ------------------------------------------------------------ properties --

#[test]
fn prop_all_strategies_identity_without_faults() {
    check("identity without faults", 40, |rng, size| {
        let w = wot_weights(rng, size.max(1));
        for s in all_strategies() {
            let enc = s.encode(&w).map_err(|e| e.to_string())?;
            let mut out = vec![0i8; w.len()];
            s.decode(&enc, &mut out);
            if out != w {
                return Err(format!("{} altered clean weights", s.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_single_flip_per_block_always_corrected() {
    check("single flip corrected", 40, |rng, size| {
        let w = wot_weights(rng, size.max(1));
        for name in ["ecc", "in-place"] {
            let s = strategy_by_name(name).unwrap();
            let mut enc = s.encode(&w).map_err(|e| e.to_string())?;
            let block_bits = 64u64;
            let nblocks = (w.len() / 8) as u64;
            // flip one random bit in every block (data side)
            for bi in 0..nblocks {
                enc.flip_bit(bi * block_bits + rng.below(block_bits));
            }
            let mut out = vec![0i8; w.len()];
            let stats = s.decode(&enc, &mut out);
            if out != w {
                return Err(format!("{name}: weights not recovered"));
            }
            if stats.corrected != nblocks {
                return Err(format!(
                    "{name}: corrected {} != {} blocks",
                    stats.corrected, nblocks
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_double_flip_detected_never_miscorrected() {
    check("double flip detected", 40, |rng, size| {
        let w = wot_weights(rng, size.max(1));
        for name in ["ecc", "in-place"] {
            let s = strategy_by_name(name).unwrap();
            let base = s.encode(&w).map_err(|e| e.to_string())?;
            let bits_per_block = if name == "ecc" { 72 } else { 64 };
            let mut enc = base.clone();
            // two distinct flips within block 0 (oob positions mapped)
            let b1 = rng.below(bits_per_block);
            let mut b2 = rng.below(bits_per_block);
            while b2 == b1 {
                b2 = rng.below(bits_per_block);
            }
            let data_bits = (enc.data.len() as u64) * 8;
            let map = |b: u64| -> u64 {
                if b < 64 {
                    b
                } else {
                    // block 0's check byte lives at oob byte 0
                    data_bits + (b - 64)
                }
            };
            enc.flip_bit(map(b1));
            enc.flip_bit(map(b2));
            let mut out = vec![0i8; w.len()];
            let stats = s.decode(&enc, &mut out);
            if stats.detected != 1 {
                return Err(format!(
                    "{name}: double flip at {b1},{b2} -> detected={} (miscorrection?)",
                    stats.detected
                ));
            }
            // all blocks except 0 must decode exactly
            if out[8..] != w[8..] {
                return Err(format!("{name}: damage leaked outside block 0"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bch_corrects_any_two_flips_per_block() {
    check("bch double correction", 30, |rng, size| {
        let w = ext_weights(rng, size.max(1));
        let s = strategy_by_name("bch16").unwrap();
        let mut enc = s.encode(&w).map_err(|e| e.to_string())?;
        let nblocks = (w.len() / 16) as u64;
        for bi in 0..nblocks {
            let b1 = rng.below(128);
            let mut b2 = rng.below(128);
            while b2 == b1 {
                b2 = rng.below(128);
            }
            enc.flip_bit(bi * 128 + b1);
            enc.flip_bit(bi * 128 + b2);
        }
        let mut out = vec![0i8; w.len()];
        s.decode(&enc, &mut out);
        if out != w {
            return Err("bch16 failed to correct 2 flips/block".into());
        }
        Ok(())
    });
}

#[test]
fn prop_parity_zero_zeroes_every_odd_corruption() {
    check("parity zeroes odd corruption", 40, |rng, size| {
        let w = wot_weights(rng, size.max(1));
        let s = strategy_by_name("zero").unwrap();
        let mut enc = s.encode(&w).map_err(|e| e.to_string())?;
        let victim = rng.below(w.len() as u64) as usize;
        // odd number of flips in the victim byte
        let nflips = 1 + 2 * rng.below(4);
        let bits: Vec<u64> = {
            let mut r2 = Rng::new(rng.next_u64());
            r2.distinct(8, nflips)
        };
        for b in bits {
            enc.flip_bit(victim as u64 * 8 + b);
        }
        let mut out = vec![0i8; w.len()];
        let stats = s.decode(&enc, &mut out);
        if out[victim] != 0 {
            return Err(format!("victim byte not zeroed ({})", out[victim]));
        }
        if stats.zeroed != 1 {
            return Err(format!("zeroed={} != 1", stats.zeroed));
        }
        Ok(())
    });
}

#[test]
fn prop_scrub_equals_decode_reencode() {
    // Valid precondition: at most one flip per block (uncorrectable
    // blocks are deliberately left as stored by scrub, while a
    // decode+reencode would launder them — see inplace::scrub_block).
    check("scrub == decode+reencode", 30, |rng, size| {
        let w = wot_weights(rng, size.max(1));
        for name in ["ecc", "in-place"] {
            let s = strategy_by_name(name).unwrap();
            let mut enc = s.encode(&w).map_err(|e| e.to_string())?;
            // at most one data-bit flip per 64-bit block
            let nblocks = (w.len() / 8) as u64;
            for bi in 0..nblocks {
                if rng.below(3) == 0 {
                    enc.flip_bit(bi * 64 + rng.below(64));
                }
            }
            let mut via_scrub = enc.clone();
            s.scrub(&mut via_scrub);
            // reference: decode then re-encode
            let mut out = vec![0i8; w.len()];
            s.decode(&enc, &mut out);
            let reref = s.encode(&out).map_err(|e| e.to_string())?;
            if via_scrub.data != reref.data || via_scrub.oob != reref.oob {
                return Err(format!("{name}: scrub image != decode+reencode image"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_overhead_invariant() {
    check("overhead accounting", 20, |rng, size| {
        let w = wot_weights(rng, size.max(1));
        for s in all_strategies() {
            let enc = s.encode(&w).map_err(|e| e.to_string())?;
            let want = (w.len() as f64 * s.overhead()).round() as usize;
            if enc.oob.len() != want {
                return Err(format!(
                    "{}: oob {} != {} (overhead {})",
                    s.name(),
                    enc.oob.len(),
                    want,
                    s.overhead()
                ));
            }
            if enc.data.len() != w.len() {
                return Err(format!("{}: data len changed", s.name()));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------- tile equivalence --

/// Flip a random set of stored bits (possibly zero, possibly dense) —
/// the fault mask the tiled/scalar equivalence properties quantify over.
fn random_fault_mask(rng: &mut Rng, enc: &mut Encoded) {
    let total = enc.total_bits();
    // zero-fault (pure clean path), sparse (mostly-clean tiles) or
    // dense (many dirty lanes); repeated positions allowed.
    let nflips = match rng.below(4) {
        0 => 0,
        1 => 1 + rng.below(3),
        _ => rng.below(total / 16 + 2),
    };
    for _ in 0..nflips {
        enc.flip_bit(rng.below(total));
    }
}

#[test]
fn prop_tiled_decode_scrub_equal_scalar_all_strategies() {
    use zsecc::ecc::all_strategies_ext;
    // For every strategy (InplaceZs sign-restore included), any fault
    // mask, and buffer sizes straddling tile boundaries (64 blocks =
    // one tile), the tiled span forms must be bit-identical to the
    // scalar primitives: same decode output, same DecodeStats, same
    // scrubbed image.
    check("tiled == scalar", 30, |rng, size| {
        // sizes around 0.5..2.5 tiles, ragged (non-tile-multiple) included
        let nblocks = 1 + rng.below(2 * size as u64 + 40) as usize;
        let w8 = wot_weights(rng, nblocks);
        let w16 = ext_weights(rng, nblocks);
        let seed = rng.next_u64();
        for s in all_strategies_ext() {
            let w: &[i8] = if s.name() == "bch16" { &w16 } else { &w8 };
            let mut enc = s.encode(w).map_err(|e| e.to_string())?;
            let mut mask_rng = Rng::new(seed);
            random_fault_mask(&mut mask_rng, &mut enc);
            // decode: tiled vs scalar
            let mut a = vec![0i8; w.len()];
            let mut b = vec![0i8; w.len()];
            let sa = s.decode_span(&enc.data, &enc.oob, &mut a);
            let sb = s.decode_span_tiled(&enc.data, &enc.oob, &mut b);
            if a != b {
                return Err(format!("{}: tiled decode output differs", s.name()));
            }
            if sa != sb {
                return Err(format!("{}: decode stats {sb:?} != scalar {sa:?}", s.name()));
            }
            // scrub: tiled vs scalar
            let (mut da, mut oa) = (enc.data.clone(), enc.oob.clone());
            let (mut db, mut ob) = (enc.data.clone(), enc.oob.clone());
            let ra = s.scrub_span(&mut da, &mut oa);
            let rb = s.scrub_span_tiled(&mut db, &mut ob);
            if da != db || oa != ob {
                return Err(format!("{}: tiled scrub image differs", s.name()));
            }
            if ra != rb {
                return Err(format!("{}: scrub stats {rb:?} != scalar {ra:?}", s.name()));
            }
            // clean probe never lies about a provably clean whole tile
            if enc.data.len() >= 512 {
                let opt = 512 / s.block_bytes() * s.oob_bytes_per_block();
                let (dt, ot) = (&enc.data[..512], &enc.oob[..opt]);
                let mut tout = vec![0i8; 512];
                if s.tile_is_clean(dt, ot) && !s.decode_tile(dt, ot, &mut tout).is_clean() {
                    return Err(format!("{}: clean probe contradicted decode", s.name()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tiled_range_windows_equal_scalar_span() {
    use zsecc::ecc::all_strategies_ext;
    // decode_range/scrub_range are routed through the tiled forms; a
    // random block-aligned window (tile-unaligned boundaries included)
    // must match the scalar span over the same window.
    check("tiled range == scalar window", 25, |rng, size| {
        let nblocks = 2 + rng.below(2 * size as u64 + 80) as usize;
        let w8 = wot_weights(rng, nblocks);
        let w16 = ext_weights(rng, nblocks);
        let seed = rng.next_u64();
        for s in all_strategies_ext() {
            let w: &[i8] = if s.name() == "bch16" { &w16 } else { &w8 };
            let mut enc = s.encode(w).map_err(|e| e.to_string())?;
            let mut mask_rng = Rng::new(seed);
            random_fault_mask(&mut mask_rng, &mut enc);
            let block = s.block_bytes().max(1);
            let blocks_total = enc.data.len() / block;
            let lo = rng.below(blocks_total as u64) as usize * block;
            let span_blocks = (enc.data.len() - lo) / block;
            let hi = lo + block + rng.below(span_blocks as u64) as usize * block;
            let hi = hi.min(enc.data.len());
            let (os, oe) = s.oob_window(lo, hi, enc.data.len(), enc.oob.len());
            // decode window
            let mut a = vec![0i8; hi - lo];
            let mut b = vec![0i8; hi - lo];
            let sa = s.decode_span(&enc.data[lo..hi], &enc.oob[os..oe], &mut a);
            let sb = s.decode_range(&enc, lo, hi, &mut b);
            if a != b || sa != sb {
                return Err(format!("{} [{lo},{hi}): range decode differs", s.name()));
            }
            // scrub window
            let mut tiled = enc.clone();
            let rb = s.scrub_range(&mut tiled, lo, hi);
            let mut want = enc.clone();
            let ra = s.scrub_span(&mut want.data[lo..hi], &mut want.oob[os..oe]);
            if tiled.data != want.data || tiled.oob != want.oob || ra != rb {
                return Err(format!("{} [{lo},{hi}): range scrub differs", s.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_range_outcome_detected_sets_equal_scalar_reference() {
    use zsecc::ecc::all_strategies_ext;
    // The recovery tier trusts DecodeOutcome's block list to name its
    // unknowns: for every strategy (milr's probe-only detection
    // included — safe here because this property asserts nothing about
    // correction), any fault mask, and ragged / tile-unaligned windows,
    // decode_range_outcome and scrub_range_outcome must report exactly
    // the ascending block set a per-block scalar decode of the same
    // window finds, with stats and output identical to the plain forms.
    check("range outcome detected set == scalar", 25, |rng, size| {
        let nblocks = 2 + rng.below(2 * size as u64 + 80) as usize;
        let w8 = wot_weights(rng, nblocks);
        let w16 = ext_weights(rng, nblocks);
        let seed = rng.next_u64();
        let mut strategies = all_strategies_ext();
        strategies.push(strategy_by_name("milr").unwrap());
        for s in strategies {
            let w: &[i8] = if s.name() == "bch16" { &w16 } else { &w8 };
            let mut enc = s.encode(w).map_err(|e| e.to_string())?;
            let mut mask_rng = Rng::new(seed);
            random_fault_mask(&mut mask_rng, &mut enc);
            let block = s.block_bytes().max(1);
            let blocks_total = enc.data.len() / block;
            let lo = rng.below(blocks_total as u64) as usize * block;
            let span_blocks = (enc.data.len() - lo) / block;
            let hi = (lo + block + rng.below(span_blocks as u64) as usize * block)
                .min(enc.data.len());
            // scalar reference: decode every block of the window alone
            let mut want = Vec::new();
            let mut k = lo;
            while k < hi {
                let ke = (k + block).min(hi);
                let (os, oe) = s.oob_window(k, ke, enc.data.len(), enc.oob.len());
                let mut out = vec![0i8; ke - k];
                if s.decode_span(&enc.data[k..ke], &enc.oob[os..oe], &mut out).detected > 0 {
                    want.push(k / block);
                }
                k = ke;
            }
            // decode window: same set, same stats/output as decode_range
            let mut a = vec![0i8; hi - lo];
            let mut b = vec![0i8; hi - lo];
            let outc = s.decode_range_outcome(&enc, lo, hi, &mut a);
            let stats = s.decode_range(&enc, lo, hi, &mut b);
            if outc.detected_blocks != want {
                return Err(format!(
                    "{} [{lo},{hi}): decode outcome blocks {:?} != scalar {:?}",
                    s.name(),
                    outc.detected_blocks,
                    want
                ));
            }
            if outc.overflow {
                return Err(format!("{}: window this small must not overflow", s.name()));
            }
            if outc.stats != stats || a != b {
                return Err(format!("{} [{lo},{hi}): outcome decode diverged", s.name()));
            }
            // scrub window: block identities recorded during the pass
            // (parity-zero heals its image, a post-scrub decode finds
            // nothing), and the scrubbed image matches the plain form
            let mut tiled = enc.clone();
            let soutc = s.scrub_range_outcome(&mut tiled, lo, hi);
            let mut plain = enc.clone();
            let sstats = s.scrub_range(&mut plain, lo, hi);
            if soutc.detected_blocks != want {
                return Err(format!(
                    "{} [{lo},{hi}): scrub outcome blocks {:?} != scalar {:?}",
                    s.name(),
                    soutc.detected_blocks,
                    want
                ));
            }
            if soutc.stats != sstats || tiled.data != plain.data || tiled.oob != plain.oob {
                return Err(format!("{} [{lo},{hi}): outcome scrub diverged", s.name()));
            }
        }
        Ok(())
    });
}

// --------------------------------------------------- shard equivalence --

#[test]
fn prop_sharded_bank_equals_whole_buffer_path() {
    use zsecc::memory::{FaultModel, MemoryBank, ShardedBank};
    // For every strategy and every shard count (ragged last shards
    // included via the random block count), the sharded store must be
    // bit-identical to the monolithic path: same decode output, same
    // DecodeStats totals, same scrubbed image.
    check("sharded == monolithic", 25, |rng, size| {
        let nblocks = 1 + rng.below(size.max(1) as u64) as usize;
        let w8 = wot_weights(rng, nblocks);
        let w16 = ext_weights(rng, nblocks);
        let seed = rng.next_u64();
        for name in ["faulty", "zero", "ecc", "in-place", "bch16"] {
            let w: &[i8] = if name == "bch16" { &w16 } else { &w8 };
            for shards in [1usize, 2, 7, 64] {
                let mut mono = MemoryBank::new(strategy_by_name(name).unwrap(), w)
                    .map_err(|e| e.to_string())?;
                let mut sb =
                    ShardedBank::new(strategy_by_name(name).unwrap(), w, shards, 4)
                        .map_err(|e| e.to_string())?;
                mono.inject(FaultModel::Uniform, 2e-3, seed);
                sb.inject(FaultModel::Uniform, 2e-3, seed);
                if mono.image().data != sb.image().data
                    || mono.image().oob != sb.image().oob
                {
                    return Err(format!("{name} x{shards}: injected images differ"));
                }
                let mut a = vec![0i8; w.len()];
                let mut b = vec![0i8; w.len()];
                let stats_a = mono.read(&mut a);
                let stats_b = sb.read(&mut b);
                if a != b {
                    return Err(format!("{name} x{shards}: decode outputs differ"));
                }
                if stats_a != stats_b {
                    return Err(format!(
                        "{name} x{shards}: decode stats {stats_a:?} != {stats_b:?}"
                    ));
                }
                let scr_a = mono.scrub();
                let scr_b = sb.scrub();
                if scr_a != scr_b {
                    return Err(format!(
                        "{name} x{shards}: scrub stats {scr_a:?} != {scr_b:?}"
                    ));
                }
                if mono.image().data != sb.image().data
                    || mono.image().oob != sb.image().oob
                {
                    return Err(format!("{name} x{shards}: scrubbed images differ"));
                }
            }
        }
        Ok(())
    });
}

// ------------------------------------------------- pool equivalence --

#[test]
fn prop_pool_run_jobs_equals_scoped_reference() {
    use zsecc::memory::pool::{run_jobs, run_jobs_scoped};
    // The persistent pool's compat wrapper and the old scoped-spawn
    // fan-out must compute the same result multiset for any job list
    // and worker count (pool results are additionally in submission
    // order; scoped results are bucket-ordered, so compare sorted).
    check("pool run_jobs == scoped", 25, |rng, size| {
        let njobs = 1 + rng.below(3 * size as u64 + 1) as usize;
        let jobs: Vec<(usize, u64)> = (0..njobs).map(|i| (i, rng.next_u64())).collect();
        let f = |(i, x): (usize, u64)| (i, x.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(17));
        for workers in [1usize, 2, 7, zsecc::memory::ShardedBank::auto_workers()] {
            let mut a = run_jobs(jobs.clone(), workers, f);
            let mut b = run_jobs_scoped(jobs.clone(), workers, f);
            a.sort_unstable();
            b.sort_unstable();
            if a != b {
                return Err(format!("pool != scoped at {workers} workers"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pool_backed_bank_identical_across_worker_counts() {
    use zsecc::memory::{FaultModel, ShardedBank};
    // ShardedBank decode/scrub passes ride the persistent pool; the
    // DecodeStats, decode output and scrubbed image must be identical
    // for every worker count (1 = the pool-free serial path) and every
    // strategy.
    check("bank identical across workers", 12, |rng, size| {
        let nblocks = 1 + rng.below(size.max(1) as u64 + 24) as usize;
        let w8 = wot_weights(rng, nblocks);
        let w16 = ext_weights(rng, nblocks);
        let seed = rng.next_u64();
        for name in ["faulty", "zero", "ecc", "in-place", "bch16"] {
            let w: &[i8] = if name == "bch16" { &w16 } else { &w8 };
            let mut reference: Option<(Vec<i8>, DecodeStats, DecodeStats, Vec<u8>, Vec<u8>)> =
                None;
            for workers in [1usize, 2, 7, ShardedBank::auto_workers()] {
                let mut sb = ShardedBank::new(strategy_by_name(name).unwrap(), w, 13, workers)
                    .map_err(|e| e.to_string())?;
                sb.inject(FaultModel::Uniform, 2e-3, seed);
                let mut out = vec![0i8; w.len()];
                let read_stats = sb.read(&mut out);
                let scrub_stats = sb.scrub();
                let got = (
                    out,
                    read_stats,
                    scrub_stats,
                    sb.image().data.clone(),
                    sb.image().oob.clone(),
                );
                match &reference {
                    None => reference = Some(got),
                    Some(want) => {
                        if got != *want {
                            return Err(format!(
                                "{name}: {workers}-worker pass differs from 1-worker pass"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

// --------------------------------------------- copy-on-write reset --

#[test]
fn prop_cow_reset_equals_full_reset_for_every_fault_model() {
    use zsecc::memory::ShardedBank;
    // Trial reset is copy-on-write (only fault-touched code blocks are
    // copied back from the pristine image). For every fault model and
    // strategy — scrub writes in between included — the reset image
    // must be byte-identical to pristine, and post-reset behavior
    // (stuck-at reads stored cells!) identical to a fresh bank's.
    check("cow reset == full reset", 12, |rng, size| {
        let nblocks = 1 + rng.below(size.max(1) as u64 + 16) as usize;
        let w8 = wot_weights(rng, nblocks);
        let w16 = ext_weights(rng, nblocks);
        for model in fault_model_menagerie(rng) {
            let seed = rng.next_u64();
            for name in ["faulty", "zero", "ecc", "in-place", "bch16"] {
                let w: &[i8] = if name == "bch16" { &w16 } else { &w8 };
                let mut fresh = ShardedBank::new(strategy_by_name(name).unwrap(), w, 6, 2)
                    .map_err(|e| e.to_string())?;
                let mut sb = ShardedBank::new(strategy_by_name(name).unwrap(), w, 6, 2)
                    .map_err(|e| e.to_string())?;
                sb.inject(model, 2e-2, seed);
                if rng.below(2) == 1 {
                    sb.scrub(); // scrub's stored-byte writes must restore too
                }
                sb.reset();
                let clean = sb.image().data == fresh.image().data
                    && sb.image().oob == fresh.image().oob;
                if !clean {
                    return Err(format!("{} {name}: COW reset left residue", model.tag()));
                }
                // behavior after reset matches a never-faulted bank
                let seed2 = seed ^ 0xD1CE;
                sb.inject(model, 1e-2, seed2);
                fresh.inject(model, 1e-2, seed2);
                let same = sb.image().data == fresh.image().data
                    && sb.image().oob == fresh.image().oob;
                if !same {
                    return Err(format!("{} {name}: post-reset divergence", model.tag()));
                }
            }
        }
        Ok(())
    });
}

// ----------------------------------------------------------- json laws --

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 1),
        2 => Json::Num((rng.next_u64() % 100_000) as f64 / 8.0 - 1000.0),
        3 => {
            let n = rng.below(8) as usize;
            Json::Str(
                (0..n)
                    .map(|_| {
                        let chars = ['a', 'Z', '"', '\\', '\n', 'é', '😀', ' '];
                        chars[rng.below(chars.len() as u64) as usize]
                    })
                    .collect(),
            )
        }
        4 => Json::Arr(
            (0..rng.below(5))
                .map(|_| random_json(rng, depth - 1))
                .collect(),
        ),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    check("json roundtrip", 200, |rng, _size| {
        let v = random_json(rng, 3);
        let text = v.to_string();
        let re = Json::parse(&text).map_err(|e| format!("reparse failed: {e}\n{text}"))?;
        if re != v {
            return Err(format!("roundtrip mismatch: {text}"));
        }
        Ok(())
    });
}

// ------------------------------------------------------------ rng laws --

#[test]
fn prop_distinct_is_distinct_and_in_range() {
    check("rng distinct", 100, |rng, size| {
        let n = 1 + rng.below(1000 * size as u64);
        let k = rng.below(n + 1);
        let v = Rng::new(rng.next_u64()).distinct(n, k);
        if v.len() != k as usize {
            return Err(format!("len {} != k {k}", v.len()));
        }
        let set: std::collections::HashSet<_> = v.iter().collect();
        if set.len() != v.len() {
            return Err("duplicates".into());
        }
        if v.iter().any(|&x| x >= n) {
            return Err("out of range".into());
        }
        Ok(())
    });
}

// ------------------------------------------------ fault-model laws --

/// A representative draw of every fault model, parameters randomized.
fn fault_model_menagerie(rng: &mut Rng) -> Vec<zsecc::memory::FaultModel> {
    use zsecc::memory::FaultModel;
    vec![
        FaultModel::Uniform,
        FaultModel::Burst {
            len: 1 + rng.below(5) as u32,
        },
        FaultModel::RowBurst {
            row_bits: 32 * (1 + rng.below(8)),
            len: 1 + rng.below(4) as u32,
        },
        FaultModel::StuckAt { bit: 1 },
        FaultModel::Hotspot {
            frac: 0.01 + rng.f64() * 0.5,
        },
    ]
}

#[test]
fn prop_fault_models_deterministic_and_exact_count() {
    use zsecc::memory::{FaultInjector, FaultModel};
    check("fault models det/exact", 30, |rng, size| {
        let nbytes = 8 * size.max(1);
        let zero = Encoded {
            data: vec![0u8; nbytes],
            oob: vec![0u8; nbytes / 8],
            n: nbytes,
        };
        let total = zero.total_bits();
        let budget = 1 + rng.below(total / 4 + 1);
        for model in fault_model_menagerie(rng) {
            let seed = rng.next_u64();
            // (a) deterministic per seed
            let mut a = zero.clone();
            let mut b = zero.clone();
            let fa = FaultInjector::new(model, seed).inject_count(&mut a, budget);
            let fb = FaultInjector::new(model, seed).inject_count(&mut b, budget);
            if a.data != b.data || a.oob != b.oob || fa != fb {
                return Err(format!("{}: same seed, different injection", model.tag()));
            }
            // (b) every reported flip is a distinct bit...
            let ones: u64 = a
                .data
                .iter()
                .chain(&a.oob)
                .map(|x| u64::from(x.count_ones()))
                .sum();
            if ones != fa {
                return Err(format!(
                    "{}: {} set bits vs {} reported flips",
                    model.tag(),
                    ones,
                    fa
                ));
            }
            // ...and on an all-zero image the count is exactly what the
            // model promises for the budget
            let expect = match model {
                FaultModel::Uniform | FaultModel::StuckAt { .. } => budget.min(total),
                FaultModel::Hotspot { frac } => {
                    // budget saturates at the window capacity
                    let window = ((total as f64 * frac.clamp(0.0, 1.0)).ceil() as u64)
                        .clamp(1, total);
                    budget.min(window)
                }
                FaultModel::Burst { len } => {
                    let len = u64::from(len.max(1));
                    (budget / len).min(total / len) * len
                }
                FaultModel::RowBurst { row_bits, len } => {
                    let len = u64::from(len.max(1));
                    let row = row_bits.max(len).min(total);
                    let slots = (total / row) * (row / len) + (total % row) / len;
                    (budget / len).min(slots) * len
                }
            };
            if fa != expect {
                return Err(format!(
                    "{}: flipped {} of a {} budget, promised {}",
                    model.tag(),
                    fa,
                    budget,
                    expect
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fault_models_mark_exactly_the_hit_shards() {
    use zsecc::memory::ShardedBank;
    check("fault models dirty shards", 15, |rng, size| {
        let nblocks = 1 + rng.below(size.max(1) as u64) as usize;
        let w = wot_weights(rng, nblocks);
        for model in fault_model_menagerie(rng) {
            let seed = rng.next_u64();
            for name in ["ecc", "in-place"] {
                for shards in [1usize, 3, 16] {
                    let mut sb = ShardedBank::new(strategy_by_name(name).unwrap(), &w, shards, 2)
                        .map_err(|e| e.to_string())?;
                    let before_data = sb.image().data.clone();
                    let before_oob = sb.image().oob.clone();
                    sb.inject(model, 2e-2, seed);
                    // ground truth: shards owning a changed stored byte
                    let ranges: Vec<(usize, usize)> =
                        (0..sb.num_shards()).map(|i| sb.shard_range(i)).collect();
                    let shard_of_byte = |data_byte: usize| -> usize {
                        ranges
                            .iter()
                            .position(|&(s, e)| data_byte >= s && data_byte < e)
                            .unwrap_or(ranges.len() - 1)
                    };
                    let opb = sb.strategy().oob_bytes_per_block();
                    let block = sb.strategy().block_bytes();
                    let mut expect = Vec::new();
                    for (i, (a, b)) in before_data.iter().zip(&sb.image().data).enumerate() {
                        if a != b {
                            expect.push(shard_of_byte(i));
                        }
                    }
                    for (i, (a, b)) in before_oob.iter().zip(&sb.image().oob).enumerate() {
                        if a != b {
                            expect.push(shard_of_byte(i / opb * block));
                        }
                    }
                    expect.sort_unstable();
                    expect.dedup();
                    let mut got = sb.take_dirty();
                    got.sort_unstable();
                    if got != expect {
                        return Err(format!(
                            "{} {} x{}: dirty {:?} != changed {:?}",
                            model.tag(),
                            name,
                            shards,
                            got,
                            expect
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

// -------------------------------------------------- fault-rate semantics --

#[test]
fn prop_fault_rate_exact_count() {
    use zsecc::memory::{FaultInjector, FaultModel};
    check("fault count semantics", 50, |rng, size| {
        let nbytes = 8 * size.max(1);
        let mut enc = Encoded {
            data: vec![0u8; nbytes],
            oob: vec![0u8; nbytes / 8],
            n: nbytes,
        };
        let rate = [1e-3, 1e-2, 5e-2][rng.below(3) as usize];
        let mut inj = FaultInjector::new(FaultModel::Uniform, rng.next_u64());
        let n = inj.inject(&mut enc, rate);
        let expect = (enc.total_bits() as f64 * rate).round() as u64;
        if n != expect {
            return Err(format!("injected {n}, expected {expect}"));
        }
        let ones: u32 = enc
            .data
            .iter()
            .chain(&enc.oob)
            .map(|b| b.count_ones())
            .sum();
        if ones as u64 != n {
            return Err("flips not distinct".into());
        }
        Ok(())
    });
}

// ------------------------------------------------ BER estimator laws --

/// Convergence property of the scrub scheduler's online BER estimator:
/// a single-shard bank scrubbed every virtual tick under a stationary
/// fault process ends with a Wilson interval that brackets the true
/// injected rate — across every fault model. Burst-family models
/// deposit whole runs inside one code block, and a block-level code
/// reports one *event* per hit block however many bits the burst
/// carried, so their truth is the realized flip rate divided by the
/// burst length (the window the estimator can actually observe).
#[test]
fn prop_ber_estimator_brackets_injected_rate() {
    use std::time::Duration;
    use zsecc::memory::{FaultModel, SchedulerConfig, ScrubScheduler, ShardedBank};

    // (model, event bits per observable event, usable rates). Rates
    // are capped per model: a block that has gone uncorrectable stops
    // reporting new arrivals, so the accumulated dead-block fraction
    // (~ rate x ticks x block_bits / burst_len) must stay well inside
    // the Wilson interval's relative width — burst-family models kill
    // a whole block per event and need the lowest rates.
    type Case = (FaultModel, f64, &'static [f64]);
    let models: [Case; 5] = [
        (FaultModel::Uniform, 1.0, &[2.5e-5, 5e-5, 1e-4]),
        (FaultModel::StuckAt { bit: 1 }, 1.0, &[2.5e-5, 5e-5, 1e-4]),
        (FaultModel::HotspotAt { start: 0.3, frac: 0.5 }, 1.0, &[2.5e-5, 5e-5]),
        (FaultModel::Burst { len: 3 }, 3.0, &[2.5e-5]),
        (FaultModel::RowBurst { row_bits: 256, len: 4 }, 4.0, &[2.5e-5]),
    ];
    check("ber estimator brackets", 8, |rng, _size| {
        let seed0 = rng.next_u64();
        let (model, event_bits, rates) = models[rng.below(models.len() as u64) as usize];
        let rate = rates[rng.below(rates.len() as u64) as usize];
        let weights = wot_weights(&mut Rng::new(seed0 ^ 1), 4096); // 32 KiB
        let mut bank =
            ShardedBank::new(strategy_by_name("in-place").unwrap(), &weights, 1, 1).unwrap();
        let bits = bank.shard_bits(0) as f64;
        let tick = Duration::from_secs(1);
        // fixed 1-tick cadence, slow decay: long memory tightens the
        // interval around the stationary rate
        let mut cfg = SchedulerConfig::fixed(tick);
        cfg.decay = 0.98;
        let mut sched = ScrubScheduler::new(cfg, &[bits as u64], Duration::ZERO);
        let ticks = 150u64;
        for t in 0..ticks {
            bank.inject(model, rate, seed0 ^ (t + 1).wrapping_mul(0x9E3779B97F4A7C15));
            let stats = bank.scrub_shard(0);
            sched.record_pass(0, &stats, tick * (t as u32 + 1));
        }
        let realized = bank.faults_injected as f64 / (bits * ticks as f64);
        let truth = realized / event_bits;
        let (lo, hi) = sched.ber_bounds(0);
        if !(lo <= truth && truth <= hi) {
            return Err(format!(
                "{}: truth {truth:.3e} outside Wilson ({lo:.3e}, {hi:.3e}), \
                 realized {realized:.3e}, rate {rate:.0e}",
                model.tag()
            ));
        }
        // and the interval is informative, not vacuous
        if hi >= 1e-2 {
            return Err(format!("{}: vacuous upper bound {hi:.3e}", model.tag()));
        }
        Ok(())
    });
}

// ------------------------------------------------ fleet arbitration --

/// A random cross-model demand set: distinct (model, shard) pairs with
/// arbitrary pass costs, urgency signals, and deferral histories.
fn random_demands(rng: &mut Rng, size: usize) -> Vec<zsecc::memory::ScrubDemand> {
    use zsecc::memory::ScrubDemand;
    let n = rng.below(3 * size as u64 + 2) as usize;
    (0..n)
        .map(|i| ScrubDemand {
            model: rng.below(4) as usize,
            shard: i, // shard index unique => (model, shard) distinct
            bits: 64 * (1 + rng.below(64)),
            ber_upper: rng.f64() * 1e-3,
            lateness_secs: rng.f64() * 30.0,
            deferrals: rng.below(8) as u32,
        })
        .collect()
}

/// Conservation: for any demand set and budget, the arbiter never
/// spends more bits than the budget, never grants a pass it was not
/// asked for, never grants the same shard twice, is deterministic, and
/// — whenever the budget covers the largest single demand — grants at
/// least one pass (the lemma the starvation bound stands on).
#[test]
fn prop_fleet_arbitration_conserves_the_budget() {
    use zsecc::memory::arbitrate;
    check("fleet budget conservation", 60, |rng, size| {
        let demands = random_demands(rng, size);
        let starve_after = 1 + rng.below(6) as u32;
        let max_bits = demands.iter().map(|d| d.bits).max().unwrap_or(0);
        let budget = match rng.below(3) {
            0 => rng.below(max_bits + 1),          // tight: may grant nothing
            1 => max_bits + rng.below(max_bits + 1), // covers the largest demand
            _ => u64::MAX,                          // unbounded
        };
        let grants = arbitrate(&demands, budget, starve_after);
        let by_key: std::collections::BTreeMap<(usize, usize), u64> =
            demands.iter().map(|d| ((d.model, d.shard), d.bits)).collect();
        let mut spent = 0u64;
        let mut seen = std::collections::BTreeSet::new();
        for g in &grants {
            let bits = by_key
                .get(&(g.model, g.shard))
                .ok_or_else(|| format!("granted undemanded shard ({}, {})", g.model, g.shard))?;
            if !seen.insert((g.model, g.shard)) {
                return Err(format!("duplicate grant ({}, {})", g.model, g.shard));
            }
            spent = spent.saturating_add(*bits);
        }
        if budget != u64::MAX && spent > budget {
            return Err(format!("spent {spent} bits of a {budget} budget"));
        }
        if !demands.is_empty() && budget >= max_bits && grants.is_empty() {
            return Err(format!(
                "budget {budget} covers the largest demand ({max_bits}) but nothing was granted"
            ));
        }
        if arbitrate(&demands, budget, starve_after) != grants {
            return Err("arbitration is not deterministic".into());
        }
        Ok(())
    });
}

/// Starvation-freedom over the live planner: a permanently overloaded
/// fleet (every shard due every wakeup, demand far above budget) with a
/// hot shard whose urgency dominates — and migrates between models —
/// must still scrub every shard at least once every
/// `starve_after + total_shards` wakeups once the books warm up,
/// while each wakeup's granted bits stay within the budget.
#[test]
fn prop_fleet_planner_never_starves_a_due_shard() {
    use std::time::Duration;
    use zsecc::memory::{FleetArbitration, SchedulerConfig, ScrubScheduler};
    check("fleet starvation freedom", 12, |rng, size| {
        let nmodels = 1 + rng.below(3) as usize;
        let shards_per = 2 + rng.below((size as u64 / 8).max(1) + 4) as usize;
        let shard_bits = 512u64;
        let budget_passes = 1 + rng.below(3);
        let starve_after = 1 + rng.below(4) as u32;
        let tick = Duration::from_secs(1);
        let mut fleet = FleetArbitration::new(Some(budget_passes * shard_bits), starve_after);
        let mut scheds: Vec<ScrubScheduler> = (0..nmodels)
            .map(|_| {
                // fixed 1-tick cadence: with virtual time stepping one
                // tick per wakeup, every shard is due at every wakeup —
                // the permanent-overload worst case.
                ScrubScheduler::new(
                    SchedulerConfig::fixed(tick),
                    &vec![shard_bits; shards_per],
                    Duration::ZERO,
                )
            })
            .collect();
        let slots: Vec<usize> = (0..nmodels).map(|_| fleet.register(shards_per)).collect();
        let total_shards = (nmodels * shards_per) as u64;
        let bound = u64::from(starve_after) + total_shards;
        let wakeups = 4 * bound + 16;
        let mut last_grant = vec![vec![0u64; shards_per]; nmodels];
        let mut hot = (0usize, 0usize);
        for w in 1..=wakeups {
            // the hotspot migrates across models/shards every few wakeups
            if w % (bound / 2 + 1) == 0 {
                hot = (
                    rng.below(nmodels as u64) as usize,
                    rng.below(shards_per as u64) as usize,
                );
            }
            let now = tick * (w as u32);
            let grants = {
                let refs: Vec<(usize, &ScrubScheduler)> =
                    slots.iter().copied().zip(scheds.iter()).collect();
                fleet.plan(&refs, now)
            };
            let spent = grants.len() as u64 * shard_bits;
            if spent > budget_passes * shard_bits {
                return Err(format!(
                    "wakeup {w}: spent {spent} bits of a {} budget",
                    budget_passes * shard_bits
                ));
            }
            for g in &grants {
                // pump the hot shard's error history so its Wilson
                // upper bound (and urgency) dominates the field
                let detected = if (g.model, g.shard) == hot { 40 } else { 0 };
                let stats = DecodeStats { corrected: 0, detected, zeroed: 0 };
                scheds[g.model].record_pass(g.shard, &stats, now);
                last_grant[g.model][g.shard] = w;
            }
        }
        // warm-up excluded: the first `bound` wakeups drain the initial
        // all-due burst in deterministic order
        for (mi, lane) in last_grant.iter().enumerate() {
            for (si, &last) in lane.iter().enumerate() {
                let wait = wakeups - last;
                if last == 0 || wait > bound {
                    return Err(format!(
                        "model {mi} shard {si}: last grant at wakeup {last} of {wakeups} \
                         (wait {wait} > bound {bound}, starve_after {starve_after}, \
                         {total_shards} shards, {budget_passes} passes/wakeup)"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wear_is_deterministic_and_monotone_within_envelope() {
    // The closed-loop wear process over random parameters and image
    // sizes: (1) two instances with one seed agree strike for strike;
    // (2) the stuck set only ever grows; (3) the realized stuck count
    // is exactly floor(cumulative expectation) until the cap binds —
    // the drift envelope is an identity, not a statistical bound;
    // (4) per-tick strikes never exceed stuck cells plus the two
    // transient populations' own floor-of-expectation envelopes.
    use zsecc::memory::{Wear, WearParams};
    check("wear drift envelope", 25, |rng, size| {
        let nbytes = (size.max(1)) * 64;
        let w = wot_weights(rng, nbytes / 8);
        let enc = strategy_by_name("in-place")
            .unwrap()
            .encode(&w)
            .map_err(|e| e.to_string())?;
        let total = enc.total_bits();
        let p = WearParams {
            transient_rate: rng.f64() * 1e-3,
            wear_rate: rng.f64() * 1e-3,
            accel: 1.0 + rng.f64() * 0.1,
            window_start: rng.f64(),
            window_frac: 0.05 + rng.f64() * 0.3,
            max_stuck_frac: 0.01 + rng.f64() * 0.05,
            hot_rate: rng.f64() * 1e-2,
        };
        let seed = rng.next_u64();
        let mut a = Wear::new(p, seed).map_err(|e| e.to_string())?;
        let mut b = Wear::new(p, seed).map_err(|e| e.to_string())?;
        let window = ((total as f64 * p.window_frac).ceil() as u64).clamp(1, total);
        let cap = ((total as f64 * p.max_stuck_frac) as u64).min(window);
        let mut expected_stuck = 0.0f64;
        let mut rate = p.wear_rate;
        let mut prev_stuck = 0u64;
        let (mut transient_budget, mut hot_budget) = (0.0f64, 0.0f64);
        for t in 0..30u64 {
            a.advance(total);
            b.advance(total);
            let strikes = a.strike_positions(&enc);
            if strikes != b.strike_positions(&enc) {
                return Err(format!("tick {t}: same seed, different strikes"));
            }
            let stuck = a.stuck_cells();
            if stuck < prev_stuck {
                return Err(format!("tick {t}: stuck set shrank {prev_stuck} -> {stuck}"));
            }
            prev_stuck = stuck;
            expected_stuck += rate * total as f64;
            rate = (rate * p.accel).min(1.0);
            // floor-of-expectation identity, with one cell of slack:
            // this summation rounds in a different order than the
            // implementation's carry chain, so near-integer crossings
            // may disagree by an ulp (the fixed-value unit test in
            // memory::fault pins the exact identity)
            if stuck < cap && (stuck as i64 - expected_stuck.floor() as i64).abs() > 1 {
                return Err(format!(
                    "tick {t}: {stuck} stuck cells vs floor expectation {}",
                    expected_stuck.floor()
                ));
            }
            if stuck > cap {
                return Err(format!("tick {t}: {stuck} stuck cells exceed cap {cap}"));
            }
            // strike-rate envelope: re-asserts are at most the stuck
            // set; each transient population realizes at most the floor
            // of its cumulative expectation (carries never bank more
            // than one flip)
            transient_budget += p.transient_rate * total as f64;
            hot_budget += p.hot_rate * window as f64;
            // + 2: the same ulp slack, one per transient population
            let bound = stuck + transient_budget.floor() as u64 + hot_budget.floor() as u64 + 2;
            if (strikes.len() as u64) > bound {
                return Err(format!(
                    "tick {t}: {} strikes exceed envelope {bound}",
                    strikes.len()
                ));
            }
        }
        Ok(())
    });
}
