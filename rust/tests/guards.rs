//! Compute-path guard integration tests over the public API: exhaustive
//! single-flip ABFT sweeps across batch sizes (one short of, equal to,
//! and one past the model's natural execution batch), clamp accounting
//! for range supervision, and the guards-off byte-identity contract.

use zsecc::runtime::guard::{
    residual_pp, ComputeFault, ComputeFaults, DenseModel, GuardMode, GuardReport,
};
use zsecc::util::rng::Rng;

const DIMS: &[(usize, usize)] = &[(12, 10), (10, 8)];

/// The model's "natural" batch in these sweeps; tests run {1, EXEC,
/// EXEC + 1} to cover the degenerate, aligned, and ragged cases.
const EXEC: usize = 4;

fn model_and_input(batch: usize) -> (DenseModel, Vec<f32>) {
    let n: usize = DIMS.iter().map(|&(r, c)| r * c).sum();
    let mut rng = Rng::new(17);
    let w: Vec<f32> = (0..n).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    let mut model = DenseModel::from_flat(&w, DIMS).unwrap();
    let x: Vec<f32> = (0..batch * model.input_dim())
        .map(|_| (rng.f64() * 2.0 - 1.0) as f32)
        .collect();
    model.calibrate(&x, batch, 0.05);
    (model, x)
}

fn bitwise_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(u, v)| u.to_bits() == v.to_bits())
}

/// Every single-bit flip, on every element of every activation and
/// accumulator plane, at every bit position, across all three batch
/// sizes: ABFT either repairs it bitwise or the flip was numerically
/// negligible (sub-tolerance — the detect-or-negligible contract).
/// High-exponent flips (bit 30 — a guaranteed huge corruption) must
/// all be caught and repaired exactly.
#[test]
fn abft_repairs_every_single_flip_or_proves_it_negligible() {
    for &batch in &[1usize, EXEC, EXEC + 1] {
        let (model, x) = model_and_input(batch);
        let clean = model.forward(&x, batch);
        for layer in 0..DIMS.len() {
            for site in ["activations", "accumulators"] {
                let elems = match site {
                    "activations" => model.activation_elems(layer, batch),
                    _ => model.accumulator_elems(layer, batch),
                };
                for index in 0..elems {
                    for bit in 0..32u32 {
                        let mut faults = ComputeFaults::default();
                        let f = ComputeFault { layer, index, bit };
                        match site {
                            "activations" => faults.activations.push(f),
                            _ => faults.accumulators.push(f),
                        }
                        let mut report = GuardReport::default();
                        let y = model.forward_guarded(
                            &x,
                            batch,
                            GuardMode::Abft,
                            &faults,
                            &mut report,
                        );
                        let tag = format!("batch={batch} {site} layer={layer} [{index}]^{bit}");
                        assert!(report.abft_checks > 0, "{tag}: no checks ran");
                        if report.recomputes > 0 {
                            assert!(report.abft_trips > 0, "{tag}");
                            assert!(
                                bitwise_eq(&y, &clean),
                                "{tag}: repaired output is not bitwise clean"
                            );
                        } else {
                            // escaped the checksum: must be sub-tolerance
                            let r = residual_pp(&y, &clean);
                            assert!(r < 0.25, "{tag}: escaped flip left {r} pp residual");
                        }
                        if bit == 30 {
                            assert!(
                                report.abft_trips > 0 && bitwise_eq(&y, &clean),
                                "{tag}: high-exponent flip must be caught and repaired"
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Range supervision counts exactly the out-of-envelope activations it
/// clamps: bit-30 flips blast calibrated-in-range values to magnitude
/// >= 2 (outside any envelope calibrated on (-1, 1) data), so the clamp
/// count must equal the number of struck elements — and a clean pass
/// through an armed range guard clamps nothing and changes no byte.
#[test]
fn clamp_count_matches_injected_out_of_envelope_activations() {
    for &batch in &[1usize, EXEC, EXEC + 1] {
        let (model, x) = model_and_input(batch);
        let clean = model.forward(&x, batch);

        let mut report = GuardReport::default();
        let y = model.forward_guarded(
            &x,
            batch,
            GuardMode::Range,
            &ComputeFaults::default(),
            &mut report,
        );
        assert_eq!(report.range_clamps, 0, "batch={batch}: clean pass clamped");
        assert!(bitwise_eq(&y, &clean), "batch={batch}: clean pass changed bytes");

        // Strike distinct elements of the layer-0 input plane. Only
        // layer 0 is safe for an exact count: its values sit in (-1, 1)
        // where a bit-30 flip always lands outside the envelope, while
        // deeper planes can hold magnitudes >= 2 whose bit-30 flip
        // collapses *into* range.
        let strikes = model.activation_elems(0, batch).min(7);
        let mut faults = ComputeFaults::default();
        for index in 0..strikes {
            faults.activations.push(ComputeFault { layer: 0, index, bit: 30 });
        }
        let mut on = GuardReport::default();
        let y_on = model.forward_guarded(&x, batch, GuardMode::Range, &faults, &mut on);
        assert_eq!(
            on.range_clamps, strikes as u64,
            "batch={batch}: clamp count != injected out-of-envelope strikes"
        );
        let mut off = GuardReport::default();
        let y_off = model.forward_guarded(&x, batch, GuardMode::Off, &faults, &mut off);
        assert_eq!(off.range_clamps, 0);
        assert!(
            residual_pp(&y_on, &clean) < residual_pp(&y_off, &clean),
            "batch={batch}: clamping must beat running the blast through unguarded"
        );
    }
}

/// Guards off means *off*: byte-identical outputs to the plain forward
/// pass and an untouched report, at every batch size.
#[test]
fn guards_off_is_byte_identical_to_unguarded_forward() {
    for &batch in &[1usize, EXEC, EXEC + 1] {
        let (model, x) = model_and_input(batch);
        let clean = model.forward(&x, batch);
        let mut report = GuardReport::default();
        let y = model.forward_guarded(
            &x,
            batch,
            GuardMode::Off,
            &ComputeFaults::default(),
            &mut report,
        );
        assert!(bitwise_eq(&y, &clean), "batch={batch}");
        assert_eq!(report, GuardReport::default(), "batch={batch}: off mode counted something");
        assert!(!report.any());
    }
}
