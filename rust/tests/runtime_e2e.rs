//! End-to-end tests over the real AOT artifacts + PJRT runtime.
//!
//! These need `make artifacts` to have run; if the artifacts directory
//! is absent (e.g. a bare checkout), every test is skipped with a
//! message rather than failing — `make test` builds artifacts first.

use std::sync::Arc;

use zsecc::harness::eval::EvalCtx;
use zsecc::memory::FaultModel;
use zsecc::model::{load_weights, EvalSet, Manifest};
use zsecc::quant::{dequantize_into, wot_violations};
use zsecc::runtime::Runtime;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = zsecc::artifacts_dir();
    if dir.join("index.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts at {}", dir.display());
        None
    }
}

#[test]
fn exported_weights_satisfy_wot_constraint() {
    let Some(dir) = artifacts() else { return };
    for model in zsecc::model::manifest::list_models(&dir).unwrap() {
        let man = Manifest::load_model(&dir, &model).unwrap();
        let w = load_weights(&man.weights_path(), man.num_weights).unwrap();
        assert_eq!(
            wot_violations(&w),
            0,
            "{model}: exported weights violate the WOT constraint"
        );
        // pre-WOT buffers generally do NOT satisfy it (that's the point)
        let pre = load_weights(&man.prewot_path(), man.num_weights).unwrap();
        let _ = wot_violations(&pre); // just must load & parse
    }
}

#[test]
fn rust_accuracy_matches_python_within_tolerance() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let ds = Arc::new(EvalSet::load(&dir.join("dataset.eval.bin")).unwrap());
    for model in ["squeezenet_s", "resnet18_s"] {
        let mut ctx = EvalCtx::load(&dir, model, 256, rt.clone(), ds.clone()).unwrap();
        let man = &ctx.man;
        // Cross-language check: the accuracy of the exported int8 buffer
        // through rust-PJRT must match python's wot_acc closely (same
        // weights, same eval split, same math modulo op ordering).
        assert!(
            (ctx.base_acc - man.wot_acc).abs() < 0.02,
            "{model}: rust acc {} vs python wot_acc {}",
            ctx.base_acc,
            man.wot_acc
        );
        // In-place ECC at 1e-6 must be indistinguishable from fault-free.
        let (acc, _, _) = ctx.faulty_trial("in-place", FaultModel::Uniform, 1e-6, 1).unwrap();
        assert!(
            (acc - ctx.base_acc).abs() < 0.005,
            "{model}: in-place at 1e-6 dropped {} -> {}",
            ctx.base_acc,
            acc
        );
    }
}

#[test]
fn pallas_variant_matches_fast_variant() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let ds = EvalSet::load(&dir.join("dataset.eval.bin")).unwrap();
    let model = "inception_s"; // smallest pallas artifact
    let man = Manifest::load_model(&dir, model).unwrap();
    let b = man.pallas_batch;
    let fast = rt.load_model(&man, b).unwrap();
    let pallas = rt.load(&man.hlo_pallas_path(b).unwrap(), b, &man).unwrap();
    let q = load_weights(&man.weights_path(), man.num_weights).unwrap();
    let mut f = vec![0f32; q.len()];
    dequantize_into(&q, &man.layers, &mut f);
    let wb = rt.bind_weights(&f).unwrap();
    let imgs = ds.batch(0, b);
    let a = fast.run(&rt, &wb, imgs).unwrap();
    let p = pallas.run(&rt, &wb, imgs).unwrap();
    assert_eq!(a.len(), p.len());
    let max_diff = a
        .iter()
        .zip(&p)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(
        max_diff < 1e-3,
        "pallas HLO diverges from fast HLO: max diff {max_diff}"
    );
}

#[test]
fn table2_mini_grid_shape_holds() {
    let Some(dir) = artifacts() else { return };
    use zsecc::harness::table2;
    let cfg = table2::Config {
        models: vec!["squeezenet_s".into()],
        strategies: ["faulty", "zero", "ecc", "in-place"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        rates: vec![1e-4, 1e-3],
        trials: 3,
        batch: 256,
        fault_model: FaultModel::Uniform,
        ..Default::default()
    };
    let t2 = table2::run(&dir, &cfg, false).unwrap();
    for (name, ok) in t2.shape_checks(&cfg) {
        assert!(ok, "shape check failed: {name}");
    }
}

#[test]
fn fig_series_pass_shape_checks() {
    let Some(dir) = artifacts() else { return };
    let models = zsecc::model::manifest::list_models(&dir).unwrap();
    let logs = zsecc::harness::fig34::run(&dir, &models).unwrap();
    for (name, ok) in zsecc::harness::fig34::shape_checks(&logs) {
        assert!(ok, "{name}");
    }
    // Fig 1: pre-WOT large positions roughly uniform; post-WOT zero in 0..6
    let figs = zsecc::harness::fig1::run(&dir, &models).unwrap();
    for f in &figs {
        let viol: u64 = f.post_wot[..7].iter().sum();
        assert_eq!(viol, 0, "{}: post-WOT violations", f.model);
    }
}

#[test]
fn serving_stack_over_real_model() {
    let Some(dir) = artifacts() else { return };
    use zsecc::coordinator::{BatchPolicy, Server, ServerConfig};
    let cfg = ServerConfig {
        strategy: "in-place".into(),
        policy: BatchPolicy {
            max_batch: 32,
            max_wait: std::time::Duration::from_millis(4),
        },
        scrub_interval: Some(std::time::Duration::from_millis(50)),
        fault_rate_per_interval: 1e-6,
        fault_seed: 5,
        ..ServerConfig::default()
    };
    let ds = EvalSet::load(&dir.join("dataset.eval.bin")).unwrap();
    let srv = Server::start_pjrt(&dir, "inception_s", &cfg).unwrap();
    let mut correct = 0usize;
    let n = 64;
    let mut rxs = Vec::new();
    for i in 0..n {
        rxs.push((srv.submit(ds.image(i).to_vec()).unwrap(), ds.labels[i] as usize));
    }
    for (rx, label) in rxs {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
        correct += (resp.pred == label) as usize;
    }
    let man = Manifest::load_model(&dir, "inception_s").unwrap();
    let acc = correct as f64 / n as f64;
    assert!(
        acc > man.wot_acc - 0.15,
        "served accuracy {acc} too far below {}",
        man.wot_acc
    );
    srv.shutdown();
}
