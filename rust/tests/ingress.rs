//! Ingress front-door integration tests over the public API: FIFO
//! ordering through the lock-free slab ring, shutdown draining,
//! multi-producer exactly-once delivery through a full `Server`, and
//! typed overload backpressure. These complement the unit and loom
//! permutation tests inside `coordinator::ingress` by exercising only
//! the exported surface (`IngressRing`, `Server::try_submit`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use zsecc::coordinator::server::BatchExec;
use zsecc::coordinator::{
    BatchPolicy, IngressPolicy, IngressRing, PushError, RingConfig, Server, ServerConfig,
};

/// Mock executor: prediction = first element of each input row.
struct Echo {
    dim: usize,
    batch: usize,
    /// Per-batch simulated compute.
    cost: Duration,
}

impl BatchExec for Echo {
    fn batch(&self) -> usize {
        self.batch
    }
    fn input_dim(&self) -> usize {
        self.dim
    }
    fn exec(&mut self, images: &[f32], count: usize) -> anyhow::Result<Vec<usize>> {
        if !self.cost.is_zero() {
            std::thread::sleep(self.cost);
        }
        Ok((0..count).map(|i| images[i * self.dim] as usize).collect())
    }
    fn refresh(&mut self, _w: &[f32]) -> anyhow::Result<()> {
        Ok(())
    }
}

fn ring_cfg(max_batch: usize, ring_depth: usize, wait_ms: u64) -> ServerConfig {
    ServerConfig {
        strategy: "faulty".into(),
        policy: BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
        },
        scrub_interval: None,
        fault_rate_per_interval: 0.0,
        fault_seed: 0,
        ingress: IngressPolicy::Ring,
        ring_depth,
        ..ServerConfig::default()
    }
}

/// Slot order equals arrival order, across sealed batches, including a
/// trailing partial batch sealed by the deadline path.
#[test]
fn ring_fifo_within_and_across_batches() {
    let ring = IngressRing::new(RingConfig {
        depth: 2,
        cap: 4,
        dim: 1,
        max_wait: Duration::from_secs(3600), // sealed explicitly below
    });
    const TOTAL: u64 = 102; // 25 full batches + one partial
    let mut pushed = 0u64;
    let mut next_expect = 0u64;
    while next_expect < TOTAL {
        while pushed < TOTAL {
            let (tx, _rx) = channel();
            match ring.push(pushed, &[pushed as f32], tx) {
                Ok(()) => pushed += 1,
                Err(PushError::Overloaded) => break,
                Err(e) => panic!("unexpected push error: {e}"),
            }
        }
        if let Some(batch) = ring.try_next_sealed() {
            for slot in 0..batch.count() {
                let lane = batch.take_lane(slot);
                assert_eq!(lane.id, next_expect, "slot order must equal arrival order");
                next_expect += 1;
            }
        } else {
            // The tail batch is partial: seal it the way the deadline
            // path would.
            ring.seal_open_now();
        }
    }
    assert_eq!(ring.in_flight(), 0);
}

/// Inputs land in the slab at the slot the reservation assigned.
#[test]
fn ring_inputs_written_in_place_per_slot() {
    let ring = IngressRing::new(RingConfig {
        depth: 2,
        cap: 4,
        dim: 3,
        max_wait: Duration::from_secs(3600),
    });
    for id in 0..4u64 {
        let (tx, _rx) = channel();
        let v = id as f32;
        ring.push(id, &[v, v + 0.25, v + 0.5], tx).unwrap();
    }
    let batch = ring.next_sealed().expect("full batch seals itself");
    assert_eq!(batch.count(), 4);
    batch.with_inputs(|inp| {
        for slot in 0..4 {
            let v = slot as f32;
            assert_eq!(&inp[slot * 3..slot * 3 + 3], &[v, v + 0.25, v + 0.5]);
        }
    });
    for slot in 0..4 {
        assert_eq!(batch.take_lane(slot).id, slot as u64);
    }
}

/// Requests pending at shutdown are still answered: close() drains the
/// open partial batch and the dispatcher serves everything sealed
/// before exiting.
#[test]
fn server_shutdown_drains_pending_ring_requests() {
    let cfg = ring_cfg(2, 8, 200);
    let srv = Server::start_with(
        || {
            Ok(Box::new(Echo {
                dim: 1,
                batch: 2,
                cost: Duration::from_millis(10),
            }) as Box<dyn BatchExec>)
        },
        1,
        &cfg,
        None,
    )
    .unwrap();
    let mut rxs = Vec::new();
    for i in 0..7u64 {
        // Retry transient overload: the slow executor can briefly back
        // the ring up.
        loop {
            match srv.try_submit(vec![i as f32]) {
                Ok(rx) => {
                    rxs.push((i, rx));
                    break;
                }
                Err(PushError::Overloaded) => std::thread::yield_now(),
                Err(e) => panic!("unexpected push error: {e}"),
            }
        }
    }
    srv.shutdown();
    for (i, rx) in rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("request pending at shutdown must still be answered");
        assert_eq!(resp.pred, i as usize);
    }
}

/// Multi-producer stress through the full server: every submitted
/// request is answered exactly once with its own prediction.
#[test]
fn ring_server_multi_producer_exactly_once() {
    const PRODUCERS: u64 = 8;
    const PER_PRODUCER: u64 = 50;
    let cfg = ring_cfg(4, 4, 1);
    let srv = Server::start_with(
        || {
            Ok(Box::new(Echo {
                dim: 1,
                batch: 4,
                cost: Duration::ZERO,
            }) as Box<dyn BatchExec>)
        },
        1,
        &cfg,
        None,
    )
    .unwrap();
    let answered = AtomicU64::new(0);
    std::thread::scope(|scope| {
        for p in 0..PRODUCERS {
            let srv = &srv;
            let answered = &answered;
            scope.spawn(move || {
                let mut rxs = Vec::new();
                for i in 0..PER_PRODUCER {
                    let val = p * 1000 + i;
                    loop {
                        match srv.try_submit(vec![val as f32]) {
                            Ok(rx) => {
                                rxs.push((val, rx));
                                break;
                            }
                            Err(PushError::Overloaded) => std::thread::yield_now(),
                            Err(e) => panic!("unexpected push error: {e}"),
                        }
                    }
                }
                for (val, rx) in rxs {
                    let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
                    assert_eq!(resp.pred, val as usize, "response routed to wrong lane");
                    // Exactly once: the lane's sender is dropped after
                    // the single response, so a second receive must
                    // report disconnection, not another message.
                    assert!(rx.recv_timeout(Duration::from_millis(1)).is_err());
                    answered.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    assert_eq!(answered.load(Ordering::Relaxed), PRODUCERS * PER_PRODUCER);
    // Snapshot after shutdown joins the dispatcher: the final sealed
    // batch is recycled only after its responses fan out, so an
    // immediate occupancy read could still see it in flight.
    let metrics = srv.metrics.clone();
    srv.shutdown();
    let snap = metrics.ingress().expect("ring server exports ingress gauges");
    assert_eq!(snap.occupancy, 0, "all slots recycled");
    assert!(snap.occupancy_hwm >= 1);
}

/// A saturated ring refuses with the typed `Overloaded` error and
/// recovers once the executor drains.
#[test]
fn ring_overload_is_typed_and_recoverable() {
    struct Gated {
        gate: Arc<Mutex<()>>,
    }
    impl BatchExec for Gated {
        fn batch(&self) -> usize {
            1
        }
        fn input_dim(&self) -> usize {
            1
        }
        fn exec(&mut self, _images: &[f32], count: usize) -> anyhow::Result<Vec<usize>> {
            let _g = self.gate.lock().unwrap();
            Ok(vec![7; count])
        }
        fn refresh(&mut self, _w: &[f32]) -> anyhow::Result<()> {
            Ok(())
        }
    }
    let gate = Arc::new(Mutex::new(()));
    let held = gate.lock().unwrap();
    let gate2 = gate.clone();
    let cfg = ring_cfg(1, 2, 1);
    let srv = Server::start_with(
        move || Ok(Box::new(Gated { gate: gate2 }) as Box<dyn BatchExec>),
        1,
        &cfg,
        None,
    )
    .unwrap();
    // depth(2) x cap(1) slots plus at most one batch held at the gate:
    // a bounded number of submits succeed, then the typed refusal.
    let mut rxs = Vec::new();
    let mut overloaded = false;
    for _ in 0..16 {
        match srv.try_submit(vec![0.0]) {
            Ok(rx) => rxs.push(rx),
            Err(PushError::Overloaded) => {
                overloaded = true;
                break;
            }
            Err(e) => panic!("unexpected push error: {e}"),
        }
    }
    assert!(overloaded, "saturated ring must refuse with Overloaded");
    assert!(rxs.len() <= 3, "admissions bounded by ring capacity");
    drop(held);
    for rx in rxs {
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().pred, 7);
    }
    // Recovered: the next submit is admitted again.
    let rx = srv.try_submit(vec![0.0]).expect("ring admits after drain");
    assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().pred, 7);
    srv.shutdown();
}
