//! Campaign-engine integration tests — all on the synthetic
//! (artifact-free) runner: ledger checkpoint/resume bit-identity,
//! early-stopping bounds, and fingerprint safety.

use std::path::PathBuf;

use zsecc::harness::campaign::{self, Config, SyntheticRunner, TrialPolicy};
use zsecc::memory::{FaultModel, FaultSite};
use zsecc::model::RecoveryMode;
use zsecc::runtime::GuardMode;
use zsecc::util::json::Json;

fn base_cfg(ledger: Option<PathBuf>, jobs: usize) -> Config {
    Config {
        models: vec!["synthetic".to_string()],
        strategies: vec![
            "faulty".to_string(),
            "ecc".to_string(),
            "in-place".to_string(),
        ],
        rates: vec![1e-9, 5e-3],
        fault_models: vec![FaultModel::Uniform, FaultModel::Burst { len: 2 }],
        sites: vec![FaultSite::Weights],
        guards: vec![GuardMode::Off],
        recovery: vec![RecoveryMode::Off],
        policy: TrialPolicy::adaptive(3, 8, 0.05, 0.95),
        jobs,
        ledger,
        resume: false,
        stop_after: None,
        runner_tag: "synthetic:n2048".to_string(),
        verbose: false,
    }
}

fn runner() -> SyntheticRunner {
    SyntheticRunner::new(2048, 4, 2)
}

fn temp_ledger(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("zsecc_campaign_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{tag}.ledger.json"));
    let _ = std::fs::remove_file(&path);
    path
}

#[test]
fn interrupted_campaign_resumes_bit_identically() {
    let ledger = temp_ledger("resume");

    // one-shot reference run, no ledger at all
    let oneshot = campaign::run(&base_cfg(None, 1), &runner()).unwrap();
    assert!(oneshot.complete);
    assert_eq!(oneshot.cells.len(), 12, "3 strategies x 2 rates x 2 faults");

    // the same campaign interrupted after 5 cells
    let mut cfg = base_cfg(Some(ledger.clone()), 1);
    cfg.stop_after = Some(5);
    let partial = campaign::run(&cfg, &runner()).unwrap();
    assert!(!partial.complete, "interrupted run must say so");
    assert_eq!(partial.cells.len(), 5);

    // resumed under different parallelism: completes, and the canonical
    // JSON is byte-identical to the uninterrupted run
    let mut cfg = base_cfg(Some(ledger.clone()), 3);
    cfg.resume = true;
    let resumed = campaign::run(&cfg, &runner()).unwrap();
    assert!(resumed.complete);
    assert_eq!(
        resumed.canonical_json().to_string(),
        oneshot.canonical_json().to_string(),
        "resume must be bit-identical to a one-shot run"
    );

    // resuming the now-complete ledger computes nothing new (stop_after
    // forbids any fresh cell) and still reproduces the same bytes
    let mut cfg = base_cfg(Some(ledger.clone()), 2);
    cfg.resume = true;
    cfg.stop_after = Some(0);
    let replay = campaign::run(&cfg, &runner()).unwrap();
    assert!(replay.complete, "every cell must come from the ledger");
    assert_eq!(
        replay.canonical_json().to_string(),
        oneshot.canonical_json().to_string()
    );

    // and the ledger file itself is valid JSON holding the full grid
    let text = std::fs::read_to_string(&ledger).unwrap();
    let v = Json::parse(&text).unwrap();
    assert_eq!(v.req("cells").unwrap().as_obj().unwrap().len(), 12);
}

#[test]
fn early_stopping_never_violates_trial_bounds() {
    let report = campaign::run(&base_cfg(None, 2), &runner()).unwrap();
    assert!(report.complete);
    for c in &report.cells {
        assert!(
            (3..=8).contains(&c.trials()),
            "{}: {} trials outside [3, 8]",
            c.spec.key(),
            c.trials()
        );
        // a cell that stopped early must have met the CI target
        if c.trials() < 8 {
            assert!(
                c.half_width <= 0.05 + 1e-12,
                "{}: stopped at {} trials with hw {}",
                c.spec.key(),
                c.trials(),
                c.half_width
            );
        }
    }
    // at rate 1e-9 the flip budget rounds to zero: deterministically
    // zero drop and zero variance, so every such cell stops at the
    // minimum bound — early stopping at work, and never below min
    for c in report.cells.iter().filter(|c| c.spec.rate == 1e-9) {
        assert_eq!(c.trials(), 3, "{}", c.spec.key());
        assert_eq!(c.half_width, 0.0);
        assert!(c.drops.iter().all(|&d| d == 0.0));
    }
}

#[test]
fn ledger_refuses_a_foreign_campaign() {
    let ledger = temp_ledger("foreign");
    let mut cfg = base_cfg(Some(ledger.clone()), 1);
    cfg.stop_after = Some(2);
    campaign::run(&cfg, &runner()).unwrap();

    // same ledger, different grid -> fingerprint mismatch, hard error
    let mut other = base_cfg(Some(ledger), 1);
    other.rates = vec![1e-4];
    other.resume = true;
    let err = campaign::run(&other, &runner()).unwrap_err().to_string();
    assert!(err.contains("fingerprint"), "unexpected error: {err}");
}

/// Compute-site cells (activations/accumulators through the guarded
/// dense head) ride the same ledger machinery as storage cells: a
/// guards-on/off grid checkpoints, resumes bit-identically under
/// different parallelism, and the guarded sibling of every cell —
/// which by construction sees the identical fault sequence — lands at
/// a strictly lower mean residual.
#[test]
fn compute_site_cells_checkpoint_resume_and_beat_unguarded() {
    let mk = |ledger: Option<PathBuf>, jobs: usize| {
        let mut cfg = base_cfg(ledger, jobs);
        cfg.strategies = vec!["ecc".to_string()];
        cfg.rates = vec![2e-3];
        cfg.fault_models = vec![FaultModel::Uniform];
        cfg.sites = vec![FaultSite::Activations, FaultSite::Accumulators];
        cfg.guards = vec![GuardMode::Off, GuardMode::Full];
        cfg.policy = TrialPolicy::fixed(3);
        cfg
    };
    let runner = || SyntheticRunner::new(64 * 16, 4, 1);
    let oneshot = campaign::run(&mk(None, 1), &runner()).unwrap();
    assert!(oneshot.complete);
    assert_eq!(oneshot.cells.len(), 4, "2 sites x 2 guard modes");
    for site in [FaultSite::Activations, FaultSite::Accumulators] {
        let mean = |guard: GuardMode| {
            let c = oneshot
                .cells
                .iter()
                .find(|c| c.spec.site == site && c.spec.guard == guard)
                .unwrap();
            c.drops.iter().sum::<f64>() / c.drops.len() as f64
        };
        assert!(
            mean(GuardMode::Full) < mean(GuardMode::Off),
            "site {}: guards on must beat guards off at equal faults",
            site.tag()
        );
    }

    let ledger = temp_ledger("compute_resume");
    let mut cfg = mk(Some(ledger.clone()), 1);
    cfg.stop_after = Some(2);
    let partial = campaign::run(&cfg, &runner()).unwrap();
    assert!(!partial.complete);
    let mut cfg = mk(Some(ledger), 3);
    cfg.resume = true;
    let resumed = campaign::run(&cfg, &runner()).unwrap();
    assert!(resumed.complete);
    assert_eq!(
        resumed.canonical_json().to_string(),
        oneshot.canonical_json().to_string(),
        "compute-site resume must be bit-identical to a one-shot run"
    );
}

/// The recovery axis rides the same grid/ledger machinery as guards:
/// at equal injected faults (recovery modes are excluded from trial
/// seeds), the milr cell reconstructs implicated blocks and lands at a
/// strictly lower mean residual than its off sibling — and the axis is
/// part of the resume fingerprint.
#[test]
fn recovery_axis_beats_off_at_equal_faults_and_fingerprints() {
    let mk = |ledger: Option<PathBuf>| {
        let mut cfg = base_cfg(ledger, 2);
        cfg.strategies = vec!["milr".to_string()];
        // ~3 flips per trial over 2048x8 stored bits: enough strikes
        // for probe-visible detections, sparse enough that several
        // trials leave the solver's trusted rows clean.
        cfg.rates = vec![2e-4];
        cfg.fault_models = vec![FaultModel::Uniform];
        cfg.recovery = vec![RecoveryMode::Off, RecoveryMode::Milr];
        cfg.policy = TrialPolicy::fixed(32);
        cfg
    };
    let report = campaign::run(&mk(None), &runner()).unwrap();
    assert!(report.complete);
    assert_eq!(report.cells.len(), 2, "one off cell, one milr cell");
    let cell = |mode: RecoveryMode| {
        report
            .cells
            .iter()
            .find(|c| c.spec.recovery == mode)
            .unwrap()
    };
    let (off, on) = (cell(RecoveryMode::Off), cell(RecoveryMode::Milr));
    assert_eq!(off.recovered, 0, "an unarmed tier never recovers");
    assert_eq!(
        off.detected, on.detected,
        "equal fault sequences must implicate the same blocks"
    );
    assert!(
        on.recovered > 0,
        "32 trials at 2e-4 must reconstruct at least one block"
    );
    let mean = |c: &campaign::CellResult| c.drops.iter().sum::<f64>() / c.drops.len() as f64;
    assert!(
        mean(on) < mean(off),
        "recovered blocks must strictly reduce the residual: {} vs {}",
        mean(on),
        mean(off)
    );

    // a ledger written for the swept axis refuses a grid without it
    let ledger = temp_ledger("recovery_axis");
    let mut cfg = mk(Some(ledger.clone()));
    cfg.stop_after = Some(1);
    campaign::run(&cfg, &runner()).unwrap();
    let mut other = mk(Some(ledger));
    other.recovery = vec![RecoveryMode::Off];
    other.resume = true;
    let err = campaign::run(&other, &runner()).unwrap_err().to_string();
    assert!(err.contains("fingerprint"), "unexpected error: {err}");
}

#[test]
fn jobs_and_shard_geometry_do_not_change_results() {
    // worker count is an execution knob; shard/worker geometry of the
    // synthetic bank is decode plumbing — neither may leak into results
    let serial = campaign::run(&base_cfg(None, 1), &runner()).unwrap();
    let parallel = campaign::run(&base_cfg(None, 8), &runner()).unwrap();
    assert_eq!(
        serial.canonical_json().to_string(),
        parallel.canonical_json().to_string()
    );
    let other_geometry =
        campaign::run(&base_cfg(None, 2), &SyntheticRunner::new(2048, 7, 4)).unwrap();
    assert_eq!(
        serial.canonical_json().to_string(),
        other_geometry.canonical_json().to_string()
    );
}
