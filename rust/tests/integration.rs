//! Integration tests across modules that do NOT need the PJRT runtime or
//! built artifacts: manifest/weights/dataset loaders against a synthetic
//! artifact directory, memory-bank + strategy + fault-injection flows,
//! the ablation studies' qualitative outcomes, and the coordinator under
//! a mock executor with live fault injection and scrubbing.

use std::path::PathBuf;

use zsecc::coordinator::{BatchPolicy, Server, ServerConfig};
use zsecc::ecc::strategy_by_name;
use zsecc::harness::ablation;
use zsecc::memory::{FaultModel, MemoryBank};
use zsecc::model::{load_weights, EvalSet, Manifest};
use zsecc::quant::{dequantize_into, wot_violations};
use zsecc::util::rng::Rng;

/// Build a synthetic artifact directory: manifest + weights + dataset.
fn synth_artifacts(tag: &str) -> (PathBuf, Vec<i8>) {
    let dir = std::env::temp_dir().join(format!("zsecc_it_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Rng::new(42);
    let n = 512usize;
    let weights: Vec<i8> = (0..n)
        .map(|i| {
            if i % 8 == 7 {
                (rng.below(256) as i64 - 128) as i8
            } else {
                (rng.below(128) as i64 - 64) as i8
            }
        })
        .collect();
    let bytes: Vec<u8> = weights.iter().map(|&w| w as u8).collect();
    std::fs::write(dir.join("m.weights.bin"), &bytes).unwrap();
    std::fs::write(dir.join("m.prewot.bin"), &bytes).unwrap();
    std::fs::write(
        dir.join("m.wot_log.json"),
        r#"{"model":"m","step":[0,1],"n_large":[100,0],"acc_before":[0.5,0.9],
           "acc_after":[0.4,0.9],"final_acc":0.9,"int8_acc":0.9}"#,
    )
    .unwrap();
    let manifest = format!(
        r#"{{"model":"m","num_classes":10,"img_size":32,"input_dim":3072,
          "num_weights":{n},"float_acc":0.91,"int8_acc":0.9,"wot_acc":0.9,
          "batches":[4],"pallas_batch":4,
          "layers":[
            {{"name":"a.w","shape":[256],"offset":0,"size":256,"scale":0.01,"scale_prewot":0.01}},
            {{"name":"b.w","shape":[2,128],"offset":256,"size":256,"scale":0.02,"scale_prewot":0.02}}],
          "files":{{"weights":"m.weights.bin","prewot":"m.prewot.bin",
                   "wot_log":"m.wot_log.json","hlo":{{"4":"m.b4.hlo.txt"}},
                   "hlo_pallas":{{}},"hlo_prewot":{{}}}}}}"#
    );
    std::fs::write(dir.join("m.manifest.json"), manifest).unwrap();
    std::fs::write(dir.join("index.json"), r#"{"models":{"m":"m.manifest.json"}}"#).unwrap();
    // dataset: 8 images of dim 4
    let mut ds = Vec::new();
    ds.extend(8u32.to_le_bytes());
    ds.extend(4u32.to_le_bytes());
    for i in 0..32 {
        ds.extend((i as f32).to_le_bytes());
    }
    ds.extend([0u8, 1, 2, 3, 4, 5, 6, 7]);
    std::fs::write(dir.join("dataset.eval.bin"), ds).unwrap();
    (dir, weights)
}

#[test]
fn manifest_weights_dataset_load_and_agree() {
    let (dir, weights) = synth_artifacts("load");
    let man = Manifest::load_model(&dir, "m").unwrap();
    assert_eq!(man.num_weights, 512);
    assert_eq!(man.layers.len(), 2);
    let w = load_weights(&man.weights_path(), man.num_weights).unwrap();
    assert_eq!(w, weights);
    assert_eq!(wot_violations(&w), 0);
    let ds = EvalSet::load(&dir.join("dataset.eval.bin")).unwrap();
    assert_eq!((ds.n, ds.dim), (8, 4));
    // per-layer dequantization uses each layer's scale
    let mut f = vec![0f32; w.len()];
    dequantize_into(&w, &man.layers, &mut f);
    assert!((f[0] - w[0] as f32 * 0.01).abs() < 1e-7);
    assert!((f[300] - w[300] as f32 * 0.02).abs() < 1e-7);
    let models = zsecc::model::manifest::list_models(&dir).unwrap();
    assert_eq!(models, vec!["m".to_string()]);
}

#[test]
fn wot_log_parses_and_passes_shape_checks() {
    let (dir, _) = synth_artifacts("wotlog");
    let logs = vec![zsecc::harness::fig34::load_log(&dir.join("m.wot_log.json")).unwrap()];
    for (name, ok) in zsecc::harness::fig34::shape_checks(&logs) {
        assert!(ok, "{name}");
    }
}

#[test]
fn end_to_end_memory_protection_flow() {
    // The full Table-2 cell mechanics without PJRT: encode -> inject ->
    // decode -> compare weight corruption across strategies.
    let (_dir, weights) = synth_artifacts("flow");
    let corrupted = |name: &str, rate: f64| -> usize {
        let mut bank = MemoryBank::new(strategy_by_name(name).unwrap(), &weights).unwrap();
        bank.inject(FaultModel::Uniform, rate, 7);
        let mut out = vec![0i8; weights.len()];
        bank.read(&mut out);
        out.iter().zip(&weights).filter(|(a, b)| a != b).count()
    };
    // at 1e-3, protection ordering must hold on raw weight corruption
    let f = corrupted("faulty", 1e-3);
    let e = corrupted("ecc", 1e-3);
    let i = corrupted("in-place", 1e-3);
    assert!(e <= f, "ecc {e} vs faulty {f}");
    assert!(i <= f, "in-place {i} vs faulty {f}");
    // at 1e-4 on 4096 bits we expect ~0 corrupted weights for ecc classes
    assert_eq!(corrupted("ecc", 1e-4), 0);
    assert_eq!(corrupted("in-place", 1e-4), 0);
}

#[test]
fn ablation_qualitative_outcomes() {
    // BCH-16 beats SEC-DED under double-error pressure...
    let rows = ablation::code_strength(&[3e-3], 64 * 64, 3).unwrap();
    assert!(rows[0].bch_err <= rows[0].inplace_err);
    // ...and under 2-bit bursts.
    let b = ablation::burst(&[2], 1e-3, 64 * 64, 3).unwrap();
    assert!(b[0].bch_err <= b[0].inplace_err);
    // scrubbing never hurts
    let s = ablation::scrub_study(&[8], 2e-4, 64 * 32).unwrap();
    assert!(s[0].with_scrub_err <= s[0].without_scrub_err);
}

#[test]
fn loaders_reject_corrupt_artifacts() {
    // Failure injection on the artifact surface: every loader must fail
    // loudly (never panic, never silently truncate).
    let (dir, _) = synth_artifacts("corrupt");
    // truncated weights
    std::fs::write(dir.join("m.weights.bin"), [0u8; 10]).unwrap();
    let man = Manifest::load_model(&dir, "m").unwrap();
    assert!(load_weights(&man.weights_path(), man.num_weights).is_err());
    // manifest with a layer gap
    let text = std::fs::read_to_string(dir.join("m.manifest.json")).unwrap();
    std::fs::write(
        dir.join("m.manifest.json"),
        text.replace("\"offset\":256", "\"offset\":264"),
    )
    .unwrap();
    assert!(Manifest::load_model(&dir, "m").is_err());
    // garbage JSON
    std::fs::write(dir.join("m.manifest.json"), "{not json").unwrap();
    assert!(Manifest::load_model(&dir, "m").is_err());
    // truncated dataset
    std::fs::write(dir.join("dataset.eval.bin"), [9u8; 11]).unwrap();
    assert!(EvalSet::load(&dir.join("dataset.eval.bin")).is_err());
    // missing files
    assert!(Manifest::load_model(&dir, "nope").is_err());
}

#[test]
fn bank_rejects_unthrottled_weights_for_zero_space_codes() {
    let mut w = vec![0i8; 64];
    w[0] = 127; // violates standard WOT
    assert!(MemoryBank::new(strategy_by_name("in-place").unwrap(), &w).is_err());
    assert!(MemoryBank::new(strategy_by_name("bch16").unwrap(), &w).is_err());
    // but out-of-band schemes accept anything
    assert!(MemoryBank::new(strategy_by_name("ecc").unwrap(), &w).is_ok());
    assert!(MemoryBank::new(strategy_by_name("zero").unwrap(), &w).is_ok());
    // and non-block-multiple buffers are rejected by block codes
    let w9 = vec![0i8; 9];
    assert!(MemoryBank::new(strategy_by_name("ecc").unwrap(), &w9).is_err());
}

#[test]
fn coordinator_with_protected_bank_and_live_faults() {
    struct Mock;
    impl zsecc::coordinator::server::BatchExec for Mock {
        fn batch(&self) -> usize {
            4
        }
        fn input_dim(&self) -> usize {
            2
        }
        fn exec(&mut self, images: &[f32], count: usize) -> anyhow::Result<Vec<usize>> {
            Ok((0..count).map(|i| images[i * 2] as usize).collect())
        }
        fn refresh(&mut self, _w: &[f32]) -> anyhow::Result<()> {
            Ok(())
        }
    }
    let (_dir, weights) = synth_artifacts("coord");
    // 512 weights across 4 shards, scrubbed by 2 workers: the serving
    // path's store (built from the whole-buffer bank, no re-encode).
    let bank = MemoryBank::new(strategy_by_name("in-place").unwrap(), &weights)
        .unwrap()
        .into_sharded(4, 2);
    let man = Manifest::load_model(&_dir, "m").unwrap();
    let cfg = ServerConfig {
        strategy: "in-place".into(),
        policy: BatchPolicy {
            max_batch: 4,
            max_wait: std::time::Duration::from_millis(2),
        },
        scrub_interval: Some(std::time::Duration::from_millis(5)),
        fault_rate_per_interval: 1e-4,
        fault_seed: 3,
        shards: 4,
        scrub_workers: 2,
        ..ServerConfig::default()
    };
    let srv = Server::start_with(
        || Ok(Box::new(Mock) as Box<dyn zsecc::coordinator::server::BatchExec>),
        2,
        &cfg,
        Some((bank, man.layers.clone())),
    )
    .unwrap();
    for round in 0..20 {
        let rx = srv.submit(vec![round as f32 % 4.0, 0.0]).unwrap();
        let resp = rx
            .recv_timeout(std::time::Duration::from_secs(5))
            .unwrap();
        assert_eq!(resp.pred, (round % 4) as usize);
        std::thread::sleep(std::time::Duration::from_millis(3));
    }
    let scrubs = srv
        .metrics
        .scrubs
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(scrubs >= 2, "scrub loop must have run (got {scrubs})");
    srv.shutdown();
}
