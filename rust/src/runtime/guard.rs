//! Compute-path protection: ABFT checksummed dense execution and
//! activation range supervision.
//!
//! Everything before this module guards weights *at rest*; a fault that
//! strikes during inference — in an activation buffer or a MAC
//! accumulator — passes through silently. Two classic guards close that
//! gap:
//!
//! * **ABFT matmul** (FT-CNN, Zhao et al., PAPERS.md): row/column
//!   checksums of a dense layer `y[B,C] = x[B,D] · w[D,C]` are computed
//!   over the *staged* (pre-strike) inputs and verified against the
//!   produced outputs after execution. The column check (per output
//!   class, summed over the batch) is the detector; the row check (per
//!   batch row) localizes which rows to recompute, so the
//!   recompute-on-mismatch fallback re-executes only the implicated
//!   rows from the staged inputs. Checksum cost is `O(D·C + B·D)` per
//!   batch against the matmul's `O(B·D·C)` — a `~1/B + 1/C` overhead.
//!   Floating-point reassociation makes exact equality impossible, so
//!   verification uses an error bound derived from the absolute-value
//!   mass of the products ([`DenseLayer::tolerance`]); a corruption
//!   whose effect stays under that bound is below the numerical noise
//!   floor and is not a silent data corruption by construction.
//! * **Activation range supervision** (Geissler et al., PAPERS.md):
//!   per-layer min/max envelopes recorded by a calibration pass over
//!   clean data; at serve time every activation is clamped into its
//!   envelope and each clamp is counted. Bit flips that blow an
//!   exponent land far outside any calibrated envelope, so clamping
//!   converts the large (prediction-flipping) corruptions into bounded,
//!   *counted* events.
//!
//! [`DenseModel`] is the pure-Rust guarded reference executor the
//! campaign's compute-site trials and the guard tests run (the PJRT
//! graph is opaque — faults cannot be injected mid-HLO).
//! [`GuardedExecutable`] wraps a PJRT [`Executable`]: range supervision
//! applies to any model (input + logits envelopes), while end-to-end
//! ABFT applies when the model is a pure linear map (`num_weights ==
//! input_dim · num_classes`), which is the only shape whose checksum
//! relation survives an opaque executable.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::runtime::{Executable, Runtime, WeightsBuf};
use crate::util::json::{arr, num, obj, s, Json};

// ---------------------------------------------------------------- mode --

/// Which guards are armed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GuardMode {
    /// No guards: the execution path is byte-identical to an unguarded
    /// run (pinned by tests).
    Off,
    /// Activation range supervision only.
    Range,
    /// ABFT checksummed matmul only.
    Abft,
    /// Both guards.
    Full,
}

impl GuardMode {
    pub fn abft(self) -> bool {
        matches!(self, GuardMode::Abft | GuardMode::Full)
    }

    pub fn range(self) -> bool {
        matches!(self, GuardMode::Range | GuardMode::Full)
    }

    /// Stable tag — ledger keys, JSON reports, CLI. `parse` accepts
    /// every string `tag` produces.
    pub fn tag(self) -> &'static str {
        match self {
            GuardMode::Off => "off",
            GuardMode::Range => "range",
            GuardMode::Abft => "abft",
            GuardMode::Full => "full",
        }
    }

    pub fn parse(text: &str) -> anyhow::Result<GuardMode> {
        match text {
            "off" => Ok(GuardMode::Off),
            "range" => Ok(GuardMode::Range),
            "abft" => Ok(GuardMode::Abft),
            "full" => Ok(GuardMode::Full),
            _ => anyhow::bail!("unknown guard mode '{text}' (off | range | abft | full)"),
        }
    }
}

// ------------------------------------------------------------ envelope --

/// A calibrated min/max range for one activation buffer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Envelope {
    pub lo: f32,
    pub hi: f32,
}

impl Envelope {
    pub fn new(lo: f32, hi: f32) -> Envelope {
        Envelope { lo, hi }
    }

    /// Inverted bounds that any observation will overwrite.
    pub fn empty() -> Envelope {
        Envelope {
            lo: f32::INFINITY,
            hi: f32::NEG_INFINITY,
        }
    }

    /// Grow the envelope to include `v` (non-finite values ignored —
    /// calibration data is clean by contract, but never poison bounds).
    pub fn observe(&mut self, v: f32) {
        if v.is_finite() {
            self.lo = self.lo.min(v);
            self.hi = self.hi.max(v);
        }
    }

    /// Widen by `margin` of the observed span on each side, so values a
    /// hair outside the calibration sample are not flagged. A
    /// degenerate (single-point) span widens by `margin` absolute.
    pub fn widen(&self, margin: f64) -> Envelope {
        let span = f64::from(self.hi) - f64::from(self.lo);
        let pad = if span > 0.0 { span * margin } else { margin.max(0.0) };
        Envelope {
            lo: (f64::from(self.lo) - pad) as f32,
            hi: (f64::from(self.hi) + pad) as f32,
        }
    }

    pub fn contains(&self, v: f32) -> bool {
        v >= self.lo && v <= self.hi
    }

    /// Clamp every value into the envelope, returning how many were
    /// out of range. Non-finite values always count: NaN and -inf pin
    /// to `lo`, +inf to `hi` — range supervision is also the serve
    /// path's last line of defense against poisoned buffers.
    pub fn clamp_count(&self, xs: &mut [f32]) -> u64 {
        let mut clamped = 0u64;
        for v in xs {
            if v.is_nan() {
                *v = self.lo;
                clamped += 1;
            } else if *v > self.hi {
                *v = self.hi;
                clamped += 1;
            } else if *v < self.lo {
                *v = self.lo;
                clamped += 1;
            }
        }
        clamped
    }
}

/// One named calibrated envelope (layer inputs, or the logits plane).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerEnvelope {
    pub name: String,
    pub env: Envelope,
}

/// The output of a calibration pass: named per-buffer envelopes plus
/// the parameters that produced them. Stored in the model `Manifest`
/// under the optional `guards` key (see `zsecc calibrate`).
#[derive(Clone, Debug, PartialEq)]
pub struct Calibration {
    /// Envelope widening applied at record time (fraction of span).
    pub margin: f64,
    /// Clean batches observed.
    pub batches: usize,
    pub layers: Vec<LayerEnvelope>,
}

impl Calibration {
    pub fn envelope(&self, name: &str) -> Option<Envelope> {
        self.layers.iter().find(|l| l.name == name).map(|l| l.env)
    }

    /// The envelope guarding the model input buffer: `input` when the
    /// calibration came from the serve path, else the first dense
    /// layer's (`layer0`).
    pub fn input_envelope(&self) -> Option<Envelope> {
        self.envelope("input").or_else(|| self.envelope("layer0"))
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("margin", num(self.margin)),
            ("batches", num(self.batches as f64)),
            (
                "layers",
                arr(self.layers.iter().map(|l| {
                    obj(vec![
                        ("name", s(&l.name)),
                        ("lo", num(f64::from(l.env.lo))),
                        ("hi", num(f64::from(l.env.hi))),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Calibration> {
        let margin = v
            .req("margin")?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("guards 'margin' must be a number"))?;
        let batches = v
            .req("batches")?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("guards 'batches' must be a number"))?
            as usize;
        let mut layers = Vec::new();
        for lv in v
            .req("layers")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("guards 'layers' must be an array"))?
        {
            let name = lv
                .req("name")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("guards layer 'name' must be a string"))?
                .to_string();
            let grab = |k: &str| -> anyhow::Result<f32> {
                let x = lv
                    .req(k)?
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("guards layer '{name}' field '{k}' must be a number"))?;
                Ok(x as f32)
            };
            let env = Envelope::new(grab("lo")?, grab("hi")?);
            anyhow::ensure!(
                env.lo.is_finite() && env.hi.is_finite() && env.lo <= env.hi,
                "guards layer '{name}' envelope [{}, {}] is not a finite ordered range",
                env.lo,
                env.hi
            );
            layers.push(LayerEnvelope { name, env });
        }
        anyhow::ensure!(!layers.is_empty(), "guards calibration holds no envelopes");
        Ok(Calibration {
            margin,
            batches,
            layers,
        })
    }
}

// ------------------------------------------------------------ counters --

/// Guard activity of one guarded run (plain counters; campaign trials
/// and tests read these directly).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GuardReport {
    /// ABFT batch verifications performed.
    pub abft_checks: u64,
    /// Rows implicated by a checksum mismatch (detections).
    pub abft_trips: u64,
    /// Rows recomputed from staged inputs (corrections).
    pub recomputes: u64,
    /// Activations clamped back into their envelope.
    pub range_clamps: u64,
}

impl GuardReport {
    pub fn any(&self) -> bool {
        self.abft_trips > 0 || self.range_clamps > 0
    }
}

/// Shared atomic guard counters for the serve path; `Metrics` holds an
/// `Arc` to the same instance the guarded executor bumps.
#[derive(Debug, Default)]
pub struct GuardStats {
    pub abft_checks: AtomicU64,
    pub abft_trips: AtomicU64,
    pub recomputes: AtomicU64,
    pub range_clamps: AtomicU64,
}

impl GuardStats {
    pub fn absorb(&self, r: &GuardReport) {
        self.abft_checks.fetch_add(r.abft_checks, Ordering::Relaxed);
        self.abft_trips.fetch_add(r.abft_trips, Ordering::Relaxed);
        self.recomputes.fetch_add(r.recomputes, Ordering::Relaxed);
        self.range_clamps.fetch_add(r.range_clamps, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> GuardReport {
        GuardReport {
            abft_checks: self.abft_checks.load(Ordering::Relaxed),
            abft_trips: self.abft_trips.load(Ordering::Relaxed),
            recomputes: self.recomputes.load(Ordering::Relaxed),
            range_clamps: self.range_clamps.load(Ordering::Relaxed),
        }
    }
}

// -------------------------------------------------------------- faults --

/// One transient compute-path bit flip: `bit` of element `index` of
/// `layer`'s targeted buffer (activations or accumulators, chosen by
/// which [`ComputeFaults`] list carries it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ComputeFault {
    pub layer: usize,
    pub index: usize,
    pub bit: u32,
}

/// Transient faults to strike during a guarded forward pass.
/// Activation faults hit the staged input buffer *after* ABFT
/// checksums are taken (an SEU on the buffer feeding the MACs);
/// accumulator faults hit the output plane after the MACs run. Both
/// model transient strikes: a recompute from the staged inputs is
/// clean.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ComputeFaults {
    pub activations: Vec<ComputeFault>,
    pub accumulators: Vec<ComputeFault>,
}

impl ComputeFaults {
    pub fn is_empty(&self) -> bool {
        self.activations.is_empty() && self.accumulators.is_empty()
    }
}

fn apply_faults(faults: &[ComputeFault], layer: usize, buf: &mut [f32]) {
    for f in faults {
        if f.layer == layer && f.index < buf.len() {
            let bits = buf[f.index].to_bits() ^ (1u32 << (f.bit & 31));
            buf[f.index] = f32::from_bits(bits);
        }
    }
}

// --------------------------------------------------------- dense layer --

/// One dense layer `y[B,C] = x[B,D] · w[D,C]` with precomputed checksum
/// weights: `wrow[d] = Σ_c w[d,c]` folds a whole output row into one
/// scalar for the row check, `wabs[d] = Σ_c |w[d,c]|` bounds its
/// rounding mass for the tolerance.
#[derive(Clone, Debug)]
pub struct DenseLayer {
    pub rows: usize,
    pub cols: usize,
    w: Vec<f32>,
    wrow: Vec<f64>,
    wabs: Vec<f64>,
}

impl DenseLayer {
    pub fn new(w: Vec<f32>, rows: usize, cols: usize) -> anyhow::Result<DenseLayer> {
        anyhow::ensure!(
            rows > 0 && cols > 0 && w.len() == rows * cols,
            "dense layer wants {rows}x{cols} = {} weights, got {}",
            rows * cols,
            w.len()
        );
        anyhow::ensure!(
            w.iter().all(|v| v.is_finite()),
            "dense layer weights must be finite"
        );
        let mut wrow = vec![0f64; rows];
        let mut wabs = vec![0f64; rows];
        for d in 0..rows {
            for c in 0..cols {
                let wv = f64::from(w[d * cols + c]);
                wrow[d] += wv;
                wabs[d] += wv.abs();
            }
        }
        Ok(DenseLayer {
            rows,
            cols,
            w,
            wrow,
            wabs,
        })
    }

    pub fn weights(&self) -> &[f32] {
        &self.w
    }

    /// One output row — the unit both the full matmul and the
    /// recompute fallback go through, so a recomputed row is bitwise
    /// identical to a cleanly computed one.
    fn matmul_row(&self, xr: &[f32], yr: &mut [f32]) {
        yr.fill(0.0);
        for (d, &xv) in xr.iter().enumerate() {
            let wr = &self.w[d * self.cols..(d + 1) * self.cols];
            for (c, &wv) in wr.iter().enumerate() {
                yr[c] += xv * wv;
            }
        }
    }

    /// Plain (unguarded) batch matmul.
    pub fn matmul(&self, x: &[f32], batch: usize, y: &mut [f32]) {
        debug_assert_eq!(x.len(), batch * self.rows);
        debug_assert_eq!(y.len(), batch * self.cols);
        for b in 0..batch {
            self.matmul_row(
                &x[b * self.rows..(b + 1) * self.rows],
                &mut y[b * self.cols..(b + 1) * self.cols],
            );
        }
    }

    /// Verification bound for a checksum whose products carry the given
    /// absolute-value `mass`: each f32 MAC contributes up to one ulp of
    /// its running sum, `terms` partial sums stack, and a safety factor
    /// absorbs the f64 reference's own (much smaller) rounding.
    pub fn tolerance(&self, mass: f64, batch: usize) -> f64 {
        let terms = (self.rows + batch) as f64;
        1e-9 + mass * terms * f64::from(f32::EPSILON) * 8.0
    }

    /// ABFT verify: compare `y` (claimed `x_staged · w`) against f64
    /// row/column checksums of the *staged* inputs. Returns the batch
    /// rows implicated by a mismatch — empty means verified. The column
    /// check detects (it sees every output element exactly once); the
    /// row check localizes; a column trip that no row localizes (e.g. a
    /// corruption whose row-sum cancels against `wrow ≈ 0`) implicates
    /// the whole batch.
    pub fn verify(&self, x_staged: &[f32], batch: usize, y: &[f32]) -> Vec<usize> {
        debug_assert_eq!(x_staged.len(), batch * self.rows);
        debug_assert_eq!(y.len(), batch * self.cols);
        let mut colsum = vec![0f64; self.rows];
        let mut colabs = vec![0f64; self.rows];
        for b in 0..batch {
            let xr = &x_staged[b * self.rows..(b + 1) * self.rows];
            for (d, &xv) in xr.iter().enumerate() {
                let xv = f64::from(xv);
                colsum[d] += xv;
                colabs[d] += xv.abs();
            }
        }
        let mut col_trip = false;
        for c in 0..self.cols {
            let mut chk = 0f64;
            let mut mass = 0f64;
            for d in 0..self.rows {
                let wv = f64::from(self.w[d * self.cols + c]);
                chk += colsum[d] * wv;
                mass += colabs[d] * wv.abs();
            }
            let mut ysum = 0f64;
            for b in 0..batch {
                ysum += f64::from(y[b * self.cols + c]);
            }
            if !ysum.is_finite() || (ysum - chk).abs() > self.tolerance(mass, batch) {
                col_trip = true;
                break;
            }
        }
        let mut suspects = Vec::new();
        for b in 0..batch {
            let xr = &x_staged[b * self.rows..(b + 1) * self.rows];
            let mut chk = 0f64;
            let mut mass = 0f64;
            for (d, &xv) in xr.iter().enumerate() {
                let xv = f64::from(xv);
                chk += xv * self.wrow[d];
                mass += xv.abs() * self.wabs[d];
            }
            let mut ysum = 0f64;
            for c in 0..self.cols {
                ysum += f64::from(y[b * self.cols + c]);
            }
            if !ysum.is_finite() || (ysum - chk).abs() > self.tolerance(mass, self.cols) {
                suspects.push(b);
            }
        }
        if col_trip && suspects.is_empty() {
            // detected but not localized: recompute everything
            return (0..batch).collect();
        }
        suspects
    }
}

// --------------------------------------------------------- dense model --

/// A pure-Rust dense network (matmul layers, ReLU between them) with
/// both guards wired through [`DenseModel::forward_guarded`]. This is
/// the reference compute path the campaign's `activations` /
/// `accumulators` fault sites execute.
#[derive(Clone, Debug)]
pub struct DenseModel {
    pub layers: Vec<DenseLayer>,
    /// Per-layer *input* envelopes; empty until [`DenseModel::calibrate`]
    /// or [`DenseModel::set_envelopes`].
    envs: Vec<Envelope>,
}

impl DenseModel {
    pub fn new(layers: Vec<DenseLayer>) -> anyhow::Result<DenseModel> {
        anyhow::ensure!(!layers.is_empty(), "dense model wants at least one layer");
        for pair in layers.windows(2) {
            anyhow::ensure!(
                pair[0].cols == pair[1].rows,
                "layer shapes do not chain: {}x{} -> {}x{}",
                pair[0].rows,
                pair[0].cols,
                pair[1].rows,
                pair[1].cols
            );
        }
        Ok(DenseModel {
            layers,
            envs: Vec::new(),
        })
    }

    /// Build from one flat weight buffer split by `(rows, cols)` dims.
    pub fn from_flat(w: &[f32], dims: &[(usize, usize)]) -> anyhow::Result<DenseModel> {
        let want: usize = dims.iter().map(|&(r, c)| r * c).sum();
        anyhow::ensure!(
            w.len() == want,
            "flat weights hold {} values, dims want {want}",
            w.len()
        );
        let mut layers = Vec::with_capacity(dims.len());
        let mut at = 0;
        for &(r, c) in dims {
            layers.push(DenseLayer::new(w[at..at + r * c].to_vec(), r, c)?);
            at += r * c;
        }
        DenseModel::new(layers)
    }

    pub fn input_dim(&self) -> usize {
        self.layers[0].rows
    }

    pub fn output_dim(&self) -> usize {
        self.layers[self.layers.len() - 1].cols
    }

    /// Elements of the buffer a fault site targets at `layer`:
    /// activations strike the layer's input plane, accumulators its
    /// output plane.
    pub fn activation_elems(&self, layer: usize, batch: usize) -> usize {
        batch * self.layers[layer].rows
    }

    pub fn accumulator_elems(&self, layer: usize, batch: usize) -> usize {
        batch * self.layers[layer].cols
    }

    /// Plain forward pass — the unguarded reference.
    pub fn forward(&self, x: &[f32], batch: usize) -> Vec<f32> {
        self.forward_guarded(
            x,
            batch,
            GuardMode::Off,
            &ComputeFaults::default(),
            &mut GuardReport::default(),
        )
    }

    /// Record per-layer input envelopes (plus the logits plane) from
    /// one clean batch, widen by `margin`, and arm them on the model.
    pub fn calibrate(&mut self, x: &[f32], batch: usize, margin: f64) -> Calibration {
        let mut named = Vec::with_capacity(self.layers.len() + 1);
        let mut envs = Vec::with_capacity(self.layers.len());
        let mut act = x.to_vec();
        for (l, layer) in self.layers.iter().enumerate() {
            let mut env = Envelope::empty();
            act.iter().for_each(|&v| env.observe(v));
            let env = env.widen(margin);
            envs.push(env);
            named.push(LayerEnvelope {
                name: format!("layer{l}"),
                env,
            });
            let mut y = vec![0f32; batch * layer.cols];
            layer.matmul(&act, batch, &mut y);
            if l + 1 < self.layers.len() {
                y.iter_mut().for_each(|v| *v = v.max(0.0));
            }
            act = y;
        }
        let mut logits = Envelope::empty();
        act.iter().for_each(|&v| logits.observe(v));
        named.push(LayerEnvelope {
            name: "logits".to_string(),
            env: logits.widen(margin),
        });
        self.envs = envs;
        Calibration {
            margin,
            batches: 1,
            layers: named,
        }
    }

    /// Arm previously recorded envelopes (e.g. loaded from a manifest).
    pub fn set_envelopes(&mut self, calib: &Calibration) -> anyhow::Result<()> {
        let mut envs = Vec::with_capacity(self.layers.len());
        for l in 0..self.layers.len() {
            let name = format!("layer{l}");
            envs.push(
                calib
                    .envelope(&name)
                    .ok_or_else(|| anyhow::anyhow!("calibration misses envelope '{name}'"))?,
            );
        }
        self.envs = envs;
        Ok(())
    }

    /// Guarded forward pass. Per layer: stage the input, take ABFT
    /// checksums of the staged (clean) buffer, strike the transient
    /// activation faults, range-clamp the execution buffer, run the
    /// matmul, strike the accumulator faults, then ABFT-verify and
    /// recompute implicated rows from the staged inputs. With
    /// `GuardMode::Off` and no faults this is exactly the plain matmul
    /// chain — bitwise identical outputs (pinned by tests).
    pub fn forward_guarded(
        &self,
        x: &[f32],
        batch: usize,
        mode: GuardMode,
        faults: &ComputeFaults,
        report: &mut GuardReport,
    ) -> Vec<f32> {
        assert_eq!(x.len(), batch * self.input_dim(), "input shape mismatch");
        let range = mode.range() && !self.envs.is_empty();
        let mut staged = x.to_vec();
        let mut y = Vec::new();
        for (l, layer) in self.layers.iter().enumerate() {
            let mut exec = staged.clone();
            apply_faults(&faults.activations, l, &mut exec);
            if range {
                report.range_clamps += self.envs[l].clamp_count(&mut exec);
            }
            y = vec![0f32; batch * layer.cols];
            layer.matmul(&exec, batch, &mut y);
            apply_faults(&faults.accumulators, l, &mut y);
            if mode.abft() {
                report.abft_checks += 1;
                let suspects = layer.verify(&staged, batch, &y);
                report.abft_trips += suspects.len() as u64;
                for b in suspects {
                    layer.matmul_row(
                        &staged[b * layer.rows..(b + 1) * layer.rows],
                        &mut y[b * layer.cols..(b + 1) * layer.cols],
                    );
                    report.recomputes += 1;
                }
            }
            if l + 1 < self.layers.len() {
                y.iter_mut().for_each(|v| *v = v.max(0.0));
            }
            staged = std::mem::take(&mut y);
        }
        staged
    }
}

/// Relative L1 residual between a (possibly corrupted) output and its
/// clean reference, in percent — the campaign's silent-data-corruption
/// rate for compute-site trials. Magnitude-weighted on purpose: range
/// clamping shrinks every out-of-envelope error toward the reference,
/// so the residual strictly drops whenever a clamp fires, which a
/// mismatch *count* would not show.
pub fn residual_pp(y: &[f32], reference: &[f32]) -> f64 {
    debug_assert_eq!(y.len(), reference.len());
    let mut err = 0f64;
    let mut mag = 0f64;
    for (a, r) in y.iter().zip(reference) {
        let d = f64::from(*a) - f64::from(*r);
        err += if d.is_finite() { d.abs() } else { f64::from(f32::MAX) };
        mag += f64::from(*r).abs();
    }
    100.0 * err / mag.max(1e-12)
}

// ---------------------------------------------------- PJRT integration --

/// A PJRT [`Executable`] behind both guards. Range supervision clamps
/// the input batch into the calibrated `input` envelope before upload
/// and the returned logits into the `logits` envelope after; ABFT
/// verifies the logits against f64 checksums of the host weight matrix
/// and re-runs the batch once on a mismatch (transient faults don't
/// repeat; a persistent mismatch is surfaced as trips with no matching
/// recompute credit). ABFT requires the model to be a pure linear map —
/// `num_weights == input_dim · num_classes` — because an opaque
/// executable only preserves the checksum relation end-to-end when the
/// whole model *is* the matmul; `new` refuses anything else.
pub struct GuardedExecutable {
    pub exe: Executable,
    mode: GuardMode,
    input_env: Option<Envelope>,
    logit_env: Option<Envelope>,
    head: Option<DenseLayer>,
    stats: Arc<GuardStats>,
}

impl GuardedExecutable {
    pub fn new(
        exe: Executable,
        mode: GuardMode,
        calib: Option<&Calibration>,
        host_weights: Option<&[f32]>,
    ) -> anyhow::Result<GuardedExecutable> {
        let (input_env, logit_env) = if mode.range() {
            let calib = calib.ok_or_else(|| {
                anyhow::anyhow!(
                    "guard mode '{}' needs a calibration (run `zsecc calibrate` first)",
                    mode.tag()
                )
            })?;
            let input = calib.input_envelope().ok_or_else(|| {
                anyhow::anyhow!("calibration has no input envelope ('input' or 'layer0')")
            })?;
            (Some(input), calib.envelope("logits"))
        } else {
            (None, None)
        };
        let head = if mode.abft() {
            let w = host_weights
                .ok_or_else(|| anyhow::anyhow!("ABFT guard needs the host weight buffer"))?;
            anyhow::ensure!(
                exe.num_weights == exe.input_dim * exe.num_classes,
                "ABFT over an opaque executable needs a pure linear model \
                 ({}x{} = {} weights, manifest has {}) — use guard mode 'range'",
                exe.input_dim,
                exe.num_classes,
                exe.input_dim * exe.num_classes,
                exe.num_weights
            );
            Some(DenseLayer::new(
                w.to_vec(),
                exe.input_dim,
                exe.num_classes,
            )?)
        } else {
            None
        };
        Ok(GuardedExecutable {
            exe,
            mode,
            input_env,
            logit_env,
            head,
            stats: Arc::new(GuardStats::default()),
        })
    }

    /// The atomic counters this executable bumps — share with `Metrics`.
    pub fn stats(&self) -> Arc<GuardStats> {
        Arc::clone(&self.stats)
    }

    pub fn mode(&self) -> GuardMode {
        self.mode
    }

    /// Run one guarded batch; returns logits like [`Executable::run`].
    /// `GuardMode::Off` delegates untouched.
    pub fn run(
        &self,
        rt: &Runtime,
        weights: &WeightsBuf,
        images: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        if self.mode == GuardMode::Off {
            return self.exe.run(rt, weights, images);
        }
        let mut report = GuardReport::default();
        let mut staged = images.to_vec();
        if let Some(env) = self.input_env {
            report.range_clamps += env.clamp_count(&mut staged);
        }
        let mut logits = self.exe.run(rt, weights, &staged)?;
        if let Some(head) = &self.head {
            report.abft_checks += 1;
            let suspects = head.verify(&staged, self.exe.batch, &logits);
            if !suspects.is_empty() {
                report.abft_trips += suspects.len() as u64;
                logits = self.exe.run(rt, weights, &staged)?;
                if head.verify(&staged, self.exe.batch, &logits).is_empty() {
                    report.recomputes += suspects.len() as u64;
                }
            }
        }
        if let Some(env) = self.logit_env {
            report.range_clamps += env.clamp_count(&mut logits);
        }
        self.stats.absorb(&report);
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n)
            .map(|_| lo + (hi - lo) * rng.f64() as f32)
            .collect()
    }

    /// A layer whose weights are bounded away from zero, so any
    /// meaningful input corruption has a meaningful output effect.
    fn test_layer(rng: &mut Rng, rows: usize, cols: usize) -> DenseLayer {
        let w: Vec<f32> = (0..rows * cols)
            .map(|_| {
                let v = 0.25 + 0.75 * rng.f64() as f32;
                if rng.f64() < 0.5 {
                    -v
                } else {
                    v
                }
            })
            .collect();
        DenseLayer::new(w, rows, cols).unwrap()
    }

    fn test_model(rng: &mut Rng, dims: &[(usize, usize)]) -> DenseModel {
        DenseModel::new(dims.iter().map(|&(r, c)| test_layer(rng, r, c)).collect()).unwrap()
    }

    #[test]
    fn guard_mode_tags_roundtrip() {
        for m in [
            GuardMode::Off,
            GuardMode::Range,
            GuardMode::Abft,
            GuardMode::Full,
        ] {
            assert_eq!(GuardMode::parse(m.tag()).unwrap(), m);
        }
        assert!(GuardMode::parse("on").is_err());
        assert!(!GuardMode::Off.abft() && !GuardMode::Off.range());
        assert!(GuardMode::Full.abft() && GuardMode::Full.range());
    }

    #[test]
    fn envelope_clamp_counts_exactly_the_out_of_range_values() {
        let env = Envelope::new(-1.0, 1.0);
        let mut xs = vec![0.0, -1.0, 1.0, 1.5, -2.0, f32::NAN, f32::INFINITY, 0.25];
        let clamped = env.clamp_count(&mut xs);
        assert_eq!(clamped, 4, "1.5, -2.0, NaN and inf are out of range");
        assert_eq!(xs, vec![0.0, -1.0, 1.0, 1.0, -1.0, -1.0, 1.0, 0.25]);
    }

    #[test]
    fn envelope_widen_handles_degenerate_spans() {
        let mut e = Envelope::empty();
        e.observe(2.0);
        let w = e.widen(0.1);
        assert!(w.lo < 2.0 && w.hi > 2.0, "point span still widens");
        let mut e = Envelope::empty();
        e.observe(0.0);
        e.observe(10.0);
        let w = e.widen(0.05);
        assert_eq!((w.lo, w.hi), (-0.5, 10.5));
    }

    #[test]
    fn calibration_json_roundtrips() {
        let calib = Calibration {
            margin: 0.05,
            batches: 4,
            layers: vec![
                LayerEnvelope {
                    name: "layer0".into(),
                    env: Envelope::new(-0.5, 1.5),
                },
                LayerEnvelope {
                    name: "logits".into(),
                    env: Envelope::new(-12.0, 9.0),
                },
            ],
        };
        let back = Calibration::from_json(&calib.to_json()).unwrap();
        assert_eq!(back, calib);
        assert_eq!(back.input_envelope(), Some(Envelope::new(-0.5, 1.5)));
        // malformed envelopes are refused
        let bad = Json::parse(
            r#"{"margin":0.1,"batches":1,"layers":[{"name":"layer0","lo":2.0,"hi":1.0}]}"#,
        )
        .unwrap();
        assert!(Calibration::from_json(&bad).is_err());
    }

    #[test]
    fn guards_off_is_bitwise_identical_to_plain_matmul() {
        let mut rng = Rng::new(7);
        let model = test_model(&mut rng, &[(24, 16), (16, 10)]);
        let x = rand_vec(&mut rng, 5 * 24, -1.0, 1.0);
        let plain = model.forward(&x, 5);
        let mut report = GuardReport::default();
        let off = model.forward_guarded(&x, 5, GuardMode::Off, &ComputeFaults::default(), &mut report);
        assert_eq!(report, GuardReport::default(), "off mode counts nothing");
        let a: Vec<u32> = plain.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = off.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "guards off must not perturb a single bit");
    }

    #[test]
    fn clean_runs_never_trip_abft() {
        let mut rng = Rng::new(11);
        let model = test_model(&mut rng, &[(32, 24), (24, 8)]);
        for batch in [1usize, 4, 9] {
            let x = rand_vec(&mut rng, batch * 32, -2.0, 2.0);
            let mut report = GuardReport::default();
            let y = model.forward_guarded(
                &x,
                batch,
                GuardMode::Abft,
                &ComputeFaults::default(),
                &mut report,
            );
            assert_eq!(report.abft_trips, 0, "false positive at batch {batch}");
            assert_eq!(report.abft_checks, 2);
            let bits_ref: Vec<u32> = model.forward(&x, batch).iter().map(|v| v.to_bits()).collect();
            let bits: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits, bits_ref);
        }
    }

    /// The satellite contract: every single-flip into matmul inputs or
    /// accumulators is either detected by the ABFT verify or its whole
    /// output effect is below the checksum tolerance (numerical noise,
    /// not an SDC). Exponent-bit flips — the prediction flippers — are
    /// always detected.
    #[test]
    fn abft_catches_every_meaningful_single_flip() {
        let mut rng = Rng::new(23);
        let (d, c) = (16usize, 8usize);
        let model = test_model(&mut rng, &[(d, c)]);
        let layer = &model.layers[0];
        // exec-shaped batches around a nominal exec width of 4
        for batch in [1usize, 4, 5] {
            let x = rand_vec(&mut rng, batch * d, 0.1, 1.0);
            let clean = model.forward(&x, batch);
            // an undetected fault is under every per-column tolerance,
            // so its total output effect is under the sum of them
            let mass: f64 = x.iter().map(|v| f64::from(v.abs())).sum();
            let noise_floor = c as f64 * layer.tolerance(mass, batch + c);
            for site in 0..2 {
                let elems = if site == 0 { batch * d } else { batch * c };
                for index in 0..elems {
                    for bit in 0..32u32 {
                        let fault = ComputeFault {
                            layer: 0,
                            index,
                            bit,
                        };
                        let faults = if site == 0 {
                            ComputeFaults {
                                activations: vec![fault],
                                ..Default::default()
                            }
                        } else {
                            ComputeFaults {
                                accumulators: vec![fault],
                                ..Default::default()
                            }
                        };
                        let mut off = GuardReport::default();
                        let corrupted =
                            model.forward_guarded(&x, batch, GuardMode::Off, &faults, &mut off);
                        let effect: f64 = corrupted
                            .iter()
                            .zip(&clean)
                            .map(|(a, b)| {
                                let e = f64::from(*a) - f64::from(*b);
                                if e.is_finite() {
                                    e.abs()
                                } else {
                                    f64::INFINITY
                                }
                            })
                            .sum();
                        let mut report = GuardReport::default();
                        let guarded =
                            model.forward_guarded(&x, batch, GuardMode::Abft, &faults, &mut report);
                        if report.abft_trips > 0 {
                            // detected -> recompute restores the clean bits
                            assert_eq!(report.recomputes, report.abft_trips);
                            let a: Vec<u32> = guarded.iter().map(|v| v.to_bits()).collect();
                            let b: Vec<u32> = clean.iter().map(|v| v.to_bits()).collect();
                            assert_eq!(a, b, "recompute must restore batch {batch} exactly");
                        } else {
                            assert!(
                                effect <= noise_floor,
                                "undetected flip site={site} index={index} bit={bit} \
                                 batch={batch} has effect {effect:e} above noise {noise_floor:e}"
                            );
                        }
                        // exponent flips of non-tiny values never escape
                        if bit >= 23 && bit < 31 && effect > noise_floor {
                            assert!(
                                report.abft_trips > 0,
                                "exponent flip escaped: site={site} index={index} bit={bit}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn range_guard_clamps_every_out_of_envelope_activation() {
        let mut rng = Rng::new(31);
        let mut model = test_model(&mut rng, &[(16, 8)]);
        let batch = 4usize;
        let x = rand_vec(&mut rng, batch * 16, 0.0, 1.0);
        model.calibrate(&x, batch, 0.05);
        // flip the top exponent bit of k distinct activations: values in
        // (0, 2) jump far outside the [0,1]-ish envelope
        let k = 7usize;
        let faults = ComputeFaults {
            activations: (0..k)
                .map(|i| ComputeFault {
                    layer: 0,
                    index: i * 3,
                    bit: 30,
                })
                .collect(),
            ..Default::default()
        };
        let mut report = GuardReport::default();
        let y = model.forward_guarded(&x, batch, GuardMode::Range, &faults, &mut report);
        assert_eq!(
            report.range_clamps, k as u64,
            "clamp count must equal the injected out-of-envelope activations"
        );
        let clean = model.forward(&x, batch);
        let mut off = GuardReport::default();
        let unguarded = model.forward_guarded(&x, batch, GuardMode::Off, &faults, &mut off);
        assert!(
            residual_pp(&y, &clean) < residual_pp(&unguarded, &clean),
            "clamping must strictly shrink the residual"
        );
        // in-envelope flips (low mantissa bits of values in [0,1)) do
        // not count as clamps
        let benign = ComputeFaults {
            activations: vec![ComputeFault {
                layer: 0,
                index: 1,
                bit: 2,
            }],
            ..Default::default()
        };
        let mut report = GuardReport::default();
        model.forward_guarded(&x, batch, GuardMode::Range, &benign, &mut report);
        assert_eq!(report.range_clamps, 0);
    }

    #[test]
    fn full_mode_recovers_transient_faults_exactly() {
        let mut rng = Rng::new(41);
        let mut model = test_model(&mut rng, &[(24, 12), (12, 6)]);
        let batch = 4usize;
        let x = rand_vec(&mut rng, batch * 24, 0.0, 1.0);
        model.calibrate(&x, batch, 0.05);
        let clean = model.forward(&x, batch);
        let faults = ComputeFaults {
            activations: vec![
                ComputeFault {
                    layer: 0,
                    index: 5,
                    bit: 30,
                },
                ComputeFault {
                    layer: 1,
                    index: 3,
                    bit: 28,
                },
            ],
            accumulators: vec![ComputeFault {
                layer: 1,
                index: 2,
                bit: 29,
            }],
        };
        let mut report = GuardReport::default();
        let y = model.forward_guarded(&x, batch, GuardMode::Full, &faults, &mut report);
        assert!(report.abft_trips > 0);
        let a: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = clean.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "transient faults must be fully recomputed away");
    }

    #[test]
    fn guard_stats_absorb_and_snapshot() {
        let stats = GuardStats::default();
        stats.absorb(&GuardReport {
            abft_checks: 3,
            abft_trips: 1,
            recomputes: 1,
            range_clamps: 7,
        });
        stats.absorb(&GuardReport {
            abft_checks: 1,
            abft_trips: 0,
            recomputes: 0,
            range_clamps: 2,
        });
        assert_eq!(
            stats.snapshot(),
            GuardReport {
                abft_checks: 4,
                abft_trips: 1,
                recomputes: 1,
                range_clamps: 9,
            }
        );
    }

    #[test]
    fn residual_metric_is_zero_only_on_match() {
        let r = vec![1.0f32, -2.0, 3.0];
        assert_eq!(residual_pp(&r, &r), 0.0);
        let y = vec![1.0f32, -2.5, 3.0];
        assert!(residual_pp(&y, &r) > 0.0);
    }
}
