//! PJRT runtime: load AOT-compiled HLO text, compile on the CPU client,
//! execute inference batches from the L3 hot path.
//!
//! Interchange is HLO *text* (see DESIGN.md section 3); weights arrive
//! as one flat dequantized f32 buffer that is uploaded to the device
//! once per scrub epoch (`bind_weights`) and shared across all batches
//! executed against it — the request path uploads only images.
//!
//! [`guard`] adds the optional compute-path protection layer: ABFT
//! checksummed dense execution with recompute-on-mismatch and
//! activation range supervision ([`GuardedExecutable`] wraps an
//! [`Executable`]; `guard::DenseModel` is the pure-Rust guarded
//! reference path the campaign's compute fault sites run).

use std::path::Path;
use std::sync::Arc;

use crate::model::{EvalSet, Manifest};

pub mod guard;

pub use guard::{GuardMode, GuardReport, GuardStats, GuardedExecutable};

/// Shared PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// A compiled inference executable for one (model, batch) pair:
/// `(weights f32[P], images f32[B, D]) -> (logits f32[B, C],)`.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub input_dim: usize,
    pub num_classes: usize,
    pub num_weights: usize,
}

/// Device-resident weights, reusable across batches.
pub struct WeightsBuf {
    buf: xla::PjRtBuffer,
}

impl Runtime {
    pub fn cpu() -> anyhow::Result<Arc<Runtime>> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Arc::new(Runtime { client }))
    }

    /// Load + compile an HLO text artifact.
    pub fn load(
        &self,
        path: &Path,
        batch: usize,
        man: &Manifest,
    ) -> anyhow::Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(Executable {
            exe,
            batch,
            input_dim: man.input_dim,
            num_classes: man.num_classes,
            num_weights: man.num_weights,
        })
    }

    /// Convenience: the standard ("fast") executable for a batch size.
    pub fn load_model(&self, man: &Manifest, batch: usize) -> anyhow::Result<Executable> {
        self.load(&man.hlo_path(batch)?, batch, man)
    }

    /// Upload a flat f32 weight buffer to the device.
    pub fn bind_weights(&self, weights: &[f32]) -> anyhow::Result<WeightsBuf> {
        let buf = self
            .client
            .buffer_from_host_buffer(weights, &[weights.len()], None)
            .map_err(|e| anyhow::anyhow!("uploading weights: {e:?}"))?;
        Ok(WeightsBuf { buf })
    }

    /// Upload an image batch (flat, batch * dim elements).
    fn bind_images(
        &self,
        images: &[f32],
        batch: usize,
        dim: usize,
    ) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(images, &[batch, dim], None)
            .map_err(|e| anyhow::anyhow!("uploading images: {e:?}"))
    }
}

impl Executable {
    /// Run one batch; returns logits, row-major batch x num_classes.
    pub fn run(
        &self,
        rt: &Runtime,
        weights: &WeightsBuf,
        images: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            images.len() == self.batch * self.input_dim,
            "expected {}x{} image elements, got {}",
            self.batch,
            self.input_dim,
            images.len()
        );
        let img_buf = rt.bind_images(images, self.batch, self.input_dim)?;
        let out = self
            .exe
            .execute_b(&[&weights.buf, &img_buf])
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        // The AOT path lowers with return_tuple=True: unwrap the 1-tuple.
        let logits = lit
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        logits
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("logits to_vec: {e:?}"))
    }

    /// Argmax predictions for one batch.
    pub fn predict(
        &self,
        rt: &Runtime,
        weights: &WeightsBuf,
        images: &[f32],
    ) -> anyhow::Result<Vec<usize>> {
        let logits = self.run(rt, weights, images)?;
        Ok(argmax_rows(&logits, self.num_classes))
    }
}

/// Row-wise argmax over a flat logits buffer.
pub fn argmax_rows(logits: &[f32], classes: usize) -> Vec<usize> {
    logits
        .chunks_exact(classes)
        .map(|row| {
            let mut best = 0;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            best
        })
        .collect()
}

/// Accuracy of an executable over the whole eval set (ragged tail padded
/// with copies of an in-range image; pad rows are not scored).
pub fn accuracy(
    rt: &Runtime,
    exe: &Executable,
    weights: &WeightsBuf,
    ds: &EvalSet,
) -> anyhow::Result<f64> {
    let b = exe.batch;
    let mut correct = 0usize;
    let mut at = 0usize;
    let mut padded = vec![0f32; b * exe.input_dim];
    while at < ds.n {
        let take = b.min(ds.n - at);
        let preds = if take == b {
            exe.predict(rt, weights, ds.batch(at, b))?
        } else {
            padded[..take * exe.input_dim].copy_from_slice(ds.batch(at, take));
            for i in take..b {
                let src = ds.image(at);
                padded[i * exe.input_dim..(i + 1) * exe.input_dim].copy_from_slice(src);
            }
            exe.predict(rt, weights, &padded)?
        };
        for i in 0..take {
            if preds[i] == ds.labels[at + i] as usize {
                correct += 1;
            }
        }
        at += take;
    }
    Ok(correct as f64 / ds.n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_rows_basic() {
        let l = [0.1, 0.9, 0.0, 3.0, -1.0, 2.0];
        assert_eq!(argmax_rows(&l, 3), vec![1, 0]);
    }

    #[test]
    fn argmax_rows_ties_pick_first() {
        let l = [1.0, 1.0, 0.5, 0.5];
        assert_eq!(argmax_rows(&l, 2), vec![0, 0]);
    }
}
