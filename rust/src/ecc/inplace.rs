//! In-place zero-space ECC (paper section 4.2, Fig. 2).
//!
//! Storage layout of one protected 64-bit block (8 int8 weights, WOT
//! constraint: weights 0..6 in [-64, 63], weight 7 unconstrained):
//!
//! ```text
//!   byte i (i < 7):  [ s v v v v v v c ]   bit7..bit0
//!                      |             `-- bit6 := check bit i  (in-place)
//!                      `---------------- sign bit (informative)
//!   byte 7:          all 8 bits informative (the free byte)
//! ```
//!
//! Because a two's-complement value in [-64, 63] has bit6 == bit7, bit6
//! is non-informative: the encoder overwrites it with a check bit of the
//! (64, 57) Hsiao code, and the decoder — after standard SEC-DED
//! correction over the stored 64 bits — restores it with a sign-bit copy
//! (the "additional wiring" of the paper's Fig. 2 hardware sketch).

use super::hsiao::Outcome;
use super::secded::code_6457_inplace;

/// Number of weights per protected block.
pub const BLOCK: usize = 8;
/// Small-weight range the WOT constraint enforces on bytes 0..6.
pub const SMALL_LO: i8 = -64;
pub const SMALL_HI: i8 = 63;

/// Does this value fit the small-weight range (bit6 non-informative)?
#[inline]
pub fn is_small(w: i8) -> bool {
    (SMALL_LO..=SMALL_HI).contains(&w)
}

/// Branch-free constraint check for one 64-bit block: a byte is small
/// iff bit6 == bit7; `w ^ (w << 1)` puts that disagreement at bit7 of
/// each byte, masked to bytes 0..6. Zero iff the block is encodable.
#[inline(always)]
pub fn violation_mask_u64(w: u64) -> u64 {
    (w ^ (w << 1)) & 0x0080_8080_8080_8080
}

/// Fast whole-buffer constraint check (the encode hot path); the slow
/// index-listing variant below is only used to build error messages.
///
/// True iff `encode` will accept the buffer: every whole block passes
/// the WOT mask check *and* the buffer is whole blocks. A ragged tail
/// can never form a (64, 57) codeword, so it fails here just as
/// `encode` rejects it — the two predicates agree on every input
/// (previously `chunks_exact` silently skipped the tail and a
/// non-multiple-of-8 buffer could pass a check that encode then
/// rejected).
pub fn satisfies_constraint(weights: &[i8]) -> bool {
    weights.len() % BLOCK == 0
        && weights.chunks_exact(BLOCK).all(|chunk| {
            let mut b = [0u8; 8];
            for (d, &s) in b.iter_mut().zip(chunk) {
                *d = s as u8;
            }
            violation_mask_u64(u64::from_le_bytes(b)) == 0
        })
}

/// Check the WOT block constraint over a full weight buffer; returns the
/// indices (into `weights`) of violating values, empty when every value
/// is in range (a ragged tail's values are checked as the head of a
/// would-be block — positions 0..6 constrained).
pub fn constraint_violations(weights: &[i8]) -> Vec<usize> {
    weights
        .chunks(BLOCK)
        .enumerate()
        .flat_map(|(bi, chunk)| {
            chunk[..chunk.len().min(BLOCK - 1)]
                .iter()
                .enumerate()
                .filter(|(_, &w)| !is_small(w))
                .map(move |(j, _)| bi * BLOCK + j)
        })
        .collect()
}

/// Bit mask of the seven in-place check positions (bit 6 of bytes 0..6
/// of the little-endian u64).
pub const CHECK_MASK: u64 = 0x0040_4040_4040_4040;

/// SPREAD[s] = the check-bit word whose bit (i*8+6) is set iff bit i of
/// the syndrome `s` is set. Because the check columns are unit vectors,
/// `w | SPREAD[syndrome(w)]` has syndrome zero when the check positions
/// of `w` are cleared.
fn spread_table() -> &'static [u64; 128] {
    use std::sync::OnceLock;
    static T: OnceLock<[u64; 128]> = OnceLock::new();
    T.get_or_init(|| {
        let mut t = [0u64; 128];
        for (s, entry) in t.iter_mut().enumerate() {
            let mut m = 0u64;
            for i in 0..7 {
                if s & (1 << i) != 0 {
                    m |= 1u64 << (i * 8 + 6);
                }
            }
            *entry = m;
        }
        t
    })
}

/// The Fig. 2 sign-copy wire on a whole word: bit6 := bit7, bytes 0..6.
#[inline(always)]
pub fn restore_u64(w: u64) -> u64 {
    (w & !CHECK_MASK) | ((w >> 1) & CHECK_MASK)
}

/// Pre-resolved code + spread table. Hot loops resolve this ONCE and
/// call the `*_with` functions: resolving the OnceLock per block keeps
/// the LUT base pointers out of registers and costs ~1.6x decode
/// throughput (measured; EXPERIMENTS.md §Perf).
#[derive(Clone, Copy)]
pub struct InplaceCtx {
    code: &'static super::hsiao::HsiaoCode,
    spread: &'static [u64; 128],
}

pub fn ctx() -> InplaceCtx {
    InplaceCtx {
        code: code_6457_inplace(),
        spread: spread_table(),
    }
}

/// Encode one 64-bit block (u64 fast path): overwrite the bit6 slots of
/// bytes 0..6 with the (64, 57) check bits.
#[inline(always)]
pub fn encode_u64_with(cx: InplaceCtx, w: u64) -> u64 {
    let cleared = w & !CHECK_MASK;
    cleared | cx.spread[cx.code.syndrome_u64(cleared) as usize]
}

/// Decode one 64-bit block (u64 fast path): SEC-DED correct, then the
/// sign-copy restore. Returns (weights_word, outcome).
#[inline(always)]
pub fn decode_u64_with(cx: InplaceCtx, mut w: u64) -> (u64, Outcome) {
    let s = cx.code.syndrome_u64(w);
    if s == 0 {
        return (restore_u64(w), Outcome::Clean);
    }
    match cx.code.correction(s) {
        Some(pos) => {
            w ^= 1u64 << pos;
            (restore_u64(w), Outcome::Corrected(pos))
        }
        None => (restore_u64(w), Outcome::Detected),
    }
}

/// Scrub one 64-bit block: a corrected codeword IS the original encoded
/// word, so no re-encode is needed; uncorrectable blocks are preserved.
/// Returns (stored_word, outcome).
#[inline(always)]
pub fn scrub_u64_with(cx: InplaceCtx, w: u64) -> (u64, Outcome) {
    let s = cx.code.syndrome_u64(w);
    if s == 0 {
        return (w, Outcome::Clean);
    }
    match cx.code.correction(s) {
        Some(pos) => (w ^ (1u64 << pos), Outcome::Corrected(pos)),
        None => (w, Outcome::Detected),
    }
}

/// Convenience one-shot variants (tests, non-hot callers).
#[inline]
pub fn encode_u64(w: u64) -> u64 {
    encode_u64_with(ctx(), w)
}
#[inline]
pub fn decode_u64(w: u64) -> (u64, Outcome) {
    decode_u64_with(ctx(), w)
}
#[inline]
pub fn scrub_u64(w: u64) -> (u64, Outcome) {
    scrub_u64_with(ctx(), w)
}

/// Encode one block in place: bytes 0..6 get their bit6 replaced by the
/// Hsiao (64, 57) check bits. Caller guarantees the WOT constraint.
#[inline]
pub fn encode_block(block: &mut [u8; BLOCK]) {
    *block = encode_u64(u64::from_le_bytes(*block)).to_le_bytes();
}

/// Decode one block in place: SEC-DED over the stored 64 bits, then the
/// sign-copy restore of bit6 in bytes 0..6.
#[inline]
pub fn decode_block(block: &mut [u8; BLOCK]) -> Outcome {
    let (w, out) = decode_u64(u64::from_le_bytes(*block));
    *block = w.to_le_bytes();
    out
}

/// The Fig. 2 sign-copy wire: bit6 := bit7 for bytes 0..6.
#[inline]
pub fn restore_block(block: &mut [u8; BLOCK]) {
    *block = restore_u64(u64::from_le_bytes(*block)).to_le_bytes();
}

/// Scrub one block: correct a single error in the *stored* image (a
/// corrected codeword is exactly the original encoded word, so no
/// re-encode is needed). Uncorrectable (detected) blocks are left
/// exactly as stored — rewriting them would launder the double-error
/// evidence (same policy as SEC-DED (72,64) scrubbing).
#[inline]
pub fn scrub_block(block: &mut [u8; BLOCK]) -> Outcome {
    let (w, out) = scrub_u64(u64::from_le_bytes(*block));
    *block = w.to_le_bytes();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn wot_block(rng: &mut Rng) -> [u8; BLOCK] {
        let mut b = [0u8; BLOCK];
        for (i, v) in b.iter_mut().enumerate() {
            let w: i8 = if i < BLOCK - 1 {
                (rng.below(128) as i64 - 64) as i8 // [-64, 63]
            } else {
                (rng.below(256) as i64 - 128) as i8 // any int8
            };
            *v = w as u8;
        }
        b
    }

    #[test]
    fn roundtrip_no_fault() {
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            let orig = wot_block(&mut rng);
            let mut enc = orig;
            encode_block(&mut enc);
            // stored image differs from orig only in bit6 of bytes 0..6
            for i in 0..BLOCK - 1 {
                assert_eq!(enc[i] & !0x40, orig[i] & !0x40);
            }
            assert_eq!(enc[7], orig[7]);
            let mut dec = enc;
            assert_eq!(decode_block(&mut dec), Outcome::Clean);
            assert_eq!(dec, orig, "sign-copy must reconstruct the weights");
        }
    }

    #[test]
    fn single_bit_flip_always_recovers_weights() {
        let mut rng = Rng::new(8);
        for _ in 0..200 {
            let orig = wot_block(&mut rng);
            let mut enc = orig;
            encode_block(&mut enc);
            for bit in 0..64 {
                let mut w = enc;
                w[bit / 8] ^= 1 << (bit % 8);
                let mut dec = w;
                match decode_block(&mut dec) {
                    Outcome::Corrected(p) => assert_eq!(p, bit),
                    o => panic!("expected Corrected, got {o:?}"),
                }
                assert_eq!(dec, orig, "flip at bit {bit} must be healed");
            }
        }
    }

    #[test]
    fn double_flip_detected_and_signs_still_restored() {
        let mut rng = Rng::new(9);
        for _ in 0..200 {
            let orig = wot_block(&mut rng);
            let mut enc = orig;
            encode_block(&mut enc);
            let b1 = rng.below(64) as usize;
            let mut b2 = rng.below(64) as usize;
            while b2 == b1 {
                b2 = rng.below(64) as usize;
            }
            let mut w = enc;
            w[b1 / 8] ^= 1 << (b1 % 8);
            w[b2 / 8] ^= 1 << (b2 % 8);
            let mut dec = w;
            assert_eq!(decode_block(&mut dec), Outcome::Detected);
            // even when uncorrectable, bit6 of small weights must obey
            // the sign-copy invariant afterwards
            for i in 0..BLOCK - 1 {
                assert_eq!((dec[i] >> 6) & 1, (dec[i] >> 7) & 1);
            }
        }
    }

    #[test]
    fn violations_found() {
        let mut w = vec![0i8; 16];
        w[3] = 64; // violating (position 3 of block 0)
        w[15] = -128; // fine (free position of block 1)
        assert_eq!(constraint_violations(&w), vec![3]);
    }

    #[test]
    fn ragged_tail_agrees_with_encode() {
        use crate::ecc::strategy_by_name;
        // regression: a 12-weight buffer used to pass the constraint
        // check (chunks_exact skipped the 4-byte tail) while encode
        // rejected it — the predicate must match encode's verdict.
        let ragged = vec![0i8; 12];
        assert!(!satisfies_constraint(&ragged));
        assert!(strategy_by_name("in-place").unwrap().encode(&ragged).is_err());
        // tail *values* are still diagnosed: position 9 sits at block
        // offset 1 of the partial block, which the constraint covers
        let mut bad_tail = vec![0i8; 12];
        bad_tail[9] = 100;
        assert_eq!(constraint_violations(&bad_tail), vec![9]);
        // whole blocks keep working
        assert!(satisfies_constraint(&[0i8; 16]));
    }

    #[test]
    fn scrub_refreshes_check_bits() {
        let mut rng = Rng::new(10);
        let orig = wot_block(&mut rng);
        let mut enc = orig;
        encode_block(&mut enc);
        let mut hit = enc;
        hit[2] ^= 1 << 1; // single fault
        assert!(matches!(scrub_block(&mut hit), Outcome::Corrected(_)));
        assert_eq!(hit, enc, "scrub must restore the exact stored image");
    }
}
