//! The `Protection` trait: one interface over the paper's four Table-2
//! strategies (faulty / zero / ecc / in-place) plus the BCH extension.
//!
//! An encoded image is `data` (what replaces the raw weight bytes) plus
//! `oob` (out-of-band check storage, empty for zero-space schemes).
//! Fault injection targets *all* stored bits (data + oob), matching the
//! paper's definition of fault rate over the bits a scheme actually
//! keeps in memory.
//!
//! Every scheme here is a *per-block* code, which the trait exposes as
//! block-range APIs: `decode_span`/`scrub_span` operate on a
//! block-aligned window of the stored image and are the primitive every
//! strategy implements natively; `decode_range`/`scrub_range` address a
//! window of an [`Encoded`] by `[start, end)` byte offsets; the classic
//! whole-buffer `decode`/`scrub` are the `[0, len)` special case. The
//! sharded memory bank leans on this to scrub disjoint shards of one
//! stored image from parallel workers.
//!
//! On top of the scalar span primitive sits the tiled hot path:
//! `decode_tile`/`scrub_tile` process one 512-byte tile (64 blocks) and
//! are overridden by the Hsiao-coded strategies with the word-parallel
//! engine of [`crate::ecc::tile`] — all-lane syndromes from a bit
//! transpose, a one-word all-clean proof, scalar fallback only for the
//! (rare) dirty lanes. `decode_span_tiled`/`scrub_span_tiled` chunk any
//! block-aligned window into tiles plus a scalar tail, and the range
//! APIs route through them, so every decode/scrub in the system — shard
//! workers, campaign trials, the serving scrub loop — rides the tile
//! engine while `decode_span`/`scrub_span` stay available as the scalar
//! reference the equivalence proptests (and the bench) compare against.

use super::{bch, inplace, parity, secded, tile};
use crate::ecc::hsiao::Outcome;

/// Stored image of a protected weight buffer.
#[derive(Clone, Debug)]
pub struct Encoded {
    /// In-band bytes (same length as the weight buffer).
    pub data: Vec<u8>,
    /// Out-of-band check bytes (empty for zero-space schemes).
    pub oob: Vec<u8>,
    /// Number of weights represented.
    pub n: usize,
}

impl Encoded {
    /// Total stored bits — the denominator of the paper's fault rate.
    pub fn total_bits(&self) -> u64 {
        8 * (self.data.len() + self.oob.len()) as u64
    }

    /// Flip one stored bit; positions index data bits first, then oob.
    pub fn flip_bit(&mut self, pos: u64) {
        let byte = (pos / 8) as usize;
        let bit = (pos % 8) as u8;
        if byte < self.data.len() {
            self.data[byte] ^= 1 << bit;
        } else {
            self.oob[byte - self.data.len()] ^= 1 << bit;
        }
    }

    /// Read one stored bit (same position indexing as `flip_bit`) —
    /// the stuck-at fault model needs the value a cell currently holds.
    pub fn get_bit(&self, pos: u64) -> bool {
        let byte = (pos / 8) as usize;
        let bit = (pos % 8) as u8;
        let v = if byte < self.data.len() {
            self.data[byte]
        } else {
            self.oob[byte - self.data.len()]
        };
        v >> bit & 1 == 1
    }
}

/// Counters reported by a decode/scrub pass.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DecodeStats {
    /// Blocks with a single error corrected (bits for bch).
    pub corrected: u64,
    /// Blocks with an uncorrectable (detected) error.
    pub detected: u64,
    /// Weights zeroed by the parity-zero action.
    pub zeroed: u64,
}

impl DecodeStats {
    pub fn add(&mut self, o: &DecodeStats) {
        self.corrected += o.corrected;
        self.detected += o.detected;
        self.zeroed += o.zeroed;
    }

    /// True when the pass saw no error of any kind.
    pub fn is_clean(&self) -> bool {
        *self == DecodeStats::default()
    }
}

/// Upper bound on the block indices one [`DecodeOutcome`] records.
/// Beyond the cap the pass keeps counting (the `stats` stay exact) but
/// stops listing — `overflow` tells callers the list is truncated, the
/// same bounded-tracking discipline the sharded store's copy-on-write
/// tracker uses. At fault rates where more than a thousand blocks per
/// pass go uncorrectable, per-block recovery is hopeless anyway.
pub const DETECTED_BLOCK_CAP: usize = 1024;

/// A decode/scrub pass's counters plus *which* blocks were left
/// detected-uncorrectable — the localization the recovery tier needs
/// to name the weight coordinates to solve for. Block indices are
/// relative to the `base_block` the pass was given (absolute image
/// indices when callers pass `start / block_bytes`), ascending, at
/// most one entry per block.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DecodeOutcome {
    pub stats: DecodeStats,
    /// Blocks still detected-uncorrectable when the pass finished,
    /// truncated at [`DETECTED_BLOCK_CAP`].
    pub detected_blocks: Vec<usize>,
    /// True when detections were dropped because the list hit the cap.
    pub overflow: bool,
}

impl DecodeOutcome {
    /// Record one detected-uncorrectable block, respecting the cap.
    pub fn push_detected(&mut self, block: usize) {
        if self.detected_blocks.len() < DETECTED_BLOCK_CAP {
            self.detected_blocks.push(block);
        } else {
            self.overflow = true;
        }
    }

    /// Merge another pass's outcome (stats add, lists concatenate under
    /// the cap; overflow is sticky).
    pub fn add(&mut self, o: &DecodeOutcome) {
        self.stats.add(&o.stats);
        for &b in &o.detected_blocks {
            self.push_detected(b);
        }
        self.overflow |= o.overflow;
    }
}

/// How a *clean* (syndrome-free) stored data byte maps to its weight
/// byte — lets the fused decode→dequant path consume clean tiles
/// straight from the stored image with no intermediate i8 buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CleanPath {
    /// Stored data bytes are the weight bytes (faulty / zero / ecc).
    Copy,
    /// In-place (64, 57): bit 6 of bytes 0..6 of every 8-byte block
    /// carries a check bit; the weight byte restores it with the
    /// byte-local sign copy (bit6 := bit7), so callers can fold the
    /// restore into a per-byte LUT.
    SignRestore,
}

/// Copy clean stored bytes into an i8 weight window, 8 bytes per move
/// (safe u8→i8 chunk cast; byte loop only on a sub-word tail).
pub(crate) fn copy_clean(data: &[u8], out: &mut [i8]) {
    debug_assert_eq!(data.len(), out.len());
    let mut src = data.chunks_exact(8);
    let mut dst = out.chunks_exact_mut(8);
    for (chunk, o) in (&mut src).zip(&mut dst) {
        let w = u64::from_le_bytes(chunk.try_into().unwrap());
        o.copy_from_slice(&tile::lane_i8(w));
    }
    for (&b, o) in src.remainder().iter().zip(dst.into_remainder()) {
        *o = b as i8;
    }
}

/// The quantization grid a strategy's weights were trained onto —
/// which values a *reconstructed* weight may legally take. The paper's
/// WOT training leaves every `period`-th element full-range int8 and
/// constrains the rest to `[lo, hi]`; the recovery tier snaps its
/// least-squares solves onto this grid and the re-encode enforces it,
/// so a solver using the wrong grid either hands back out-of-range
/// weights (bch16 under the plain-WOT grid) or silently legalizes
/// garbage. Exposed per strategy so escalation callers never guess.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantGrid {
    /// Constraint period in elements; element `i` with
    /// `i % period == period - 1` is unconstrained (full int8 range).
    pub period: usize,
    /// Inclusive bounds for the constrained elements.
    pub lo: i8,
    pub hi: i8,
}

impl QuantGrid {
    /// Plain WOT: every 8th weight full-range, the rest in `[-64, 63]`.
    pub const WOT8: QuantGrid = QuantGrid {
        period: 8,
        lo: -64,
        hi: 63,
    };
    /// Extended WOT for the 128-bit BCH blocks: every 16th weight
    /// full-range, the rest in `[-32, 31]`.
    pub const WOT16_EXT: QuantGrid = QuantGrid {
        period: 16,
        lo: -32,
        hi: 31,
    };

    /// Legal `(lo, hi)` for flat element index `e`.
    pub fn bounds(&self, e: usize) -> (f64, f64) {
        if self.period > 0 && e % self.period == self.period - 1 {
            (-128.0, 127.0)
        } else {
            (f64::from(self.lo), f64::from(self.hi))
        }
    }
}

/// A memory-protection strategy.
///
/// `decode_span` is the one required decode primitive; `scrub_span`,
/// the `*_range` addressing forms and the whole-buffer `decode`/`scrub`
/// all have defaults derived from it (plus `encode` for the scrub
/// fallback). The built-in strategies override `scrub_span` natively so
/// scrubbing never round-trips through a weight re-encode, and the
/// Hsiao-coded strategies override `decode_tile`/`scrub_tile` with the
/// word-parallel engine.
pub trait Protection: Send + Sync {
    /// Paper name: "faulty", "zero", "ecc", "in-place", "bch16".
    fn name(&self) -> &'static str;
    /// Does the scheme rely on (extended) ECC hardware? (Table 2 column.)
    fn ecc_hw(&self) -> bool;
    /// Space overhead as a fraction of the raw weight bytes.
    fn overhead(&self) -> f64;
    /// Data bytes per independent code block. Range/span windows must be
    /// aligned to this (1 = byte-granular, no alignment constraint).
    fn block_bytes(&self) -> usize;
    /// Out-of-band check bytes per code block (0 for zero-space schemes).
    fn oob_bytes_per_block(&self) -> usize;
    /// Encode a weight buffer (length % block_bytes == 0) into a stored
    /// image.
    fn encode(&self, weights: &[i8]) -> anyhow::Result<Encoded>;

    /// The quantization grid this strategy's weights live on — what the
    /// recovery tier must snap reconstructed values to so the re-encode
    /// accepts them. Every paper strategy trains plain WOT except
    /// `bch16`, which overrides with the extended grid.
    fn quant_grid(&self) -> QuantGrid {
        QuantGrid::WOT8
    }

    /// Decode a block-aligned window of a stored image. `data`/`oob` are
    /// the window's slices (`oob` covers exactly `data`'s blocks) and
    /// `out.len() == data.len()`; the stored bytes are not modified.
    fn decode_span(&self, data: &[u8], oob: &[u8], out: &mut [i8]) -> DecodeStats;

    /// Scrub a block-aligned window: correct the stored bytes in place
    /// (so latent single errors do not accumulate into doubles).
    /// Default: decode the span, re-encode, write back — uncorrectable
    /// spans are left as stored when the re-encode fails.
    fn scrub_span(&self, data: &mut [u8], oob: &mut [u8]) -> DecodeStats {
        let mut w = vec![0i8; data.len()];
        let stats = self.decode_span(data, oob, &mut w);
        if let Ok(re) = self.encode(&w) {
            data.copy_from_slice(&re.data);
            oob.copy_from_slice(&re.oob);
        }
        stats
    }

    /// Decode exactly one tile ([`tile::TILE_BYTES`] data bytes, `oob`
    /// covering its blocks). Strategies with a word-parallel engine
    /// override this; the default is the scalar span path, so the tiled
    /// wrappers below are correct for every implementor.
    fn decode_tile(&self, data: &[u8], oob: &[u8], out: &mut [i8]) -> DecodeStats {
        self.decode_span(data, oob, out)
    }

    /// Scrub exactly one tile in place (same contract as `decode_tile`).
    fn scrub_tile(&self, data: &mut [u8], oob: &mut [u8]) -> DecodeStats {
        self.scrub_span(data, oob)
    }

    /// Word-parallel clean probe of exactly one tile: `true` proves
    /// every block syndrome-free, so a decode is a straight copy (plus
    /// sign restore for in-place schemes) and a scrub is a no-op.
    /// Conservative default: `false` sends callers down the
    /// `decode_tile` path.
    fn tile_is_clean(&self, _data: &[u8], _oob: &[u8]) -> bool {
        false
    }

    /// Clean-block byte mapping (see [`CleanPath`]); paired with
    /// `tile_is_clean` by the fused decode→dequant path.
    fn clean_path(&self) -> CleanPath {
        CleanPath::Copy
    }

    /// Tiled decode of a block-aligned window: whole 512-byte tiles go
    /// through `decode_tile` (word-parallel where overridden), the
    /// ragged tail through the scalar span path. Bit-identical to
    /// `decode_span` — the equivalence proptests pin this down — and
    /// what the range APIs and the sharded store actually call.
    fn decode_span_tiled(&self, data: &[u8], oob: &[u8], out: &mut [i8]) -> DecodeStats {
        let opt = tile::TILE_BYTES / self.block_bytes() * self.oob_bytes_per_block();
        let mut stats = DecodeStats::default();
        let (mut d, mut o) = (0usize, 0usize);
        while data.len() - d >= tile::TILE_BYTES {
            let e = d + tile::TILE_BYTES;
            stats.add(&self.decode_tile(&data[d..e], &oob[o..o + opt], &mut out[d..e]));
            d = e;
            o += opt;
        }
        if d < data.len() {
            stats.add(&self.decode_span(&data[d..], &oob[o..], &mut out[d..]));
        }
        stats
    }

    /// Tiled scrub of a block-aligned window (see `decode_span_tiled`).
    fn scrub_span_tiled(&self, data: &mut [u8], oob: &mut [u8]) -> DecodeStats {
        let opt = tile::TILE_BYTES / self.block_bytes() * self.oob_bytes_per_block();
        let mut stats = DecodeStats::default();
        let (mut d, mut o) = (0usize, 0usize);
        while data.len() - d >= tile::TILE_BYTES {
            let e = d + tile::TILE_BYTES;
            stats.add(&self.scrub_tile(&mut data[d..e], &mut oob[o..o + opt]));
            d = e;
            o += opt;
        }
        if d < data.len() {
            let (_, dtail) = data.split_at_mut(d);
            let (_, otail) = oob.split_at_mut(o);
            stats.add(&self.scrub_span(dtail, otail));
        }
        stats
    }

    /// Map a block-aligned `[start, end)` data-byte window to its
    /// out-of-band check window.
    fn oob_window(
        &self,
        start: usize,
        end: usize,
        data_len: usize,
        oob_len: usize,
    ) -> (usize, usize) {
        let (b, o) = (self.block_bytes(), self.oob_bytes_per_block());
        if o == 0 {
            return (0, 0);
        }
        let os = start / b * o;
        let oe = if end == data_len { oob_len } else { end / b * o };
        (os, oe)
    }

    /// Decode the window `[start, end)` (block-aligned byte offsets into
    /// `enc.data`) into `out` (`out.len() == end - start`). The whole
    /// buffer is `decode_range(enc, 0, enc.data.len(), out)`. Routed
    /// through the tiled span form — scalar behavior, tile speed.
    fn decode_range(&self, enc: &Encoded, start: usize, end: usize, out: &mut [i8]) -> DecodeStats {
        let b = self.block_bytes();
        debug_assert!(start % b == 0 && (end % b == 0 || end == enc.data.len()));
        let (os, oe) = self.oob_window(start, end, enc.data.len(), enc.oob.len());
        self.decode_span_tiled(&enc.data[start..end], &enc.oob[os..oe], out)
    }

    /// Scrub the window `[start, end)` of the stored image in place
    /// (tiled, like `decode_range`).
    fn scrub_range(&self, enc: &mut Encoded, start: usize, end: usize) -> DecodeStats {
        let b = self.block_bytes();
        debug_assert!(start % b == 0 && (end % b == 0 || end == enc.data.len()));
        let (os, oe) = self.oob_window(start, end, enc.data.len(), enc.oob.len());
        self.scrub_span_tiled(&mut enc.data[start..end], &mut enc.oob[os..oe])
    }

    /// Like [`Protection::decode_span_tiled`], but also reports *which*
    /// blocks were detected-uncorrectable (indices offset by
    /// `base_block`, so passing `start / block_bytes` yields absolute
    /// image indices). Tile-size chunks take the fast path; only chunks
    /// whose stats show a detection are re-walked block-by-block to
    /// locate it, so the clean/correctable common case pays one
    /// outcome allocation and nothing else.
    fn decode_span_outcome(
        &self,
        data: &[u8],
        oob: &[u8],
        out: &mut [i8],
        base_block: usize,
    ) -> DecodeOutcome {
        let b = self.block_bytes();
        let opb = self.oob_bytes_per_block();
        let opt = tile::TILE_BYTES / b * opb;
        let mut outc = DecodeOutcome::default();
        let (mut d, mut o) = (0usize, 0usize);
        while d < data.len() {
            let e = (d + tile::TILE_BYTES).min(data.len());
            let oe = if e == data.len() { oob.len() } else { o + opt };
            let stats = if e - d == tile::TILE_BYTES {
                self.decode_tile(&data[d..e], &oob[o..oe], &mut out[d..e])
            } else {
                self.decode_span(&data[d..e], &oob[o..oe], &mut out[d..e])
            };
            if stats.detected > 0 {
                // locate the detections: one block at a time, rewriting
                // the same output bytes the chunk pass already produced
                let (mut k, mut ok) = (d, o);
                while k < e {
                    let ke = (k + b).min(e);
                    let oke = if ke == data.len() { oob.len() } else { ok + opb };
                    let bs = self.decode_span(&data[k..ke], &oob[ok..oke], &mut out[k..ke]);
                    if bs.detected > 0 {
                        outc.push_detected(base_block + k / b);
                    }
                    k = ke;
                    ok = oke;
                }
            }
            outc.stats.add(&stats);
            d = e;
            o = oe;
        }
        outc
    }

    /// Scrub counterpart of [`Protection::decode_span_outcome`]. Blocks
    /// must be identified *during* the pass — parity-zero's scrub heals
    /// its stored image (zeroed weight, cleared parity), so a post-scrub
    /// decode finds nothing — hence dirty chunks scrub block-by-block.
    /// Provably-clean tiles still skip via the one-word probe, so at
    /// realistic fault rates the pass stays tile-speed.
    fn scrub_span_outcome(&self, data: &mut [u8], oob: &mut [u8], base_block: usize) -> DecodeOutcome {
        let b = self.block_bytes();
        let opb = self.oob_bytes_per_block();
        let opt = tile::TILE_BYTES / b * opb;
        let mut outc = DecodeOutcome::default();
        let (mut d, mut o) = (0usize, 0usize);
        while d < data.len() {
            let e = (d + tile::TILE_BYTES).min(data.len());
            let oe = if e == data.len() { oob.len() } else { o + opt };
            if e - d == tile::TILE_BYTES && self.tile_is_clean(&data[d..e], &oob[o..oe]) {
                d = e;
                o = oe;
                continue;
            }
            let (mut k, mut ok) = (d, o);
            while k < e {
                let ke = (k + b).min(e);
                let oke = if ke == data.len() { oob.len() } else { ok + opb };
                let bs = self.scrub_span(&mut data[k..ke], &mut oob[ok..oke]);
                if bs.detected > 0 {
                    outc.push_detected(base_block + k / b);
                }
                outc.stats.add(&bs);
                k = ke;
                ok = oke;
            }
            d = e;
            o = oe;
        }
        outc
    }

    /// [`Protection::decode_range`] with block localization: decode the
    /// window `[start, end)` and report absolute detected block indices.
    fn decode_range_outcome(
        &self,
        enc: &Encoded,
        start: usize,
        end: usize,
        out: &mut [i8],
    ) -> DecodeOutcome {
        let b = self.block_bytes();
        debug_assert!(start % b == 0 && (end % b == 0 || end == enc.data.len()));
        let (os, oe) = self.oob_window(start, end, enc.data.len(), enc.oob.len());
        self.decode_span_outcome(&enc.data[start..end], &enc.oob[os..oe], out, start / b)
    }

    /// [`Protection::scrub_range`] with block localization.
    fn scrub_range_outcome(&self, enc: &mut Encoded, start: usize, end: usize) -> DecodeOutcome {
        let b = self.block_bytes();
        debug_assert!(start % b == 0 && (end % b == 0 || end == enc.data.len()));
        let (os, oe) = self.oob_window(start, end, enc.data.len(), enc.oob.len());
        self.scrub_span_outcome(&mut enc.data[start..end], &mut enc.oob[os..oe], start / b)
    }

    /// Decode the whole stored image into weights, correcting what the
    /// scheme can; the image itself is not modified.
    fn decode(&self, enc: &Encoded, out: &mut [i8]) -> DecodeStats {
        self.decode_range(enc, 0, enc.data.len(), out)
    }

    /// Scrub the whole stored image in place.
    fn scrub(&self, enc: &mut Encoded) -> DecodeStats {
        self.scrub_range(enc, 0, enc.data.len())
    }
}

// ------------------------------------------------------------- faulty --

/// No protection: raw weight bytes in memory.
pub struct Unprotected;

impl Protection for Unprotected {
    fn name(&self) -> &'static str {
        "faulty"
    }
    fn ecc_hw(&self) -> bool {
        false
    }
    fn overhead(&self) -> f64 {
        0.0
    }
    fn block_bytes(&self) -> usize {
        1
    }
    fn oob_bytes_per_block(&self) -> usize {
        0
    }
    fn encode(&self, weights: &[i8]) -> anyhow::Result<Encoded> {
        Ok(Encoded {
            data: weights.iter().map(|&w| w as u8).collect(),
            oob: Vec::new(),
            n: weights.len(),
        })
    }
    fn decode_span(&self, data: &[u8], _oob: &[u8], out: &mut [i8]) -> DecodeStats {
        copy_clean(data, out);
        DecodeStats::default()
    }
    fn scrub_span(&self, _data: &mut [u8], _oob: &mut [u8]) -> DecodeStats {
        DecodeStats::default() // nothing to correct, nothing to re-encode
    }
    fn tile_is_clean(&self, _data: &[u8], _oob: &[u8]) -> bool {
        true // no code, nothing to be dirty
    }
}

// -------------------------------------------------------- parity-zero --

/// Parity-Zero: 1 parity bit per weight byte; zero the weight on detect.
pub struct ParityZero;

impl Protection for ParityZero {
    fn name(&self) -> &'static str {
        "zero"
    }
    fn ecc_hw(&self) -> bool {
        false
    }
    fn overhead(&self) -> f64 {
        0.125
    }
    fn block_bytes(&self) -> usize {
        8
    }
    fn oob_bytes_per_block(&self) -> usize {
        1
    }
    fn encode(&self, weights: &[i8]) -> anyhow::Result<Encoded> {
        let data: Vec<u8> = weights.iter().map(|&w| w as u8).collect();
        let oob = parity::encode_oob(&data);
        Ok(Encoded {
            data,
            oob,
            n: weights.len(),
        })
    }
    fn decode_span(&self, data: &[u8], oob: &[u8], out: &mut [i8]) -> DecodeStats {
        let mut stats = DecodeStats::default();
        // u64 fast path: 8 parities per word (see parity::parity_word),
        // branch only on the (rare) mismatching words.
        let mut chunks = data.chunks_exact(8);
        let mut i = 0usize;
        for chunk in &mut chunks {
            let w = u64::from_le_bytes(chunk.try_into().unwrap());
            let mism = parity::parity_word(w) ^ oob[i / 8];
            if mism == 0 {
                out[i..i + 8].copy_from_slice(&tile::lane_i8(w));
            } else {
                for j in 0..8 {
                    if mism & (1 << j) != 0 {
                        out[i + j] = 0;
                        stats.detected += 1;
                        stats.zeroed += 1;
                    } else {
                        out[i + j] = chunk[j] as i8;
                    }
                }
            }
            i += 8;
        }
        for (j, &b) in chunks.remainder().iter().enumerate() {
            if parity::check(b, oob, i + j) {
                out[i + j] = b as i8;
            } else {
                out[i + j] = 0;
                stats.detected += 1;
                stats.zeroed += 1;
            }
        }
        stats
    }
    fn scrub_span(&self, data: &mut [u8], oob: &mut [u8]) -> DecodeStats {
        // Zero the weight on mismatch and clear its parity bit (the
        // parity of 0 is 0) — bit-identical to decode + re-encode, minus
        // the intermediate weight buffer.
        let mut stats = DecodeStats::default();
        for (i, b) in data.iter_mut().enumerate() {
            if !parity::check(*b, oob, i) {
                *b = 0;
                oob[i / 8] &= !(1 << (i % 8));
                stats.detected += 1;
                stats.zeroed += 1;
            }
        }
        // Re-encode also launders flips in the padding bits of a ragged
        // final check byte; mirror that so scrub images stay canonical.
        if data.len() % 8 != 0 {
            let mask = (1u16 << (data.len() % 8)) as u8 - 1;
            oob[data.len() / 8] &= mask;
        }
        stats
    }
    fn tile_is_clean(&self, data: &[u8], oob: &[u8]) -> bool {
        // OR-fold the per-word parity mismatches: one branch per tile.
        let mut acc = 0u8;
        for (chunk, &o) in data.chunks_exact(8).zip(oob) {
            acc |= parity::parity_word(u64::from_le_bytes(chunk.try_into().unwrap())) ^ o;
        }
        acc == 0
    }
    // decode_tile keeps the default (= decode_span): the span path is
    // already word-parallel with a per-word clean fast path, so an
    // extra whole-tile probe would only redo the same parity folds.
    fn scrub_tile(&self, data: &mut [u8], oob: &mut [u8]) -> DecodeStats {
        // the probe pays here: scrub_span re-checks parity byte-by-byte
        if self.tile_is_clean(data, oob) {
            return DecodeStats::default(); // clean tile: scrub is a no-op
        }
        self.scrub_span(data, oob)
    }
}

// ------------------------------------------------------ SEC-DED 72/64 --

/// Conventional SEC-DED (72, 64): one out-of-band check byte per 8-byte
/// block (the paper's "ecc" row; 12.5% overhead).
pub struct Secded7264;

impl Protection for Secded7264 {
    fn name(&self) -> &'static str {
        "ecc"
    }
    fn ecc_hw(&self) -> bool {
        true
    }
    fn overhead(&self) -> f64 {
        0.125
    }
    fn block_bytes(&self) -> usize {
        8
    }
    fn oob_bytes_per_block(&self) -> usize {
        1
    }
    fn encode(&self, weights: &[i8]) -> anyhow::Result<Encoded> {
        anyhow::ensure!(
            weights.len() % 8 == 0,
            "weight buffer must be whole 64-bit blocks"
        );
        let code = secded::code_7264();
        let data: Vec<u8> = weights.iter().map(|&w| w as u8).collect();
        let mut oob = vec![0u8; weights.len() / 8];
        // With unit check columns, the check byte IS the data syndrome.
        for (o, chunk) in oob.iter_mut().zip(data.chunks_exact(8)) {
            *o = code.syndrome_u64(u64::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(Encoded {
            data,
            oob,
            n: weights.len(),
        })
    }
    fn decode_span(&self, data: &[u8], oob: &[u8], out: &mut [i8]) -> DecodeStats {
        let code = secded::code_7264();
        let mut stats = DecodeStats::default();
        for (bi, chunk) in data.chunks_exact(8).enumerate() {
            let mut w = u64::from_le_bytes(chunk.try_into().unwrap());
            let s = code.syndrome_u64(w) ^ code.syndrome_oob(oob[bi]);
            if s != 0 {
                match code.correction(s) {
                    Some(pos) if pos < 64 => {
                        w ^= 1u64 << pos;
                        stats.corrected += 1;
                    }
                    Some(_) => stats.corrected += 1, // flip was in the check byte
                    None => stats.detected += 1,
                }
            }
            out[bi * 8..bi * 8 + 8].copy_from_slice(&tile::lane_i8(w));
        }
        stats
    }
    fn tile_is_clean(&self, data: &[u8], oob: &[u8]) -> bool {
        tile::tile_7264().dirty_lanes(&tile::load_lanes(data), &tile::oob_planes(oob)) == 0
    }
    fn decode_tile(&self, data: &[u8], oob: &[u8], out: &mut [i8]) -> DecodeStats {
        let lanes = tile::load_lanes(data);
        let dirty = tile::tile_7264().dirty_lanes(&lanes, &tile::oob_planes(oob));
        let mut stats = DecodeStats::default();
        if dirty == 0 {
            copy_clean(data, out);
            return stats;
        }
        let code = secded::code_7264();
        for (j, &lane) in lanes.iter().enumerate() {
            let mut w = lane;
            if dirty >> j & 1 == 1 {
                let s = code.syndrome_u64(w) ^ code.syndrome_oob(oob[j]);
                if s != 0 {
                    match code.correction(s) {
                        Some(pos) if pos < 64 => {
                            w ^= 1u64 << pos;
                            stats.corrected += 1;
                        }
                        Some(_) => stats.corrected += 1,
                        None => stats.detected += 1,
                    }
                }
            }
            out[j * 8..j * 8 + 8].copy_from_slice(&tile::lane_i8(w));
        }
        stats
    }
    fn scrub_tile(&self, data: &mut [u8], oob: &mut [u8]) -> DecodeStats {
        let lanes = tile::load_lanes(data);
        let mut dirty = tile::tile_7264().dirty_lanes(&lanes, &tile::oob_planes(oob));
        let mut stats = DecodeStats::default();
        let code = secded::code_7264();
        while dirty != 0 {
            let j = dirty.trailing_zeros() as usize;
            dirty &= dirty - 1;
            let w = lanes[j];
            let s = code.syndrome_u64(w) ^ code.syndrome_oob(oob[j]);
            if s == 0 {
                continue;
            }
            match code.correction(s) {
                Some(pos) if pos < 64 => {
                    data[j * 8..j * 8 + 8].copy_from_slice(&(w ^ (1u64 << pos)).to_le_bytes());
                    stats.corrected += 1;
                }
                Some(pos) => {
                    oob[j] ^= 1 << (pos - 64);
                    stats.corrected += 1;
                }
                None => stats.detected += 1, // leave stored image as-is
            }
        }
        stats
    }
    fn scrub_span(&self, data: &mut [u8], oob: &mut [u8]) -> DecodeStats {
        let code = secded::code_7264();
        let mut stats = DecodeStats::default();
        for (bi, chunk) in data.chunks_exact_mut(8).enumerate() {
            let w = u64::from_le_bytes((&*chunk).try_into().unwrap());
            let s = code.syndrome_u64(w) ^ code.syndrome_oob(oob[bi]);
            if s == 0 {
                continue;
            }
            match code.correction(s) {
                Some(pos) if pos < 64 => {
                    chunk.copy_from_slice(&(w ^ (1u64 << pos)).to_le_bytes());
                    stats.corrected += 1;
                }
                Some(pos) => {
                    oob[bi] ^= 1 << (pos - 64);
                    stats.corrected += 1;
                }
                None => stats.detected += 1, // leave stored image as-is
            }
        }
        stats
    }
}

// --------------------------------------------------- in-place (64,57) --

/// The paper's contribution: in-place zero-space ECC.
pub struct InplaceZs;

impl Protection for InplaceZs {
    fn name(&self) -> &'static str {
        "in-place"
    }
    fn ecc_hw(&self) -> bool {
        true
    }
    fn overhead(&self) -> f64 {
        0.0
    }
    fn block_bytes(&self) -> usize {
        8
    }
    fn oob_bytes_per_block(&self) -> usize {
        0
    }
    fn encode(&self, weights: &[i8]) -> anyhow::Result<Encoded> {
        anyhow::ensure!(
            weights.len() % 8 == 0,
            "weight buffer must be whole 64-bit blocks"
        );
        if !inplace::satisfies_constraint(weights) {
            let viol = inplace::constraint_violations(weights);
            anyhow::bail!(
                "WOT constraint violated at {} positions (first: {:?}) — run WOT first",
                viol.len(),
                &viol[..viol.len().min(4)]
            );
        }
        let mut data: Vec<u8> = weights.iter().map(|&w| w as u8).collect();
        let cx = inplace::ctx();
        for chunk in data.chunks_exact_mut(8) {
            let w = u64::from_le_bytes((&*chunk).try_into().unwrap());
            chunk.copy_from_slice(&inplace::encode_u64_with(cx, w).to_le_bytes());
        }
        Ok(Encoded {
            data,
            oob: Vec::new(),
            n: weights.len(),
        })
    }
    fn decode_span(&self, data: &[u8], _oob: &[u8], out: &mut [i8]) -> DecodeStats {
        let mut stats = DecodeStats::default();
        let cx = inplace::ctx();
        for (bi, chunk) in data.chunks_exact(8).enumerate() {
            let (w, outcome) =
                inplace::decode_u64_with(cx, u64::from_le_bytes(chunk.try_into().unwrap()));
            match outcome {
                Outcome::Clean => {}
                Outcome::Corrected(_) => stats.corrected += 1,
                Outcome::Detected => stats.detected += 1,
            }
            out[bi * 8..bi * 8 + 8].copy_from_slice(&tile::lane_i8(w));
        }
        stats
    }
    fn scrub_span(&self, data: &mut [u8], _oob: &mut [u8]) -> DecodeStats {
        let mut stats = DecodeStats::default();
        let cx = inplace::ctx();
        for chunk in data.chunks_exact_mut(8) {
            let (w, outcome) =
                inplace::scrub_u64_with(cx, u64::from_le_bytes((&*chunk).try_into().unwrap()));
            match outcome {
                Outcome::Clean => {}
                Outcome::Corrected(_) => {
                    stats.corrected += 1;
                    chunk.copy_from_slice(&w.to_le_bytes());
                }
                Outcome::Detected => stats.detected += 1,
            }
        }
        stats
    }
    fn tile_is_clean(&self, data: &[u8], _oob: &[u8]) -> bool {
        tile::tile_6457().dirty_lanes(&tile::load_lanes(data), &tile::NO_OOB) == 0
    }
    fn clean_path(&self) -> CleanPath {
        CleanPath::SignRestore
    }
    fn decode_tile(&self, data: &[u8], _oob: &[u8], out: &mut [i8]) -> DecodeStats {
        let lanes = tile::load_lanes(data);
        let dirty = tile::tile_6457().dirty_lanes(&lanes, &tile::NO_OOB);
        let mut stats = DecodeStats::default();
        if dirty == 0 {
            // clean fast path: straight copy + branch-free sign restore
            for (j, &w) in lanes.iter().enumerate() {
                out[j * 8..j * 8 + 8].copy_from_slice(&tile::lane_i8(inplace::restore_u64(w)));
            }
            return stats;
        }
        let cx = inplace::ctx();
        for (j, &lane) in lanes.iter().enumerate() {
            let w = if dirty >> j & 1 == 0 {
                inplace::restore_u64(lane)
            } else {
                let (w, outcome) = inplace::decode_u64_with(cx, lane);
                match outcome {
                    Outcome::Clean => {}
                    Outcome::Corrected(_) => stats.corrected += 1,
                    Outcome::Detected => stats.detected += 1,
                }
                w
            };
            out[j * 8..j * 8 + 8].copy_from_slice(&tile::lane_i8(w));
        }
        stats
    }
    fn scrub_tile(&self, data: &mut [u8], _oob: &mut [u8]) -> DecodeStats {
        let lanes = tile::load_lanes(data);
        let mut dirty = tile::tile_6457().dirty_lanes(&lanes, &tile::NO_OOB);
        let mut stats = DecodeStats::default();
        let cx = inplace::ctx();
        while dirty != 0 {
            let j = dirty.trailing_zeros() as usize;
            dirty &= dirty - 1;
            let (w, outcome) = inplace::scrub_u64_with(cx, lanes[j]);
            match outcome {
                Outcome::Clean => {}
                Outcome::Corrected(_) => {
                    stats.corrected += 1;
                    data[j * 8..j * 8 + 8].copy_from_slice(&w.to_le_bytes());
                }
                Outcome::Detected => stats.detected += 1,
            }
        }
        stats
    }
}

// ------------------------------------------------------ BCH extension --

/// Zero-space double-error correction over 16-byte blocks (extended WOT
/// constraint: first 15 weights of each block in [-32, 31]).
pub struct Bch16;

impl Protection for Bch16 {
    fn name(&self) -> &'static str {
        "bch16"
    }
    fn ecc_hw(&self) -> bool {
        true
    }
    fn overhead(&self) -> f64 {
        0.0
    }
    fn block_bytes(&self) -> usize {
        bch::BLOCK
    }
    fn oob_bytes_per_block(&self) -> usize {
        0
    }
    fn quant_grid(&self) -> QuantGrid {
        QuantGrid::WOT16_EXT
    }
    fn encode(&self, weights: &[i8]) -> anyhow::Result<Encoded> {
        anyhow::ensure!(
            weights.len() % bch::BLOCK == 0,
            "weight buffer must be whole 128-bit blocks"
        );
        if !bch::satisfies_constraint_ext(weights) {
            let viol = bch::constraint_violations_ext(weights);
            anyhow::bail!(
                "extended WOT constraint violated at {} positions",
                viol.len()
            );
        }
        let mut data: Vec<u8> = weights.iter().map(|&w| w as u8).collect();
        for chunk in data.chunks_exact_mut(bch::BLOCK) {
            let block: &mut [u8; bch::BLOCK] = chunk.try_into().unwrap();
            bch::encode_block(block);
        }
        Ok(Encoded {
            data,
            oob: Vec::new(),
            n: weights.len(),
        })
    }
    fn decode_span(&self, data: &[u8], _oob: &[u8], out: &mut [i8]) -> DecodeStats {
        let mut stats = DecodeStats::default();
        let mut block = [0u8; bch::BLOCK];
        for (bi, chunk) in data.chunks_exact(bch::BLOCK).enumerate() {
            block.copy_from_slice(chunk);
            match bch::decode_block(&mut block) {
                bch::BchOutcome::Clean => {}
                bch::BchOutcome::Corrected(_) => stats.corrected += 1,
                bch::BchOutcome::Detected => stats.detected += 1,
            }
            let at = bi * bch::BLOCK;
            out[at..at + bch::BLOCK].copy_from_slice(&block.map(|b| b as i8));
        }
        stats
    }
    fn scrub_span(&self, data: &mut [u8], _oob: &mut [u8]) -> DecodeStats {
        // Per-block scrub: heal correctable blocks in place, leave
        // uncorrectable blocks exactly as stored (the old whole-buffer
        // decode+re-encode default abandoned the entire pass when any
        // block was uncorrectable).
        let mut stats = DecodeStats::default();
        let mut block = [0u8; bch::BLOCK];
        for chunk in data.chunks_exact_mut(bch::BLOCK) {
            block.copy_from_slice(chunk);
            match bch::decode_block(&mut block) {
                bch::BchOutcome::Clean => {}
                bch::BchOutcome::Corrected(_) => {
                    stats.corrected += 1;
                    bch::encode_block(&mut block);
                    chunk.copy_from_slice(&block);
                }
                bch::BchOutcome::Detected => stats.detected += 1,
            }
        }
        stats
    }
}

// -------------------------------------------------------------- lookup --

/// The paper's Table-2 strategy set, in row order.
pub fn all_strategies() -> Vec<Box<dyn Protection>> {
    vec![
        Box::new(Unprotected),
        Box::new(ParityZero),
        Box::new(Secded7264),
        Box::new(InplaceZs),
    ]
}

/// Every strategy including the bch16 extension (shard-equivalence tests
/// and benches sweep this).
pub fn all_strategies_ext() -> Vec<Box<dyn Protection>> {
    let mut v = all_strategies();
    v.push(Box::new(Bch16));
    v
}

/// Lookup by paper name (includes the bch16 extension and the MILR
/// plaintext-recovery strategy). `milr` deliberately stays out of
/// `all_strategies`/`all_strategies_ext`: those sets are swept by
/// equivalence properties that assume single-flip *correction*, which
/// milr delegates to the algebraic recovery tier instead of the code.
pub fn strategy_by_name(name: &str) -> anyhow::Result<Box<dyn Protection>> {
    Ok(match name {
        "faulty" => Box::new(Unprotected) as Box<dyn Protection>,
        "zero" => Box::new(ParityZero),
        "ecc" => Box::new(Secded7264),
        "in-place" | "inplace" => Box::new(InplaceZs),
        "bch16" => Box::new(Bch16),
        "milr" => Box::new(super::milr::Milr),
        _ => anyhow::bail!("unknown strategy '{name}' (faulty|zero|ecc|in-place|bch16|milr)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn wot_weights(n: usize, seed: u64) -> Vec<i8> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                if i % 8 == 7 {
                    (rng.below(256) as i64 - 128) as i8
                } else {
                    (rng.below(128) as i64 - 64) as i8
                }
            })
            .collect()
    }

    #[test]
    fn all_strategies_roundtrip_clean() {
        let w = wot_weights(1024, 5);
        for s in all_strategies() {
            let enc = s.encode(&w).unwrap();
            let mut out = vec![0i8; w.len()];
            let stats = s.decode(&enc, &mut out);
            assert_eq!(out, w, "{} altered clean weights", s.name());
            assert_eq!(stats, DecodeStats::default());
        }
    }

    #[test]
    fn overheads_match_paper() {
        assert_eq!(strategy_by_name("faulty").unwrap().overhead(), 0.0);
        assert_eq!(strategy_by_name("zero").unwrap().overhead(), 0.125);
        assert_eq!(strategy_by_name("ecc").unwrap().overhead(), 0.125);
        assert_eq!(strategy_by_name("in-place").unwrap().overhead(), 0.0);
        let w = wot_weights(800, 6);
        // overhead accounting must match actual storage
        for s in all_strategies() {
            let enc = s.encode(&w).unwrap();
            let expect = (w.len() as f64 * s.overhead()).round() as usize;
            assert_eq!(enc.oob.len(), expect, "{}", s.name());
        }
    }

    #[test]
    fn oob_geometry_matches_encode() {
        let w = wot_weights(512, 13);
        for s in all_strategies_ext() {
            let enc = s.encode(&w).unwrap();
            assert_eq!(enc.data.len() % s.block_bytes(), 0, "{}", s.name());
            assert_eq!(
                enc.oob.len(),
                enc.data.len() / s.block_bytes() * s.oob_bytes_per_block(),
                "{}: oob length must be blocks * oob_bytes_per_block",
                s.name()
            );
        }
    }

    #[test]
    fn inplace_rejects_unthrottled() {
        let mut w = wot_weights(64, 7);
        w[1] = 100; // violates
        assert!(strategy_by_name("in-place").unwrap().encode(&w).is_err());
    }

    #[test]
    fn ecc_and_inplace_correct_single_flip_per_block() {
        let w = wot_weights(512, 8);
        for name in ["ecc", "in-place"] {
            let s = strategy_by_name(name).unwrap();
            let mut enc = s.encode(&w).unwrap();
            let mut rng = Rng::new(9);
            // one flip in each block's stored bits
            let nblocks = w.len() / 8;
            for bi in 0..nblocks {
                let bit = rng.below(64);
                enc.flip_bit(bi as u64 * 64 + bit);
            }
            let mut out = vec![0i8; w.len()];
            let stats = s.decode(&enc, &mut out);
            assert_eq!(out, w, "{name} must correct 1 flip/block");
            assert_eq!(stats.corrected, nblocks as u64, "{name}");
        }
    }

    #[test]
    fn zero_strategy_zeroes_detected() {
        let w = wot_weights(64, 10);
        let s = strategy_by_name("zero").unwrap();
        let mut enc = s.encode(&w).unwrap();
        enc.data[5] ^= 0x04;
        let mut out = vec![0i8; w.len()];
        let stats = s.decode(&enc, &mut out);
        assert_eq!(out[5], 0);
        assert_eq!(stats.zeroed, 1);
        for (i, (&a, &b)) in out.iter().zip(&w).enumerate() {
            if i != 5 {
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn scrub_heals_single_then_survives_second_flip() {
        // The scrubbing rationale: two flips separated by a scrub are
        // both correctable; without scrub they'd be a double error.
        let w = wot_weights(8, 11);
        let s = strategy_by_name("in-place").unwrap();
        let mut enc = s.encode(&w).unwrap();
        enc.flip_bit(3);
        let stats = s.scrub(&mut enc);
        assert_eq!(stats.corrected, 1);
        enc.flip_bit(40);
        let mut out = vec![0i8; 8];
        let stats = s.decode(&enc, &mut out);
        assert_eq!(stats.corrected, 1);
        assert_eq!(out, w);
    }

    #[test]
    fn decode_range_matches_window_of_full_decode() {
        let w = wot_weights(64 * 8, 21);
        for s in all_strategies() {
            let mut enc = s.encode(&w).unwrap();
            let mut rng = Rng::new(22);
            let total = enc.total_bits();
            for _ in 0..24 {
                enc.flip_bit(rng.below(total));
            }
            let mut full = vec![0i8; w.len()];
            let full_stats = s.decode(&enc, &mut full);
            // window = the middle half, aligned to the largest block size
            let (a, b) = (w.len() / 4 / 16 * 16, 3 * w.len() / 4 / 16 * 16);
            let mut win = vec![0i8; b - a];
            s.decode_range(&enc, a, b, &mut win);
            assert_eq!(win, full[a..b], "{}: window mismatch", s.name());
            // ranges tile the buffer: stats must sum to the full pass
            let mut sum = DecodeStats::default();
            let mut out3 = vec![0i8; w.len()];
            for (lo, hi) in [(0, a), (a, b), (b, w.len())] {
                sum.add(&s.decode_range(&enc, lo, hi, &mut out3[lo..hi]));
            }
            assert_eq!(sum, full_stats, "{}: stats must tile", s.name());
            assert_eq!(out3, full, "{}: tiled decode mismatch", s.name());
        }
    }

    #[test]
    fn scrub_range_tiles_like_full_scrub() {
        let w = wot_weights(64 * 8, 31);
        for s in all_strategies() {
            let mut enc = s.encode(&w).unwrap();
            let mut rng = Rng::new(32);
            let total = enc.total_bits();
            for _ in 0..24 {
                enc.flip_bit(rng.below(total));
            }
            let mut whole = enc.clone();
            let whole_stats = s.scrub(&mut whole);
            let mut tiled = enc.clone();
            let mut sum = DecodeStats::default();
            let (a, b) = (w.len() / 4 / 16 * 16, 3 * w.len() / 4 / 16 * 16);
            for (lo, hi) in [(0, a), (a, b), (b, w.len())] {
                sum.add(&s.scrub_range(&mut tiled, lo, hi));
            }
            assert_eq!(sum, whole_stats, "{}: scrub stats must tile", s.name());
            assert_eq!(tiled.data, whole.data, "{}: scrub data mismatch", s.name());
            assert_eq!(tiled.oob, whole.oob, "{}: scrub oob mismatch", s.name());
        }
    }

    #[test]
    fn tiled_span_forms_match_scalar_on_multi_tile_buffers() {
        // 2 full tiles + a ragged 3-block tail, one flip per tile plus
        // a clean stretch: tiled and scalar must agree bit-for-bit.
        let w = wot_weights(2 * 64 * 8 + 3 * 8, 17);
        for s in all_strategies() {
            let mut enc = s.encode(&w).unwrap();
            enc.flip_bit(5); // tile 0
            enc.flip_bit(64 * 64 + 700); // tile 1
            let mut a = vec![0i8; w.len()];
            let mut b = vec![0i8; w.len()];
            let sa = s.decode_span(&enc.data, &enc.oob, &mut a);
            let sb = s.decode_span_tiled(&enc.data, &enc.oob, &mut b);
            assert_eq!(a, b, "{}: tiled decode output", s.name());
            assert_eq!(sa, sb, "{}: tiled decode stats", s.name());
            let (mut da, mut oa) = (enc.data.clone(), enc.oob.clone());
            let (mut db, mut ob) = (enc.data.clone(), enc.oob.clone());
            let ra = s.scrub_span(&mut da, &mut oa);
            let rb = s.scrub_span_tiled(&mut db, &mut ob);
            assert_eq!(da, db, "{}: tiled scrub data", s.name());
            assert_eq!(oa, ob, "{}: tiled scrub oob", s.name());
            assert_eq!(ra, rb, "{}: tiled scrub stats", s.name());
        }
    }

    #[test]
    fn clean_tile_probe_agrees_with_decode() {
        let w = wot_weights(64 * 8, 19);
        for s in all_strategies() {
            let enc = s.encode(&w).unwrap();
            assert!(
                s.tile_is_clean(&enc.data, &enc.oob),
                "{}: pristine tile must probe clean",
                s.name()
            );
            if s.block_bytes() == 1 {
                continue; // unprotected: no syndrome to dirty
            }
            let mut hit = enc.clone();
            hit.data[100] ^= 0x08;
            assert!(
                !s.tile_is_clean(&hit.data, &hit.oob),
                "{}: corrupted tile must probe dirty",
                s.name()
            );
        }
    }

    #[test]
    fn decode_outcome_names_the_uncorrectable_blocks() {
        // multi-tile buffer (2 tiles + ragged 3-block tail); double
        // flips in chosen blocks must surface as exactly those indices,
        // with stats identical to the plain decode.
        let w = wot_weights(2 * 64 * 8 + 3 * 8, 41);
        let victims = [3usize, 70, 130]; // tile 0, tile 1, ragged tail
        for name in ["ecc", "in-place"] {
            let s = strategy_by_name(name).unwrap();
            let mut enc = s.encode(&w).unwrap();
            for &bi in &victims {
                enc.flip_bit(bi as u64 * 64 + 1);
                enc.flip_bit(bi as u64 * 64 + 9);
            }
            let mut a = vec![0i8; w.len()];
            let mut b = vec![0i8; w.len()];
            let plain = s.decode(&enc, &mut a);
            let outc = s.decode_range_outcome(&enc, 0, enc.data.len(), &mut b);
            assert_eq!(outc.stats, plain, "{name}: outcome stats drifted");
            assert_eq!(a, b, "{name}: outcome decode output drifted");
            assert_eq!(outc.detected_blocks, victims, "{name}");
            assert!(!outc.overflow);
            // a window starting mid-image reports absolute indices
            let start = 64 * 8; // tile 1
            let mut win = vec![0i8; enc.data.len() - start];
            let outw = s.decode_range_outcome(&enc, start, enc.data.len(), &mut win);
            assert_eq!(outw.detected_blocks, [70, 130], "{name}: base offset");
        }
    }

    #[test]
    fn scrub_outcome_matches_plain_scrub_and_finds_blocks() {
        let w = wot_weights(64 * 8 + 5 * 8, 43);
        for s in all_strategies_ext() {
            if s.block_bytes() == 1 {
                continue; // unprotected never detects
            }
            let mut enc = s.encode(&w).unwrap();
            // double-flip data bits of blocks 2 and 66 (block size 8)
            // or 1 and 33 (block size 16) — same byte positions either way
            let bb = s.block_bytes();
            let victims: Vec<usize> = [2usize, 66].iter().map(|&v| v * 8 / bb).collect();
            // two flips per 64-bit lane defeat the Hsiao codes (even-
            // weight syndrome -> detect); bch16 corrects doubles, so it
            // gets a third flip
            let flips: &[u64] = if bb == 16 { &[2, 11, 21] } else { &[2, 11] };
            for &v in &[2u64, 66] {
                for &f in flips {
                    enc.flip_bit(v * 64 + f);
                }
            }
            let mut plain = enc.clone();
            let pstats = s.scrub(&mut plain);
            let len = enc.data.len();
            let outc = s.scrub_range_outcome(&mut enc, 0, len);
            assert_eq!(outc.stats, pstats, "{}: scrub outcome stats", s.name());
            assert_eq!(enc.data, plain.data, "{}: scrub outcome image", s.name());
            assert_eq!(enc.oob, plain.oob, "{}: scrub outcome oob", s.name());
            assert!(pstats.detected > 0, "{}: victims must stay detected", s.name());
            let mut got = outc.detected_blocks.clone();
            got.dedup();
            assert_eq!(got, victims, "{}: scrubbed block set", s.name());
        }
    }

    #[test]
    fn outcome_list_caps_and_flags_overflow() {
        let nblocks = DETECTED_BLOCK_CAP + 40;
        let w = wot_weights(nblocks * 8, 47);
        let s = strategy_by_name("ecc").unwrap();
        let mut enc = s.encode(&w).unwrap();
        for bi in 0..nblocks as u64 {
            enc.flip_bit(bi * 64 + 3);
            enc.flip_bit(bi * 64 + 12);
        }
        let mut out = vec![0i8; w.len()];
        let outc = s.decode_range_outcome(&enc, 0, enc.data.len(), &mut out);
        assert_eq!(outc.stats.detected, nblocks as u64, "stats stay exact");
        assert_eq!(outc.detected_blocks.len(), DETECTED_BLOCK_CAP);
        assert!(outc.overflow, "cap hit must be flagged");
    }

    #[test]
    fn bch16_corrects_double_flip_in_block() {
        let mut rng = Rng::new(12);
        let w: Vec<i8> = (0..160)
            .map(|i| {
                if i % 16 == 15 {
                    (rng.below(256) as i64 - 128) as i8
                } else {
                    (rng.below(64) as i64 - 32) as i8
                }
            })
            .collect();
        let s = strategy_by_name("bch16").unwrap();
        let mut enc = s.encode(&w).unwrap();
        enc.flip_bit(3);
        enc.flip_bit(77); // same 128-bit block
        let mut out = vec![0i8; w.len()];
        let stats = s.decode(&enc, &mut out);
        assert_eq!(out, w, "bch16 must correct a double flip");
        assert_eq!(stats.corrected, 1);
    }
}
