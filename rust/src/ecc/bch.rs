//! Future-work extension (paper section 6): double-error-correcting
//! BCH-style protection fed entirely from non-informative bits.
//!
//! The paper notes that stronger codes (e.g. BCH) "require more parity
//! bits, for which the regularized training may need to be extended to
//! create more free bits". This module implements exactly that trade:
//!
//! * **Extended WOT constraint**: bytes 0..14 of every 16-byte block are
//!   confined to [-32, 31] — a two's-complement value then has bits
//!   5 and 6 equal to bit 7, i.e. *two* non-informative bits per small
//!   weight (30 free bits per 128-bit block).
//! * **Code**: a shortened binary BCH over GF(2^8) with t = 2 (16 check
//!   bits <= 30 free bits), correcting any two bit errors and detecting
//!   most triples, still at zero space cost.
//!
//! Decoding: syndromes S1 = sum a^p, S3 = sum a^{3p}; single error when
//! S3 = S1^3; double errors located by the quadratic error-locator via
//! Chien search. After correction the sign-copy restore runs over both
//! free bits of every small weight.

use std::sync::OnceLock;

/// Block geometry.
pub const BLOCK: usize = 16; // bytes per protected block
pub const NBITS: usize = BLOCK * 8; // 128 codeword bits
pub const SMALL_LO: i8 = -32;
pub const SMALL_HI: i8 = 31;
/// Free-bit mask within a small byte: bits 5 and 6.
const FREE_MASK: u8 = 0b0110_0000;

// ---------------------------------------------------------------- GF(2^8)

const POLY: u32 = 0x11D;

struct Gf {
    exp: [u8; 512],
    log: [u16; 256],
}

fn gf() -> &'static Gf {
    static GF: OnceLock<Gf> = OnceLock::new();
    GF.get_or_init(|| {
        let mut exp = [0u8; 512];
        let mut log = [0u16; 256];
        let mut x: u32 = 1;
        for i in 0..255 {
            exp[i] = x as u8;
            log[x as usize] = i as u16;
            x <<= 1;
            if x & 0x100 != 0 {
                x ^= POLY;
            }
        }
        for i in 255..512 {
            exp[i] = exp[i - 255];
        }
        Gf { exp, log }
    })
}

#[inline]
fn gmul(a: u8, b: u8) -> u8 {
    if a == 0 || b == 0 {
        return 0;
    }
    let g = gf();
    g.exp[(g.log[a as usize] + g.log[b as usize]) as usize]
}

#[inline]
fn gdiv(a: u8, b: u8) -> u8 {
    debug_assert!(b != 0);
    if a == 0 {
        return 0;
    }
    let g = gf();
    g.exp[(g.log[a as usize] + 255 - g.log[b as usize]) as usize]
}

#[inline]
fn gpow_alpha(e: usize) -> u8 {
    gf().exp[e % 255]
}

// ----------------------------------------------------------- code tables

struct BchTables {
    /// a^p per bit position (h3 = a^{3p} is folded into `slut`/`cols`
    /// at construction and not needed at decode time).
    h1: [u8; NBITS],
    /// Inverse of the 16x16 GF(2) map check-bits -> (S1 | S3 << 8).
    minv: [u16; 16],
    /// Per-byte syndrome LUT: slut[byte][value] = S1 | S3 << 8 of the
    /// set bits of `value` at byte position `byte` (the decode hot path
    /// — 16 lookups replace a per-set-bit GF walk).
    slut: Vec<[u16; 256]>,
    /// Encode spread tables: check-bit vector byte -> u64 mask over the
    /// low word of the block (all 16 check positions live in bytes 0..7).
    sp_lo: [u64; 256],
    sp_hi: [u64; 256],
    /// Mask of all check-bit positions within the low word.
    check_mask_lo: u64,
}

fn check_positions() -> [usize; 16] {
    let mut pos = [0usize; 16];
    for byte in 0..8 {
        pos[2 * byte] = byte * 8 + 5;
        pos[2 * byte + 1] = byte * 8 + 6;
    }
    pos
}

/// Invert a 16x16 GF(2) matrix given as 16 column vectors (u16 each).
/// Returns the inverse as column vectors. Panics if singular.
fn invert16(cols: [u16; 16]) -> [u16; 16] {
    // rows[i] = bits of row i across columns; augment with identity.
    let mut a = [0u32; 16]; // low 16 bits: matrix row, high 16: identity
    for (i, row) in a.iter_mut().enumerate() {
        let mut r = 0u16;
        for (j, c) in cols.iter().enumerate() {
            if c >> i & 1 == 1 {
                r |= 1 << j;
            }
        }
        *row = r as u32 | (1u32 << (16 + i));
    }
    for col in 0..16 {
        let piv = (col..16)
            .find(|&r| a[r] >> col & 1 == 1)
            .expect("BCH check matrix singular");
        a.swap(col, piv);
        for r in 0..16 {
            if r != col && a[r] >> col & 1 == 1 {
                a[r] ^= a[col];
            }
        }
    }
    // Extract inverse columns: inv[j] has bit i = element (i, j) of A^-1.
    let mut inv = [0u16; 16];
    for (i, row) in a.iter().enumerate() {
        let r = (row >> 16) as u16;
        for (j, c) in inv.iter_mut().enumerate() {
            if r >> j & 1 == 1 {
                *c |= 1 << i;
            }
        }
    }
    inv
}

fn tables() -> &'static BchTables {
    static T: OnceLock<BchTables> = OnceLock::new();
    T.get_or_init(|| {
        let mut h1 = [0u8; NBITS];
        let mut h3 = [0u8; NBITS];
        for p in 0..NBITS {
            h1[p] = gpow_alpha(p);
            h3[p] = gpow_alpha(3 * p);
        }
        let check_pos = check_positions();
        let mut cols = [0u16; 16];
        for (j, &p) in check_pos.iter().enumerate() {
            cols[j] = (h1[p] as u16) | ((h3[p] as u16) << 8);
        }
        let minv = invert16(cols);
        let mut slut = vec![[0u16; 256]; BLOCK];
        for (byte, table) in slut.iter_mut().enumerate() {
            for v in 0..256usize {
                let mut s = 0u16;
                for j in 0..8 {
                    if v & (1 << j) != 0 {
                        let p = byte * 8 + j;
                        s ^= (h1[p] as u16) | ((h3[p] as u16) << 8);
                    }
                }
                table[v] = s;
            }
        }
        let mut sp_lo = [0u64; 256];
        let mut sp_hi = [0u64; 256];
        let mut check_mask_lo = 0u64;
        for &p in &check_pos {
            debug_assert!(p < 64);
            check_mask_lo |= 1u64 << p;
        }
        for v in 0..256usize {
            for j in 0..8 {
                if v & (1 << j) != 0 {
                    sp_lo[v] |= 1u64 << check_pos[j];
                    sp_hi[v] |= 1u64 << check_pos[8 + j];
                }
            }
        }
        BchTables {
            h1,
            minv,
            slut,
            sp_lo,
            sp_hi,
            check_mask_lo,
        }
    })
}

// ------------------------------------------------------------- block ops

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BchOutcome {
    Clean,
    Corrected(usize), // number of bits corrected (1 or 2)
    Detected,
}

#[inline]
fn syndromes(block: &[u8; BLOCK]) -> (u8, u8) {
    let t = tables();
    let mut s = 0u16;
    for (byte, &v) in block.iter().enumerate() {
        s ^= t.slut[byte][v as usize];
    }
    ((s & 0xff) as u8, (s >> 8) as u8)
}

/// Is the extended small-weight constraint satisfied by this value?
#[inline]
pub fn is_small_ext(w: i8) -> bool {
    (SMALL_LO..=SMALL_HI).contains(&w)
}

/// Branch-free extended-constraint check on a 64-bit word: a byte is in
/// [-32, 31] iff bits 5 and 6 both equal bit 7; disagreements collect at
/// bit 7 of each byte.
#[inline(always)]
fn ext_violation_mask_u64(w: u64) -> u64 {
    ((w ^ (w << 1)) | (w ^ (w << 2))) & 0x8080_8080_8080_8080
}

/// Fast whole-buffer extended-constraint check (encode hot path).
/// Agrees with `encode` on every input: a ragged tail cannot form a
/// whole 128-bit block, so it fails here just as encode rejects it
/// (same contract as `inplace::satisfies_constraint`).
pub fn satisfies_constraint_ext(weights: &[i8]) -> bool {
    weights.len() % BLOCK == 0
        && weights.chunks_exact(BLOCK).all(|chunk| {
            let mut b = [0u8; BLOCK];
            for (d, &s) in b.iter_mut().zip(chunk) {
                *d = s as u8;
            }
            let lo = u64::from_le_bytes(b[..8].try_into().unwrap());
            let hi = u64::from_le_bytes(b[8..].try_into().unwrap());
            // byte 15 (top byte of `hi`) is the free byte
            ext_violation_mask_u64(lo) == 0
                && (ext_violation_mask_u64(hi) & 0x0080_8080_8080_8080) == 0
        })
}

/// Indices violating the extended constraint (first 15 of each 16).
pub fn constraint_violations_ext(weights: &[i8]) -> Vec<usize> {
    weights
        .chunks_exact(BLOCK)
        .enumerate()
        .flat_map(|(bi, chunk)| {
            chunk[..BLOCK - 1]
                .iter()
                .enumerate()
                .filter(|(_, &w)| !is_small_ext(w))
                .map(move |(j, _)| bi * BLOCK + j)
        })
        .collect()
}

/// Sign-copy restore of both free bits for bytes 0..14.
#[inline]
pub fn restore_block(block: &mut [u8; BLOCK]) {
    for b in block.iter_mut().take(BLOCK - 1) {
        let sign = (*b >> 7) & 1;
        let fill = if sign == 1 { FREE_MASK } else { 0 };
        *b = (*b & !FREE_MASK) | fill;
    }
}

/// Encode: overwrite the 16 check positions so S1 = S3 = 0.
pub fn encode_block(block: &mut [u8; BLOCK]) {
    let t = tables();
    // All check positions live in the low 8 bytes: one masked store.
    let mut lo = u64::from_le_bytes(block[..8].try_into().unwrap());
    lo &= !t.check_mask_lo;
    block[..8].copy_from_slice(&lo.to_le_bytes());
    let (s1, s3) = syndromes(block);
    let target = (s1 as u16) | ((s3 as u16) << 8);
    // check-bit vector c = M^-1 * target
    let mut c = 0u16;
    for (i, col) in t.minv.iter().enumerate() {
        if (target >> i) & 1 == 1 {
            c ^= col;
        }
    }
    lo |= t.sp_lo[(c & 0xff) as usize] | t.sp_hi[(c >> 8) as usize];
    block[..8].copy_from_slice(&lo.to_le_bytes());
    debug_assert_eq!(syndromes(block), (0, 0));
}

/// Decode + sign restore. Corrects up to two bit errors.
pub fn decode_block(block: &mut [u8; BLOCK]) -> BchOutcome {
    let (s1, s3) = syndromes(block);
    let out = if s1 == 0 && s3 == 0 {
        BchOutcome::Clean
    } else if s1 != 0 && s3 == gmul(gmul(s1, s1), s1) {
        // single error at p = log(S1)
        let p = gf().log[s1 as usize] as usize;
        if p < NBITS {
            block[p / 8] ^= 1 << (p % 8);
            BchOutcome::Corrected(1)
        } else {
            BchOutcome::Detected
        }
    } else if s1 != 0 {
        // two errors: e1 + e2 = S1, e1*e2 = (S3 + S1^3) / S1
        let s1cube = gmul(gmul(s1, s1), s1);
        let prod = gdiv(s3 ^ s1cube, s1);
        // Chien search over the 128 shortened positions.
        let t = tables();
        let mut roots = [0usize; 2];
        let mut nroots = 0;
        for p in 0..NBITS {
            let x = t.h1[p];
            // x^2 + S1 x + prod == 0 ?
            if gmul(x, x) ^ gmul(s1, x) ^ prod == 0 {
                if nroots < 2 {
                    roots[nroots] = p;
                }
                nroots += 1;
            }
        }
        if nroots == 2 {
            for &p in &roots {
                block[p / 8] ^= 1 << (p % 8);
            }
            BchOutcome::Corrected(2)
        } else {
            BchOutcome::Detected
        }
    } else {
        // S1 == 0, S3 != 0: uncorrectable.
        BchOutcome::Detected
    };
    restore_block(block);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ext_block(rng: &mut Rng) -> [u8; BLOCK] {
        let mut b = [0u8; BLOCK];
        for (i, v) in b.iter_mut().enumerate() {
            let w: i8 = if i < BLOCK - 1 {
                (rng.below(64) as i64 - 32) as i8
            } else {
                (rng.below(256) as i64 - 128) as i8
            };
            *v = w as u8;
        }
        b
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(21);
        for _ in 0..500 {
            let orig = ext_block(&mut rng);
            let mut enc = orig;
            encode_block(&mut enc);
            let mut dec = enc;
            assert_eq!(decode_block(&mut dec), BchOutcome::Clean);
            assert_eq!(dec, orig);
        }
    }

    #[test]
    fn corrects_all_single_flips() {
        let mut rng = Rng::new(22);
        for _ in 0..20 {
            let orig = ext_block(&mut rng);
            let mut enc = orig;
            encode_block(&mut enc);
            for bit in 0..NBITS {
                let mut w = enc;
                w[bit / 8] ^= 1 << (bit % 8);
                let mut dec = w;
                assert!(matches!(decode_block(&mut dec), BchOutcome::Corrected(1)));
                assert_eq!(dec, orig, "single flip at {bit}");
            }
        }
    }

    #[test]
    fn corrects_all_double_flips() {
        let mut rng = Rng::new(23);
        let orig = ext_block(&mut rng);
        let mut enc = orig;
        encode_block(&mut enc);
        for b1 in 0..NBITS {
            for b2 in (b1 + 1)..NBITS {
                let mut w = enc;
                w[b1 / 8] ^= 1 << (b1 % 8);
                w[b2 / 8] ^= 1 << (b2 % 8);
                let mut dec = w;
                assert!(
                    matches!(decode_block(&mut dec), BchOutcome::Corrected(2)),
                    "double flip {b1},{b2}"
                );
                assert_eq!(dec, orig, "double flip {b1},{b2}");
            }
        }
    }

    #[test]
    fn violations_ext() {
        let mut w = vec![0i8; 32];
        w[0] = 32; // violation
        w[15] = 127; // free byte, fine
        w[20] = -33; // violation in second block
        assert_eq!(constraint_violations_ext(&w), vec![0, 20]);
    }
}
