//! Error-correction codes and protection strategies.
//!
//! * [`hsiao`] — generic Hsiao SEC-DED code machinery (odd-weight-column
//!   H matrix, byte-LUT syndrome computation, single-correct/double-detect).
//! * [`secded`] — the two instantiations the paper uses: the conventional
//!   out-of-band (72, 64, 1) and the in-place (64, 57, 1).
//! * [`inplace`] — in-place zero-space ECC: check bits live in the
//!   non-informative bit6 of the first seven bytes of every 64-bit block
//!   (paper section 4.2 + Fig. 2 datapath).
//! * [`parity`] — the Parity-Zero baseline (detect + zero the weight).
//! * [`bch`] — future-work extension (paper section 6): a double-error-
//!   correcting BCH code fed from the *two* free bits per byte that the
//!   extended WOT constraint provides.
//! * [`strategy`] — the `Protection` trait unifying all of the above
//!   (plus unprotected), with exact space-overhead accounting.

pub mod bch;
pub mod hsiao;
pub mod inplace;
pub mod parity;
pub mod secded;
pub mod strategy;

pub use hsiao::{HsiaoCode, Outcome};
pub use strategy::{
    all_strategies, all_strategies_ext, strategy_by_name, DecodeStats, Encoded, Protection,
};
