//! Error-correction codes and protection strategies.
//!
//! * [`hsiao`] — generic Hsiao SEC-DED code machinery (odd-weight-column
//!   H matrix, byte-LUT syndrome computation, single-correct/double-detect).
//! * [`secded`] — the two instantiations the paper uses: the conventional
//!   out-of-band (72, 64, 1) and the in-place (64, 57, 1).
//! * [`inplace`] — in-place zero-space ECC: check bits live in the
//!   non-informative bit6 of the first seven bytes of every 64-bit block
//!   (paper section 4.2 + Fig. 2 datapath).
//! * [`parity`] — the Parity-Zero baseline (detect + zero the weight).
//! * [`bch`] — future-work extension (paper section 6): a double-error-
//!   correcting BCH code fed from the *two* free bits per byte that the
//!   extended WOT constraint provides.
//! * [`milr`] — MILR-style plaintext strategy: zero stored redundancy,
//!   detection via the free WOT bit6==bit7 invariant, correction
//!   delegated to algebraic layer recovery ([`crate::model::recovery`]).
//! * [`tile`] — the word-parallel (bitsliced) tile decode engine:
//!   64 blocks per iteration via a 64x64 bit transpose and XOR-parity
//!   syndrome planes, with a one-word all-clean proof that turns clean
//!   decodes into straight copies and clean scrubs into no-ops.
//! * [`strategy`] — the `Protection` trait unifying all of the above
//!   (plus unprotected), with exact space-overhead accounting.

pub mod bch;
pub mod hsiao;
pub mod inplace;
pub mod milr;
pub mod parity;
pub mod secded;
pub mod strategy;
pub mod tile;

pub use hsiao::{HsiaoCode, Outcome};
pub use strategy::{
    all_strategies, all_strategies_ext, strategy_by_name, CleanPath, DecodeOutcome, DecodeStats,
    Encoded, Protection, QuantGrid, DETECTED_BLOCK_CAP,
};
