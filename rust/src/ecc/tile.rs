//! Word-parallel (bitsliced) tile decode engine.
//!
//! The scalar decode path pays 8 dependent LUT loads per 64-bit block
//! (`HsiaoCode::syndrome_u64`) plus a branchy correction even when the
//! block is clean — the overwhelmingly common case at realistic fault
//! rates. This module processes a *tile* of 64 blocks (512 data bytes)
//! at once:
//!
//!  1. bit-transpose the 64x64 tile (classic masked-swap transpose,
//!     6 rounds of 32 swap ops) so word `t[p]` holds codeword bit `p`
//!     of every lane;
//!  2. compute each syndrome bit for all 64 lanes as one XOR-parity
//!     over the masked bit-planes ([`TileCode::syndrome_planes`]);
//!  3. OR-reduce the syndrome planes: a zero word proves the whole
//!     tile clean with no per-lane work at all, and the set bits of a
//!     nonzero word name the (rare) lanes that need the scalar
//!     correction fallback.
//!
//! The syndrome-plane identity: lane `j`'s syndrome bit `i` is
//! `parity(w_j & M_i)` with `M_i` the mask of codeword positions whose
//! H-column has bit `i` set; after transposition that parity is bit `j`
//! of `XOR_{p in M_i} t[p]`, so 64 lanes cost what one lane used to.
//! Out-of-band check bytes (the (72, 64) code) join the same way via
//! their own bit-planes ([`oob_planes`]).

use super::hsiao::HsiaoCode;
use super::secded::{code_6457_inplace, code_7264};
use std::sync::OnceLock;

/// Blocks (lanes) per tile.
pub const LANES: usize = 64;
/// Data bytes per lane (one 64-bit codeword).
pub const LANE_BYTES: usize = 8;
/// Data bytes per tile.
pub const TILE_BYTES: usize = LANES * LANE_BYTES;

/// All-zero substitute for the check-byte planes of zero-space codes.
pub const NO_OOB: [u64; 8] = [0u64; 8];

/// In-place 64x64 bit-matrix transpose (masked-swap, LSB-first
/// convention): afterwards bit `j` of `a[p]` is bit `p` of the original
/// `a[j]`. Involution: applying it twice restores the input.
pub fn transpose64(a: &mut [u64; 64]) {
    let mut j: usize = 32;
    let mut m: u64 = 0x0000_0000_FFFF_FFFF;
    while j != 0 {
        let mut k: usize = 0;
        while k < 64 {
            let t = ((a[k] >> j) ^ a[k + j]) & m;
            a[k] ^= t << j;
            a[k + j] ^= t;
            k = (k + j + 1) & !j;
        }
        j >>= 1;
        m ^= m << j;
    }
}

/// Load a tile's 64 little-endian lane words.
#[inline]
pub fn load_lanes(data: &[u8]) -> [u64; 64] {
    debug_assert_eq!(data.len(), TILE_BYTES);
    let mut lanes = [0u64; 64];
    for (l, chunk) in lanes.iter_mut().zip(data.chunks_exact(LANE_BYTES)) {
        *l = u64::from_le_bytes(chunk.try_into().unwrap());
    }
    lanes
}

/// The weight bytes of one decoded lane word as i8 — a safe u8 -> i8
/// chunk cast that compiles to an 8-byte move, replacing the old
/// byte-by-byte scatter in the scalar decode fallbacks.
#[inline(always)]
pub fn lane_i8(w: u64) -> [i8; 8] {
    w.to_le_bytes().map(|b| b as i8)
}

/// Bit-planes of a tile's 64 out-of-band check bytes: bit `j` of
/// `planes[i]` is bit `i` of `oob[j]` (the SWAR multiply gather of
/// `parity::parity_word`, one multiply per 8 bytes per plane).
pub fn oob_planes(oob: &[u8]) -> [u64; 8] {
    debug_assert_eq!(oob.len(), LANES);
    let mut planes = [0u64; 8];
    for (g, chunk) in oob.chunks_exact(8).enumerate() {
        let w = u64::from_le_bytes(chunk.try_into().unwrap());
        for (i, p) in planes.iter_mut().enumerate() {
            let gathered =
                ((w >> i) & 0x0101_0101_0101_0101).wrapping_mul(0x0102_0408_1020_4080) >> 56;
            *p |= gathered << (g * 8);
        }
    }
    planes
}

/// The bitsliced form of a Hsiao code's parity-check matrix H: one
/// position mask per syndrome bit instead of one column per position.
pub struct TileCode {
    /// `data_masks[i]`: codeword bit positions 0..64 whose H-column has
    /// syndrome bit `i` set.
    pub data_masks: [u64; 8],
    /// Bits of the out-of-band check byte (codeword positions 64..n)
    /// contributing to syndrome bit `i`. With unit check columns this
    /// is `1 << i`; kept general so any `HsiaoCode` bitslices.
    pub oob_masks: [u8; 8],
    /// Number of check bits of the underlying code.
    pub r: usize,
}

impl TileCode {
    /// Bitslice a code with a 64-bit in-band codeword part (n = 64 for
    /// the in-place code, n = 72 for the conventional one).
    pub fn new(code: &HsiaoCode) -> TileCode {
        assert!((64..=72).contains(&code.n), "tile engine carries 64-bit lanes");
        let mut data_masks = [0u64; 8];
        let mut oob_masks = [0u8; 8];
        for (p, &c) in code.cols.iter().enumerate() {
            for i in 0..code.r {
                if c & (1 << i) != 0 {
                    if p < 64 {
                        data_masks[i] |= 1u64 << p;
                    } else {
                        oob_masks[i] |= 1 << (p - 64);
                    }
                }
            }
        }
        TileCode {
            data_masks,
            oob_masks,
            r: code.r,
        }
    }

    /// Syndrome bit-planes of a *transposed* tile: bit `j` of plane `i`
    /// is syndrome bit `i` of lane `j`. `oob` carries the check-byte
    /// bit-planes ([`NO_OOB`] for zero-space codes).
    pub fn syndrome_planes(&self, t: &[u64; 64], oob: &[u64; 8]) -> [u64; 8] {
        let mut planes = [0u64; 8];
        for (i, plane) in planes.iter_mut().enumerate().take(self.r) {
            let mut acc = 0u64;
            let mut m = self.data_masks[i];
            while m != 0 {
                acc ^= t[m.trailing_zeros() as usize];
                m &= m - 1;
            }
            let mut om = self.oob_masks[i];
            while om != 0 {
                acc ^= oob[om.trailing_zeros() as usize];
                om &= om - 1;
            }
            *plane = acc;
        }
        planes
    }

    /// Dirty-lane mask of one tile: bit `j` set iff lane `j` has a
    /// nonzero syndrome. Zero proves the whole 512-byte tile clean.
    pub fn dirty_lanes(&self, lanes: &[u64; 64], oob: &[u64; 8]) -> u64 {
        let mut t = *lanes;
        transpose64(&mut t);
        let planes = self.syndrome_planes(&t, oob);
        planes.iter().fold(0u64, |acc, &p| acc | p)
    }
}

/// Cached bitsliced form of the in-place (64, 57) code.
pub fn tile_6457() -> &'static TileCode {
    static T: OnceLock<TileCode> = OnceLock::new();
    T.get_or_init(|| TileCode::new(code_6457_inplace()))
}

/// Cached bitsliced form of the conventional (72, 64) code.
pub fn tile_7264() -> &'static TileCode {
    static T: OnceLock<TileCode> = OnceLock::new();
    T.get_or_init(|| TileCode::new(code_7264()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_matrix(rng: &mut Rng) -> [u64; 64] {
        let mut m = [0u64; 64];
        for w in m.iter_mut() {
            *w = rng.next_u64();
        }
        m
    }

    #[test]
    fn transpose_matches_naive_definition() {
        let mut rng = Rng::new(41);
        for _ in 0..50 {
            let orig = random_matrix(&mut rng);
            let mut t = orig;
            transpose64(&mut t);
            for (p, &row) in t.iter().enumerate() {
                for (j, &src) in orig.iter().enumerate() {
                    assert_eq!(
                        row >> j & 1,
                        src >> p & 1,
                        "t[{p}] bit {j} must be orig[{j}] bit {p}"
                    );
                }
            }
        }
    }

    #[test]
    fn transpose_round_trip_is_identity() {
        let mut rng = Rng::new(42);
        for _ in 0..200 {
            let orig = random_matrix(&mut rng);
            let mut m = orig;
            transpose64(&mut m);
            transpose64(&mut m);
            assert_eq!(m, orig);
        }
    }

    #[test]
    fn oob_planes_match_naive_gather() {
        let mut rng = Rng::new(43);
        for _ in 0..100 {
            let oob: Vec<u8> = (0..LANES).map(|_| rng.next_u64() as u8).collect();
            let planes = oob_planes(&oob);
            for (i, &p) in planes.iter().enumerate() {
                for (j, &b) in oob.iter().enumerate() {
                    assert_eq!(p >> j & 1, u64::from(b >> i & 1), "plane {i} lane {j}");
                }
            }
        }
    }

    #[test]
    fn syndrome_planes_match_scalar_syndromes() {
        let mut rng = Rng::new(44);
        for (code, tc, has_oob) in [
            (code_6457_inplace(), tile_6457(), false),
            (code_7264(), tile_7264(), true),
        ] {
            for _ in 0..50 {
                // arbitrary (corrupt) stored words — the identity must
                // hold for every word, not just near-codewords
                let lanes = random_matrix(&mut rng);
                let oob: Vec<u8> = (0..LANES).map(|_| rng.next_u64() as u8).collect();
                let mut t = lanes;
                transpose64(&mut t);
                let ob = if has_oob { oob_planes(&oob) } else { NO_OOB };
                let planes = tc.syndrome_planes(&t, &ob);
                for j in 0..LANES {
                    let mut want = code.syndrome_u64(lanes[j]);
                    if has_oob {
                        want ^= code.syndrome_oob(oob[j]);
                    }
                    let mut got = 0u8;
                    for (i, &p) in planes.iter().enumerate() {
                        got |= ((p >> j & 1) as u8) << i;
                    }
                    assert_eq!(got, want, "lane {j}");
                }
            }
        }
    }

    #[test]
    fn dirty_lanes_pinpoints_corrupted_lanes() {
        use crate::ecc::inplace;
        let mut rng = Rng::new(45);
        // a tile of valid in-place codewords is clean; flipping one bit
        // in lanes {3, 17, 63} dirties exactly those lanes. Clearing
        // bits 6..8 of bytes 0..6 makes any raw word WOT-encodable.
        let mut lanes = [0u64; 64];
        for w in lanes.iter_mut() {
            *w = inplace::encode_u64(rng.next_u64() & !0x00C0_C0C0_C0C0_C0C0);
        }
        let tc = tile_6457();
        assert_eq!(tc.dirty_lanes(&lanes, &NO_OOB), 0, "encoded tile must be clean");
        let mut hit = lanes;
        for &j in &[3usize, 17, 63] {
            hit[j] ^= 1u64 << (j % 64);
        }
        let dirty = tc.dirty_lanes(&hit, &NO_OOB);
        assert_eq!(dirty, (1u64 << 3) | (1u64 << 17) | (1u64 << 63));
    }

    #[test]
    fn lane_i8_is_bytewise_cast() {
        let w = 0x8001_7FFF_00FF_40C0u64;
        let got = lane_i8(w);
        for (k, &b) in w.to_le_bytes().iter().enumerate() {
            assert_eq!(got[k], b as i8);
        }
    }
}
