//! Generic Hsiao SEC-DED codes (M. Y. Hsiao, 1970).
//!
//! A Hsiao code's parity-check matrix H has distinct odd-weight columns:
//! check-bit positions carry the unit vectors, data positions carry
//! odd-weight(>=3) vectors. Properties used here:
//!   * minimum distance 4 => corrects any 1-bit error, detects any 2-bit
//!     error in a codeword;
//!   * a single-bit error yields a syndrome equal to that bit's column
//!     (odd weight); any double error yields a nonzero even-weight
//!     syndrome — the correct/detect discriminator is column membership.
//!
//! The codeword is addressed as little-endian bytes: bit position
//! `p` = byte `p / 8`, bit `p % 8`. Syndromes are computed with a
//! 256-entry LUT per codeword byte (the decode hot path of the whole
//! system: Table 2 runs millions of block decodes).

/// Decode outcome for one codeword.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Syndrome zero — no error (or an undetectable >=3-bit error).
    Clean,
    /// Single-bit error at the given bit position, already flipped back.
    Corrected(usize),
    /// Nonzero syndrome not matching any column: uncorrectable (double)
    /// error detected; codeword left untouched.
    Detected,
}

/// A concrete Hsiao code with r <= 8 check bits and n <= 256 codeword
/// bits (we instantiate (72, 64) and (64, 57)).
pub struct HsiaoCode {
    /// Number of check bits.
    pub r: usize,
    /// Codeword length in bits (multiple of 8 here).
    pub n: usize,
    /// Column (syndrome signature) of every codeword bit position.
    pub cols: Vec<u8>,
    /// Check-bit positions, index i holds the position whose column is
    /// the unit vector 1 << i.
    pub check_pos: Vec<usize>,
    /// syndrome -> bit position + 1 (0 = not a column => Detected).
    corr: Box<[u16]>,
    /// Per-byte syndrome LUT: lut[byte_idx][byte_value] = XOR of columns
    /// of the set bits. Stored as a boxed slice built once at
    /// construction — the hot loops index straight through one pointer
    /// with no Vec capacity word between the OnceLock'd code and the
    /// tables.
    lut: Box<[[u8; 256]]>,
}

/// Enumerate odd-weight r-bit values of weight >= 3 in deterministic
/// order (ascending weight, then ascending value) — the data columns.
fn odd_columns(r: usize, count: usize) -> Vec<u8> {
    let mut cols = Vec::with_capacity(count);
    let mut weights: Vec<u32> = (3..=r as u32).filter(|w| w % 2 == 1).collect();
    weights.sort_unstable();
    for w in weights {
        for v in 1u16..(1u16 << r) {
            if (v as u8).count_ones() == w {
                cols.push(v as u8);
                if cols.len() == count {
                    return cols;
                }
            }
        }
    }
    panic!(
        "not enough odd-weight columns: r={r} supports {} data bits, need {count}",
        (0..(1u16 << r)).filter(|v| v.count_ones() >= 3 && v.count_ones() % 2 == 1).count()
    );
}

impl HsiaoCode {
    /// Build a code of `n` codeword bits (n % 8 == 0) whose check bits
    /// sit at `check_pos` (length r, each < n); all other positions are
    /// data bits, assigned odd-weight columns deterministically.
    pub fn new(n: usize, check_pos: &[usize]) -> Self {
        let r = check_pos.len();
        assert!(r <= 8, "syndrome is carried in a u8");
        assert!(n % 8 == 0 && n <= 2048);
        let is_check: Vec<bool> = {
            let mut v = vec![false; n];
            for &p in check_pos {
                v[p] = true;
            }
            v
        };
        let data_cols = odd_columns(r, n - r);
        let mut cols = vec![0u8; n];
        let mut di = 0;
        for (p, col) in cols.iter_mut().enumerate() {
            if is_check[p] {
                let i = check_pos.iter().position(|&c| c == p).unwrap();
                *col = 1 << i;
            } else {
                *col = data_cols[di];
                di += 1;
            }
        }
        // Correction table: syndrome -> position + 1.
        let mut corr = vec![0u16; 1 << r];
        for (p, &c) in cols.iter().enumerate() {
            debug_assert_eq!(corr[c as usize], 0, "duplicate column {c:#x}");
            corr[c as usize] = (p + 1) as u16;
        }
        // Per-byte syndrome LUTs.
        let nbytes = n / 8;
        let mut lut = vec![[0u8; 256]; nbytes];
        for (b, table) in lut.iter_mut().enumerate() {
            for v in 0..256usize {
                let mut s = 0u8;
                for j in 0..8 {
                    if v & (1 << j) != 0 {
                        s ^= cols[b * 8 + j];
                    }
                }
                table[v] = s;
            }
        }
        HsiaoCode {
            r,
            n,
            cols,
            check_pos: check_pos.to_vec(),
            corr: corr.into_boxed_slice(),
            lut: lut.into_boxed_slice(),
        }
    }

    /// Codeword length in bytes.
    #[inline]
    pub fn nbytes(&self) -> usize {
        self.n / 8
    }

    /// Syndrome of a stored codeword (`bytes.len() == self.nbytes()`).
    #[inline]
    pub fn syndrome(&self, bytes: &[u8]) -> u8 {
        debug_assert_eq!(bytes.len(), self.nbytes());
        let mut s = 0u8;
        for (b, &v) in bytes.iter().enumerate() {
            s ^= self.lut[b][v as usize];
        }
        s
    }

    /// Write the check bits of `bytes` so that its syndrome becomes zero
    /// (check positions are overwritten; data positions untouched).
    pub fn encode(&self, bytes: &mut [u8]) {
        for &p in &self.check_pos {
            bytes[p / 8] &= !(1 << (p % 8));
        }
        let s = self.syndrome(bytes);
        for i in 0..self.r {
            if s & (1 << i) != 0 {
                let p = self.check_pos[i];
                bytes[p / 8] ^= 1 << (p % 8);
            }
        }
        debug_assert_eq!(self.syndrome(bytes), 0);
    }

    /// Correct a single-bit error in place; classify the outcome.
    #[inline]
    pub fn decode(&self, bytes: &mut [u8]) -> Outcome {
        let s = self.syndrome(bytes);
        if s == 0 {
            return Outcome::Clean;
        }
        let p = self.corr[s as usize];
        if p == 0 {
            return Outcome::Detected;
        }
        let pos = (p - 1) as usize;
        bytes[pos / 8] ^= 1 << (pos % 8);
        Outcome::Corrected(pos)
    }

    // ---- u64 fast path (hot loop of the memory subsystem) -----------
    //
    // For 64-bit codewords (the in-place (64, 57) code) and for the
    // 64-bit data half of (72, 64), the stored block is one little-
    // endian u64; an unrolled 8-lookup syndrome and table-driven
    // correction avoid the per-byte scatter/gather of the slice path.

    /// Syndrome of a 64-bit word (valid for codes with n >= 64; covers
    /// codeword bits 0..64 — for (72, 64) XOR `lut_oob` on top).
    #[inline(always)]
    pub fn syndrome_u64(&self, w: u64) -> u8 {
        debug_assert!(self.n >= 64);
        let l = &self.lut;
        l[0][(w & 0xff) as usize]
            ^ l[1][((w >> 8) & 0xff) as usize]
            ^ l[2][((w >> 16) & 0xff) as usize]
            ^ l[3][((w >> 24) & 0xff) as usize]
            ^ l[4][((w >> 32) & 0xff) as usize]
            ^ l[5][((w >> 40) & 0xff) as usize]
            ^ l[6][((w >> 48) & 0xff) as usize]
            ^ l[7][((w >> 56) & 0xff) as usize]
    }

    /// Syndrome contribution of the out-of-band check byte (byte 8 of a
    /// (72, 64) codeword).
    #[inline(always)]
    pub fn syndrome_oob(&self, oob: u8) -> u8 {
        debug_assert_eq!(self.nbytes(), 9);
        self.lut[8][oob as usize]
    }

    /// Correction position for a syndrome: Some(bit) or None (detected).
    #[inline(always)]
    pub fn correction(&self, s: u8) -> Option<usize> {
        let p = self.corr[s as usize];
        if p == 0 {
            None
        } else {
            Some((p - 1) as usize)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code7264() -> HsiaoCode {
        HsiaoCode::new(72, &[64, 65, 66, 67, 68, 69, 70, 71])
    }

    fn code6457() -> HsiaoCode {
        let checks: Vec<usize> = (0..7).map(|i| i * 8 + 6).collect();
        HsiaoCode::new(64, &checks)
    }

    #[test]
    fn columns_distinct_and_odd() {
        for code in [code7264(), code6457()] {
            let mut seen = std::collections::HashSet::new();
            for &c in &code.cols {
                assert!(c.count_ones() % 2 == 1, "even column {c:#x}");
                assert!(seen.insert(c), "duplicate column {c:#x}");
            }
        }
    }

    #[test]
    fn encode_then_clean() {
        let code = code7264();
        let mut w = [0u8; 9];
        w[..8].copy_from_slice(&0xDEADBEEF_12345678u64.to_le_bytes());
        code.encode(&mut w);
        assert_eq!(code.decode(&mut w), Outcome::Clean);
    }

    #[test]
    fn every_single_flip_corrected() {
        for code in [code7264(), code6457()] {
            let mut base = vec![0u8; code.nbytes()];
            for (i, b) in base.iter_mut().enumerate() {
                *b = (i as u8).wrapping_mul(37).wrapping_add(11);
            }
            code.encode(&mut base);
            for bit in 0..code.n {
                let mut w = base.clone();
                w[bit / 8] ^= 1 << (bit % 8);
                match code.decode(&mut w) {
                    Outcome::Corrected(p) => {
                        assert_eq!(p, bit);
                        assert_eq!(w, base, "correction must restore the codeword");
                    }
                    o => panic!("bit {bit}: expected Corrected, got {o:?}"),
                }
            }
        }
    }

    #[test]
    fn every_double_flip_detected() {
        for code in [code7264(), code6457()] {
            let mut base = vec![0u8; code.nbytes()];
            for (i, b) in base.iter_mut().enumerate() {
                *b = (i as u8).wrapping_mul(83).wrapping_add(5);
            }
            code.encode(&mut base);
            // exhaustive over all pairs
            for b1 in 0..code.n {
                for b2 in (b1 + 1)..code.n {
                    let mut w = base.clone();
                    w[b1 / 8] ^= 1 << (b1 % 8);
                    w[b2 / 8] ^= 1 << (b2 % 8);
                    assert_eq!(
                        code.decode(&mut w),
                        Outcome::Detected,
                        "flips at {b1},{b2} must be detected, not (mis)corrected"
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "not enough odd-weight columns")]
    fn too_many_data_bits_panics() {
        // r=4 supports only C(4,3)=4 data columns; ask for 12.
        HsiaoCode::new(16, &[0, 1, 2, 3]);
    }
}
