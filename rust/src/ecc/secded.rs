//! The two SEC-DED instantiations used by the paper.
//!
//! * `code_7264()` — the conventional DRAM code: 64 data bits + 8
//!   out-of-band check bits (12.5% space overhead).
//! * `code_6457_inplace()` — the in-place code: the codeword is exactly
//!   the stored 64-bit block; the 7 check bits sit at bit 6 of bytes
//!   0..6 (the non-informative bits WOT guarantees). 57 data bits =
//!   7 bits x 7 small weights + 8 bits of the free byte.
//!
//! A pleasing arithmetic fact the paper leaves implicit: with r = 7
//! there are exactly C(7,3) + C(7,5) + C(7,7) = 35 + 21 + 1 = 57
//! odd-weight(>=3) columns — the (64, 57) Hsiao code uses *all* of them,
//! so every odd syndrome is correctable and every even nonzero syndrome
//! is a detected double error.

use super::hsiao::HsiaoCode;
use std::sync::OnceLock;

/// Bit position (little-endian within the 8-byte block) of the
/// non-informative bit of byte `i`: bit 6 (value bit just below sign).
#[inline]
pub const fn noninformative_bit(byte_idx: usize) -> usize {
    byte_idx * 8 + 6
}

/// Conventional SEC-DED (72, 64): data in bytes 0..8, check bits in the
/// out-of-band byte 8 (positions 64..72).
pub fn code_7264() -> &'static HsiaoCode {
    static CODE: OnceLock<HsiaoCode> = OnceLock::new();
    CODE.get_or_init(|| HsiaoCode::new(72, &[64, 65, 66, 67, 68, 69, 70, 71]))
}

/// In-place SEC-DED (64, 57): check bits at bit 6 of bytes 0..6.
pub fn code_6457_inplace() -> &'static HsiaoCode {
    static CODE: OnceLock<HsiaoCode> = OnceLock::new();
    CODE.get_or_init(|| {
        let checks: Vec<usize> = (0..7).map(noninformative_bit).collect();
        HsiaoCode::new(64, &checks)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inplace_code_uses_all_odd_columns() {
        let code = code_6457_inplace();
        // 57 data columns + 7 unit columns = all 64 odd-weight 7-bit
        // vectors; the correction table must therefore be total over odd
        // syndromes.
        for s in 1u16..128 {
            let odd = (s as u8).count_ones() % 2 == 1;
            let correctable = code.cols.contains(&(s as u8));
            assert_eq!(odd, correctable, "syndrome {s:#x}");
        }
    }

    #[test]
    fn check_positions_are_bit6() {
        let code = code_6457_inplace();
        assert_eq!(code.check_pos, vec![6, 14, 22, 30, 38, 46, 54]);
    }

    #[test]
    fn codes_are_cached() {
        assert!(std::ptr::eq(code_7264(), code_7264()));
        assert!(std::ptr::eq(code_6457_inplace(), code_6457_inplace()));
    }
}
