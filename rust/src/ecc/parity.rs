//! Parity-Zero baseline (paper section 5.1).
//!
//! One even-parity bit per 8-bit weight, stored out-of-band (12.5%
//! overhead, like conventional parity DRAM). A parity mismatch detects
//! an odd number of flips in that byte; the recovery action is to zero
//! the weight (the paper found this beats neighbour-averaging).

/// Parity bit (even parity) of a byte.
#[inline]
pub fn parity(b: u8) -> u8 {
    (b.count_ones() & 1) as u8
}

/// SWAR: the 8 per-byte parities of a little-endian u64, bit i of the
/// result guarding byte i. Fold each byte's parity into its LSB, then
/// gather the LSBs with a multiply.
#[inline(always)]
pub fn parity_word(mut w: u64) -> u8 {
    w ^= w >> 4;
    w ^= w >> 2;
    w ^= w >> 1;
    (((w & 0x0101_0101_0101_0101).wrapping_mul(0x0102_0408_1020_4080)) >> 56) as u8
}

/// Pack per-byte parity bits: bit `i % 8` of `oob[i / 8]` guards byte i.
pub fn encode_oob(data: &[u8]) -> Vec<u8> {
    let mut oob = vec![0u8; data.len().div_ceil(8)];
    let mut chunks = data.chunks_exact(8);
    let mut i = 0;
    for chunk in &mut chunks {
        oob[i] = parity_word(u64::from_le_bytes(chunk.try_into().unwrap()));
        i += 1;
    }
    for (j, &b) in chunks.remainder().iter().enumerate() {
        oob[i] |= parity(b) << j;
    }
    oob
}

/// Check byte i against its stored parity bit.
#[inline]
pub fn check(data_byte: u8, oob: &[u8], i: usize) -> bool {
    parity(data_byte) == (oob[i / 8] >> (i % 8)) & 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parity_basics() {
        assert_eq!(parity(0b0000_0000), 0);
        assert_eq!(parity(0b0000_0001), 1);
        assert_eq!(parity(0b1111_1111), 0);
        assert_eq!(parity(0b1011_0010), 0);
    }

    #[test]
    fn roundtrip_and_detection() {
        let data: Vec<u8> = (0..100u8).map(|i| i.wrapping_mul(31)).collect();
        let oob = encode_oob(&data);
        for (i, &b) in data.iter().enumerate() {
            assert!(check(b, &oob, i));
            assert!(!check(b ^ 0x10, &oob, i), "single flip must be caught");
            assert!(
                check(b ^ 0x11, &oob, i),
                "double flip in one byte escapes parity (expected weakness)"
            );
        }
    }
}

#[cfg(test)]
mod swar_tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn parity_word_matches_scalar() {
        let mut rng = Rng::new(77);
        for _ in 0..10_000 {
            let w = rng.next_u64();
            let bytes = w.to_le_bytes();
            let mut want = 0u8;
            for (i, &b) in bytes.iter().enumerate() {
                want |= parity(b) << i;
            }
            assert_eq!(parity_word(w), want, "w={w:#x}");
        }
    }

    #[test]
    fn encode_oob_handles_ragged_tail() {
        let data: Vec<u8> = (0..13).map(|i| (i * 37) as u8).collect();
        let oob = encode_oob(&data);
        for (i, &b) in data.iter().enumerate() {
            assert!(check(b, &oob, i));
        }
    }
}
