//! MILR-style plaintext strategy: zero stored redundancy, algebraic
//! recovery as the correction tier.
//!
//! MILR (see PAPERS.md) observes that CNN layer weights are recoverable
//! from the layer equation itself — given a calibration batch `X` and
//! checkpointed pre-activation outputs `Y`, a corrupted row of `W` is the
//! solution of `Y = X·W` — so no check bits need to be *stored* at all.
//! This strategy is that extreme point on the in-place/zero-space axis:
//!
//! * **storage**: the WOT-constrained weights verbatim, no OOB bytes, no
//!   in-place check-bit substitution. Overhead is exactly 0 and the
//!   stored image IS the weight buffer.
//! * **detection**: the WOT constraint (bytes 0..6 of every 64-bit block
//!   in [-64, 63], i.e. bit6 == bit7) is itself a free parity-like
//!   invariant. [`inplace::violation_mask_u64`] probes it in one XOR; a
//!   nonzero mask means the block was struck. This probe is deliberately
//!   cheap and *partial*: it sees only flips that break the bit6/bit7
//!   agreement of bytes 0..6 (14 of the 64 stored bits) — byte-7 flips
//!   and low-bit flips pass unseen. ABFT/range guards upstream
//!   ([`crate::runtime::guard`]) and the recovery tier's own residual
//!   verification cover the gap.
//! * **correction**: none here. `decode` serves the stored bytes as-is
//!   and reports detections; `scrub` is a probe-only pass (there is no
//!   redundancy to heal from). Correction is the job of
//!   [`crate::model::recovery`], which solves the layer equation for the
//!   implicated blocks and writes the result back via the bank.
//!
//! The strategy still *enforces* WOT at encode time — without it the
//! detection probe would fire on clean data — so it slots into the same
//! Table-2 grid as `in-place` with identical model preparation cost.

use super::strategy::{copy_clean, DecodeStats, Encoded, Protection};
use super::{inplace, tile};

/// MILR plaintext strategy: zero-redundancy storage, WOT-probe detection,
/// correction delegated to algebraic layer recovery.
pub struct Milr;

impl Protection for Milr {
    fn name(&self) -> &'static str {
        "milr"
    }
    fn ecc_hw(&self) -> bool {
        false
    }
    fn overhead(&self) -> f64 {
        0.0
    }
    fn block_bytes(&self) -> usize {
        8
    }
    fn oob_bytes_per_block(&self) -> usize {
        0
    }
    fn encode(&self, weights: &[i8]) -> anyhow::Result<Encoded> {
        anyhow::ensure!(
            weights.len() % 8 == 0,
            "weight buffer must be whole 64-bit blocks"
        );
        if !inplace::satisfies_constraint(weights) {
            let viol = inplace::constraint_violations(weights);
            anyhow::bail!(
                "WOT constraint violated at {} positions (first: {:?}) — run WOT first",
                viol.len(),
                &viol[..viol.len().min(4)]
            );
        }
        Ok(Encoded {
            data: weights.iter().map(|&w| w as u8).collect(),
            oob: Vec::new(),
            n: weights.len(),
        })
    }
    fn decode_span(&self, data: &[u8], _oob: &[u8], out: &mut [i8]) -> DecodeStats {
        let mut stats = DecodeStats::default();
        for (bi, chunk) in data.chunks_exact(8).enumerate() {
            let w = u64::from_le_bytes(chunk.try_into().unwrap());
            if inplace::violation_mask_u64(w) != 0 {
                stats.detected += 1;
            }
            out[bi * 8..bi * 8 + 8].copy_from_slice(&tile::lane_i8(w));
        }
        // encode enforces whole blocks, but serve any ragged window the
        // caller hands us the same way `copy_clean` would
        let tail = data.len() - data.len() % 8;
        if tail < data.len() {
            copy_clean(&data[tail..], &mut out[tail..]);
        }
        stats
    }
    fn scrub_span(&self, data: &mut [u8], _oob: &mut [u8]) -> DecodeStats {
        // probe-only: there is no stored redundancy to heal from, and
        // rewriting would launder the evidence the recovery tier needs
        let mut stats = DecodeStats::default();
        for chunk in data.chunks_exact(8) {
            if inplace::violation_mask_u64(u64::from_le_bytes(chunk.try_into().unwrap())) != 0 {
                stats.detected += 1;
            }
        }
        stats
    }
    fn tile_is_clean(&self, data: &[u8], _oob: &[u8]) -> bool {
        data.chunks_exact(8)
            .map(|c| inplace::violation_mask_u64(u64::from_le_bytes(c.try_into().unwrap())))
            .fold(0u64, |acc, m| acc | m)
            == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn wot_weights(n: usize, seed: u64) -> Vec<i8> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                if i % 8 == 7 {
                    (rng.below(256) as i64 - 128) as i8
                } else {
                    (rng.below(128) as i64 - 64) as i8
                }
            })
            .collect()
    }

    #[test]
    fn stores_plaintext_and_roundtrips_clean() {
        let w = wot_weights(64 * 8 + 16, 11);
        let s = Milr;
        let enc = s.encode(&w).unwrap();
        assert!(enc.oob.is_empty(), "zero stored redundancy");
        let as_bytes: Vec<u8> = w.iter().map(|&v| v as u8).collect();
        assert_eq!(enc.data, as_bytes, "stored image IS the weights");
        let mut out = vec![0i8; w.len()];
        let stats = s.decode(&enc, &mut out);
        assert!(stats.is_clean());
        assert_eq!(out, w);
        assert!(s.tile_is_clean(&enc.data[..crate::ecc::tile::TILE_BYTES], &[]));
    }

    #[test]
    fn probe_sees_wot_breaking_flips_and_serves_stored_bytes() {
        let w = wot_weights(16 * 8, 12);
        let s = Milr;
        let mut enc = s.encode(&w).unwrap();
        // bit6 of byte 0 in block 3: breaks bit6==bit7 -> detected
        enc.flip_bit(3 * 64 + 6);
        let mut out = vec![0i8; w.len()];
        let stats = s.decode(&enc, &mut out);
        assert_eq!(stats.detected, 1);
        assert_eq!(stats.corrected, 0, "milr never corrects");
        assert_eq!(
            out[3 * 8] as u8,
            w[3 * 8] as u8 ^ 0x40,
            "corrupted byte is served as stored — recovery happens upstream"
        );
        // scrub must not touch the image (probe only)
        let before = enc.data.clone();
        let sstats = s.scrub(&mut enc);
        assert_eq!(sstats.detected, 1);
        assert_eq!(enc.data, before, "scrub is probe-only");
        assert!(!s.tile_is_clean(&enc.data[..w.len().min(512)], &[]));
    }

    #[test]
    fn probe_is_honestly_partial_byte7_flip_passes_unseen() {
        let w = wot_weights(8 * 8, 13);
        let s = Milr;
        let mut enc = s.encode(&w).unwrap();
        enc.flip_bit(2 * 64 + 7 * 8 + 3); // block 2, free byte 7, bit 3
        enc.flip_bit(5 * 64 + 2 * 8); // block 5, byte 2, low bit
        let mut out = vec![0i8; w.len()];
        let stats = s.decode(&enc, &mut out);
        assert!(
            stats.is_clean(),
            "byte-7 and low-bit flips are outside the probe's coverage"
        );
        assert_ne!(out, w, "…so the corruption is served silently");
    }

    #[test]
    fn encode_rejects_non_wot_input() {
        let mut w = wot_weights(4 * 8, 14);
        w[1] = 100; // byte 1 of block 0 out of [-64, 63]
        assert!(Milr.encode(&w).is_err());
        assert!(Milr.encode(&wot_weights(12, 15)).is_err(), "ragged buffer");
    }
}
