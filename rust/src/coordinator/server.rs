//! Per-model serving stack: batcher + inference thread + scrub thread.
//!
//! The inference thread owns every PJRT object (they are not Send); it
//! pulls batches from the `Batcher`, executes, and answers requests.
//! The scrub thread owns the protected `MemoryBank`: it periodically
//! injects environmental faults (when configured), scrubs the stored
//! image, decodes + dequantizes, and ships a fresh f32 weight buffer to
//! the inference thread over a channel — weights never cross the request
//! path, exactly the paper's deployment model (weights live encoded in
//! memory; the ECC decode sits between memory and compute).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, Batcher, Request, Response};
use super::metrics::Metrics;
use crate::ecc::strategy_by_name;
use crate::memory::{FaultModel, MemoryBank};
use crate::model::{load_weights, Manifest};
use crate::quant::dequantize_into;
use crate::runtime::{argmax_rows, Runtime};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Protection strategy name ("faulty" | "zero" | "ecc" | "in-place").
    pub strategy: String,
    pub policy: BatchPolicy,
    /// Scrub period; `None` disables the scrub loop.
    pub scrub_interval: Option<Duration>,
    /// Fraction of stored bits flipped per scrub interval (environmental
    /// fault simulation); 0 disables injection.
    pub fault_rate_per_interval: f64,
    pub fault_seed: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            strategy: "in-place".into(),
            policy: BatchPolicy::default(),
            scrub_interval: Some(Duration::from_millis(100)),
            fault_rate_per_interval: 0.0,
            fault_seed: 1,
        }
    }
}

/// Executes padded batches; implemented by the PJRT path and by mocks in
/// tests (so coordinator logic is testable without artifacts).
pub trait BatchExec {
    /// Max batch size of the underlying executable.
    fn batch(&self) -> usize;
    fn input_dim(&self) -> usize;
    /// Execute `count <= batch()` images (flat, padded buffer sized for
    /// a full batch); returns `count` predictions.
    fn exec(&mut self, images: &[f32], count: usize) -> anyhow::Result<Vec<usize>>;
    /// Swap in freshly decoded weights.
    fn refresh(&mut self, weights: &[f32]) -> anyhow::Result<()>;
}

/// A running server.
pub struct Server {
    batcher: Arc<Batcher>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    stop: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    pub input_dim: usize,
}

impl Server {
    /// Start with a custom executor factory (runs on the inference
    /// thread — this is how the non-Send PJRT objects stay confined).
    pub fn start_with<F>(
        make_exec: F,
        input_dim: usize,
        cfg: &ServerConfig,
        mut bank: Option<(MemoryBank, Vec<crate::model::Layer>)>,
    ) -> anyhow::Result<Server>
    where
        F: FnOnce() -> anyhow::Result<Box<dyn BatchExec>> + Send + 'static,
    {
        let batcher = Arc::new(Batcher::new(cfg.policy));
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let (weights_tx, weights_rx): (Sender<Vec<f32>>, Receiver<Vec<f32>>) = channel();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();

        // ---- inference thread ----
        let b = batcher.clone();
        let m = metrics.clone();
        let inf = std::thread::Builder::new()
            .name("zsecc-infer".into())
            .spawn(move || {
                let mut exec = match make_exec() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let bsz = exec.batch();
                let dim = exec.input_dim();
                let mut buf = vec![0f32; bsz * dim];
                while let Some(batch) = b.next_batch() {
                    // Non-blocking weight refresh before each batch.
                    while let Ok(w) = weights_rx.try_recv() {
                        if exec.refresh(&w).is_ok() {
                            m.weight_refreshes.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    let count = batch.len().min(bsz);
                    for (i, r) in batch.iter().take(count).enumerate() {
                        buf[i * dim..(i + 1) * dim].copy_from_slice(&r.image);
                    }
                    let preds = match exec.exec(&buf, count) {
                        Ok(p) => p,
                        Err(_) => vec![usize::MAX; count],
                    };
                    let now = Instant::now();
                    m.record_batch(count);
                    for (r, &p) in batch.iter().zip(&preds) {
                        let lat = now.duration_since(r.submitted);
                        m.record_latency_us(lat.as_secs_f64() * 1e6);
                        let _ = r.resp.send(Response {
                            id: r.id,
                            pred: p,
                            latency: lat,
                        });
                    }
                    // Anything beyond bsz goes back through the queue.
                    for r in batch.into_iter().skip(count) {
                        let _ = b.push(r);
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("inference thread died during startup"))??;

        let mut threads = vec![inf];

        // ---- scrub thread (owns the MemoryBank) ----
        if let (Some(interval), Some((mut mb, layers))) =
            (cfg.scrub_interval, bank.take())
        {
            let m = metrics.clone();
            let stop2 = stop.clone();
            let rate = cfg.fault_rate_per_interval;
            let seed0 = cfg.fault_seed;
            let t = std::thread::Builder::new()
                .name("zsecc-scrub".into())
                .spawn(move || {
                    let mut qbuf = vec![0i8; mb.n_weights()];
                    let mut epoch = 0u64;
                    while !stop2.load(Ordering::Relaxed) {
                        std::thread::sleep(interval);
                        if rate > 0.0 {
                            let n = mb.inject(FaultModel::Uniform, rate, seed0 ^ epoch);
                            m.faults_injected.fetch_add(n, Ordering::Relaxed);
                        }
                        let stats = mb.scrub();
                        m.corrected.fetch_add(stats.corrected, Ordering::Relaxed);
                        m.detected.fetch_add(stats.detected, Ordering::Relaxed);
                        m.scrubs.fetch_add(1, Ordering::Relaxed);
                        mb.read(&mut qbuf);
                        let mut w = vec![0f32; qbuf.len()];
                        dequantize_into(&qbuf, &layers, &mut w);
                        if weights_tx.send(w).is_err() {
                            break; // inference thread gone
                        }
                        epoch += 1;
                    }
                })?;
            threads.push(t);
        }

        Ok(Server {
            batcher,
            metrics,
            next_id: AtomicU64::new(0),
            stop,
            threads,
            input_dim,
        })
    }

    /// Start the real PJRT-backed server for a model in `artifacts_dir`.
    pub fn start_pjrt(
        artifacts_dir: &std::path::Path,
        model: &str,
        cfg: &ServerConfig,
    ) -> anyhow::Result<Server> {
        let man = Manifest::load_model(artifacts_dir, model)?;
        let weights = load_weights(&man.weights_path(), man.num_weights)?;
        let bank = MemoryBank::new(strategy_by_name(&cfg.strategy)?, &weights)?;
        let layers = man.layers.clone();

        // Initial decoded weights for the inference thread.
        let batch = cfg.policy.max_batch;
        anyhow::ensure!(
            man.batches.contains(&batch),
            "no exported executable for batch {batch} (have {:?})",
            man.batches
        );
        let man2 = man.clone();
        let w0 = {
            let mut mb = MemoryBank::new(strategy_by_name(&cfg.strategy)?, &weights)?;
            let mut q = vec![0i8; weights.len()];
            mb.read(&mut q);
            let mut w = vec![0f32; q.len()];
            dequantize_into(&q, &man.layers, &mut w);
            w
        };
        let input_dim = man.input_dim;
        Server::start_with(
            move || {
                let rt = Runtime::cpu()?;
                let exe = rt.load_model(&man2, batch)?;
                let wbuf = rt.bind_weights(&w0)?;
                Ok(Box::new(PjrtExec {
                    rt,
                    exe,
                    wbuf,
                }) as Box<dyn BatchExec>)
            },
            input_dim,
            cfg,
            Some((bank, layers)),
        )
    }

    /// Submit one image; returns the response channel.
    pub fn submit(&self, image: Vec<f32>) -> anyhow::Result<Receiver<Response>> {
        let (tx, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            image,
            submitted: Instant::now(),
            resp: tx,
        };
        self.batcher
            .push(req)
            .map_err(|_| anyhow::anyhow!("server shut down"))?;
        Ok(rx)
    }

    /// Graceful shutdown: drain the queue, stop all threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.batcher.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// The real PJRT executor (lives on the inference thread).
struct PjrtExec {
    rt: Arc<Runtime>,
    exe: crate::runtime::Executable,
    wbuf: crate::runtime::WeightsBuf,
}

impl BatchExec for PjrtExec {
    fn batch(&self) -> usize {
        self.exe.batch
    }
    fn input_dim(&self) -> usize {
        self.exe.input_dim
    }
    fn exec(&mut self, images: &[f32], count: usize) -> anyhow::Result<Vec<usize>> {
        let logits = self.exe.run(&self.rt, &self.wbuf, images)?;
        let mut preds = argmax_rows(&logits, self.exe.num_classes);
        preds.truncate(count);
        Ok(preds)
    }
    fn refresh(&mut self, weights: &[f32]) -> anyhow::Result<()> {
        self.wbuf = self.rt.bind_weights(weights)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mock executor: predicts class = round(first pixel), counts calls.
    struct Mock {
        batch: usize,
        dim: usize,
        weights_seen: usize,
    }

    impl BatchExec for Mock {
        fn batch(&self) -> usize {
            self.batch
        }
        fn input_dim(&self) -> usize {
            self.dim
        }
        fn exec(&mut self, images: &[f32], count: usize) -> anyhow::Result<Vec<usize>> {
            Ok((0..count)
                .map(|i| images[i * self.dim] as usize)
                .collect())
        }
        fn refresh(&mut self, _w: &[f32]) -> anyhow::Result<()> {
            self.weights_seen += 1;
            Ok(())
        }
    }

    fn mock_cfg() -> ServerConfig {
        ServerConfig {
            strategy: "in-place".into(),
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
            },
            scrub_interval: None,
            fault_rate_per_interval: 0.0,
            fault_seed: 0,
        }
    }

    #[test]
    fn serves_and_answers() {
        let srv = Server::start_with(
            || {
                Ok(Box::new(Mock {
                    batch: 4,
                    dim: 3,
                    weights_seen: 0,
                }) as Box<dyn BatchExec>)
            },
            3,
            &mock_cfg(),
            None,
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..10 {
            rxs.push(srv.submit(vec![i as f32, 0.0, 0.0]).unwrap());
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.pred, i);
        }
        assert_eq!(
            srv.metrics.requests.load(Ordering::Relaxed),
            10
        );
        srv.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let srv = Server::start_with(
            || {
                Ok(Box::new(Mock {
                    batch: 2,
                    dim: 1,
                    weights_seen: 0,
                }) as Box<dyn BatchExec>)
            },
            1,
            &mock_cfg(),
            None,
        )
        .unwrap();
        let m = srv.metrics.clone();
        let b = srv.batcher.clone();
        srv.shutdown();
        let _ = (m, b);
    }

    #[test]
    fn failed_startup_propagates() {
        let r = Server::start_with(
            || Err(anyhow::anyhow!("boom")),
            1,
            &mock_cfg(),
            None,
        );
        assert!(r.is_err());
    }

    #[test]
    fn scrub_thread_refreshes_weights() {
        use crate::ecc::strategy_by_name;
        let weights = vec![0i8; 64];
        let bank = MemoryBank::new(strategy_by_name("in-place").unwrap(), &weights).unwrap();
        let layers = vec![crate::model::Layer {
            name: "a".into(),
            shape: vec![64],
            offset: 0,
            size: 64,
            scale: 1.0,
            scale_prewot: 1.0,
        }];
        let mut cfg = mock_cfg();
        cfg.scrub_interval = Some(Duration::from_millis(5));
        cfg.fault_rate_per_interval = 1e-3;
        let srv = Server::start_with(
            || {
                Ok(Box::new(Mock {
                    batch: 4,
                    dim: 1,
                    weights_seen: 0,
                }) as Box<dyn BatchExec>)
            },
            1,
            &cfg,
            Some((bank, layers)),
        )
        .unwrap();
        // Give the scrub loop a few periods, keep traffic flowing so the
        // inference thread drains the refresh channel.
        for _ in 0..10 {
            let rx = srv.submit(vec![1.0]).unwrap();
            let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(srv.metrics.scrubs.load(Ordering::Relaxed) >= 2);
        assert!(srv.metrics.weight_refreshes.load(Ordering::Relaxed) >= 1);
        srv.shutdown();
    }
}
