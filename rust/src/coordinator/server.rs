//! Per-model serving stack: batcher + inference thread + a scrub lane
//! on the fleet arbiter.
//!
//! The inference thread owns every PJRT object (they are not Send); it
//! pulls batches from the `Batcher`, executes, and answers requests.
//! The protected `ShardedBank` is owned by a [`FleetArbiter`] control
//! loop ([`super::fleet`]) — a private fleet-of-one by default, or one
//! shared across co-hosted models via [`Server::start_with_fleet`].
//! The arbiter periodically injects environmental faults (when
//! configured), scrubs the stored image shard-by-shard on a worker
//! pool, and ships *incremental* weight updates to the inference
//! thread over a channel — only the shards whose stored bytes changed
//! are decoded (fused decode + dequantize, no full-buffer i8 pass) and
//! sent as `offset + f32 slice` deltas; the full buffer crosses the
//! channel only when every shard is dirty. Weights never cross the
//! request path, exactly the paper's deployment model (weights live
//! encoded in memory; the ECC decode sits between memory and compute).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, Batcher, Response};
use super::fleet::{FleetArbiter, FleetConfig, ScrubUnit};
use super::ingress::{Ingress, IngressPolicy, IngressRing, PushError, RingConfig};
use super::metrics::Metrics;
use crate::ecc::strategy_by_name;
use crate::memory::{SchedulerConfig, ScrubPolicy, ShardedBank};
use crate::model::{
    dense_shapes, load_weights, DenseShape, Manifest, RecoveryMode, RecoverySet,
};
use crate::quant::dequantize_into;
use crate::runtime::guard::{Calibration, Envelope, GuardMode, GuardReport, GuardStats};
use crate::runtime::{argmax_rows, Runtime};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Protection strategy name ("faulty" | "zero" | "ecc" | "in-place").
    pub strategy: String,
    pub policy: BatchPolicy,
    /// Base scrub period; `None` disables the scrub loop. Under the
    /// fixed policy every shard is scrubbed at this cadence; under the
    /// adaptive policy it is the hot clamp (and the minimum interval).
    pub scrub_interval: Option<Duration>,
    /// Scrub scheduling policy: `Fixed` is the classic
    /// every-shard-every-interval loop, `Adaptive` gives each shard its
    /// own deadline from the online BER estimator (hot shards scrub at
    /// `scrub_interval`, clean shards decay toward
    /// `scrub_max_interval`).
    pub scrub_policy: ScrubPolicy,
    /// Adaptive upper clamp on a shard's scrub interval; `None` uses
    /// 16 x `scrub_interval`.
    pub scrub_max_interval: Option<Duration>,
    /// Fraction of stored bits flipped per scrub interval (environmental
    /// fault simulation); 0 disables injection. Injection happens at
    /// scrub wakeups, scaled by the elapsed time, so the fault pressure
    /// per wall-clock second is the same under both policies.
    pub fault_rate_per_interval: f64,
    pub fault_seed: u64,
    /// Shard count of the protected weight store.
    pub shards: usize,
    /// Worker threads the scrub loop fans shards out over.
    pub scrub_workers: usize,
    /// Serving front door: the mutex batcher baseline or the lock-free
    /// slot-reservation ring (`coordinator::ingress`).
    pub ingress: IngressPolicy,
    /// Ring depth (slabs) when `ingress == Ring`; must be a power of
    /// two >= 2 ([`ServerConfig::validate`] rejects anything else with
    /// a typed [`ConfigError`]). Admission capacity is
    /// `ring_depth * max_batch`.
    pub ring_depth: usize,
    /// Compute-path guard mode for the serve path. The serve path
    /// supports range supervision (`off` | `range`): each batch is
    /// clamped into the calibrated input envelope before execution and
    /// every clamp is counted into `Metrics`. ABFT modes are refused by
    /// `validate` — the checksummed path runs through
    /// [`crate::runtime::guard::GuardedExecutable`] and the campaign's
    /// synthetic compute runner, not the opaque batch executor.
    pub guard: GuardMode,
    /// Calibrated envelopes (the manifest's `guards` section); required
    /// whenever `guard` needs range supervision.
    pub guard_calibration: Option<Calibration>,
    /// Recovery tier armed on the scrub loop: detected-uncorrectable
    /// blocks are escalated to MILR algebraic reconstruction (solve the
    /// layer equation from the calibration set, re-encode, write back)
    /// instead of being re-detected — and re-served corrupted — every
    /// pass. Blocks recovery cannot fix are quarantined in `Metrics`,
    /// never a panic.
    pub recovery: RecoveryMode,
    /// Calibration set + layer shapes the recovery solver needs;
    /// required whenever `recovery != Off`. `Server::start_pjrt` fills
    /// it from the `<model>.recovery.json` sidecar (written by `zsecc
    /// calibrate`) when the caller leaves it empty.
    pub recovery_calibration: Option<Arc<(RecoverySet, Vec<DenseShape>)>>,
    /// Residual-error budget this model declares to the fleet arbiter:
    /// expected undetected flipped bits it tolerates per shard per
    /// scrub interval. Under the adaptive policy it feeds the
    /// scheduler's interval derivation (a tighter budget means shorter
    /// intervals, hence more urgent demands at the fleet level); the
    /// fleet deficit gauge measures how far the arbiter falls short of
    /// honoring it. Must be finite and > 0.
    pub target_residual: f64,
    /// Lane name in fleet gauges and the merged router report;
    /// [`Server::start_pjrt`] sets it to the model name.
    pub fleet_label: String,
    /// Scrub-bandwidth budget for this server's *private* fleet-of-one
    /// in GB/s, converted to bits per wakeup against `scrub_interval`
    /// (see [`crate::memory::gbps_to_bits_per_wakeup`]). `None` keeps
    /// the legacy unbounded behavior (every due shard granted every
    /// wakeup). Ignored when the server enrolls in a shared arbiter —
    /// the shared [`FleetConfig`] owns the budget there. Must be finite
    /// and > 0 when set.
    pub scrub_budget_gbps: Option<f64>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            strategy: "in-place".into(),
            policy: BatchPolicy::default(),
            scrub_interval: Some(Duration::from_millis(100)),
            scrub_policy: ScrubPolicy::Fixed,
            scrub_max_interval: None,
            fault_rate_per_interval: 0.0,
            fault_seed: 1,
            shards: 8,
            scrub_workers: 4,
            // Locked stays the default for API back-compat; `zsecc
            // serve`, `examples/serve` and the benches select the ring.
            ingress: IngressPolicy::Locked,
            ring_depth: 8,
            guard: GuardMode::Off,
            guard_calibration: None,
            recovery: RecoveryMode::Off,
            recovery_calibration: None,
            // the scheduler's historical default (scheduler.rs keeps
            // the same constant); see SchedulerConfig::target_residual
            target_residual: 0.5,
            fleet_label: "model".into(),
            scrub_budget_gbps: None,
        }
    }
}

/// A structurally invalid [`ServerConfig`], caught before any thread
/// spawns or artifact loads.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ConfigError {
    /// `ring_depth` is not a power of two >= 2. Slab indices are
    /// masked, so the ring would defensively round the depth up —
    /// silently giving the operator a different admission capacity
    /// (`depth * max_batch`) than configured. Reject instead.
    RingDepth(usize),
    /// The guard mode needs calibrated envelopes but the config carries
    /// none (run `zsecc calibrate` and reload the manifest).
    GuardNeedsCalibration(GuardMode),
    /// The guard mode is not supported on this execution path.
    GuardUnsupported(GuardMode),
    /// The recovery mode needs a calibration set (and layer shapes) but
    /// the config carries none (run `zsecc calibrate` so the
    /// `<model>.recovery.json` sidecar exists, or fill
    /// `recovery_calibration` directly).
    RecoveryNeedsCalibration(RecoveryMode),
    /// `target_residual` is not a finite positive number — the fleet
    /// arbiter and the adaptive scheduler both divide by it.
    TargetResidual,
    /// `scrub_budget_gbps` is set but not a finite positive number — a
    /// zero/NaN bandwidth would silently grant no scrub passes at all.
    ScrubBudgetGbps,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::RingDepth(d) => write!(
                f,
                "ring depth {d} is invalid: must be a power of two >= 2 \
                 (slot indices are masked, not wrapped)"
            ),
            ConfigError::GuardNeedsCalibration(g) => write!(
                f,
                "guard mode '{}' needs calibrated envelopes; run `zsecc calibrate` first",
                g.tag()
            ),
            ConfigError::GuardUnsupported(g) => write!(
                f,
                "guard mode '{}' is not supported on the serve path (ABFT wraps \
                 linear executables via GuardedExecutable, or runs under \
                 `zsecc campaign --synthetic`); use 'off' or 'range'",
                g.tag()
            ),
            ConfigError::RecoveryNeedsCalibration(r) => write!(
                f,
                "recovery mode '{}' needs a calibration set; run `zsecc calibrate` \
                 so the recovery sidecar exists",
                r.tag()
            ),
            ConfigError::TargetResidual => write!(
                f,
                "target_residual must be a finite number > 0 \
                 (expected new error bits per shard per scrub interval)"
            ),
            ConfigError::ScrubBudgetGbps => write!(
                f,
                "scrub_budget_gbps must be a finite number > 0 when set \
                 (scrub bandwidth the private fleet-of-one may spend)"
            ),
        }
    }
}

impl std::error::Error for ConfigError {}

impl ServerConfig {
    /// Structural validation, run by [`Server::start_with`] and the CLI
    /// front ends before anything is built from the config.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.ingress == IngressPolicy::Ring
            && (self.ring_depth < 2 || !self.ring_depth.is_power_of_two())
        {
            return Err(ConfigError::RingDepth(self.ring_depth));
        }
        if self.guard.abft() {
            return Err(ConfigError::GuardUnsupported(self.guard));
        }
        if self.guard.range()
            && self
                .guard_calibration
                .as_ref()
                .and_then(|c| c.input_envelope())
                .is_none()
        {
            return Err(ConfigError::GuardNeedsCalibration(self.guard));
        }
        if self.recovery != RecoveryMode::Off && self.recovery_calibration.is_none() {
            return Err(ConfigError::RecoveryNeedsCalibration(self.recovery));
        }
        if !self.target_residual.is_finite() || self.target_residual <= 0.0 {
            return Err(ConfigError::TargetResidual);
        }
        if self
            .scrub_budget_gbps
            .is_some_and(|g| !g.is_finite() || g <= 0.0)
        {
            return Err(ConfigError::ScrubBudgetGbps);
        }
        Ok(())
    }
}

/// Fractional fault-injection budget carried across scrub wakeups.
///
/// The configured rate is "expected flips per stored bit per base
/// interval"; adaptive wakeups are unevenly spaced, and rounding each
/// wakeup's small expectation to a whole count independently would
/// systematically under-inject (possibly to zero, forever) versus the
/// fixed policy at the same wall-clock rate. `take` accrues the exact
/// expectation and returns only the whole part, keeping the fractional
/// remainder, so the cumulative grant never drifts more than one flip
/// from `bits · rate · Σscale` however the wakeups are spaced.
#[derive(Debug, Default)]
pub struct FlipBudget {
    carry: f64,
}

impl FlipBudget {
    /// Accrue `bits * rate * scale` expected flips and withdraw the
    /// whole part. Degenerate inputs (zero, negative or non-finite
    /// expectations) grant nothing and leave the carry untouched.
    pub fn take(&mut self, bits: u64, rate: f64, scale: f64) -> u64 {
        let due = bits as f64 * rate * scale;
        if !due.is_finite() || due <= 0.0 {
            return 0;
        }
        self.carry += due;
        let whole = self.carry.floor();
        self.carry -= whole;
        whole as u64
    }
}

/// One incremental weight update: `values` replaces the flat f32 weight
/// window starting at element `offset`.
#[derive(Clone, Debug)]
pub struct WeightDelta {
    pub offset: usize,
    pub values: Vec<f32>,
}

/// What the scrub loop ships over the refresh channel.
pub enum WeightUpdate {
    /// Whole-buffer refresh (startup fallback / every shard dirty).
    Full(Vec<f32>),
    /// Dirty shards only.
    Deltas(Vec<WeightDelta>),
}

/// Executes padded batches; implemented by the PJRT path and by mocks in
/// tests (so coordinator logic is testable without artifacts).
pub trait BatchExec {
    /// Max batch size of the underlying executable.
    fn batch(&self) -> usize;
    fn input_dim(&self) -> usize;
    /// Execute `count <= batch()` images (flat, padded buffer sized for
    /// a full batch); returns `count` predictions.
    fn exec(&mut self, images: &[f32], count: usize) -> anyhow::Result<Vec<usize>>;
    /// Swap in freshly decoded weights (whole buffer).
    fn refresh(&mut self, weights: &[f32]) -> anyhow::Result<()>;
    /// Patch in freshly decoded weight windows (the delta variant of
    /// `refresh`). Executors that keep device-resident weights apply
    /// every delta to their host copy and re-upload once. The default is
    /// a no-op so weight-free mock executors stay trivial.
    fn refresh_delta(&mut self, _deltas: &[WeightDelta]) -> anyhow::Result<()> {
        Ok(())
    }
}

/// A running server.
pub struct Server {
    ingress: Arc<Ingress>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    /// Retirement flag of this model's scrub lane inside the fleet
    /// arbiter; `None` when the server runs without a scrub loop.
    scrub_stop: Option<Arc<AtomicBool>>,
    /// The arbiter scrubbing this model: the caller's shared fleet, or
    /// a private unbounded fleet-of-one (whose control thread stops and
    /// joins when this last handle drops at the end of `shutdown`).
    fleet: Option<Arc<FleetArbiter>>,
    threads: Vec<JoinHandle<()>>,
    pub input_dim: usize,
}

impl Server {
    /// Start with a custom executor factory (runs on the inference
    /// thread — this is how the non-Send PJRT objects stay confined).
    /// The scrub loop runs on a private fleet-of-one arbiter; use
    /// [`Server::start_with_fleet`] to share one arbiter (and its scrub
    /// budget) across co-hosted models.
    pub fn start_with<F>(
        make_exec: F,
        input_dim: usize,
        cfg: &ServerConfig,
        bank: Option<(ShardedBank, Vec<crate::model::Layer>)>,
    ) -> anyhow::Result<Server>
    where
        F: FnOnce() -> anyhow::Result<Box<dyn BatchExec>> + Send + 'static,
    {
        Server::start_with_fleet(make_exec, input_dim, cfg, bank, None)
    }

    /// [`Server::start_with`] with an explicit fleet arbiter: the
    /// model's scrub state is enrolled with `fleet` instead of a
    /// private one, so every enrolled model shares one control loop,
    /// one scrub budget and one urgency ranking.
    pub fn start_with_fleet<F>(
        make_exec: F,
        input_dim: usize,
        cfg: &ServerConfig,
        mut bank: Option<(ShardedBank, Vec<crate::model::Layer>)>,
        fleet: Option<Arc<FleetArbiter>>,
    ) -> anyhow::Result<Server>
    where
        F: FnOnce() -> anyhow::Result<Box<dyn BatchExec>> + Send + 'static,
    {
        cfg.validate()?;
        let ingress = Arc::new(match cfg.ingress {
            IngressPolicy::Locked => Ingress::Locked(Batcher::new(cfg.policy)),
            IngressPolicy::Ring => Ingress::Ring(IngressRing::new(RingConfig {
                depth: cfg.ring_depth,
                cap: cfg.policy.max_batch,
                dim: input_dim,
                max_wait: cfg.policy.max_wait,
            })),
        });
        let metrics = Arc::new(Metrics::new());
        if let Ingress::Ring(r) = &*ingress {
            metrics.set_ingress(r.stats());
        }
        // Range supervision: the inference thread wraps its executor in
        // a GuardedBatch sharing these counters with Metrics. validate()
        // guarantees the envelope exists whenever the mode wants it.
        let guard_env = if cfg.guard.range() {
            cfg.guard_calibration
                .as_ref()
                .and_then(|c| c.input_envelope())
        } else {
            None
        };
        let guard_stats = guard_env.map(|_| Arc::new(GuardStats::default()));
        if let Some(gs) = &guard_stats {
            metrics.set_guards(gs.clone());
        }
        let (weights_tx, weights_rx): (Sender<WeightUpdate>, Receiver<WeightUpdate>) = channel();
        // Applied f32 buffers travel back to the scrub thread's scratch
        // arena, so steady-state refresh epochs allocate nothing.
        let (give_tx, give_rx): (Sender<Vec<f32>>, Receiver<Vec<f32>>) = channel();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();

        // ---- inference thread ----
        let ing = ingress.clone();
        let m = metrics.clone();
        let inf = std::thread::Builder::new()
            .name("zsecc-infer".into())
            .spawn(move || {
                let mut exec = match make_exec() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                if let (Some(env), Some(stats)) = (guard_env, guard_stats) {
                    let cap = exec.batch() * exec.input_dim();
                    exec = Box::new(GuardedBatch {
                        inner: exec,
                        env,
                        stats,
                        scratch: Vec::with_capacity(cap),
                    });
                }
                let bsz = exec.batch();
                let dim = exec.input_dim();
                let mut buf = vec![0f32; bsz * dim];
                // An update whose application failed (e.g. a transient
                // device error on re-upload): retried before the next
                // batch rather than dropped — the bank has already
                // cleared those shards' dirty bits and will not resend.
                let mut pending: Option<WeightUpdate> = None;
                // Apply an update; on success its f32 buffers go back
                // to the scrub thread's arena, on failure the update is
                // returned for retry.
                let apply =
                    |exec: &mut Box<dyn BatchExec>, update: WeightUpdate| -> Option<WeightUpdate> {
                        let ok = match &update {
                            WeightUpdate::Full(w) => exec.refresh(w).is_ok(),
                            WeightUpdate::Deltas(d) => exec.refresh_delta(d).is_ok(),
                        };
                        if !ok {
                            return Some(update);
                        }
                        match update {
                            WeightUpdate::Full(w) => {
                                let _ = give_tx.send(w);
                            }
                            WeightUpdate::Deltas(deltas) => {
                                for d in deltas {
                                    let _ = give_tx.send(d.values);
                                }
                            }
                        }
                        None
                    };
                // Non-blocking weight refresh before each batch; stop
                // draining on failure to keep updates ordered.
                let drain_updates =
                    |exec: &mut Box<dyn BatchExec>, pending: &mut Option<WeightUpdate>| {
                        if let Some(update) = pending.take() {
                            match apply(exec, update) {
                                None => {
                                    m.weight_refreshes.fetch_add(1, Ordering::Relaxed);
                                }
                                failed => *pending = failed,
                            }
                        }
                        while pending.is_none() {
                            let Ok(update) = weights_rx.try_recv() else {
                                break;
                            };
                            match apply(exec, update) {
                                None => {
                                    m.weight_refreshes.fetch_add(1, Ordering::Relaxed);
                                }
                                failed => *pending = failed,
                            }
                        }
                    };
                match &*ing {
                    // Locked baseline: copy each request's image into
                    // the staging buffer, chunked FIFO under oversized
                    // batches (policy.max_batch > exec.batch()) — a
                    // requeued overflow request could otherwise starve.
                    Ingress::Locked(b) => {
                        while let Some(batch) = b.next_batch() {
                            drain_updates(&mut exec, &mut pending);
                            for chunk in batch.chunks(bsz) {
                                let count = chunk.len();
                                for (i, r) in chunk.iter().enumerate() {
                                    buf[i * dim..(i + 1) * dim].copy_from_slice(&r.image);
                                }
                                let preds = match exec.exec(&buf, count) {
                                    Ok(p) => p,
                                    Err(_) => {
                                        m.exec_failures.fetch_add(1, Ordering::Relaxed);
                                        vec![usize::MAX; count]
                                    }
                                };
                                let now = Instant::now();
                                m.record_batch(count);
                                for (r, &p) in chunk.iter().zip(&preds) {
                                    let lat = now.duration_since(r.submitted);
                                    m.record_latency_us(lat.as_secs_f64() * 1e6);
                                    let _ = r.resp.send(Response {
                                        id: r.id,
                                        pred: p,
                                        latency: lat,
                                    });
                                }
                            }
                        }
                    }
                    // Ring: producers already wrote their rows into the
                    // slab, so a matching geometry executes zero-copy
                    // straight from the slab; otherwise fall back to
                    // bsz-sized chunk copies in slot (= arrival) order.
                    Ingress::Ring(r) => {
                        let zero_copy = r.cap() == bsz && r.dim() == dim;
                        while let Some(sealed) = r.next_sealed() {
                            drain_updates(&mut exec, &mut pending);
                            let total = sealed.count();
                            let mut start = 0usize;
                            while start < total {
                                let count = (total - start).min(bsz);
                                let res = if zero_copy && start == 0 && count == total {
                                    sealed.with_inputs(|inp| exec.exec(inp, count))
                                } else {
                                    sealed.with_inputs(|inp| {
                                        buf[..count * dim].copy_from_slice(
                                            &inp[start * dim..(start + count) * dim],
                                        );
                                    });
                                    exec.exec(&buf, count)
                                };
                                let preds = match res {
                                    Ok(p) => p,
                                    Err(_) => {
                                        m.exec_failures.fetch_add(1, Ordering::Relaxed);
                                        vec![usize::MAX; count]
                                    }
                                };
                                let now = Instant::now();
                                m.record_batch(count);
                                for (slot, &p) in (start..start + count).zip(&preds) {
                                    let lane = sealed.take_lane(slot);
                                    let lat = now.duration_since(lane.submitted);
                                    m.record_latency_us(lat.as_secs_f64() * 1e6);
                                    let _ = lane.resp.send(Response {
                                        id: lane.id,
                                        pred: p,
                                        latency: lat,
                                    });
                                }
                                start += count;
                            }
                        }
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("inference thread died during startup"))??;

        let threads = vec![inf];

        // ---- scrub lane (the fleet arbiter owns the ShardedBank) ----
        let mut scrub_stop = None;
        let mut fleet_handle = None;
        if let (Some(interval), Some((sb, layers))) = (cfg.scrub_interval, bank.take()) {
            // validate() guarantees the calibration exists when armed
            let recovery = if cfg.recovery == RecoveryMode::Milr {
                cfg.recovery_calibration.clone()
            } else {
                None
            };
            let sched_cfg = match cfg.scrub_policy {
                ScrubPolicy::Fixed => SchedulerConfig::fixed(interval),
                ScrubPolicy::Adaptive => SchedulerConfig::adaptive(
                    interval,
                    cfg.scrub_max_interval.unwrap_or(interval * 16),
                ),
            }
            .with_target_residual(cfg.target_residual);
            let unit = ScrubUnit {
                label: cfg.fleet_label.clone(),
                bank: sb,
                layers,
                metrics: metrics.clone(),
                weights_tx,
                give_rx,
                rate: cfg.fault_rate_per_interval,
                seed: cfg.fault_seed,
                interval,
                sched_cfg,
                recovery,
                stop: Arc::new(AtomicBool::new(false)),
            };
            scrub_stop = Some(unit.stop.clone());
            // A private fleet-of-one reproduces the old per-server
            // scrub thread exactly (no budget cap: every due shard
            // granted every wakeup) unless the operator stated a
            // bandwidth budget, which converts to bits per wakeup
            // against this server's own scrub interval.
            let arbiter = match fleet {
                Some(f) => f,
                None => {
                    let fc = match cfg.scrub_budget_gbps {
                        Some(gbps) => FleetConfig::default().with_budget_gbps(gbps, interval),
                        None => FleetConfig::default(),
                    };
                    Arc::new(FleetArbiter::new(fc)?)
                }
            };
            arbiter.enroll(unit);
            fleet_handle = Some(arbiter);
        }

        Ok(Server {
            ingress,
            metrics,
            next_id: AtomicU64::new(0),
            scrub_stop,
            fleet: fleet_handle,
            threads,
            input_dim,
        })
    }

    /// Start the real PJRT-backed server for a model in `artifacts_dir`.
    pub fn start_pjrt(
        artifacts_dir: &std::path::Path,
        model: &str,
        cfg: &ServerConfig,
    ) -> anyhow::Result<Server> {
        let man = Manifest::load_model(artifacts_dir, model)?;
        let weights = load_weights(&man.weights_path(), man.num_weights)?;
        let layers = man.layers.clone();

        // A range guard without an explicit calibration picks up the
        // manifest's `guards` section (written by `zsecc calibrate`);
        // validate() below still refuses if neither exists.
        let mut cfg = cfg.clone();
        // fleet gauges and the merged router report name lanes by model
        if cfg.fleet_label == ServerConfig::default().fleet_label {
            cfg.fleet_label = model.to_string();
        }
        if cfg.guard.range() && cfg.guard_calibration.is_none() {
            cfg.guard_calibration = man.guards.clone();
        }
        // An armed recovery tier without an explicit calibration picks
        // up the `<model>.recovery.json` sidecar (written by `zsecc
        // calibrate`); a missing sidecar is a load error here, the same
        // validate() refusal path as guards.
        if cfg.recovery != RecoveryMode::Off && cfg.recovery_calibration.is_none() {
            let path = RecoverySet::sidecar_path(artifacts_dir, model);
            if path.exists() {
                let set = RecoverySet::load(&path)?;
                cfg.recovery_calibration = Some(Arc::new((set, dense_shapes(&man.layers))));
            }
        }
        let cfg = &cfg;

        let batch = cfg.policy.max_batch;
        anyhow::ensure!(
            man.batches.contains(&batch),
            "no exported executable for batch {batch} (have {:?})",
            man.batches
        );

        // Encode once; the initial f32 weights are decoded from the same
        // bank the scrub thread will own.
        let mut bank = ShardedBank::new(
            strategy_by_name(&cfg.strategy)?,
            &weights,
            cfg.shards,
            cfg.scrub_workers,
        )?;
        let mut q = vec![0i8; weights.len()];
        bank.read(&mut q);
        let mut w0 = vec![0f32; q.len()];
        dequantize_into(&q, &man.layers, &mut w0);

        let man2 = man.clone();
        let input_dim = man.input_dim;
        Server::start_with(
            move || {
                let rt = Runtime::cpu()?;
                let exe = rt.load_model(&man2, batch)?;
                let wbuf = rt.bind_weights(&w0)?;
                Ok(Box::new(PjrtExec {
                    rt,
                    exe,
                    wbuf,
                    host: w0,
                }) as Box<dyn BatchExec>)
            },
            input_dim,
            cfg,
            Some((bank, layers)),
        )
    }

    /// Submit one image; returns the response channel. Typed errors:
    /// a ring front door under overload returns
    /// [`PushError::Overloaded`] for the caller (router, load shedder)
    /// to act on; the locked baseline never overloads (its queue is
    /// unbounded).
    pub fn try_submit(&self, image: Vec<f32>) -> Result<Receiver<Response>, PushError> {
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.ingress.push_owned(id, image, tx)?;
        Ok(rx)
    }

    /// [`try_submit`](Server::try_submit) with the pre-ingress `anyhow`
    /// signature, kept for callers that treat every refusal alike.
    pub fn submit(&self, image: Vec<f32>) -> anyhow::Result<Receiver<Response>> {
        self.try_submit(image).map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Which front door this server runs.
    pub fn ingress_policy(&self) -> IngressPolicy {
        self.ingress.policy()
    }

    /// Graceful shutdown: drain the queue, stop all threads, retire the
    /// scrub lane. Returns immediately-ish however long the scrub
    /// interval is — the fleet control thread parks on an interruptible
    /// wait, not a sleep. On a shared fleet the lane is dropped at the
    /// arbiter's next wakeup (triggered here); a private fleet-of-one
    /// is stopped and joined when its last handle drops below.
    pub fn shutdown(mut self) {
        if let Some(stop) = &self.scrub_stop {
            stop.store(true, Ordering::Release);
        }
        if let Some(fleet) = &self.fleet {
            fleet.wake();
        }
        self.ingress.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Range supervision around any executor: every live row of an
/// incoming batch is clamped into the calibrated input envelope before
/// execution (on a scratch copy — the staging/slab buffer is shared and
/// must not be mutated), and each clamp bumps the shared guard
/// counters that `Metrics` reports.
struct GuardedBatch {
    inner: Box<dyn BatchExec>,
    env: Envelope,
    stats: Arc<GuardStats>,
    scratch: Vec<f32>,
}

impl BatchExec for GuardedBatch {
    fn batch(&self) -> usize {
        self.inner.batch()
    }
    fn input_dim(&self) -> usize {
        self.inner.input_dim()
    }
    fn exec(&mut self, images: &[f32], count: usize) -> anyhow::Result<Vec<usize>> {
        self.scratch.clear();
        self.scratch.extend_from_slice(images);
        // Only the `count` live rows: pad rows are copies of live ones
        // and would double-count their trips.
        let live = count * self.inner.input_dim();
        let clamps = self.env.clamp_count(&mut self.scratch[..live]);
        if clamps > 0 {
            self.stats.absorb(&GuardReport {
                range_clamps: clamps,
                ..GuardReport::default()
            });
        }
        self.inner.exec(&self.scratch, count)
    }
    fn refresh(&mut self, weights: &[f32]) -> anyhow::Result<()> {
        self.inner.refresh(weights)
    }
    fn refresh_delta(&mut self, deltas: &[WeightDelta]) -> anyhow::Result<()> {
        self.inner.refresh_delta(deltas)
    }
}

/// The real PJRT executor (lives on the inference thread). Keeps a host
/// copy of the flat f32 weights so delta refreshes patch windows and
/// re-upload once.
struct PjrtExec {
    rt: Arc<Runtime>,
    exe: crate::runtime::Executable,
    wbuf: crate::runtime::WeightsBuf,
    host: Vec<f32>,
}

impl BatchExec for PjrtExec {
    fn batch(&self) -> usize {
        self.exe.batch
    }
    fn input_dim(&self) -> usize {
        self.exe.input_dim
    }
    fn exec(&mut self, images: &[f32], count: usize) -> anyhow::Result<Vec<usize>> {
        let logits = self.exe.run(&self.rt, &self.wbuf, images)?;
        let mut preds = argmax_rows(&logits, self.exe.num_classes);
        preds.truncate(count);
        Ok(preds)
    }
    fn refresh(&mut self, weights: &[f32]) -> anyhow::Result<()> {
        self.host.clear();
        self.host.extend_from_slice(weights);
        self.wbuf = self.rt.bind_weights(&self.host)?;
        Ok(())
    }
    fn refresh_delta(&mut self, deltas: &[WeightDelta]) -> anyhow::Result<()> {
        for d in deltas {
            anyhow::ensure!(
                d.offset + d.values.len() <= self.host.len(),
                "delta [{}, {}) outside weight buffer of {}",
                d.offset,
                d.offset + d.values.len(),
                self.host.len()
            );
            self.host[d.offset..d.offset + d.values.len()].copy_from_slice(&d.values);
        }
        self.wbuf = self.rt.bind_weights(&self.host)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::FaultModel;
    use std::sync::Mutex;

    /// Mock executor: predicts class = round(first pixel), counts calls.
    struct Mock {
        batch: usize,
        dim: usize,
        weights_seen: usize,
    }

    impl BatchExec for Mock {
        fn batch(&self) -> usize {
            self.batch
        }
        fn input_dim(&self) -> usize {
            self.dim
        }
        fn exec(&mut self, images: &[f32], count: usize) -> anyhow::Result<Vec<usize>> {
            Ok((0..count)
                .map(|i| images[i * self.dim] as usize)
                .collect())
        }
        fn refresh(&mut self, _w: &[f32]) -> anyhow::Result<()> {
            self.weights_seen += 1;
            Ok(())
        }
    }

    fn mock_cfg() -> ServerConfig {
        ServerConfig {
            strategy: "in-place".into(),
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
            },
            scrub_interval: None,
            fault_rate_per_interval: 0.0,
            fault_seed: 0,
            shards: 4,
            scrub_workers: 2,
            ..ServerConfig::default()
        }
    }

    fn input_calibration(lo: f32, hi: f32) -> Calibration {
        Calibration {
            margin: 0.0,
            batches: 1,
            layers: vec![crate::runtime::guard::LayerEnvelope {
                name: "input".into(),
                env: Envelope::new(lo, hi),
            }],
        }
    }

    #[test]
    fn flip_budget_tracks_the_continuous_rate_without_drift() {
        // Uneven wakeups — the adaptive scheduler's reality. The
        // cumulative whole-flip grant must track bits*rate*Σscale
        // within one flip however the wakeups are spaced; per-wakeup
        // rounding would grant zero forever at these spacings.
        let bits = 1u64 << 20;
        let rate = 3e-6; // ~3.1 expected flips per base interval
        let mut budget = FlipBudget::default();
        let mut rng = crate::util::rng::Rng::new(7);
        let mut granted = 0u64;
        let mut elapsed = 0.0f64;
        for i in 0..10_000 {
            let scale = rng.f64() * 0.2; // wakeups at 0..20% of base
            elapsed += scale;
            granted += budget.take(bits, rate, scale);
            let expected = bits as f64 * rate * elapsed;
            assert!(
                (granted as f64 - expected).abs() < 1.0 + 1e-6,
                "wakeup {i}: granted {granted} drifted from expected {expected:.3}"
            );
        }
        assert!(granted > 0, "fractional wakeups must still inject");
        // Degenerate inputs grant nothing.
        assert_eq!(budget.take(0, rate, 1.0), 0);
        assert_eq!(budget.take(bits, 0.0, 1.0), 0);
        assert_eq!(budget.take(bits, rate, f64::NAN), 0);
        assert_eq!(budget.take(bits, -1.0, 1.0), 0);
    }

    #[test]
    fn config_validation_rejects_bad_ring_depths() {
        let mut cfg = mock_cfg();
        cfg.ingress = IngressPolicy::Ring;
        for bad in [0usize, 1, 3, 6, 12] {
            cfg.ring_depth = bad;
            assert_eq!(cfg.validate(), Err(ConfigError::RingDepth(bad)));
            // start_with refuses before spawning any thread
            let cfg2 = cfg.clone();
            let err = Server::start_with(
                || {
                    Ok(Box::new(Mock {
                        batch: 4,
                        dim: 2,
                        weights_seen: 0,
                    }) as Box<dyn BatchExec>)
                },
                2,
                &cfg2,
                None,
            )
            .unwrap_err();
            assert!(err.to_string().contains("power of two"), "{err}");
        }
        for good in [2usize, 4, 8, 64] {
            cfg.ring_depth = good;
            assert_eq!(cfg.validate(), Ok(()));
        }
        cfg.ring_depth = 3;
        cfg.ingress = IngressPolicy::Locked;
        assert_eq!(cfg.validate(), Ok(()), "depth is a ring knob; locked ignores it");
    }

    #[test]
    fn config_validation_gates_guard_modes() {
        let mut cfg = mock_cfg();
        cfg.guard = GuardMode::Range;
        assert_eq!(
            cfg.validate(),
            Err(ConfigError::GuardNeedsCalibration(GuardMode::Range))
        );
        // A calibration without an input-plane envelope is as useless
        // as none.
        cfg.guard_calibration = Some(Calibration {
            margin: 0.0,
            batches: 1,
            layers: vec![crate::runtime::guard::LayerEnvelope {
                name: "logits".into(),
                env: Envelope::new(0.0, 1.0),
            }],
        });
        assert_eq!(
            cfg.validate(),
            Err(ConfigError::GuardNeedsCalibration(GuardMode::Range))
        );
        cfg.guard_calibration = Some(input_calibration(0.0, 1.0));
        assert_eq!(cfg.validate(), Ok(()));
        for abft in [GuardMode::Abft, GuardMode::Full] {
            cfg.guard = abft;
            assert_eq!(cfg.validate(), Err(ConfigError::GuardUnsupported(abft)));
        }
    }

    #[test]
    fn config_validation_gates_recovery_modes() {
        let mut cfg = mock_cfg();
        cfg.recovery = RecoveryMode::Milr;
        assert_eq!(
            cfg.validate(),
            Err(ConfigError::RecoveryNeedsCalibration(RecoveryMode::Milr))
        );
        cfg.recovery_calibration = Some(Arc::new((
            RecoverySet {
                batch: 1,
                layers: vec![],
            },
            vec![],
        )));
        assert_eq!(cfg.validate(), Ok(()));
        // an unarmed tier never demands a calibration
        cfg.recovery = RecoveryMode::Off;
        cfg.recovery_calibration = None;
        assert_eq!(cfg.validate(), Ok(()));
    }

    #[test]
    fn range_guard_clamps_and_counts_at_the_front_door() {
        let mut cfg = mock_cfg();
        cfg.guard = GuardMode::Range;
        cfg.guard_calibration = Some(input_calibration(0.0, 5.0));
        let srv = Server::start_with(
            || {
                Ok(Box::new(Mock {
                    batch: 4,
                    dim: 2,
                    weights_seen: 0,
                }) as Box<dyn BatchExec>)
            },
            2,
            &cfg,
            None,
        )
        .unwrap();
        // Mock predicts round(first pixel): the out-of-envelope 9.0
        // must reach it clamped to 5.0, the in-envelope 3.0 untouched.
        let rx = srv.try_submit(vec![9.0, 1.0]).unwrap();
        assert_eq!(rx.recv().unwrap().pred, 5);
        let rx = srv.try_submit(vec![3.0, 1.0]).unwrap();
        assert_eq!(rx.recv().unwrap().pred, 3);
        let snap = srv.metrics.guard_snapshot().expect("guards armed");
        assert_eq!(snap.range_clamps, 1, "exactly the one wild pixel");
        let report = srv.metrics.report();
        assert!(report.contains("guards"), "report surfaces guard trips:\n{report}");
        srv.shutdown();
    }

    fn test_layers(n: usize) -> Vec<crate::model::Layer> {
        vec![crate::model::Layer {
            name: "a".into(),
            shape: vec![n],
            offset: 0,
            size: n,
            scale: 1.0,
            scale_prewot: 1.0,
        }]
    }

    #[test]
    fn serves_and_answers() {
        let srv = Server::start_with(
            || {
                Ok(Box::new(Mock {
                    batch: 4,
                    dim: 3,
                    weights_seen: 0,
                }) as Box<dyn BatchExec>)
            },
            3,
            &mock_cfg(),
            None,
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..10 {
            rxs.push(srv.submit(vec![i as f32, 0.0, 0.0]).unwrap());
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.pred, i);
        }
        assert_eq!(
            srv.metrics.requests.load(Ordering::Relaxed),
            10
        );
        srv.shutdown();
    }

    /// The same end-to-end contract as `serves_and_answers`, through
    /// the lock-free ring front door with matching geometry (cap ==
    /// exec batch), i.e. the zero-copy dispatch path.
    #[test]
    fn ring_ingress_serves_and_answers() {
        let mut cfg = mock_cfg();
        cfg.ingress = IngressPolicy::Ring;
        cfg.ring_depth = 4;
        let srv = Server::start_with(
            || {
                Ok(Box::new(Mock {
                    batch: 4,
                    dim: 3,
                    weights_seen: 0,
                }) as Box<dyn BatchExec>)
            },
            3,
            &cfg,
            None,
        )
        .unwrap();
        assert_eq!(srv.ingress_policy(), IngressPolicy::Ring);
        let mut rxs = Vec::new();
        for i in 0..10 {
            rxs.push(srv.submit(vec![i as f32, 0.0, 0.0]).unwrap());
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.pred, i);
        }
        assert_eq!(srv.metrics.requests.load(Ordering::Relaxed), 10);
        srv.shutdown();
    }

    /// Ring cap larger than the executable batch: the dispatcher must
    /// chunk-copy slab rows in slot order (the non-zero-copy path).
    #[test]
    fn ring_ingress_chunks_oversized_batches_in_order() {
        let mut cfg = mock_cfg();
        cfg.ingress = IngressPolicy::Ring;
        cfg.ring_depth = 4;
        // ring batches hold up to 5, the executable takes 2
        cfg.policy = BatchPolicy {
            max_batch: 5,
            max_wait: Duration::from_millis(30),
        };
        let srv = Server::start_with(
            || {
                Ok(Box::new(Mock {
                    batch: 2,
                    dim: 1,
                    weights_seen: 0,
                }) as Box<dyn BatchExec>)
            },
            1,
            &cfg,
            None,
        )
        .unwrap();
        let rxs: Vec<_> = (0..5).map(|i| srv.submit(vec![i as f32]).unwrap()).collect();
        for (i, rx) in rxs.iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.pred, i, "slot order == submission order");
        }
        srv.shutdown();
    }

    /// Typed backpressure surfaces through `try_submit` when the ring
    /// is saturated and nothing drains it (the executor is gated shut).
    #[test]
    fn ring_ingress_overload_is_typed() {
        struct Gated {
            gate: Arc<Mutex<()>>,
        }
        impl BatchExec for Gated {
            fn batch(&self) -> usize {
                1
            }
            fn input_dim(&self) -> usize {
                1
            }
            fn exec(&mut self, _images: &[f32], count: usize) -> anyhow::Result<Vec<usize>> {
                let _g = self.gate.lock().unwrap();
                Ok(vec![0; count])
            }
            fn refresh(&mut self, _w: &[f32]) -> anyhow::Result<()> {
                Ok(())
            }
        }
        let gate = Arc::new(Mutex::new(()));
        let held = gate.lock().unwrap();
        let gate2 = gate.clone();
        let mut cfg = mock_cfg();
        cfg.ingress = IngressPolicy::Ring;
        cfg.ring_depth = 2;
        cfg.policy = BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
        };
        let srv = Server::start_with(
            move || Ok(Box::new(Gated { gate: gate2 }) as Box<dyn BatchExec>),
            1,
            &cfg,
            None,
        )
        .unwrap();
        // Capacity is depth(2) x cap(1) = 2; the dispatcher may pull
        // one batch and block on the gate, freeing at most one slab —
        // so at most 3 admissions before Overloaded. Submit until the
        // typed error surfaces.
        let mut rxs = Vec::new();
        let mut overloaded = false;
        for _ in 0..16 {
            match srv.try_submit(vec![0.0]) {
                Ok(rx) => rxs.push(rx),
                Err(PushError::Overloaded) => {
                    overloaded = true;
                    break;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert!(overloaded, "saturated ring must report Overloaded");
        assert!(rxs.len() <= 3);
        assert!(srv.metrics.ingress().is_some());
        assert!(srv.metrics.ingress().unwrap().overloads >= 1);
        drop(held); // open the gate, let everything drain
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        srv.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let srv = Server::start_with(
            || {
                Ok(Box::new(Mock {
                    batch: 2,
                    dim: 1,
                    weights_seen: 0,
                }) as Box<dyn BatchExec>)
            },
            1,
            &mock_cfg(),
            None,
        )
        .unwrap();
        let m = srv.metrics.clone();
        let ing = srv.ingress.clone();
        srv.shutdown();
        let _ = (m, ing);
    }

    #[test]
    fn failed_startup_propagates() {
        let r = Server::start_with(
            || Err(anyhow::anyhow!("boom")),
            1,
            &mock_cfg(),
            None,
        );
        assert!(r.is_err());
    }

    #[test]
    fn exec_failures_are_counted() {
        struct Flaky;
        impl BatchExec for Flaky {
            fn batch(&self) -> usize {
                2
            }
            fn input_dim(&self) -> usize {
                1
            }
            fn exec(&mut self, _images: &[f32], _count: usize) -> anyhow::Result<Vec<usize>> {
                Err(anyhow::anyhow!("device lost"))
            }
            fn refresh(&mut self, _w: &[f32]) -> anyhow::Result<()> {
                Ok(())
            }
        }
        let srv = Server::start_with(
            || Ok(Box::new(Flaky) as Box<dyn BatchExec>),
            1,
            &mock_cfg(),
            None,
        )
        .unwrap();
        let rx = srv.submit(vec![1.0]).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.pred, usize::MAX, "failed batch answers with MAX");
        assert!(srv.metrics.exec_failures.load(Ordering::Relaxed) >= 1);
        srv.shutdown();
    }

    #[test]
    fn scrub_thread_refreshes_weights() {
        use crate::ecc::strategy_by_name;
        let weights = vec![0i8; 64];
        let bank =
            ShardedBank::new(strategy_by_name("in-place").unwrap(), &weights, 4, 2).unwrap();
        let mut cfg = mock_cfg();
        cfg.scrub_interval = Some(Duration::from_millis(5));
        cfg.fault_rate_per_interval = 1e-3;
        let srv = Server::start_with(
            || {
                Ok(Box::new(Mock {
                    batch: 4,
                    dim: 1,
                    weights_seen: 0,
                }) as Box<dyn BatchExec>)
            },
            1,
            &cfg,
            Some((bank, test_layers(64))),
        )
        .unwrap();
        // Give the scrub loop a few periods, keep traffic flowing so the
        // inference thread drains the refresh channel.
        for _ in 0..10 {
            let rx = srv.submit(vec![1.0]).unwrap();
            let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(srv.metrics.scrubs.load(Ordering::Relaxed) >= 2);
        assert!(srv.metrics.weight_refreshes.load(Ordering::Relaxed) >= 1);
        srv.shutdown();
    }

    /// The adaptive scheduler in the live loop: with injection
    /// disabled, clean passes grow every shard's interval past the
    /// base, and the scheduler gauges surface through `Metrics`.
    #[test]
    fn adaptive_policy_relaxes_clean_shards_and_exports_gauges() {
        use crate::ecc::strategy_by_name;
        let weights = vec![0i8; 256];
        let bank =
            ShardedBank::new(strategy_by_name("in-place").unwrap(), &weights, 4, 2).unwrap();
        let mut cfg = mock_cfg();
        cfg.scrub_interval = Some(Duration::from_millis(5));
        cfg.scrub_policy = ScrubPolicy::Adaptive;
        cfg.scrub_max_interval = Some(Duration::from_millis(40));
        cfg.fault_rate_per_interval = 0.0;
        let srv = Server::start_with(
            || {
                Ok(Box::new(Mock {
                    batch: 4,
                    dim: 1,
                    weights_seen: 0,
                }) as Box<dyn BatchExec>)
            },
            1,
            &cfg,
            Some((bank, test_layers(256))),
        )
        .unwrap();
        // wait until every shard has at least two passes recorded
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let gauges = srv.metrics.shard_schedules();
            if gauges.len() == 4 && gauges.iter().all(|g| g.passes >= 2) {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "scrub gauges never reached 2 passes/shard: {gauges:?}"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        let gauges = srv.metrics.shard_schedules();
        for (i, g) in gauges.iter().enumerate() {
            assert!(
                g.interval_secs > 0.005,
                "shard {i}: clean interval must grow past the base, got {}",
                g.interval_secs
            );
            assert!(g.ber_upper < 1.0, "shard {i}: evidence must bound the BER");
        }
        srv.shutdown();
    }

    /// Satellite regression: `shutdown()` must not wait out the scrub
    /// interval — the scrub thread parks on an interruptible condvar
    /// wait, so a server scrubbed hourly still shuts down in
    /// milliseconds.
    #[test]
    fn shutdown_with_long_scrub_interval_is_immediate() {
        use crate::ecc::strategy_by_name;
        let weights = vec![0i8; 64];
        let bank =
            ShardedBank::new(strategy_by_name("in-place").unwrap(), &weights, 4, 2).unwrap();
        let mut cfg = mock_cfg();
        cfg.scrub_interval = Some(Duration::from_secs(3600));
        let srv = Server::start_with(
            || {
                Ok(Box::new(Mock {
                    batch: 4,
                    dim: 1,
                    weights_seen: 0,
                }) as Box<dyn BatchExec>)
            },
            1,
            &cfg,
            Some((bank, test_layers(64))),
        )
        .unwrap();
        let t0 = Instant::now();
        srv.shutdown();
        let took = t0.elapsed();
        assert!(
            took < Duration::from_secs(2),
            "shutdown blocked on the scrub interval: {took:?}"
        );
    }

    /// Satellite regression: when the batcher releases more requests
    /// than the executable's batch size, the overflow must execute in
    /// arrival order (split into chunks), not be requeued behind newer
    /// arrivals where it could starve.
    #[test]
    fn oversized_batches_execute_in_submission_order() {
        struct LogExec {
            log: Arc<Mutex<Vec<usize>>>,
        }
        impl BatchExec for LogExec {
            fn batch(&self) -> usize {
                2
            }
            fn input_dim(&self) -> usize {
                1
            }
            fn exec(&mut self, images: &[f32], count: usize) -> anyhow::Result<Vec<usize>> {
                let mut l = self.log.lock().unwrap();
                for &px in &images[..count] {
                    l.push(px as usize);
                }
                Ok(vec![0; count])
            }
            fn refresh(&mut self, _w: &[f32]) -> anyhow::Result<()> {
                Ok(())
            }
        }
        let log = Arc::new(Mutex::new(Vec::new()));
        let log2 = log.clone();
        let mut cfg = mock_cfg();
        // policy releases up to 5 requests; the executable takes 2
        cfg.policy = BatchPolicy {
            max_batch: 5,
            max_wait: Duration::from_millis(30),
        };
        let srv = Server::start_with(
            move || Ok(Box::new(LogExec { log: log2 }) as Box<dyn BatchExec>),
            1,
            &cfg,
            None,
        )
        .unwrap();
        let rxs: Vec<_> = (0..5)
            .map(|i| srv.submit(vec![i as f32]).unwrap())
            .collect();
        for rx in &rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        srv.shutdown();
        assert_eq!(
            *log.lock().unwrap(),
            vec![0, 1, 2, 3, 4],
            "completion must follow submission order"
        );
    }

    /// The acceptance check for incremental refresh: with some (but not
    /// all) shards dirty, the scrub epoch ships per-shard deltas — never
    /// a full-buffer `Vec<f32>` — and the deltas are exactly the dirty
    /// shards' windows.
    #[test]
    fn refresh_deltas_ship_only_dirty_shards() {
        use crate::ecc::strategy_by_name;
        #[derive(Default)]
        struct Log {
            fulls: usize,
            deltas: Vec<(usize, usize)>,
        }
        struct DeltaMock {
            log: Arc<Mutex<Log>>,
        }
        impl BatchExec for DeltaMock {
            fn batch(&self) -> usize {
                4
            }
            fn input_dim(&self) -> usize {
                1
            }
            fn exec(&mut self, _images: &[f32], count: usize) -> anyhow::Result<Vec<usize>> {
                Ok(vec![0; count])
            }
            fn refresh(&mut self, _w: &[f32]) -> anyhow::Result<()> {
                self.log.lock().unwrap().fulls += 1;
                Ok(())
            }
            fn refresh_delta(&mut self, deltas: &[WeightDelta]) -> anyhow::Result<()> {
                let mut l = self.log.lock().unwrap();
                for d in deltas {
                    l.deltas.push((d.offset, d.values.len()));
                }
                Ok(())
            }
        }

        let weights = vec![0i8; 256];
        let mut bank =
            ShardedBank::new(strategy_by_name("in-place").unwrap(), &weights, 4, 2).unwrap();
        // Pre-inject a couple of flips: at most 2 of the 4 shards dirty.
        let flipped = bank.inject(FaultModel::Uniform, 1e-3, 42);
        assert!(flipped >= 1);
        let shard_ranges: Vec<(usize, usize)> =
            (0..bank.num_shards()).map(|i| bank.shard_range(i)).collect();

        let log = Arc::new(Mutex::new(Log::default()));
        let log2 = log.clone();
        let mut cfg = mock_cfg();
        cfg.scrub_interval = Some(Duration::from_millis(5));
        cfg.fault_rate_per_interval = 0.0; // no live injection
        let srv = Server::start_with(
            move || Ok(Box::new(DeltaMock { log: log2 }) as Box<dyn BatchExec>),
            1,
            &cfg,
            Some((bank, test_layers(256))),
        )
        .unwrap();
        for _ in 0..100 {
            let rx = srv.submit(vec![0.0]).unwrap();
            let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            if srv.metrics.weight_refreshes.load(Ordering::Relaxed) >= 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(
            srv.metrics.delta_refreshes.load(Ordering::Relaxed) >= 1,
            "dirty shards must have shipped as deltas"
        );
        srv.shutdown();
        let l = log.lock().unwrap();
        assert_eq!(l.fulls, 0, "no full-buffer refresh may be sent");
        assert!(!l.deltas.is_empty());
        let mut shards_hit = std::collections::BTreeSet::new();
        for &(off, len) in &l.deltas {
            assert!(
                shard_ranges.contains(&(off, off + len)),
                "delta [{off}, {}) is not a shard window",
                off + len
            );
            shards_hit.insert(off);
        }
        assert!(
            shards_hit.len() <= 2,
            "at most 2 shards can be dirty from 2 flips, saw {shards_hit:?}"
        );
    }

    /// A milr-protected bank over the synthetic WOT image plus the
    /// recovery calibration the solver needs: a `[16 x 8]` dense head at
    /// scale 0.02 with an 8-batch centered input plane — the serving
    /// equivalent of the campaign runner's recovery path.
    fn recovery_fixture() -> (
        ShardedBank,
        Vec<crate::model::Layer>,
        Arc<(RecoverySet, Vec<DenseShape>)>,
    ) {
        use crate::ecc::strategy_by_name;
        use crate::runtime::guard::DenseModel;
        let weights = crate::harness::ablation::synth_wot(128, 42);
        let bank = ShardedBank::new(strategy_by_name("milr").unwrap(), &weights, 2, 1).unwrap();
        let scale = 0.02f32;
        let w: Vec<f32> = weights.iter().map(|&v| v as f32 * scale).collect();
        let model = DenseModel::from_flat(&w, &[(16, 8)])
            .expect("the 16x8 fixture head has a valid shape");
        let mut rng = crate::util::rng::Rng::new(9);
        let x: Vec<f32> = (0..8 * 16).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        let set = RecoverySet::capture(&model, &["a".to_string()], &x, 8);
        let shapes = vec![DenseShape {
            name: "a".into(),
            offset: 0,
            rows: 16,
            cols: 8,
            scale,
        }];
        (bank, test_layers(128), Arc::new((set, shapes)))
    }

    /// Tentpole, serving path: a detected-uncorrectable milr block is
    /// escalated by the scrub loop, reconstructed from the calibration
    /// set, re-encoded clean, and surfaced through the recovery gauges —
    /// all while requests keep being answered.
    #[test]
    fn scrub_loop_escalates_and_recovers_uncorrectable_blocks() {
        let (mut bank, layers, calib) = recovery_fixture();
        // bit6 of byte 0 of block 3: probe-visible, uncorrectable by the
        // zero-redundancy code — exactly what the tier exists for.
        bank.image_mut().flip_bit(3 * 64 + 6);
        let mut cfg = mock_cfg();
        cfg.strategy = "milr".into();
        cfg.scrub_interval = Some(Duration::from_millis(5));
        cfg.recovery = RecoveryMode::Milr;
        cfg.recovery_calibration = Some(calib);
        let srv = Server::start_with(
            || {
                Ok(Box::new(Mock {
                    batch: 4,
                    dim: 1,
                    weights_seen: 0,
                }) as Box<dyn BatchExec>)
            },
            1,
            &cfg,
            Some((bank, layers)),
        )
        .unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        while srv.metrics.recovered_blocks.load(Ordering::Relaxed) == 0 {
            assert!(
                Instant::now() < deadline,
                "the scrub loop never recovered the implicated block"
            );
            let rx = srv.submit(vec![1.0]).unwrap();
            assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().pred, 1);
            std::thread::sleep(Duration::from_millis(5));
        }
        // Exact reconstruction: the block re-encoded clean, so it left
        // the detected set — nothing to quarantine, nothing re-escalated.
        assert_eq!(srv.metrics.recovered_blocks.load(Ordering::Relaxed), 1);
        assert_eq!(srv.metrics.quarantined_blocks.load(Ordering::Relaxed), 0);
        assert!(srv.metrics.quarantined().is_empty());
        let (mean_us, _, n) = srv.metrics.recovery_summary();
        assert!(n >= 1 && mean_us > 0.0, "latency series records the pass");
        let report = srv.metrics.report();
        assert!(
            report.contains("recovery recovered=1 quarantined=0"),
            "report surfaces the recovery tier:\n{report}"
        );
        srv.shutdown();
    }

    /// Graceful degradation: a probe-silent poison flip corrupts a
    /// *trusted* row of the solver's column system, so verification
    /// rejects the solve — the implicated block lands on the quarantine
    /// list (typed, bounded) and the server keeps answering.
    #[test]
    fn failed_recovery_quarantines_without_panic() {
        let (mut bank, layers, calib) = recovery_fixture();
        // the detected strike, as above ...
        bank.image_mut().flip_bit(3 * 64 + 6);
        // ... plus bit5 of element 58 (block 7): invisible to the milr
        // probe, but it poisons trusted row 7 of column 2 — the recovered
        // column's residual lands ~66x over the verification threshold.
        bank.image_mut().flip_bit(58 * 8 + 5);
        let mut cfg = mock_cfg();
        cfg.strategy = "milr".into();
        cfg.scrub_interval = Some(Duration::from_millis(5));
        cfg.recovery = RecoveryMode::Milr;
        cfg.recovery_calibration = Some(calib);
        let srv = Server::start_with(
            || {
                Ok(Box::new(Mock {
                    batch: 4,
                    dim: 1,
                    weights_seen: 0,
                }) as Box<dyn BatchExec>)
            },
            1,
            &cfg,
            Some((bank, layers)),
        )
        .unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        while srv.metrics.quarantined_blocks.load(Ordering::Relaxed) == 0 {
            assert!(
                Instant::now() < deadline,
                "the failed solve never reached the quarantine gauges"
            );
            let rx = srv.submit(vec![2.0]).unwrap();
            assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().pred, 2);
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(
            srv.metrics.recovered_blocks.load(Ordering::Relaxed),
            0,
            "a rejected solve must never be written back"
        );
        assert_eq!(srv.metrics.quarantined(), vec![3]);
        // still serving after the failure
        let rx = srv.submit(vec![4.0]).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().pred, 4);
        let report = srv.metrics.report();
        assert!(
            report.contains("quarantine n=1 blocks=[3]"),
            "report lists the quarantined block:\n{report}"
        );
        srv.shutdown();
    }

    /// Satellite regression for escalation dedupe: a milr block keeps
    /// re-detecting on every pass (zero stored redundancy, nothing to
    /// heal), so without the quarantine set the loop would re-run the
    /// same doomed solve forever. The solve-attempt counter must stay
    /// flat while passes keep accumulating.
    #[test]
    fn quarantined_blocks_are_not_resolved_every_pass() {
        let (mut bank, layers, calib) = recovery_fixture();
        // detected strike on block 3 + the probe-silent poison flip
        // that makes its solve fail verification (see
        // failed_recovery_quarantines_without_panic)
        bank.image_mut().flip_bit(3 * 64 + 6);
        bank.image_mut().flip_bit(58 * 8 + 5);
        let mut cfg = mock_cfg();
        cfg.strategy = "milr".into();
        cfg.scrub_interval = Some(Duration::from_millis(5));
        cfg.recovery = RecoveryMode::Milr;
        cfg.recovery_calibration = Some(calib);
        let srv = Server::start_with(
            || {
                Ok(Box::new(Mock {
                    batch: 4,
                    dim: 1,
                    weights_seen: 0,
                }) as Box<dyn BatchExec>)
            },
            1,
            &cfg,
            Some((bank, layers)),
        )
        .unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        while srv.metrics.quarantined_blocks.load(Ordering::Relaxed) == 0 {
            assert!(Instant::now() < deadline, "the block never quarantined");
            std::thread::sleep(Duration::from_millis(5));
        }
        let attempts = srv.metrics.recovery_solve_attempts.load(Ordering::Relaxed);
        assert_eq!(attempts, 1, "one implicated block, one solve");
        // let the loop run many more passes over the still-detected block
        let scrubs_before = srv.metrics.scrubs.load(Ordering::Relaxed);
        let deadline = Instant::now() + Duration::from_secs(10);
        while srv.metrics.scrubs.load(Ordering::Relaxed) < scrubs_before + 10 {
            assert!(Instant::now() < deadline, "scrub passes stalled");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(
            srv.metrics.recovery_solve_attempts.load(Ordering::Relaxed),
            attempts,
            "a quarantined block re-detecting every pass must not be re-solved"
        );
        assert_eq!(
            srv.metrics.quarantined_blocks.load(Ordering::Relaxed),
            1,
            "record_recovery runs once, not once per pass"
        );
        assert_eq!(srv.metrics.quarantined(), vec![3]);
        srv.shutdown();
    }

    /// First (lexicographically) triple of codeword bit positions whose
    /// flips drive `stored` to Detected. A t=2 code cannot correct
    /// three errors; most triples land on an uncorrectable syndrome,
    /// but a few alias into a correctable pattern — probing with the
    /// real decoder keeps the fixture deterministic without hardcoding
    /// code-structure knowledge.
    fn bch_detected_triple(stored: &[u8; crate::ecc::bch::BLOCK]) -> [usize; 3] {
        use crate::ecc::bch;
        for p1 in 0..bch::NBITS {
            for p2 in (p1 + 1)..bch::NBITS {
                for p3 in (p2 + 1)..bch::NBITS {
                    let mut b = *stored;
                    for p in [p1, p2, p3] {
                        b[p / 8] ^= 1 << (p % 8);
                    }
                    if bch::decode_block(&mut b) == bch::BchOutcome::Detected {
                        return [p1, p2, p3];
                    }
                }
            }
        }
        unreachable!("a t=2 code must leave some triple uncorrectable");
    }

    /// Satellite, serving path: a bch16 block hit by three flips is
    /// detected-uncorrectable, and the scrub loop escalates it to the
    /// same algebraic recovery tier milr uses — solved against the
    /// calibration set, snapped to the *extended* WOT grid, re-encoded
    /// clean. Before this path existed the block was re-detected (and
    /// re-served with wrong weights) every pass forever.
    #[test]
    fn bch16_uncorrectable_blocks_escalate_to_algebraic_recovery() {
        use crate::ecc::bch;
        use crate::ecc::strategy_by_name;
        use crate::runtime::guard::DenseModel;
        let weights = crate::harness::ablation::synth_ext(128, 42);
        let mut bank =
            ShardedBank::new(strategy_by_name("bch16").unwrap(), &weights, 2, 1).unwrap();
        let scale = 0.02f32;
        let w: Vec<f32> = weights.iter().map(|&v| v as f32 * scale).collect();
        let model = DenseModel::from_flat(&w, &[(16, 8)])
            .expect("the 16x8 fixture head has a valid shape");
        let mut rng = crate::util::rng::Rng::new(9);
        let x: Vec<f32> = (0..8 * 16).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        let set = RecoverySet::capture(&model, &["a".to_string()], &x, 8);
        let shapes = vec![DenseShape {
            name: "a".into(),
            offset: 0,
            rows: 16,
            cols: 8,
            scale,
        }];
        // what the bank stores for block 3: the raw weight bytes with
        // the 16 check positions overwritten
        let mut stored = [0u8; bch::BLOCK];
        for (d, &s) in stored
            .iter_mut()
            .zip(&weights[3 * bch::BLOCK..4 * bch::BLOCK])
        {
            *d = s as u8;
        }
        bch::encode_block(&mut stored);
        for p in bch_detected_triple(&stored) {
            bank.image_mut().flip_bit(3 * bch::NBITS + p);
        }
        let mut cfg = mock_cfg();
        cfg.strategy = "bch16".into();
        cfg.scrub_interval = Some(Duration::from_millis(5));
        cfg.recovery = RecoveryMode::Milr;
        cfg.recovery_calibration = Some(Arc::new((set, shapes)));
        let srv = Server::start_with(
            || {
                Ok(Box::new(Mock {
                    batch: 4,
                    dim: 1,
                    weights_seen: 0,
                }) as Box<dyn BatchExec>)
            },
            1,
            &cfg,
            Some((bank, test_layers(128))),
        )
        .unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        while srv.metrics.recovered_blocks.load(Ordering::Relaxed) == 0 {
            assert!(
                Instant::now() < deadline,
                "the bch16 block never escalated to recovery"
            );
            let rx = srv.submit(vec![1.0]).unwrap();
            assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().pred, 1);
            std::thread::sleep(Duration::from_millis(5));
        }
        // exact reconstruction on the extended grid: re-encoded clean,
        // nothing quarantined, and the dedupe set saw a single solve
        assert_eq!(srv.metrics.recovered_blocks.load(Ordering::Relaxed), 1);
        assert_eq!(srv.metrics.quarantined_blocks.load(Ordering::Relaxed), 0);
        assert!(srv.metrics.quarantined().is_empty());
        assert_eq!(srv.metrics.recovery_solve_attempts.load(Ordering::Relaxed), 1);
        let report = srv.metrics.report();
        assert!(
            report.contains("recovery recovered=1 quarantined=0"),
            "report surfaces the bch16 escalation:\n{report}"
        );
        srv.shutdown();
    }
}
