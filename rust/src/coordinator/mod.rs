//! Thread-based serving coordinator (tokio is unavailable offline; the
//! event loop is std::thread + mpsc channels + condvar-backed queues).
//!
//! Topology per served model:
//!
//! ```text
//!   clients --submit()--> [ Batcher queue ] --batches--> inference thread
//!                                                        (owns PJRT: !Send)
//!   scrub thread --(WeightUpdate: full | dirty-shard deltas)--> inference
//!        |                                                thread (rebind)
//!        `-- owns the ShardedBank: fault injection + parallel per-shard
//!            scrub on a scoped worker pool + dirty tracking
//! ```
//!
//! PJRT handles wrap raw pointers and are not Send, so every PJRT object
//! lives on the inference thread; other threads communicate through
//! channels only. The refresh channel carries incremental updates: only
//! shards whose stored bytes changed since the last refresh are decoded
//! (fused decode + dequantize) and shipped as `offset + f32 window`
//! deltas; a full buffer crosses only when every shard is dirty.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{Batcher, BatchPolicy, Request, Response};
pub use metrics::{Metrics, ShardCounters};
pub use router::Router;
pub use server::{BatchExec, Server, ServerConfig, WeightDelta, WeightUpdate};
