//! Thread-based serving coordinator (tokio is unavailable offline; the
//! event loop is std::thread + mpsc channels + lock-free/condvar
//! queues).
//!
//! Topology per served model (front door selected by
//! [`IngressPolicy`]):
//!
//! ```text
//!   clients --try_submit()--> [ Ingress: lock-free slab ring (Ring)
//!                               or Mutex+Condvar queue    (Locked) ]
//!                                  --sealed batches--> inference thread
//!                                                      (owns PJRT: !Send)
//!   fleet arbiter --(WeightUpdate: full | dirty-shard deltas)--> inference
//!        |                                                 thread (rebind)
//!        `-- one process-wide control loop ([`fleet`]) owning every
//!            enrolled model's ShardedBank + ScrubScheduler: fault
//!            injection, cross-model urgency ranking of due shards
//!            under one scrub budget (starvation-bounded, per-model
//!            deficit accounting), parallel per-shard scrub on a scoped
//!            worker pool, dirty tracking, MILR escalation
//! ```
//!
//! Under the ring front door producers CAS-reserve a slot and write
//! their input tensor straight into the batch slab (reserve → write →
//! seal → exec → recycle; see [`ingress`]), so the request hot path
//! takes no lock and performs no steady-state allocation; a full ring
//! is explicit [`PushError::Overloaded`] backpressure. The locked
//! batcher remains the selectable baseline.
//!
//! PJRT handles wrap raw pointers and are not Send, so every PJRT object
//! lives on the inference thread; other threads communicate through
//! channels only. The refresh channel carries incremental updates: only
//! shards whose stored bytes changed since the last refresh are decoded
//! (fused decode + dequantize) and shipped as `offset + f32 window`
//! deltas; a full buffer crosses only when every shard is dirty.

pub mod batcher;
pub mod fleet;
pub mod ingress;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{Batcher, BatchPolicy, Request, Response};
pub use fleet::{FleetArbiter, FleetConfig, FleetSnapshot, ModelLane};
pub use ingress::{
    Ingress, IngressPolicy, IngressRing, IngressSnapshot, IngressStats, PushError, RingConfig,
    SealCause, SealedBatch,
};
pub use metrics::{FleetGauge, Metrics, ShardCounters};
pub use router::Router;
pub use server::{BatchExec, Server, ServerConfig, WeightDelta, WeightUpdate};
