//! Thread-based serving coordinator (tokio is unavailable offline; the
//! event loop is std::thread + mpsc channels + condvar-backed queues).
//!
//! Topology per served model:
//!
//! ```text
//!   clients --submit()--> [ Batcher queue ] --batches--> inference thread
//!                                                        (owns PJRT: !Send)
//!   scrub thread --(decoded f32 weights)--> inference thread (rebind)
//!        |
//!        `-- owns the MemoryBank: fault injection + periodic scrub
//! ```
//!
//! PJRT handles wrap raw pointers and are not Send, so every PJRT object
//! lives on the inference thread; other threads communicate through
//! channels only.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{Batcher, BatchPolicy, Request, Response};
pub use metrics::Metrics;
pub use router::Router;
pub use server::{Server, ServerConfig};
