//! Lock-free MPSC batching ingress — the million-req/s front door.
//!
//! The mutex [`Batcher`] serializes every producer on one lock; at
//! ROADMAP-north-star traffic the lock, not the protected store or the
//! executor, becomes the bottleneck. This module replaces it on the hot
//! path with a power-of-two ring of fixed-shape batch *slabs*: input
//! tensor lanes plus response-sender lanes, allocated once from the
//! [`memory::pool`](crate::memory::pool) arena and recycled forever —
//! steady state is allocation-free.
//!
//! Lifecycle (one slab): `reserve → write → seal → exec → recycle`.
//!
//! * **Reserve** — a producer CAS-increments the reservation field of
//!   the slab's state word to claim slot `r`.
//! * **Write** — it copies its input tensor into row `r` of the slab
//!   in place, parks its response sender in lane `r`, then bumps the
//!   slab's `written` counter (Release) to publish the row.
//! * **Seal** — the producer that fills the last slot, *or* the
//!   dispatcher when the batch deadline expires, CASes the state word
//!   OPEN→SEALED. Both racers target the same word, so exactly one
//!   wins and the loser sees a clean failure — no locks, no double
//!   dispatch.
//! * **Exec** — the dispatcher waits for `written` to catch up to the
//!   sealed reservation count (so every row is published), then hands
//!   the slab to `BatchExec` zero-copy.
//! * **Recycle** — after responses fan out the slab returns to FREE
//!   and the ring tail advances to open the next batch.
//!
//! ## The state word
//!
//! Each slab is governed by a single 64-bit word:
//!
//! ```text
//!   63 62 61………………32 31………………0
//!   [state] [seq_lo:30] [reserved:32]
//! ```
//!
//! `state` ∈ {FREE, CLAIMED, OPEN, SEALED}. Folding the low 30 bits of
//! the batch sequence number into the word defeats ABA across slab
//! recycling: a CAS prepared against batch `t`'s word can never land on
//! the slab's next tenant `t + depth`. Reservation and sealing
//! serialize on this one word, which is what makes the
//! fill-vs-deadline seal race safe.
//!
//! ## Backpressure
//!
//! A full ring (every slab sealed or in flight) is explicit overload:
//! producers spin briefly helping the tail advance, then get
//! [`PushError::Overloaded`] instead of growing an unbounded queue —
//! the caller (router / load balancer) decides whether to shed or
//! retry.
//!
//! ## Validation
//!
//! The reserve/write/seal and seal/timeout races are checked under
//! `cfg(loom)` permutation tests (see `loom_model` below; CI runs them
//! with `RUSTFLAGS="--cfg loom"`). The vendored loom is an offline
//! shim that perturbs schedules at every atomic op; swap in the real
//! crate for exhaustive DPOR checking.

use std::fmt;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

#[cfg(loom)]
use loom::sync::atomic::{AtomicBool, AtomicU64, Ordering};
#[cfg(loom)]
use loom::sync::{Condvar, Mutex};
#[cfg(loom)]
use loom::thread::yield_now;
#[cfg(not(loom))]
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
#[cfg(not(loom))]
use std::sync::{Condvar, Mutex};
#[cfg(not(loom))]
use std::thread::yield_now;

use crate::coordinator::batcher::{Batcher, Request, Response};
use crate::memory::pool;

/// Closure-scoped cell for the response lanes. Under `cfg(loom)` this
/// is loom's access-tracked `UnsafeCell`; under std it is a thin
/// wrapper with the same API.
#[cfg(loom)]
use loom::cell::UnsafeCell as SlotCell;

#[cfg(not(loom))]
mod plain_cell {
    /// API mirror of `loom::cell::UnsafeCell` (closure-scoped raw
    /// pointer access) so ingress code compiles unchanged under both
    /// cfgs. Safety contract is the caller's, exactly as with
    /// `std::cell::UnsafeCell::get`.
    #[derive(Debug, Default)]
    pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

    impl<T> UnsafeCell<T> {
        pub fn new(v: T) -> Self {
            UnsafeCell(std::cell::UnsafeCell::new(v))
        }

        pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
            f(self.0.get())
        }
    }
}
#[cfg(not(loom))]
use plain_cell::UnsafeCell as SlotCell;

// ---------------------------------------------------------------------------
// State-word layout.

const RESERVED_MASK: u64 = 0xffff_ffff;
const SEQ_SHIFT: u32 = 32;
const SEQ_MASK: u64 = (1 << 30) - 1;
const STATE_SHIFT: u32 = 62;

/// Slab awaits its next tenant (recycled, claimable).
const FREE: u64 = 0;
/// A sealer is mid-way through opening it for the next batch.
const CLAIMED: u64 = 1;
/// Accepting reservations.
const OPEN: u64 = 2;
/// Frozen for dispatch; reservation field is the final batch size.
const SEALED: u64 = 3;

#[inline]
fn seq_lo(seq: u64) -> u64 {
    seq & SEQ_MASK
}

#[inline]
fn word(state: u64, seq: u64, reserved: u64) -> u64 {
    (state << STATE_SHIFT) | (seq_lo(seq) << SEQ_SHIFT) | reserved
}

#[inline]
fn w_state(w: u64) -> u64 {
    w >> STATE_SHIFT
}

#[inline]
fn w_seq(w: u64) -> u64 {
    (w >> SEQ_SHIFT) & SEQ_MASK
}

#[inline]
fn w_res(w: u64) -> u64 {
    w & RESERVED_MASK
}

/// Producer spin budget before a full ring turns into `Overloaded`.
const PUSH_SPIN_LIMIT: u32 = 256;
/// Dispatcher re-poll interval while a transient (mid-claim slab,
/// slot-0 writer between reserve and deadline store) resolves.
const POLL_TICK: Duration = Duration::from_micros(10);
/// Upper bound on any single dispatcher park. Bounding every park makes
/// a lost wakeup cost at most one tick instead of a hang, so the
/// notify path is latency optimization, not a correctness requirement.
const MAX_PARK: Duration = Duration::from_millis(1);

// ---------------------------------------------------------------------------
// Public types.

/// Why a push was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    /// Every slab is sealed or in flight — shed or retry upstream.
    Overloaded,
    /// The ring is shutting down.
    Closed,
    /// Input length does not match the ring's row width.
    Shape { got: usize, want: usize },
}

impl fmt::Display for PushError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PushError::Overloaded => write!(f, "ingress overloaded: ring full"),
            PushError::Closed => write!(f, "ingress closed"),
            PushError::Shape { got, want } => {
                write!(f, "input shape mismatch: got {got} elements, want {want}")
            }
        }
    }
}

impl std::error::Error for PushError {}

/// What froze a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SealCause {
    /// The last slot was reserved and written.
    Full,
    /// The batch deadline (pinned to its first request) expired.
    Deadline,
    /// Shutdown drained a partial batch.
    Drain,
}

/// Ring geometry and release policy.
#[derive(Clone, Copy, Debug)]
pub struct RingConfig {
    /// Number of slabs (rounded up to a power of two, min 2). Total
    /// admission capacity is `depth * cap` requests.
    pub depth: usize,
    /// Slots (requests) per batch slab.
    pub cap: usize,
    /// `f32` elements per input row.
    pub dim: usize,
    /// Deadline for a partial batch, measured from its first request.
    pub max_wait: Duration,
}

/// Selects the serving front door: the mutex [`Batcher`] baseline or
/// the lock-free ring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IngressPolicy {
    /// `Mutex<VecDeque>` + condvar baseline (PR-1 batcher).
    Locked,
    /// Lock-free slot-reservation ring (this module).
    Ring,
}

impl IngressPolicy {
    pub fn parse(s: &str) -> anyhow::Result<IngressPolicy> {
        match s {
            "locked" => Ok(IngressPolicy::Locked),
            "ring" => Ok(IngressPolicy::Ring),
            other => Err(anyhow::anyhow!(
                "unknown ingress policy '{other}' (expected 'locked' or 'ring')"
            )),
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            IngressPolicy::Locked => "locked",
            IngressPolicy::Ring => "ring",
        }
    }
}

/// Per-request metadata parked in a slab lane by the producer and
/// collected by the dispatcher during response fan-out.
pub struct Lane {
    pub id: u64,
    pub submitted: Instant,
    pub resp: Sender<Response>,
}

// ---------------------------------------------------------------------------
// Stats.

/// Concurrent ingress gauges, shared with [`Metrics`]
/// (`crate::coordinator::Metrics`) for report rows. All counters are
/// monotonic except `occupancy` (a live gauge).
pub struct IngressStats {
    /// Requests reserved but not yet recycled (live gauge).
    occupancy: AtomicU64,
    /// High-water mark of `occupancy`.
    occupancy_hwm: AtomicU64,
    /// Failed reserve/seal/claim CAS attempts (contention gauge).
    cas_retries: AtomicU64,
    seal_full: AtomicU64,
    seal_deadline: AtomicU64,
    seal_drain: AtomicU64,
    /// Pushes refused with [`PushError::Overloaded`].
    overloads: AtomicU64,
}

/// Plain-value copy of [`IngressStats`] for reporting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IngressSnapshot {
    pub occupancy: u64,
    pub occupancy_hwm: u64,
    pub cas_retries: u64,
    pub seal_full: u64,
    pub seal_deadline: u64,
    pub seal_drain: u64,
    pub overloads: u64,
}

impl IngressStats {
    /// Explicit zeroed constructor (the real loom's atomics do not
    /// implement `Default`, so no derive).
    pub fn new() -> IngressStats {
        IngressStats {
            occupancy: AtomicU64::new(0),
            occupancy_hwm: AtomicU64::new(0),
            cas_retries: AtomicU64::new(0),
            seal_full: AtomicU64::new(0),
            seal_deadline: AtomicU64::new(0),
            seal_drain: AtomicU64::new(0),
            overloads: AtomicU64::new(0),
        }
    }

    fn record_seal(&self, cause: SealCause) {
        let ctr = match cause {
            SealCause::Full => &self.seal_full,
            SealCause::Deadline => &self.seal_deadline,
            SealCause::Drain => &self.seal_drain,
        };
        ctr.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> IngressSnapshot {
        IngressSnapshot {
            occupancy: self.occupancy.load(Ordering::Relaxed),
            occupancy_hwm: self.occupancy_hwm.load(Ordering::Relaxed),
            cas_retries: self.cas_retries.load(Ordering::Relaxed),
            seal_full: self.seal_full.load(Ordering::Relaxed),
            seal_deadline: self.seal_deadline.load(Ordering::Relaxed),
            seal_drain: self.seal_drain.load(Ordering::Relaxed),
            overloads: self.overloads.load(Ordering::Relaxed),
        }
    }
}

impl Default for IngressStats {
    fn default() -> Self {
        IngressStats::new()
    }
}

// ---------------------------------------------------------------------------
// The slab.

struct Slab {
    /// The tagged state word (see module docs).
    state: AtomicU64,
    /// Rows published so far; the dispatcher waits for this to reach
    /// the sealed reservation count before touching the inputs.
    written: AtomicU64,
    /// Nanoseconds (since ring epoch) of the batch's first request;
    /// 0 = not yet stored. Pins the deadline to the *first* request.
    first_ns: AtomicU64,
    /// Owns the input allocation (`cap * dim` zero-initialized f32s,
    /// leased from the arena once). The hot path never touches this
    /// field — all access goes through `base` — it exists so the
    /// buffer can be returned to the arena on drop.
    storage: Vec<f32>,
    /// `storage.as_mut_ptr()`: producers write disjoint rows through
    /// raw pointers (two `&mut` borrows of the same `Vec` from two
    /// threads would be UB even for disjoint ranges).
    base: *mut f32,
    /// Response-sender lanes, one per slot.
    lanes: Box<[SlotCell<Option<Lane>>]>,
}

// SAFETY: the reservation protocol makes every non-atomic field
// single-writer at any instant. A row of `base` and its lane cell are
// written by exactly one producer (the slot's reserver) and then read
// by exactly one dispatcher, with the hand-off ordered by the
// `written` Release increment / Acquire read; slab reuse is ordered by
// the FREE store (Release) / claim CAS (Acquire) on `state`. `storage`
// is only touched at construction and drop (`&mut self`).
unsafe impl Send for Slab {}
unsafe impl Sync for Slab {}

// ---------------------------------------------------------------------------
// The ring.

/// Lock-free MPSC batching ring. Many producers [`push`]
/// (`IngressRing::push`); one dispatcher consumes via
/// [`next_sealed`](IngressRing::next_sealed).
pub struct IngressRing {
    slabs: Box<[Slab]>,
    mask: u64,
    cap: usize,
    dim: usize,
    wait_ns: u64,
    /// Reference instant for `first_ns` timestamps.
    epoch: Instant,
    /// Sequence number of the currently open batch.
    tail: AtomicU64,
    /// Dispatcher cursor: next batch sequence to consume.
    next_exec: AtomicU64,
    closed: AtomicBool,
    stats: Arc<IngressStats>,
    /// Dispatcher parking: producers take this lock only when the
    /// dispatcher has advertised it is waiting (Dekker-style flag), so
    /// the hot path stays lock-free — at most two notifies per batch
    /// (first request in, batch full).
    park_mx: Mutex<()>,
    park_cv: Condvar,
    dispatcher_waiting: AtomicBool,
}

enum Poll {
    /// `slab(next_exec)` is sealed with this many published rows.
    Ready(usize),
    /// Closed and fully drained.
    Done,
    /// Nothing consumable; park at most this long and re-poll.
    Park(Duration),
}

impl IngressRing {
    pub fn new(cfg: RingConfig) -> IngressRing {
        assert!(cfg.cap >= 1, "ring cap must be >= 1");
        assert!(cfg.dim >= 1, "ring dim must be >= 1");
        assert!(
            (cfg.cap as u64) <= RESERVED_MASK >> 1,
            "ring cap exceeds reservation field"
        );
        let depth = cfg.depth.max(2).next_power_of_two();
        let slabs: Vec<Slab> = (0..depth)
            .map(|i| {
                // Slot 0 of the ring starts OPEN as batch 0; the rest
                // are FREE awaiting their first claim.
                let w = if i == 0 {
                    word(OPEN, 0, 0)
                } else {
                    word(FREE, i as u64, 0)
                };
                let mut storage = pool::lease_f32(cfg.cap * cfg.dim).take();
                let base = storage.as_mut_ptr();
                Slab {
                    state: AtomicU64::new(w),
                    written: AtomicU64::new(0),
                    first_ns: AtomicU64::new(0),
                    storage,
                    base,
                    lanes: (0..cfg.cap)
                        .map(|_| SlotCell::new(None))
                        .collect::<Vec<_>>()
                        .into_boxed_slice(),
                }
            })
            .collect();
        IngressRing {
            slabs: slabs.into_boxed_slice(),
            mask: depth as u64 - 1,
            cap: cfg.cap,
            dim: cfg.dim,
            wait_ns: cfg.max_wait.as_nanos().min(u64::MAX as u128) as u64,
            epoch: Instant::now(),
            tail: AtomicU64::new(0),
            next_exec: AtomicU64::new(0),
            closed: AtomicBool::new(false),
            stats: Arc::new(IngressStats::new()),
            park_mx: Mutex::new(()),
            park_cv: Condvar::new(),
            dispatcher_waiting: AtomicBool::new(false),
        }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn depth(&self) -> usize {
        self.slabs.len()
    }

    pub fn stats(&self) -> Arc<IngressStats> {
        self.stats.clone()
    }

    /// Requests reserved but not yet recycled.
    pub fn in_flight(&self) -> u64 {
        self.stats.occupancy.load(Ordering::Relaxed)
    }

    #[inline]
    fn slab(&self, seq: u64) -> &Slab {
        &self.slabs[(seq & self.mask) as usize]
    }

    #[inline]
    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos().min(u64::MAX as u128) as u64
    }

    /// Reserve a slot in the open batch, write `image` into it in
    /// place, and park the response sender. Lock-free; bounded spin
    /// then [`PushError::Overloaded`] when the ring is full.
    pub fn push(&self, id: u64, image: &[f32], resp: Sender<Response>) -> Result<(), PushError> {
        if image.len() != self.dim {
            return Err(PushError::Shape {
                got: image.len(),
                want: self.dim,
            });
        }
        let mut spins: u32 = 0;
        loop {
            if self.closed.load(Ordering::Acquire) {
                return Err(PushError::Closed);
            }
            let t = self.tail.load(Ordering::Acquire);
            let slab = self.slab(t);
            let w = slab.state.load(Ordering::Acquire);
            if w_seq(w) == seq_lo(t) && w_state(w) == OPEN {
                let r = w_res(w);
                if r < self.cap as u64 {
                    // Reserve slot `r`: reserved occupies the low bits,
                    // so the CAS target is simply `w + 1`.
                    match slab.state.compare_exchange_weak(
                        w,
                        w + 1,
                        Ordering::AcqRel,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => {
                            self.write_slot(t, slab, r as usize, id, image, resp);
                            return Ok(());
                        }
                        Err(_) => {
                            self.stats.cas_retries.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                    }
                }
                // r == cap: the filling producer is about to seal; fall
                // through to the backoff path until the tail advances.
            }
            // Tail slab sealed / mid-claim / owned by an in-flight
            // batch: help the claim protocol along, then back off.
            self.advance_tail();
            spins += 1;
            if spins > PUSH_SPIN_LIMIT {
                self.stats.overloads.fetch_add(1, Ordering::Relaxed);
                return Err(PushError::Overloaded);
            }
            if spins % 16 == 0 {
                yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }

    /// Post-reservation half of `push`: fill row `slot` of batch `t`.
    /// Must be panic-free between the reserve CAS and the `written`
    /// increment (the shape check already ran, so the copy cannot
    /// fail) or the dispatcher would wait forever for the row.
    fn write_slot(
        &self,
        t: u64,
        slab: &Slab,
        slot: usize,
        id: u64,
        image: &[f32],
        resp: Sender<Response>,
    ) {
        // SAFETY: the reserve CAS made this thread the unique writer of
        // row `slot`; rows are disjoint; the slab cannot be recycled
        // while the row is unpublished (dispatcher waits on `written`).
        unsafe {
            std::ptr::copy_nonoverlapping(image.as_ptr(), slab.base.add(slot * self.dim), self.dim);
        }
        let lane = Lane {
            id,
            submitted: Instant::now(),
            resp,
        };
        // SAFETY: unique writer of lane `slot`, as above.
        slab.lanes[slot].with_mut(|p| unsafe { *p = Some(lane) });
        if slot == 0 {
            // First request of the batch pins its deadline (0 = unset,
            // so clamp the timestamp to at least 1).
            slab.first_ns.store(self.now_ns().max(1), Ordering::Release);
        }
        // Publish the row: the dispatcher's Acquire read of `written`
        // orders all of the above before exec.
        slab.written.fetch_add(1, Ordering::Release);
        let occ = self.stats.occupancy.fetch_add(1, Ordering::Relaxed) + 1;
        self.stats.occupancy_hwm.fetch_max(occ, Ordering::Relaxed);
        let filled = slot + 1 == self.cap;
        if filled {
            self.seal(t, SealCause::Full);
        }
        if slot == 0 || filled {
            // Only batch-start (a deadline now exists) and batch-full
            // (work is ready) change what the dispatcher would do.
            self.wake_dispatcher();
        }
    }

    /// CAS batch `seq` OPEN→SEALED, freezing its reservation count.
    /// Returns false if another sealer won (or the batch moved on) —
    /// the fill-vs-deadline race resolves here, on one word.
    fn seal(&self, seq: u64, cause: SealCause) -> bool {
        let slab = self.slab(seq);
        loop {
            let w = slab.state.load(Ordering::Acquire);
            if w_seq(w) != seq_lo(seq) || w_state(w) != OPEN {
                return false;
            }
            let sealed = word(SEALED, seq, w_res(w));
            match slab
                .state
                .compare_exchange_weak(w, sealed, Ordering::AcqRel, Ordering::Relaxed)
            {
                Ok(_) => {
                    self.stats.record_seal(cause);
                    self.advance_tail();
                    return true;
                }
                Err(_) => {
                    self.stats.cas_retries.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// If the tail batch is sealed and its successor slab is free,
    /// claim the slab, open it as the next batch, and advance the
    /// tail. Called by sealers, recyclers, and backing-off producers;
    /// any number may race — exactly one opens each batch.
    fn advance_tail(&self) {
        loop {
            let t = self.tail.load(Ordering::Acquire);
            let cur = self.slab(t);
            let wc = cur.state.load(Ordering::Acquire);
            if w_seq(wc) != seq_lo(t) || w_state(wc) != SEALED {
                return;
            }
            let nseq = t.wrapping_add(1);
            let nxt = self.slab(nseq);
            let wn = nxt.state.load(Ordering::Acquire);
            if w_state(wn) != FREE {
                // Successor still owned by batch `nseq - depth` (ring
                // full) or mid-claim by a racing sealer.
                return;
            }
            if nxt
                .state
                .compare_exchange(wn, word(CLAIMED, nseq, 0), Ordering::AcqRel, Ordering::Relaxed)
                .is_err()
            {
                self.stats.cas_retries.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            // ABA guard: the claim is only valid while the tail is
            // still `t`. A thread stalled across a whole ring cycle
            // could otherwise claim a slab already freed for a *later*
            // batch and regress the tail. While we hold CLAIMED on the
            // successor no one else can advance past `t`, so a
            // matching tail here is frozen until our store below.
            if self.tail.load(Ordering::Acquire) != t {
                nxt.state.store(wn, Ordering::Release);
                continue;
            }
            nxt.written.store(0, Ordering::Relaxed);
            nxt.first_ns.store(0, Ordering::Relaxed);
            nxt.state.store(word(OPEN, nseq, 0), Ordering::Release);
            self.tail.store(nseq, Ordering::Release);
            return;
        }
    }

    /// Seal the open tail batch now if it holds at least one request
    /// (as the deadline timer would). Exposed for the loom seal-race
    /// tests and deterministic unit tests.
    pub fn seal_open_now(&self) -> bool {
        let t = self.tail.load(Ordering::Acquire);
        let slab = self.slab(t);
        let w = slab.state.load(Ordering::Acquire);
        if w_seq(w) == seq_lo(t) && w_state(w) == OPEN && w_res(w) > 0 {
            return self.seal(t, SealCause::Deadline);
        }
        false
    }

    /// Non-blocking poll of the dispatcher cursor.
    fn poll_next(&self) -> Poll {
        let seq = self.next_exec.load(Ordering::Relaxed);
        let slab = self.slab(seq);
        let w = slab.state.load(Ordering::Acquire);
        if w_seq(w) != seq_lo(seq) {
            // Slab still mid-recycle for this sequence; help and retry.
            self.advance_tail();
            return Poll::Park(POLL_TICK);
        }
        match w_state(w) {
            SEALED => {
                let n = w_res(w);
                // Wait for in-flight writers to publish their rows; the
                // reserve CAS bounds them, so this spin is short.
                let mut spins: u32 = 0;
                while slab.written.load(Ordering::Acquire) < n {
                    spins += 1;
                    if spins % 64 == 0 {
                        yield_now();
                    } else {
                        std::hint::spin_loop();
                    }
                }
                Poll::Ready(n as usize)
            }
            OPEN => {
                let r = w_res(w);
                if r == 0 {
                    if self.closed.load(Ordering::Acquire) {
                        return Poll::Done;
                    }
                    return Poll::Park(MAX_PARK);
                }
                if self.closed.load(Ordering::Acquire) {
                    self.seal(seq, SealCause::Drain);
                    return Poll::Park(Duration::ZERO);
                }
                let first = slab.first_ns.load(Ordering::Acquire);
                if first == 0 {
                    // Slot-0 writer is between its reserve CAS and the
                    // deadline store.
                    return Poll::Park(POLL_TICK);
                }
                let deadline = first.saturating_add(self.wait_ns);
                let now = self.now_ns();
                if now >= deadline {
                    self.seal(seq, SealCause::Deadline);
                    return Poll::Park(Duration::ZERO);
                }
                Poll::Park(Duration::from_nanos(deadline - now))
            }
            // FREE/CLAIMED with a matching sequence: being opened right
            // now by a sealer in `advance_tail`.
            _ => Poll::Park(POLL_TICK),
        }
    }

    /// Block until a sealed batch is ready; `None` once the ring is
    /// closed and fully drained. Single consumer: drop the returned
    /// [`SealedBatch`] (recycling its slab) before calling again.
    pub fn next_sealed(&self) -> Option<SealedBatch<'_>> {
        loop {
            match self.poll_next() {
                Poll::Ready(count) => {
                    return Some(SealedBatch {
                        ring: self,
                        seq: self.next_exec.load(Ordering::Relaxed),
                        count,
                    })
                }
                Poll::Done => return None,
                Poll::Park(d) => {
                    if !d.is_zero() {
                        self.park(d.min(MAX_PARK));
                    }
                }
            }
        }
    }

    /// Non-blocking [`next_sealed`](IngressRing::next_sealed) (used by
    /// the loom tests, which drive the schedule themselves).
    pub fn try_next_sealed(&self) -> Option<SealedBatch<'_>> {
        match self.poll_next() {
            Poll::Ready(count) => Some(SealedBatch {
                ring: self,
                seq: self.next_exec.load(Ordering::Relaxed),
                count,
            }),
            _ => None,
        }
    }

    /// Begin shutdown: new pushes fail, pending batches drain.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.wake_dispatcher();
    }

    fn park(&self, d: Duration) {
        self.dispatcher_waiting.store(true, Ordering::SeqCst);
        {
            let g = self.park_mx.lock().unwrap();
            let _ = self.park_cv.wait_timeout(g, d).unwrap();
        }
        self.dispatcher_waiting.store(false, Ordering::SeqCst);
    }

    fn wake_dispatcher(&self) {
        if self.dispatcher_waiting.load(Ordering::SeqCst) {
            let _g = self.park_mx.lock().unwrap();
            self.park_cv.notify_all();
        }
    }
}

impl Drop for IngressRing {
    fn drop(&mut self) {
        // Return the slab input buffers to the arena; pending lanes
        // (their senders) drop with the slabs, disconnecting any
        // receivers still waiting.
        for slab in self.slabs.iter_mut() {
            pool::give(std::mem::take(&mut slab.storage));
        }
    }
}

// ---------------------------------------------------------------------------
// Sealed batch handle.

/// A sealed slab handed to the dispatcher. Rows `0..count` are
/// published; rows beyond hold stale data from the slab's previous
/// tenant (executors compute padding predictions that the caller
/// truncates, exactly like the locked path's final short chunk).
/// Dropping the handle recycles the slab and advances the consumer
/// cursor, so take every lane and send every response first.
pub struct SealedBatch<'a> {
    ring: &'a IngressRing,
    seq: u64,
    count: usize,
}

impl SealedBatch<'_> {
    /// Published rows in this batch (1..=cap).
    pub fn count(&self) -> usize {
        self.count
    }

    /// Batch sequence number (monotonic from ring creation).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Zero-copy view of the full slab (`cap * dim` elements).
    pub fn with_inputs<R>(&self, f: impl FnOnce(&[f32]) -> R) -> R {
        let slab = self.ring.slab(self.seq);
        // SAFETY: the batch is sealed and `written == count`, so no
        // producer writes this slab until it is recycled, which cannot
        // happen before `self` drops.
        let all =
            unsafe { std::slice::from_raw_parts(slab.base, self.ring.cap * self.ring.dim) };
        f(all)
    }

    /// Take lane `slot`'s response metadata (panics if taken twice —
    /// the exactly-one-response invariant).
    pub fn take_lane(&self, slot: usize) -> Lane {
        assert!(slot < self.count, "lane {slot} beyond batch count {}", self.count);
        let slab = self.ring.slab(self.seq);
        // SAFETY: sealed + written handshake as in `with_inputs`; the
        // dispatcher is the unique accessor of lanes after sealing.
        slab.lanes[slot]
            .with_mut(|p| unsafe { (*p).take() })
            .expect("ingress lane taken twice")
    }
}

impl Drop for SealedBatch<'_> {
    fn drop(&mut self) {
        let slab = self.ring.slab(self.seq);
        // Drop any untaken lanes so their receivers observe disconnect
        // rather than a hang.
        for slot in 0..self.count {
            // SAFETY: unique accessor, as in `take_lane`.
            let _ = slab.lanes[slot].with_mut(|p| unsafe { (*p).take() });
        }
        // FREE the slab (Release orders the lane drops before any
        // claim), account the gauge, and hand the cursor forward.
        slab.state.store(word(FREE, self.seq, 0), Ordering::Release);
        self.ring
            .stats
            .occupancy
            .fetch_sub(self.count as u64, Ordering::Relaxed);
        self.ring
            .next_exec
            .store(self.seq.wrapping_add(1), Ordering::Release);
        self.ring.advance_tail();
    }
}

// ---------------------------------------------------------------------------
// Runtime selector.

/// The server's front door: either the mutex batcher baseline or the
/// lock-free ring, chosen by [`IngressPolicy`] in `ServerConfig`.
pub enum Ingress {
    Locked(Batcher),
    Ring(IngressRing),
}

impl Ingress {
    /// Submit one request. The locked path takes ownership of the
    /// image; the ring path copies it into the slab and parks the
    /// spent buffer in the arena, keeping steady state allocation-free
    /// for callers that lease from the pool.
    pub fn push_owned(
        &self,
        id: u64,
        image: Vec<f32>,
        resp: Sender<Response>,
    ) -> Result<(), PushError> {
        match self {
            Ingress::Locked(b) => b
                .push(Request {
                    id,
                    image,
                    submitted: Instant::now(),
                    resp,
                })
                .map_err(|_| PushError::Closed),
            Ingress::Ring(r) => {
                r.push(id, &image, resp)?;
                pool::give(image);
                Ok(())
            }
        }
    }

    pub fn close(&self) {
        match self {
            Ingress::Locked(b) => b.close(),
            Ingress::Ring(r) => r.close(),
        }
    }

    pub fn policy(&self) -> IngressPolicy {
        match self {
            Ingress::Locked(_) => IngressPolicy::Locked,
            Ingress::Ring(_) => IngressPolicy::Ring,
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn ring(depth: usize, cap: usize, dim: usize, wait_ms: u64) -> IngressRing {
        IngressRing::new(RingConfig {
            depth,
            cap,
            dim,
            max_wait: Duration::from_millis(wait_ms),
        })
    }

    #[test]
    fn fifo_within_batch() {
        let r = ring(4, 8, 2, 0);
        let mut rxs = Vec::new();
        for i in 0..5u64 {
            let (tx, rx) = channel();
            r.push(i, &[i as f32, i as f32 + 0.5], tx).unwrap();
            rxs.push(rx);
        }
        let b = r.next_sealed().expect("zero-wait seal");
        assert_eq!(b.count(), 5);
        for slot in 0..5 {
            let lane = b.take_lane(slot);
            assert_eq!(lane.id, slot as u64, "slot order == push order");
            b.with_inputs(|inp| {
                assert_eq!(inp[slot * 2], slot as f32);
                assert_eq!(inp[slot * 2 + 1], slot as f32 + 0.5);
            });
        }
    }

    #[test]
    fn seals_on_full() {
        let r = ring(4, 4, 1, 60_000);
        for i in 0..4u64 {
            let (tx, _rx) = channel();
            r.push(i, &[0.0], tx).unwrap();
        }
        let b = r.next_sealed().expect("full seal, no deadline needed");
        assert_eq!(b.count(), 4);
        drop(b);
        assert_eq!(r.stats().snapshot().seal_full, 1);
    }

    #[test]
    fn seals_on_deadline_pinned_to_first_request() {
        let r = ring(4, 100, 1, 25);
        let (tx, _rx) = channel();
        let t0 = Instant::now();
        r.push(7, &[1.0], tx).unwrap();
        let b = r.next_sealed().expect("deadline seal");
        assert_eq!(b.count(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(20));
        drop(b);
        assert_eq!(r.stats().snapshot().seal_deadline, 1);
    }

    #[test]
    fn shutdown_drains_pending_then_none() {
        let r = ring(4, 8, 1, 60_000);
        let mut rxs = Vec::new();
        for i in 0..3u64 {
            let (tx, rx) = channel();
            r.push(i, &[0.0], tx).unwrap();
            rxs.push(rx);
        }
        r.close();
        let b = r.next_sealed().expect("partial batch drains on close");
        assert_eq!(b.count(), 3);
        drop(b);
        assert!(r.next_sealed().is_none(), "then shutdown");
        let (tx, _rx) = channel();
        assert_eq!(r.push(9, &[0.0], tx), Err(PushError::Closed));
        assert_eq!(r.stats().snapshot().seal_drain, 1);
    }

    #[test]
    fn overload_backpressure_and_recovery() {
        // depth 2 x cap 1: two sealed-but-unconsumed batches fill the
        // ring; the third push must get explicit backpressure.
        let r = ring(2, 1, 1, 60_000);
        let (tx, _rx1) = channel();
        r.push(0, &[0.0], tx).unwrap();
        let (tx, _rx2) = channel();
        r.push(1, &[0.0], tx).unwrap();
        let (tx, _rx3) = channel();
        assert_eq!(r.push(2, &[0.0], tx), Err(PushError::Overloaded));
        assert!(r.stats().snapshot().overloads >= 1);
        // Consuming one batch frees a slab and admission resumes.
        let b = r.next_sealed().unwrap();
        assert_eq!(b.count(), 1);
        drop(b);
        let (tx, _rx4) = channel();
        r.push(3, &[0.0], tx).unwrap();
        assert_eq!(r.in_flight(), 2);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let r = ring(2, 4, 4, 1);
        let (tx, _rx) = channel();
        assert_eq!(
            r.push(0, &[0.0; 3], tx),
            Err(PushError::Shape { got: 3, want: 4 })
        );
    }

    #[test]
    fn seal_open_now_is_deadline_equivalent() {
        let r = ring(4, 8, 1, 60_000);
        assert!(!r.seal_open_now(), "empty batch never seals");
        let (tx, _rx) = channel();
        r.push(0, &[0.0], tx).unwrap();
        assert!(r.seal_open_now());
        assert!(!r.seal_open_now(), "no double seal");
        let b = r.next_sealed().unwrap();
        assert_eq!(b.count(), 1);
    }

    #[test]
    fn untaken_lanes_disconnect_on_recycle() {
        let r = ring(4, 8, 1, 0);
        let (tx, rx) = channel();
        r.push(0, &[0.0], tx).unwrap();
        drop(r.next_sealed().unwrap()); // dispatcher drops without replying
        assert!(rx.recv().is_err(), "sender dropped => disconnect, not hang");
    }

    #[test]
    fn multi_producer_exactly_once() {
        use std::sync::Arc;
        let r = Arc::new(ring(8, 16, 2, 1));
        let producers = 8;
        let per = 100u64;
        let mut handles = Vec::new();
        for p in 0..producers {
            let r = Arc::clone(&r);
            handles.push(std::thread::spawn(move || {
                let mut rxs = Vec::new();
                for i in 0..per {
                    let id = p * 10_000 + i;
                    let (tx, rx) = channel();
                    loop {
                        match r.push(id, &[id as f32, 0.0], tx.clone()) {
                            Ok(()) => break,
                            Err(PushError::Overloaded) => std::thread::yield_now(),
                            Err(e) => panic!("unexpected push error: {e}"),
                        }
                    }
                    rxs.push((id, rx));
                }
                // Every request gets exactly one response, carrying
                // its own id.
                for (id, rx) in rxs {
                    let resp = rx.recv().expect("response delivered");
                    assert_eq!(resp.id, id);
                }
            }));
        }
        let dispatcher = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                let mut served = 0u64;
                while let Some(b) = r.next_sealed() {
                    for slot in 0..b.count() {
                        let lane = b.take_lane(slot);
                        let resp = Response {
                            id: lane.id,
                            pred: 0,
                            latency: lane.submitted.elapsed(),
                        };
                        let _ = lane.resp.send(resp);
                        served += 1;
                    }
                }
                served
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        r.close();
        assert_eq!(dispatcher.join().unwrap(), producers * per);
        let s = r.stats().snapshot();
        assert_eq!(s.occupancy, 0, "all slots recycled");
        assert!(s.occupancy_hwm >= 1);
        assert!(s.occupancy_hwm <= (r.depth() * r.cap()) as u64);
        assert_eq!(s.seal_full + s.seal_deadline + s.seal_drain > 0, true);
    }

    #[test]
    fn ingress_selector_round_trip() {
        assert_eq!(IngressPolicy::parse("ring").unwrap(), IngressPolicy::Ring);
        assert_eq!(
            IngressPolicy::parse("locked").unwrap(),
            IngressPolicy::Locked
        );
        assert!(IngressPolicy::parse("bogus").is_err());
        assert_eq!(IngressPolicy::Ring.tag(), "ring");
        let ing = Ingress::Ring(ring(2, 4, 1, 0));
        assert_eq!(ing.policy(), IngressPolicy::Ring);
        let (tx, _rx) = channel();
        ing.push_owned(1, vec![0.5], tx).unwrap();
        ing.close();
        let (tx, _rx) = channel();
        assert_eq!(ing.push_owned(2, vec![0.5], tx), Err(PushError::Closed));
    }
}

/// Permutation tests for the lock-free protocol, built only under
/// `RUSTFLAGS="--cfg loom"` (the CI loom job). Each body runs under
/// `loom::model`, which explores many schedules; the assertions are
/// schedule-independent invariants (exactly-once delivery, a single
/// seal winner, conserved occupancy). The vendored shim uses std
/// channels and real threads; swapping in the real loom crate keeps
/// these compiling for exhaustive DPOR runs.
#[cfg(all(test, loom))]
mod loom_model {
    use super::*;
    use std::sync::mpsc::channel;

    fn cfg(depth: usize, cap: usize) -> RingConfig {
        RingConfig {
            depth,
            cap,
            dim: 1,
            // Far future: deadlines in these tests fire only via the
            // explicit seal_open_now hook, keeping schedules in
            // control of the model, not the wall clock.
            max_wait: Duration::from_secs(3600),
        }
    }

    fn push_retrying(r: &IngressRing, id: u64) {
        let (tx, _rx) = channel();
        loop {
            match r.push(id, &[id as f32], tx.clone()) {
                Ok(()) => return,
                Err(PushError::Overloaded) => loom::thread::yield_now(),
                Err(e) => panic!("unexpected push error: {e}"),
            }
        }
    }

    /// Two producers race reserve/write against a dispatcher that
    /// randomly fires the deadline seal: every request is delivered
    /// exactly once, whatever interleaving wins.
    #[test]
    fn reserve_write_seal_race() {
        loom::model(|| {
            let r = std::sync::Arc::new(IngressRing::new(cfg(2, 2)));
            let mut handles = Vec::new();
            for i in 0..2u64 {
                let r = r.clone();
                handles.push(loom::thread::spawn(move || push_retrying(&r, i)));
            }
            let mut got = Vec::new();
            while got.len() < 2 {
                if let Some(b) = r.try_next_sealed() {
                    for slot in 0..b.count() {
                        got.push(b.take_lane(slot).id);
                    }
                } else {
                    // Model the deadline timer firing at an arbitrary
                    // point relative to the producers.
                    r.seal_open_now();
                    loom::thread::yield_now();
                }
            }
            for h in handles {
                h.join().unwrap();
            }
            got.sort_unstable();
            assert_eq!(got, vec![0, 1], "each request surfaces exactly once");
            let s = r.stats().snapshot();
            assert_eq!(s.occupancy, 0, "conserved: all reservations recycled");
            assert!(s.seal_full + s.seal_deadline >= 1);
        });
    }

    /// The "last writer fills" seal races the "timeout fires" seal on
    /// the same batch: exactly one wins, so seal causes and consumed
    /// batches stay in one-to-one correspondence.
    #[test]
    fn seal_timeout_vs_fill_race() {
        loom::model(|| {
            let r = std::sync::Arc::new(IngressRing::new(cfg(2, 2)));
            let mut handles = Vec::new();
            for i in 0..2u64 {
                let r = r.clone();
                handles.push(loom::thread::spawn(move || push_retrying(&r, i)));
            }
            let mut batches = 0u64;
            let mut total = 0usize;
            while total < 2 {
                if let Some(b) = r.try_next_sealed() {
                    batches += 1;
                    total += b.count();
                    for slot in 0..b.count() {
                        b.take_lane(slot);
                    }
                } else {
                    r.seal_open_now();
                }
            }
            for h in handles {
                h.join().unwrap();
            }
            let s = r.stats().snapshot();
            // A double seal of one batch would break this equality.
            assert_eq!(
                s.seal_full + s.seal_deadline + s.seal_drain,
                batches,
                "every consumed batch was sealed exactly once"
            );
            assert_eq!(s.occupancy, 0);
        });
    }

    /// Wraparound: with depth 2 / cap 1 every push recycles a slab, so
    /// the claim protocol's ABA guard (sequence tag + tail check) is
    /// exercised on every schedule.
    #[test]
    fn recycle_wraparound_race() {
        loom::model(|| {
            let r = std::sync::Arc::new(IngressRing::new(cfg(2, 1)));
            let mut handles = Vec::new();
            for p in 0..2u64 {
                let r = r.clone();
                handles.push(loom::thread::spawn(move || {
                    for i in 0..2u64 {
                        push_retrying(&r, p * 10 + i);
                    }
                }));
            }
            let mut got = Vec::new();
            while got.len() < 4 {
                if let Some(b) = r.try_next_sealed() {
                    for slot in 0..b.count() {
                        got.push(b.take_lane(slot).id);
                    }
                } else {
                    loom::thread::yield_now();
                }
            }
            for h in handles {
                h.join().unwrap();
            }
            got.sort_unstable();
            assert_eq!(got, vec![0, 1, 10, 11], "no request lost or duplicated");
            assert_eq!(r.stats().snapshot().occupancy, 0);
        });
    }
}
