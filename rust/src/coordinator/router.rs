//! Multi-model request router: name -> `Server` dispatch plus shared
//! admission control (a global in-flight cap provides backpressure)
//! and, when the models share a [`FleetArbiter`], the merged
//! fleet-level operator report.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

use super::batcher::Response;
use super::fleet::FleetArbiter;
use super::ingress::PushError;
use super::server::Server;

pub struct Router {
    servers: BTreeMap<String, Server>,
    inflight: AtomicU64,
    pub max_inflight: u64,
    /// Requests refused at the router's global in-flight cap.
    pub rejected: AtomicU64,
    /// Requests refused by a saturated per-model ingress ring
    /// ([`PushError::Overloaded`]) — backpressure from below the
    /// router's own cap, visible separately so operators can tell
    /// "router cap too low" from "model ring too shallow".
    pub shed: AtomicU64,
    /// The fleet arbiter shared by this router's models, when they run
    /// under one ([`Router::attach_fleet`]); folded into
    /// [`Router::fleet_report`].
    fleet: Option<Arc<FleetArbiter>>,
}

impl Router {
    pub fn new(max_inflight: u64) -> Self {
        Router {
            servers: BTreeMap::new(),
            inflight: AtomicU64::new(0),
            max_inflight,
            rejected: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            fleet: None,
        }
    }

    pub fn add(&mut self, name: &str, server: Server) {
        self.servers.insert(name.to_string(), server);
    }

    pub fn models(&self) -> Vec<&str> {
        self.servers.keys().map(|s| s.as_str()).collect()
    }

    pub fn server(&self, name: &str) -> Option<&Server> {
        self.servers.get(name)
    }

    /// Attach the fleet arbiter this router's models were started with
    /// (`Server::start_with_fleet`), so `fleet_report` can lead with
    /// the cross-model arbitration state.
    pub fn attach_fleet(&mut self, fleet: Arc<FleetArbiter>) {
        self.fleet = Some(fleet);
    }

    pub fn fleet(&self) -> Option<&Arc<FleetArbiter>> {
        self.fleet.as_ref()
    }

    /// Merged operator report: the fleet arbitration snapshot (budget,
    /// wakeups, per-lane deficits — `mode=degraded` the moment any lane
    /// was denied scrub work on the latest wakeup), then every model's
    /// own metrics report.
    pub fn fleet_report(&self) -> String {
        let mut s = String::new();
        if let Some(fleet) = &self.fleet {
            let snap = fleet.snapshot();
            s.push_str(&format!(
                "fleet mode={} budget_bits={} starve_after={} wakeups={} models={}",
                if snap.degraded() { "degraded" } else { "ok" },
                snap.budget_bits
                    .map_or_else(|| "unbounded".into(), |b| b.to_string()),
                snap.starve_after,
                snap.wakeups,
                snap.models.len(),
            ));
            for lane in &snap.models {
                s.push_str(&format!(
                    "\n  lane {} shards={} deficit_bits={} last_deficit={} starved_grants={}",
                    lane.label,
                    lane.shards,
                    lane.deficit.deficit_bits,
                    lane.deficit.last_deficit_bits,
                    lane.deficit.starved_grants,
                ));
            }
            s.push('\n');
        }
        for (name, srv) in &self.servers {
            s.push_str(&format!("model {name}\n{}\n", srv.metrics.report()));
        }
        s
    }

    /// Admission-controlled submit. `Ticket` decrements the in-flight
    /// counter when the response is received (or dropped).
    pub fn submit(&self, model: &str, image: Vec<f32>) -> anyhow::Result<Ticket<'_>> {
        let cur = self.inflight.fetch_add(1, Ordering::AcqRel);
        if cur >= self.max_inflight {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            self.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("router overloaded ({} in flight)", cur);
        }
        let srv = self
            .servers
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{model}'"))
            .inspect_err(|_| {
                self.inflight.fetch_sub(1, Ordering::AcqRel);
            })?;
        match srv.try_submit(image) {
            Ok(rx) => Ok(Ticket { rx, router: self }),
            Err(e) => {
                self.inflight.fetch_sub(1, Ordering::AcqRel);
                if e == PushError::Overloaded {
                    self.shed.fetch_add(1, Ordering::Relaxed);
                }
                Err(anyhow::anyhow!("{model}: {e}"))
            }
        }
    }

    pub fn in_flight(&self) -> u64 {
        self.inflight.load(Ordering::Acquire)
    }

    pub fn shutdown(self) {
        for (_, s) in self.servers {
            s.shutdown();
        }
    }
}

/// RAII handle over a pending response.
pub struct Ticket<'a> {
    rx: Receiver<Response>,
    router: &'a Router,
}

impl Ticket<'_> {
    pub fn wait(self, timeout: std::time::Duration) -> anyhow::Result<Response> {
        let r = self.rx.recv_timeout(timeout);
        // inflight decremented by Drop
        r.map_err(|e| anyhow::anyhow!("response: {e}"))
    }
}

impl Drop for Ticket<'_> {
    fn drop(&mut self) {
        self.router.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::server::{BatchExec, ServerConfig};
    use std::time::Duration;

    struct Echo {
        dim: usize,
    }
    impl BatchExec for Echo {
        fn batch(&self) -> usize {
            2
        }
        fn input_dim(&self) -> usize {
            self.dim
        }
        fn exec(&mut self, images: &[f32], count: usize) -> anyhow::Result<Vec<usize>> {
            Ok((0..count).map(|i| images[i * self.dim] as usize).collect())
        }
        fn refresh(&mut self, _w: &[f32]) -> anyhow::Result<()> {
            Ok(())
        }
    }

    fn echo_server() -> Server {
        let cfg = ServerConfig {
            strategy: "faulty".into(),
            policy: BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
            },
            scrub_interval: None,
            fault_rate_per_interval: 0.0,
            fault_seed: 0,
            ..ServerConfig::default()
        };
        Server::start_with(
            || Ok(Box::new(Echo { dim: 1 }) as Box<dyn BatchExec>),
            1,
            &cfg,
            None,
        )
        .unwrap()
    }

    #[test]
    fn routes_by_name() {
        let mut router = Router::new(64);
        router.add("a", echo_server());
        router.add("b", echo_server());
        let t = router.submit("a", vec![3.0]).unwrap();
        assert_eq!(t.wait(Duration::from_secs(5)).unwrap().pred, 3);
        assert!(router.submit("zzz", vec![0.0]).is_err());
        assert_eq!(router.in_flight(), 0);
        router.shutdown();
    }

    #[test]
    fn backpressure_rejects() {
        let mut router = Router::new(1);
        router.add("a", echo_server());
        let _t1 = router.submit("a", vec![1.0]).unwrap();
        assert!(
            router.submit("a", vec![2.0]).is_err(),
            "second request must be rejected at cap 1"
        );
        assert_eq!(router.rejected.load(Ordering::Relaxed), 1);
        drop(_t1);
        assert_eq!(router.in_flight(), 0);
        router.shutdown();
    }

    /// Per-model ring backpressure propagates through the router as
    /// `shed` (distinct from the router's own cap `rejected`): a gated
    /// executor keeps the model's ring full, so submits under the
    /// router cap still get refused by the ring.
    #[test]
    fn ring_overload_sheds_through_router() {
        use crate::coordinator::ingress::IngressPolicy;
        use std::sync::{Arc, Mutex};

        struct Gated {
            gate: Arc<Mutex<()>>,
        }
        impl BatchExec for Gated {
            fn batch(&self) -> usize {
                1
            }
            fn input_dim(&self) -> usize {
                1
            }
            fn exec(&mut self, _images: &[f32], count: usize) -> anyhow::Result<Vec<usize>> {
                let _g = self.gate.lock().unwrap();
                Ok(vec![0; count])
            }
            fn refresh(&mut self, _w: &[f32]) -> anyhow::Result<()> {
                Ok(())
            }
        }
        let gate = Arc::new(Mutex::new(()));
        let held = gate.lock().unwrap();
        let gate2 = gate.clone();
        let cfg = ServerConfig {
            strategy: "faulty".into(),
            policy: BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
            },
            scrub_interval: None,
            ingress: IngressPolicy::Ring,
            ring_depth: 2,
            ..ServerConfig::default()
        };
        let srv = Server::start_with(
            move || Ok(Box::new(Gated { gate: gate2 }) as Box<dyn BatchExec>),
            1,
            &cfg,
            None,
        )
        .unwrap();
        let mut router = Router::new(64);
        router.add("a", srv);
        // Ring capacity is depth(2) x cap(1) = 2 (+1 the dispatcher may
        // hold at the gate); well under the router cap of 64, so the
        // first refusal must come from the ring, not the router.
        let mut tickets = Vec::new();
        let mut refused = false;
        for _ in 0..16 {
            match router.submit("a", vec![0.0]) {
                Ok(t) => tickets.push(t),
                Err(e) => {
                    assert!(e.to_string().contains("overloaded"), "{e}");
                    refused = true;
                    break;
                }
            }
        }
        assert!(refused, "saturated ring must shed through the router");
        assert!(router.shed.load(Ordering::Relaxed) >= 1);
        assert_eq!(router.rejected.load(Ordering::Relaxed), 0);
        drop(held);
        for t in tickets {
            t.wait(Duration::from_secs(5)).unwrap();
        }
        assert_eq!(router.in_flight(), 0);
        router.shutdown();
    }

    /// An overcommitted fleet (two models, scrub budget = one shard per
    /// wakeup) must surface nonzero per-model deficit gauges and flip
    /// the merged router report to degraded mode — the typed signal
    /// that residual-error budgets are not being honored.
    #[test]
    fn overcommitted_fleet_reports_per_model_deficit() {
        use crate::coordinator::fleet::{FleetArbiter, FleetConfig};
        use crate::ecc::strategy_by_name;
        use crate::memory::ShardedBank;

        fn scrubbed_server(fleet: &Arc<FleetArbiter>, label: &str) -> Server {
            let n = 256;
            let w: Vec<i8> = (0..n).map(|i| (i % 50) as i8 - 25).collect();
            let bank =
                ShardedBank::new(strategy_by_name("in-place").unwrap(), &w, 4, 2).unwrap();
            let layers = vec![crate::model::Layer {
                name: "a".into(),
                shape: vec![n],
                offset: 0,
                size: n,
                scale: 1.0,
                scale_prewot: 1.0,
            }];
            let cfg = ServerConfig {
                strategy: "in-place".into(),
                policy: BatchPolicy {
                    max_batch: 2,
                    max_wait: Duration::from_millis(1),
                },
                scrub_interval: Some(Duration::from_millis(2)),
                fleet_label: label.into(),
                ..ServerConfig::default()
            };
            Server::start_with_fleet(
                || Ok(Box::new(Echo { dim: 1 }) as Box<dyn BatchExec>),
                1,
                &cfg,
                Some((bank, layers)),
                Some(fleet.clone()),
            )
            .unwrap()
        }

        // 4 shards x 64 in-place bytes = 512 stored bits per shard; the
        // fixed 2ms policy keeps all 8 shards (2 models) due every
        // wakeup, so a one-shard budget denies 7 of them each time.
        let fleet = Arc::new(
            FleetArbiter::new(FleetConfig {
                budget_bits: Some(512),
                starve_after: 2,
            })
            .unwrap(),
        );
        let a = scrubbed_server(&fleet, "alpha");
        let b = scrubbed_server(&fleet, "beta");
        let (ma, mb) = (a.metrics.clone(), b.metrics.clone());
        let mut router = Router::new(64);
        router.add("alpha", a);
        router.add("beta", b);
        router.attach_fleet(fleet.clone());

        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let both = [&ma, &mb].iter().all(|m| {
                m.fleet()
                    .is_some_and(|g| g.deficit_bits > 0 && g.budget_bits == 512)
            });
            if both {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "fleet gauges never showed a deficit: alpha={:?} beta={:?}",
                ma.fleet(),
                mb.fleet(),
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        // per-server reports carry the lane gauge...
        assert!(ma.report().contains("fleet mode="), "{}", ma.report());
        // ...and the merged report leads with the arbitration state
        let report = router.fleet_report();
        assert!(report.contains("budget_bits=512"), "{report}");
        assert!(report.contains("lane alpha"), "{report}");
        assert!(report.contains("lane beta"), "{report}");
        assert!(report.contains("fleet mode=degraded"), "{report}");
        let snap = fleet.snapshot();
        assert_eq!(snap.models.len(), 2);
        assert!(
            snap.models.iter().all(|l| l.deficit.deficit_bits > 0),
            "{snap:?}"
        );
        assert!(snap.degraded(), "{snap:?}");
        router.shutdown();
        // after shutdown the shared arbiter retires both lanes
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while !fleet.snapshot().models.is_empty() {
            assert!(
                std::time::Instant::now() < deadline,
                "lanes never retired: {:?}",
                fleet.snapshot()
            );
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}
