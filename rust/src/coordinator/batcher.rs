//! Dynamic batcher: size-or-deadline policy.
//!
//! Requests accumulate in a queue; a batch is released when either
//! `max_batch` requests are waiting or the oldest request has waited
//! `max_wait`. This is the standard serving trade-off (throughput from
//! large batches vs. tail latency) and one of our serving-bench sweeps.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One inference request.
pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
    pub submitted: Instant,
    pub resp: Sender<Response>,
}

/// Completed inference.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub pred: usize,
    /// Queue + execute time.
    pub latency: Duration,
}

/// Release policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(5),
        }
    }
}

struct Inner {
    queue: VecDeque<Request>,
    closed: bool,
}

/// Thread-safe request queue with the release policy.
pub struct Batcher {
    inner: Mutex<Inner>,
    cv: Condvar,
    pub policy: BatchPolicy,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            policy,
        }
    }

    /// Enqueue a request (fails after close()).
    pub fn push(&self, req: Request) -> Result<(), Request> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(req);
        }
        g.queue.push_back(req);
        self.cv.notify_one();
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Signal shutdown; wakes all waiting consumers.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Block until a batch is released by the policy; None on shutdown
    /// with an empty queue. Returns at most `max_batch` requests.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.queue.len() >= self.policy.max_batch {
                break;
            }
            if !g.queue.is_empty() {
                let oldest = g.queue.front().unwrap().submitted;
                let age = oldest.elapsed();
                if age >= self.policy.max_wait {
                    break;
                }
                let remain = self.policy.max_wait - age;
                let (ng, _t) = self.cv.wait_timeout(g, remain).unwrap();
                g = ng;
                if g.closed && g.queue.is_empty() {
                    return None;
                }
                continue;
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
        let take = g.queue.len().min(self.policy.max_batch);
        Some(g.queue.drain(..take).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn req(id: u64) -> (Request, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel();
        (
            Request {
                id,
                image: vec![0.0; 4],
                submitted: Instant::now(),
                resp: tx,
            },
            rx,
        )
    }

    #[test]
    fn releases_on_size() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
        });
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req(i);
            assert!(b.push(r).is_ok());
            rxs.push(rx);
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        assert!(b.is_empty());
    }

    #[test]
    fn releases_on_deadline() {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(20),
        }));
        let (r, _rx) = req(1);
        assert!(b.push(r).is_ok());
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn close_drains_then_none() {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
        }));
        let (r, _rx) = req(1);
        assert!(b.push(r).is_ok());
        b.close();
        assert!(b.next_batch().is_some(), "pending request still served");
        assert!(b.next_batch().is_none(), "then shutdown");
        let (r, _rx) = req(2);
        assert!(b.push(r).is_err(), "push after close fails");
    }

    #[test]
    fn concurrent_producers() {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 10,
            max_wait: Duration::from_millis(50),
        }));
        let mut handles = Vec::new();
        for t in 0..5 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..4 {
                    let (r, _rx) = req(t * 10 + i);
                    assert!(b.push(r).is_ok());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut total = 0;
        while total < 20 {
            total += b.next_batch().unwrap().len();
        }
        assert_eq!(total, 20);
    }
}
