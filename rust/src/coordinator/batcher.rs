//! Dynamic batcher: size-or-deadline policy.
//!
//! Requests accumulate in a queue; a batch is released when either
//! `max_batch` requests are waiting or the batch's deadline expires.
//! The deadline is *pinned* when the batch's first request arrives
//! (`first.submitted + max_wait`) and never recomputed on later
//! wakeups, so a stream of late arrivals cannot starve it. This is the
//! standard serving trade-off (throughput from large batches vs. tail
//! latency) and one of our serving-bench sweeps.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One inference request.
pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
    pub submitted: Instant,
    pub resp: Sender<Response>,
}

/// Completed inference.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub pred: usize,
    /// Queue + execute time.
    pub latency: Duration,
}

/// Release policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(5),
        }
    }
}

struct Inner {
    queue: VecDeque<Request>,
    closed: bool,
    /// Deadline of the batch currently forming, pinned to its first
    /// request at push time; `None` while the queue is empty.
    deadline: Option<Instant>,
}

/// Thread-safe request queue with the release policy.
pub struct Batcher {
    inner: Mutex<Inner>,
    cv: Condvar,
    pub policy: BatchPolicy,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                closed: false,
                deadline: None,
            }),
            cv: Condvar::new(),
            policy,
        }
    }

    /// Enqueue a request (fails after close()).
    pub fn push(&self, req: Request) -> Result<(), Request> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(req);
        }
        if g.queue.is_empty() {
            // This request starts a new batch: pin its deadline now.
            g.deadline = Some(req.submitted + self.policy.max_wait);
        }
        g.queue.push_back(req);
        self.cv.notify_one();
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Signal shutdown; wakes all waiting consumers.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Block until a batch is released by the policy; None on shutdown
    /// with an empty queue. Returns at most `max_batch` requests.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.queue.len() >= self.policy.max_batch {
                break;
            }
            if !g.queue.is_empty() {
                // Wait against the deadline pinned when the batch's
                // first request arrived — never recomputed here, so
                // late arrivals (which reset nothing) cannot push it
                // out and starve the batch.
                let deadline = g.deadline.expect("non-empty queue has a pinned deadline");
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (ng, _t) = self.cv.wait_timeout(g, deadline - now).unwrap();
                g = ng;
                if g.closed && g.queue.is_empty() {
                    return None;
                }
                continue;
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
        let take = g.queue.len().min(self.policy.max_batch);
        let batch: Vec<Request> = g.queue.drain(..take).collect();
        // Overflow left behind starts the next batch: re-pin to its
        // (already waiting) first request.
        g.deadline = g
            .queue
            .front()
            .map(|r| r.submitted + self.policy.max_wait);
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn req(id: u64) -> (Request, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel();
        (
            Request {
                id,
                image: vec![0.0; 4],
                submitted: Instant::now(),
                resp: tx,
            },
            rx,
        )
    }

    #[test]
    fn releases_on_size() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
        });
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req(i);
            assert!(b.push(r).is_ok());
            rxs.push(rx);
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        assert!(b.is_empty());
    }

    #[test]
    fn releases_on_deadline() {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(20),
        }));
        let (r, _rx) = req(1);
        assert!(b.push(r).is_ok());
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t.elapsed() >= Duration::from_millis(15));
    }

    /// Satellite regression: the release deadline is pinned to the
    /// batch's *first* request. A stream of late arrivals — each
    /// younger than `max_wait` — must not push the deadline out; the
    /// batch releases at `first.submitted + max_wait` regardless.
    #[test]
    fn deadline_pinned_to_first_request_under_late_arrivals() {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 100, // never released on size
            max_wait: Duration::from_millis(40),
        }));
        let t0 = Instant::now();
        let (first, _rx0) = req(0);
        assert!(b.push(first).is_ok());
        // Late arrivals every 5ms for well past the deadline; a
        // drifting implementation (deadline derived from recent queue
        // state on each wakeup) would keep waiting.
        let feeder = {
            let b = b.clone();
            std::thread::spawn(move || {
                let mut kept = Vec::new();
                for i in 1..30 {
                    std::thread::sleep(Duration::from_millis(5));
                    let (r, rx) = req(i);
                    if b.push(r).is_err() {
                        break; // batcher closed by the main thread
                    }
                    kept.push(rx);
                }
                kept
            })
        };
        let batch = b.next_batch().unwrap();
        let elapsed = t0.elapsed();
        assert_eq!(batch[0].id, 0, "first request leads the batch");
        assert!(
            elapsed >= Duration::from_millis(35),
            "released before the pinned deadline: {elapsed:?}"
        );
        assert!(
            elapsed < Duration::from_millis(120),
            "late arrivals starved the deadline: {elapsed:?}"
        );
        b.close();
        let _ = feeder.join().unwrap();
    }

    #[test]
    fn close_drains_then_none() {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
        }));
        let (r, _rx) = req(1);
        assert!(b.push(r).is_ok());
        b.close();
        assert!(b.next_batch().is_some(), "pending request still served");
        assert!(b.next_batch().is_none(), "then shutdown");
        let (r, _rx) = req(2);
        assert!(b.push(r).is_err(), "push after close fails");
    }

    #[test]
    fn concurrent_producers() {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 10,
            max_wait: Duration::from_millis(50),
        }));
        let mut handles = Vec::new();
        for t in 0..5 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..4 {
                    let (r, _rx) = req(t * 10 + i);
                    assert!(b.push(r).is_ok());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut total = 0;
        while total < 20 {
            total += b.next_batch().unwrap().len();
        }
        assert_eq!(total, 20);
    }
}
