//! Fleet scrub arbitration: one process-wide control loop scrubbing
//! every registered model's protected weight store.
//!
//! Before this module each `Server` ran its own scrub thread at its own
//! cadence; N co-hosted models meant N loops competing blindly for the
//! same memory bandwidth and worker pool. The [`FleetArbiter`] replaces
//! them with a single control thread that owns every model's
//! [`ShardedBank`] + [`ScrubScheduler`] pair (a [`ScrubUnit`], enrolled
//! by `Server::start_with_fleet`) and, each wakeup, asks the pure
//! planner in [`crate::memory::scheduler`] which due shards — across
//! all models — deserve the fleet's per-wakeup scrub budget:
//!
//! * due shards are ranked by Wilson-upper BER urgency
//!   (`ber_upper x bits x lateness`), so a hot shard on model A
//!   preempts a routine pass on idle model B;
//! * a deferral counter per shard caps how long preemption can hold a
//!   shard back ([`FleetConfig::starve_after`]) — overdue work is
//!   eventually forced through regardless of ranking, giving every
//!   shard a bounded wait;
//! * denied work accrues into per-model [`ModelDeficit`] accounting,
//!   published as the `fleet` gauge on each model's [`Metrics`] — a
//!   growing deficit is the typed "this fleet is overcommitted"
//!   degraded-mode signal, long before residual errors show up in
//!   served predictions.
//!
//! A server without a shared arbiter gets a private fleet-of-one with
//! no budget cap, which degenerates to exactly the old per-server scrub
//! loop (every due shard granted every wakeup). The arbiter thread
//! never holds an `Arc<FleetArbiter>` — only the inner shared state —
//! so dropping the last handle can always stop and join it.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::metrics::{FleetGauge, Metrics};
use super::server::{FlipBudget, WeightDelta, WeightUpdate};
use crate::memory::{
    pool, FaultModel, FleetArbitration, ModelDeficit, SchedulerConfig, ScrubScheduler, ShardedBank,
};
use crate::model::{recover_blocks, DenseShape, Layer, RecoverySet};

/// How long the control thread parks when no model is enrolled (a poke
/// from `enroll`/`wake`/`Drop` interrupts it immediately).
const IDLE_PARK: Duration = Duration::from_secs(3600);

/// Fleet-level scrub bandwidth policy.
#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    /// Stored bits the whole fleet may scrub per wakeup; `None` grants
    /// every due shard (the single-model legacy behavior). The
    /// starvation bound needs the budget to fit the largest single
    /// shard — a smaller budget can never grant that shard at all.
    pub budget_bits: Option<u64>,
    /// Wakeups a due shard may lose the urgency ranking before the
    /// arbiter force-grants it (clamped to >= 1).
    pub starve_after: u32,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            budget_bits: None,
            starve_after: 4,
        }
    }
}

impl FleetConfig {
    /// Derive the per-wakeup bit budget from an operator-facing
    /// scrub-bandwidth figure in GB/s (see
    /// [`crate::memory::scheduler::gbps_to_bits_per_wakeup`]): the
    /// fleet may spend `gbps x wakeup` worth of stored bits each
    /// wakeup. A non-positive or non-finite `gbps` converts to a zero
    /// budget — nothing is ever granted — rather than `None`'s
    /// unbounded legacy behavior, so a typo'd bandwidth fails loudly.
    pub fn with_budget_gbps(mut self, gbps: f64, wakeup: Duration) -> FleetConfig {
        self.budget_bits = Some(crate::memory::scheduler::gbps_to_bits_per_wakeup(
            gbps, wakeup,
        ));
        self
    }

    /// The pure arbitration state this config describes — the same
    /// `FleetArbitration::new` call the control thread makes at
    /// startup. The closed-loop simulation harness drives this planner
    /// directly (register banks, `plan` each tick), so a policy the
    /// sim certifies is byte-for-byte the law production executes.
    pub fn planner(&self) -> FleetArbitration {
        FleetArbitration::new(self.budget_bits, self.starve_after)
    }
}

/// Everything the fleet control loop needs to scrub one model: the
/// protected store, its refresh plumbing toward the inference thread,
/// fault-injection knobs and the recovery tier. Built by
/// `Server::start_with_fleet`, moved into the arbiter at enrollment.
pub(crate) struct ScrubUnit {
    /// Operator-facing lane name (the model name under `start_pjrt`).
    pub(crate) label: String,
    pub(crate) bank: ShardedBank,
    pub(crate) layers: Vec<Layer>,
    pub(crate) metrics: Arc<Metrics>,
    /// Refresh channel toward this model's inference thread.
    pub(crate) weights_tx: std::sync::mpsc::Sender<WeightUpdate>,
    /// Applied f32 buffers coming back for the scratch arena.
    pub(crate) give_rx: std::sync::mpsc::Receiver<Vec<f32>>,
    /// Expected flips per stored bit per `interval` (0 = no injection).
    pub(crate) rate: f64,
    pub(crate) seed: u64,
    /// Base scrub interval (rate scaling + scheduler hot clamp).
    pub(crate) interval: Duration,
    pub(crate) sched_cfg: SchedulerConfig,
    /// MILR escalation context; `None` leaves uncorrectables as stored.
    pub(crate) recovery: Option<Arc<(RecoverySet, Vec<DenseShape>)>>,
    /// Set by `Server::shutdown`; the arbiter drops the unit (bank,
    /// channels and all) at its next wakeup.
    pub(crate) stop: Arc<AtomicBool>,
}

/// One enrolled model's runtime state inside the control loop.
struct Lane {
    /// Slot in the [`FleetArbitration`] deferral/deficit tables.
    slot: usize,
    unit: ScrubUnit,
    sched: ScrubScheduler,
    budget: FlipBudget,
    epoch: u64,
    last_wake: Duration,
    /// Blocks whose recovery already failed and which are still
    /// detected: bch16/milr scrubs re-detect an uncorrectable block
    /// every pass, and without this set every pass would re-run the
    /// same doomed algebraic solve. Entries leave when a scrub of
    /// their shard stops reporting them (healed or rewritten).
    quarantine: BTreeSet<usize>,
}

/// Per-model lane view inside a [`FleetSnapshot`].
#[derive(Clone, Debug, Default)]
pub struct ModelLane {
    pub label: String,
    pub shards: usize,
    pub deficit: ModelDeficit,
}

/// Point-in-time view of the whole fleet, refreshed after every arbiter
/// wakeup; the router folds it into its merged report.
#[derive(Clone, Debug, Default)]
pub struct FleetSnapshot {
    /// `None` = unbounded (every due shard granted).
    pub budget_bits: Option<u64>,
    pub starve_after: u32,
    pub wakeups: u64,
    pub models: Vec<ModelLane>,
}

impl FleetSnapshot {
    /// True when any lane was denied scrub work on the latest wakeup.
    pub fn degraded(&self) -> bool {
        self.models.iter().any(|m| m.deficit.last_deficit_bits > 0)
    }
}

#[derive(Default)]
struct SharedState {
    /// Units enrolled but not yet adopted by the control thread.
    pending: Vec<ScrubUnit>,
    stopped: bool,
    /// Wake request (enrollment, shutdown of a member, external poke).
    poke: bool,
    snapshot: FleetSnapshot,
}

struct FleetShared {
    cfg: FleetConfig,
    state: Mutex<SharedState>,
    cv: Condvar,
}

impl FleetShared {
    /// Set `f` on the state and wake the control thread.
    fn poke_with(&self, f: impl FnOnce(&mut SharedState)) {
        let mut st = self.state.lock().unwrap();
        f(&mut st);
        st.poke = true;
        self.cv.notify_all();
    }
}

/// Handle to the process-wide scrub control loop. Clone the `Arc` into
/// every `Server::start_with_fleet` call that should share the budget;
/// dropping the last handle stops and joins the control thread (each
/// enrolled unit is dropped with it, releasing its bank).
pub struct FleetArbiter {
    shared: Arc<FleetShared>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

impl FleetArbiter {
    /// Spawn the control thread (idle-parked until the first
    /// enrollment).
    pub fn new(cfg: FleetConfig) -> anyhow::Result<FleetArbiter> {
        let cfg = FleetConfig {
            budget_bits: cfg.budget_bits,
            starve_after: cfg.starve_after.max(1),
        };
        let shared = Arc::new(FleetShared {
            cfg,
            state: Mutex::new(SharedState {
                snapshot: FleetSnapshot {
                    budget_bits: cfg.budget_bits,
                    starve_after: cfg.starve_after,
                    ..FleetSnapshot::default()
                },
                ..SharedState::default()
            }),
            cv: Condvar::new(),
        });
        let inner = shared.clone();
        let thread = std::thread::Builder::new()
            .name("zsecc-fleet".into())
            .spawn(move || control_loop(&inner))?;
        Ok(FleetArbiter {
            shared,
            thread: Mutex::new(Some(thread)),
        })
    }

    pub fn config(&self) -> FleetConfig {
        self.shared.cfg
    }

    /// Hand a model's scrub state to the control loop (adopted at the
    /// next wakeup, which this call triggers immediately).
    pub(crate) fn enroll(&self, unit: ScrubUnit) {
        self.shared.poke_with(|st| st.pending.push(unit));
    }

    /// Wake the control thread out of its park (used by
    /// `Server::shutdown` after setting a unit's stop flag, so the
    /// retiring model's bank is released promptly).
    pub fn wake(&self) {
        self.shared.poke_with(|_| {});
    }

    /// Latest fleet snapshot (empty `models` before the first wakeup
    /// that saw an enrolled unit).
    pub fn snapshot(&self) -> FleetSnapshot {
        self.shared.state.lock().unwrap().snapshot.clone()
    }
}

impl Drop for FleetArbiter {
    fn drop(&mut self) {
        self.shared.poke_with(|st| st.stopped = true);
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

/// The control loop: park until the earliest shard deadline across
/// every lane (or a poke), adopt/retire lanes, inject each lane's
/// environmental faults, let the [`FleetArbitration`] planner pick the
/// wakeup's grants, then scrub / escalate / refresh each granted lane
/// exactly as the old per-server loop did.
fn control_loop(shared: &FleetShared) {
    let t0 = Instant::now();
    let mut fleet = FleetArbitration::new(shared.cfg.budget_bits, shared.cfg.starve_after);
    let mut lanes: Vec<Lane> = Vec::new();
    loop {
        let sleep = lanes
            .iter()
            .map(|l| l.sched.next_deadline())
            .min()
            .map(|d| d.saturating_sub(t0.elapsed()))
            .unwrap_or(IDLE_PARK);
        let fresh: Vec<ScrubUnit> = {
            let mut st = shared.state.lock().unwrap();
            let deadline = Instant::now() + sleep;
            while !st.stopped && !st.poke {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (g, _) = shared.cv.wait_timeout(st, deadline - now).unwrap();
                st = g;
            }
            if st.stopped {
                return;
            }
            st.poke = false;
            st.pending.drain(..).collect()
        };
        for unit in fresh {
            // Registration-relative start: every shard of the new lane
            // is due immediately, and its deadlines live on the same
            // arbiter clock as everyone else's.
            let now = t0.elapsed();
            let nshards = unit.bank.num_shards();
            let shard_bits: Vec<u64> = (0..nshards).map(|i| unit.bank.shard_bits(i)).collect();
            let sched = ScrubScheduler::new(unit.sched_cfg, &shard_bits, now);
            let slot = fleet.register(nshards);
            lanes.push(Lane {
                slot,
                unit,
                sched,
                budget: FlipBudget::default(),
                epoch: 0,
                last_wake: now,
                quarantine: BTreeSet::new(),
            });
        }
        // A retiring lane's Server set its stop flag: dropping the lane
        // releases the bank and closes the refresh channel.
        lanes.retain(|l| !l.unit.stop.load(Ordering::Acquire));
        if lanes.is_empty() {
            shared.state.lock().unwrap().snapshot.models.clear();
            continue;
        }
        let now = t0.elapsed();
        for l in &mut lanes {
            inject_faults(l, now);
            l.last_wake = now;
        }
        let grants = {
            let refs: Vec<(usize, &ScrubScheduler)> =
                lanes.iter().map(|l| (l.slot, &l.sched)).collect();
            fleet.plan(&refs, now)
        };
        for l in &mut lanes {
            let due: Vec<usize> = grants
                .iter()
                .filter(|g| g.model == l.slot)
                .map(|g| g.shard)
                .collect();
            scrub_lane(l, &due, now);
        }
        publish(shared, &fleet, &lanes);
    }
}

/// Drain the lane's arena give-backs and apply its fault pressure for
/// the elapsed wall clock (identical semantics to the old per-server
/// loop: rate is "per base interval", scaled by time since the lane's
/// last wakeup, fractional expectations carried in [`FlipBudget`]).
fn inject_faults(l: &mut Lane, now: Duration) {
    while let Ok(buf) = l.unit.give_rx.try_recv() {
        pool::give(buf);
    }
    if l.unit.rate <= 0.0 {
        return;
    }
    let scale = if l.unit.interval > Duration::ZERO {
        (now - l.last_wake).as_secs_f64() / l.unit.interval.as_secs_f64()
    } else {
        1.0
    };
    let bits = l.unit.bank.total_bits();
    let whole = l.budget.take(bits, l.unit.rate, scale);
    if whole > 0 {
        // adjusted rate injects exactly `whole` flips
        let n = l.unit.bank.inject(
            FaultModel::Uniform,
            whole as f64 / bits as f64,
            l.unit.seed ^ l.epoch,
        );
        l.unit.metrics.faults_injected.fetch_add(n, Ordering::Relaxed);
    }
}

/// Scrub the granted shards of one lane, escalate its uncorrectables,
/// and ship its weight refreshes — the body of the old per-server scrub
/// wakeup, now driven by the arbiter's grant list instead of the lane's
/// own due list.
fn scrub_lane(l: &mut Lane, due: &[usize], now: Duration) {
    let m = &l.unit.metrics;
    let sb = &mut l.unit.bank;
    let nshards = sb.num_shards();
    // the recovery tier needs block identities, so an armed lane scrubs
    // through the outcome API
    let per_shard: Vec<(usize, crate::ecc::DecodeStats)> = if l.unit.recovery.is_some() {
        sb.scrub_subset_outcome(due)
            .into_iter()
            .map(|(i, o)| (i, o.stats))
            .collect()
    } else {
        sb.scrub_subset(due)
    };
    let mut stats = crate::ecc::DecodeStats::default();
    for &(i, s) in &per_shard {
        stats.add(&s);
        l.sched.record_pass(i, &s, now);
        m.record_shard_scrub(i, &s);
    }
    m.corrected.fetch_add(stats.corrected, Ordering::Relaxed);
    m.detected.fetch_add(stats.detected, Ordering::Relaxed);
    m.scrubs.fetch_add(1, Ordering::Relaxed);
    m.set_shard_schedules((0..nshards).map(|i| l.sched.snapshot(i, now)).collect());
    // Escalate detected-uncorrectable blocks to the recovery tier
    // before shipping refreshes, so a recovered block (its shard goes
    // dirty) is re-served clean this same wakeup. Failures quarantine —
    // never a panic — and the quarantine set dedupes them out of later
    // escalations: a block whose solve failed once is not re-solved
    // every pass while nothing about it changed.
    if let Some(ctx) = &l.unit.recovery {
        let (blocks, _overflow) = sb.take_detected();
        let detected: BTreeSet<usize> = blocks.into_iter().collect();
        // A quarantined block heals when a scrub of its shard stops
        // detecting it (corrected, rewritten, or re-randomized into a
        // valid codeword). Prune only within the shards scrubbed this
        // wakeup: an unscrubbed shard reported nothing, and absence
        // there means stale information, not health.
        let bb = sb.strategy().block_bytes();
        for &i in due {
            let (s, e) = sb.shard_range(i);
            let (bs, be) = (s / bb, e.div_ceil(bb));
            l.quarantine
                .retain(|&b| !(bs..be).contains(&b) || detected.contains(&b));
        }
        let fresh = detected.iter().any(|b| !l.quarantine.contains(b));
        if fresh {
            let t_rec = Instant::now();
            let (calib, shapes) = &**ctx;
            // the whole detected set goes to the solver — a fresh block
            // can share columns with a quarantined one, and the joint
            // solve may now succeed where the lone one failed
            let batch: Vec<usize> = detected.iter().copied().collect();
            m.recovery_solve_attempts
                .fetch_add(batch.len() as u64, Ordering::Relaxed);
            // current plaintext view: trusted rows feed the solver as
            // truth, implicated rows are the unknowns
            let mut decoded = pool::lease_i8(sb.n_weights());
            sb.read(&mut decoded);
            let grid = sb.strategy().quant_grid();
            // the solve runs on the process-wide pool
            let outcome = pool::run_jobs(vec![batch], 1, |b| {
                recover_blocks(calib, shapes, &decoded, &b, bb, grid)
            })
            .pop()
            .expect("one recovery job in, one outcome out");
            let mut recovered = Vec::with_capacity(outcome.recovered.len());
            let mut quarantined: Vec<usize> =
                outcome.quarantined.iter().map(|(b, _)| *b).collect();
            for rb in &outcome.recovered {
                match sb.apply_recovery(rb.block, &rb.weights) {
                    Ok(()) => recovered.push(rb.block),
                    Err(_) => quarantined.push(rb.block),
                }
            }
            for b in &recovered {
                l.quarantine.remove(b);
            }
            l.quarantine.extend(quarantined.iter().copied());
            m.record_recovery(&recovered, &quarantined, t_rec.elapsed().as_secs_f64() * 1e6);
        }
    }
    let dirty = sb.take_dirty();
    l.epoch += 1;
    if dirty.is_empty() {
        return; // nothing decoded, nothing sent
    }
    let update = if dirty.len() == nshards {
        // Whole image dirty: one full buffer beats nshards deltas.
        // Fused decode → dequant over the worker pool into an arena
        // buffer.
        let mut w = pool::lease_f32(sb.n_weights());
        sb.decode_dequant_all(&l.unit.layers, &mut w);
        m.full_refreshes.fetch_add(1, Ordering::Relaxed);
        WeightUpdate::Full(w.take())
    } else {
        let mut scratch = pool::lease_i8(0);
        let mut deltas = Vec::with_capacity(dirty.len());
        for i in dirty {
            let (s, e) = sb.shard_range(i);
            let mut values = pool::lease_f32(e - s);
            sb.decode_dequant_shard(i, &l.unit.layers, &mut scratch, &mut values);
            m.record_shard_refresh(i);
            deltas.push(WeightDelta {
                offset: s,
                values: values.take(),
            });
        }
        WeightUpdate::Deltas(deltas)
    };
    if l.unit.weights_tx.send(update).is_err() {
        // inference thread gone: retire the lane at the next wakeup
        l.unit.stop.store(true, Ordering::Release);
    }
}

/// Refresh every lane's `fleet` gauge and the shared snapshot.
fn publish(shared: &FleetShared, fleet: &FleetArbitration, lanes: &[Lane]) {
    let budget_gauge = shared.cfg.budget_bits.unwrap_or(0);
    let mut snap = FleetSnapshot {
        budget_bits: shared.cfg.budget_bits,
        starve_after: fleet.starve_after(),
        wakeups: fleet.wakeups(),
        models: Vec::with_capacity(lanes.len()),
    };
    for l in lanes {
        let d = fleet.deficit(l.slot);
        l.unit.metrics.set_fleet(FleetGauge {
            budget_bits: budget_gauge,
            deficit_bits: d.deficit_bits,
            last_deficit_bits: d.last_deficit_bits,
            starved_grants: d.starved_grants,
            wakeups: fleet.wakeups(),
        });
        snap.models.push(ModelLane {
            label: l.unit.label.clone(),
            shards: l.unit.bank.num_shards(),
            deficit: d,
        });
    }
    shared.state.lock().unwrap().snapshot = snap;
}
