//! Serving metrics: request counters, latency series, memory-protection
//! event counters (corrected / detected / scrub passes), execution
//! failures, and per-shard scrub/refresh counters for the sharded store.

use crate::ecc::DecodeStats;
use crate::util::stats::Series;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Per-shard counter snapshot (scrub loop + refresh channel activity).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardCounters {
    pub scrubs: u64,
    /// Scrub passes that saw no error at all — the tile engine's
    /// clean-span fast path (decode = copy, scrub = no-op). At realistic
    /// fault rates this should dominate `scrubs`; a falling ratio is an
    /// early sign of rising fault pressure on the shard.
    pub clean_scrubs: u64,
    pub corrected: u64,
    pub detected: u64,
    pub zeroed: u64,
    /// Weight deltas shipped for this shard over the refresh channel.
    pub refreshes: u64,
}

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batch_sizes_sum: AtomicU64,
    pub corrected: AtomicU64,
    pub detected: AtomicU64,
    pub scrubs: AtomicU64,
    pub faults_injected: AtomicU64,
    /// Refresh *messages* applied by the inference thread (one per
    /// `WeightUpdate`, whether full or a delta batch).
    pub weight_refreshes: AtomicU64,
    /// Whole-buffer weight refreshes shipped by the scrub loop.
    pub full_refreshes: AtomicU64,
    /// Individual per-shard weight deltas shipped by the scrub loop —
    /// counts shards, not messages (one Deltas message carrying 3 dirty
    /// shards adds 3 here and 1 to `weight_refreshes` when applied).
    pub delta_refreshes: AtomicU64,
    /// Batches whose executor call failed (requests were answered with
    /// `pred == usize::MAX`) — previously invisible to operators.
    pub exec_failures: AtomicU64,
    latency_us: Mutex<Series>,
    shards: Mutex<Vec<ShardCounters>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_sizes_sum
            .fetch_add(size as u64, Ordering::Relaxed);
        self.requests.fetch_add(size as u64, Ordering::Relaxed);
    }

    pub fn record_latency_us(&self, us: f64) {
        self.latency_us.lock().unwrap().push(us);
    }

    pub fn latency_summary(&self) -> (f64, f64, f64, usize) {
        let s = self.latency_us.lock().unwrap();
        (s.mean(), s.p(50.0), s.p(99.0), s.len())
    }

    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_sizes_sum.load(Ordering::Relaxed) as f64 / b as f64
    }

    fn shard_slot(shards: &mut Vec<ShardCounters>, idx: usize) -> &mut ShardCounters {
        if shards.len() <= idx {
            shards.resize(idx + 1, ShardCounters::default());
        }
        &mut shards[idx]
    }

    /// Record one scrub pass over shard `idx`.
    pub fn record_shard_scrub(&self, idx: usize, stats: &DecodeStats) {
        let mut shards = self.shards.lock().unwrap();
        let c = Self::shard_slot(&mut shards, idx);
        c.scrubs += 1;
        if stats.is_clean() {
            c.clean_scrubs += 1;
        }
        c.corrected += stats.corrected;
        c.detected += stats.detected;
        c.zeroed += stats.zeroed;
    }

    /// Record one weight delta shipped for shard `idx`.
    pub fn record_shard_refresh(&self, idx: usize) {
        self.delta_refreshes.fetch_add(1, Ordering::Relaxed);
        let mut shards = self.shards.lock().unwrap();
        Self::shard_slot(&mut shards, idx).refreshes += 1;
    }

    /// Snapshot of the per-shard counters.
    pub fn shard_counters(&self) -> Vec<ShardCounters> {
        self.shards.lock().unwrap().clone()
    }

    pub fn report(&self) -> String {
        let (mean, p50, p99, n) = self.latency_summary();
        let mut s = format!(
            "requests={} batches={} mean_batch={:.1} latency(mean/p50/p99)={:.0}/{:.0}/{:.0}us (n={}) corrected={} detected={} scrubs={} faults={} refresh_msgs_applied={} full_sent={} shard_deltas_sent={} exec_failures={}",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch(),
            mean,
            p50,
            p99,
            n,
            self.corrected.load(Ordering::Relaxed),
            self.detected.load(Ordering::Relaxed),
            self.scrubs.load(Ordering::Relaxed),
            self.faults_injected.load(Ordering::Relaxed),
            self.weight_refreshes.load(Ordering::Relaxed),
            self.full_refreshes.load(Ordering::Relaxed),
            self.delta_refreshes.load(Ordering::Relaxed),
            self.exec_failures.load(Ordering::Relaxed),
        );
        let shards = self.shards.lock().unwrap();
        if !shards.is_empty() {
            s.push_str("\n  shard  scrubs   clean corrected detected zeroed refreshes");
            for (i, c) in shards.iter().enumerate() {
                s.push_str(&format!(
                    "\n  {:>5} {:>7} {:>7} {:>9} {:>8} {:>6} {:>9}",
                    i, c.scrubs, c.clean_scrubs, c.corrected, c.detected, c.zeroed, c.refreshes
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.requests.load(Ordering::Relaxed), 12);
        assert!((m.mean_batch() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn latency_percentiles() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record_latency_us(i as f64);
        }
        let (_mean, p50, p99, n) = m.latency_summary();
        assert_eq!(n, 100);
        assert!((p50 - 50.5).abs() < 1.0);
        assert!(p99 >= 99.0);
    }

    #[test]
    fn shard_counters_grow_on_demand() {
        let m = Metrics::new();
        let stats = DecodeStats {
            corrected: 2,
            detected: 1,
            zeroed: 0,
        };
        m.record_shard_scrub(3, &stats);
        m.record_shard_scrub(3, &DecodeStats::default()); // clean pass
        m.record_shard_refresh(3);
        m.record_shard_refresh(0);
        let c = m.shard_counters();
        assert_eq!(c.len(), 4);
        assert_eq!(c[3].scrubs, 2);
        assert_eq!(c[3].clean_scrubs, 1, "only the error-free pass is clean");
        assert_eq!(c[3].corrected, 2);
        assert_eq!(c[3].detected, 1);
        assert_eq!(c[3].refreshes, 1);
        assert_eq!(c[0].refreshes, 1);
        assert_eq!(m.delta_refreshes.load(Ordering::Relaxed), 2);
        assert!(m.report().contains("shard"));
    }
}
