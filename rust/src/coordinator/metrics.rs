//! Serving metrics: request counters, latency series, memory-protection
//! event counters (corrected / detected / scrub passes), execution
//! failures, per-shard scrub/refresh counters for the sharded store,
//! and the scrub scheduler's per-shard BER/deadline/overdue gauges.

use crate::coordinator::ingress::{IngressSnapshot, IngressStats};
use crate::ecc::{DecodeStats, DETECTED_BLOCK_CAP};
use crate::memory::ShardSchedule;
use crate::runtime::guard::{GuardReport, GuardStats};
use crate::util::stats::Series;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Fleet-arbiter gauges for one model lane, published after every
/// arbiter wakeup. `budget_bits == 0` means the fleet is unbounded
/// (every due shard is granted, deficits stay zero).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FleetGauge {
    /// Fleet-wide scrub budget per wakeup, in stored bits (0 = unbounded).
    pub budget_bits: u64,
    /// Cumulative bits of due-but-denied scrub work for this model —
    /// the residual-error budget deficit. Monotone growth means the
    /// fleet is overcommitted: this model's shards are being scrubbed
    /// later than its `target_residual` asks for.
    pub deficit_bits: u64,
    /// Bits denied on the most recent wakeup alone. Nonzero here is the
    /// degraded-mode signal; zero with a large `deficit_bits` means the
    /// overload was transient and has cleared.
    pub last_deficit_bits: u64,
    /// Grants this model received via the starvation guarantee rather
    /// than by urgency ranking — how often it only got bandwidth
    /// because the arbiter forced fairness.
    pub starved_grants: u64,
    /// Fleet arbiter wakeups observed so far (shared across models).
    pub wakeups: u64,
}

impl FleetGauge {
    /// True when the most recent wakeup denied scrub work to this
    /// model — the operator-facing degraded-mode predicate.
    pub fn degraded(&self) -> bool {
        self.last_deficit_bits > 0
    }
}

/// Per-shard counter snapshot (scrub loop + refresh channel activity).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardCounters {
    pub scrubs: u64,
    /// Scrub passes that saw no error at all — the tile engine's
    /// clean-span fast path (decode = copy, scrub = no-op). At realistic
    /// fault rates this should dominate `scrubs`; a falling ratio is an
    /// early sign of rising fault pressure on the shard.
    pub clean_scrubs: u64,
    pub corrected: u64,
    pub detected: u64,
    pub zeroed: u64,
    /// Weight deltas shipped for this shard over the refresh channel.
    pub refreshes: u64,
}

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batch_sizes_sum: AtomicU64,
    pub corrected: AtomicU64,
    pub detected: AtomicU64,
    /// Scrub-loop wakeups. Under the fixed policy every wakeup scrubs
    /// every shard (a full epoch); under the adaptive policy a wakeup
    /// scrubs only the due shards — per-shard pass counts live in
    /// [`Metrics::shard_counters`] / [`Metrics::shard_schedules`].
    pub scrubs: AtomicU64,
    pub faults_injected: AtomicU64,
    /// Refresh *messages* applied by the inference thread (one per
    /// `WeightUpdate`, whether full or a delta batch).
    pub weight_refreshes: AtomicU64,
    /// Whole-buffer weight refreshes shipped by the scrub loop.
    pub full_refreshes: AtomicU64,
    /// Individual per-shard weight deltas shipped by the scrub loop —
    /// counts shards, not messages (one Deltas message carrying 3 dirty
    /// shards adds 3 here and 1 to `weight_refreshes` when applied).
    pub delta_refreshes: AtomicU64,
    /// Batches whose executor call failed (requests were answered with
    /// `pred == usize::MAX`) — previously invisible to operators.
    pub exec_failures: AtomicU64,
    /// Blocks the MILR recovery tier reconstructed and re-encoded clean.
    pub recovered_blocks: AtomicU64,
    /// Blocks recovery gave up on — quarantined, served as decoded until
    /// a later scrub or refresh clears them.
    pub quarantined_blocks: AtomicU64,
    /// Blocks submitted to the algebraic solver, across all escalations.
    /// The scrub loop dedupes against the quarantine set, so a block
    /// whose recovery failed once is not re-solved every pass — this
    /// counter staying flat while the block stays detected is the
    /// regression signal that dedupe works.
    pub recovery_solve_attempts: AtomicU64,
    latency_us: Mutex<Series>,
    /// Wall-clock cost of each recovery escalation (solve + re-encode +
    /// write-back for one batch of implicated blocks).
    recovery_us: Mutex<Series>,
    /// Block indices currently quarantined — the typed degradation
    /// signal: recovery failed, the block is served as decoded until a
    /// later scrub heals it or a later escalation recovers it. Bounded
    /// at [`DETECTED_BLOCK_CAP`], sorted, deduplicated.
    quarantine: Mutex<Vec<usize>>,
    /// Live handle to the ring front door's gauges (occupancy
    /// high-water mark, CAS retries, seal-cause split, overload
    /// rejections); `None` under the locked baseline. The counters
    /// themselves live in the ring and are read lock-free — this mutex
    /// only guards attachment.
    ingress: Mutex<Option<Arc<IngressStats>>>,
    /// Live handle to the compute-path guard counters (range clamps,
    /// ABFT checks/trips/recomputes); `None` when the server runs
    /// unguarded. Same attachment pattern as `ingress`.
    guards: Mutex<Option<Arc<GuardStats>>>,
    shards: Mutex<Vec<ShardCounters>>,
    /// Scheduler gauges, one slot per shard: Wilson BER bounds, current
    /// interval, deadline headroom, cumulative overdue passes. Written
    /// wholesale by the scrub loop after each wakeup.
    sched: Mutex<Vec<ShardSchedule>>,
    /// Fleet-arbiter lane gauges for this model; `None` until the fleet
    /// control loop's first wakeup (or forever, when the server runs
    /// without a scrub loop).
    fleet: Mutex<Option<FleetGauge>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_sizes_sum
            .fetch_add(size as u64, Ordering::Relaxed);
        self.requests.fetch_add(size as u64, Ordering::Relaxed);
    }

    pub fn record_latency_us(&self, us: f64) {
        self.latency_us.lock().unwrap().push(us);
    }

    pub fn latency_summary(&self) -> (f64, f64, f64, usize) {
        let s = self.latency_us.lock().unwrap();
        (s.mean(), s.p(50.0), s.p(99.0), s.len())
    }

    /// Record one recovery escalation: block identities reconstructed
    /// and quarantined, plus the wall-clock latency of the attempt.
    /// Recovered blocks leave the quarantine list (a later pass may
    /// heal what an earlier one could not); quarantined blocks join it.
    pub fn record_recovery(&self, recovered: &[usize], quarantined: &[usize], us: f64) {
        self.recovered_blocks
            .fetch_add(recovered.len() as u64, Ordering::Relaxed);
        self.quarantined_blocks
            .fetch_add(quarantined.len() as u64, Ordering::Relaxed);
        self.recovery_us.lock().unwrap().push(us);
        let mut q = self.quarantine.lock().unwrap();
        q.retain(|b| !recovered.contains(b));
        q.extend_from_slice(quarantined);
        q.sort_unstable();
        q.dedup();
        q.truncate(DETECTED_BLOCK_CAP);
    }

    /// Blocks currently quarantined (sorted, deduplicated, bounded).
    pub fn quarantined(&self) -> Vec<usize> {
        self.quarantine.lock().unwrap().clone()
    }

    /// Recovery latency summary: `(mean, p99, attempts)`.
    pub fn recovery_summary(&self) -> (f64, f64, usize) {
        let s = self.recovery_us.lock().unwrap();
        (s.mean(), s.p(99.0), s.len())
    }

    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_sizes_sum.load(Ordering::Relaxed) as f64 / b as f64
    }

    fn shard_slot(shards: &mut Vec<ShardCounters>, idx: usize) -> &mut ShardCounters {
        if shards.len() <= idx {
            shards.resize(idx + 1, ShardCounters::default());
        }
        &mut shards[idx]
    }

    /// Record one scrub pass over shard `idx`.
    pub fn record_shard_scrub(&self, idx: usize, stats: &DecodeStats) {
        let mut shards = self.shards.lock().unwrap();
        let c = Self::shard_slot(&mut shards, idx);
        c.scrubs += 1;
        if stats.is_clean() {
            c.clean_scrubs += 1;
        }
        c.corrected += stats.corrected;
        c.detected += stats.detected;
        c.zeroed += stats.zeroed;
    }

    /// Record one weight delta shipped for shard `idx`.
    pub fn record_shard_refresh(&self, idx: usize) {
        self.delta_refreshes.fetch_add(1, Ordering::Relaxed);
        let mut shards = self.shards.lock().unwrap();
        Self::shard_slot(&mut shards, idx).refreshes += 1;
    }

    /// Attach the ring ingress gauges (done once at server startup
    /// when the ring front door is selected).
    pub fn set_ingress(&self, stats: Arc<IngressStats>) {
        *self.ingress.lock().unwrap() = Some(stats);
    }

    /// Snapshot of the ingress gauges; `None` under the locked
    /// baseline.
    pub fn ingress(&self) -> Option<IngressSnapshot> {
        self.ingress.lock().unwrap().as_ref().map(|s| s.snapshot())
    }

    /// Attach the guard counters (done once at server startup when a
    /// guard mode is armed).
    pub fn set_guards(&self, stats: Arc<GuardStats>) {
        *self.guards.lock().unwrap() = Some(stats);
    }

    /// Snapshot of the guard counters; `None` when guards are off.
    pub fn guard_snapshot(&self) -> Option<GuardReport> {
        self.guards.lock().unwrap().as_ref().map(|g| g.snapshot())
    }

    /// Snapshot of the per-shard counters.
    pub fn shard_counters(&self) -> Vec<ShardCounters> {
        self.shards.lock().unwrap().clone()
    }

    /// Publish the scrub scheduler's per-shard gauges (one snapshot per
    /// shard, replacing the previous set).
    pub fn set_shard_schedules(&self, gauges: Vec<ShardSchedule>) {
        *self.sched.lock().unwrap() = gauges;
    }

    /// Latest scheduler gauges (empty before the first scrub wakeup).
    pub fn shard_schedules(&self) -> Vec<ShardSchedule> {
        self.sched.lock().unwrap().clone()
    }

    /// Publish this model's fleet-arbiter lane gauges (done by the
    /// fleet control loop after every wakeup).
    pub fn set_fleet(&self, gauge: FleetGauge) {
        *self.fleet.lock().unwrap() = Some(gauge);
    }

    /// Latest fleet lane gauges; `None` before the first fleet wakeup.
    pub fn fleet(&self) -> Option<FleetGauge> {
        *self.fleet.lock().unwrap()
    }

    pub fn report(&self) -> String {
        let (mean, p50, p99, n) = self.latency_summary();
        let mut s = format!(
            "requests={} batches={} mean_batch={:.1} latency(mean/p50/p99)={:.0}/{:.0}/{:.0}us (n={}) corrected={} detected={} scrubs={} faults={} refresh_msgs_applied={} full_sent={} shard_deltas_sent={} exec_failures={}",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch(),
            mean,
            p50,
            p99,
            n,
            self.corrected.load(Ordering::Relaxed),
            self.detected.load(Ordering::Relaxed),
            self.scrubs.load(Ordering::Relaxed),
            self.faults_injected.load(Ordering::Relaxed),
            self.weight_refreshes.load(Ordering::Relaxed),
            self.full_refreshes.load(Ordering::Relaxed),
            self.delta_refreshes.load(Ordering::Relaxed),
            self.exec_failures.load(Ordering::Relaxed),
        );
        let (rec_mean, rec_p99, rec_n) = self.recovery_summary();
        if rec_n > 0 {
            s.push_str(&format!(
                "\n  recovery recovered={} quarantined={} latency(mean/p99)={:.0}/{:.0}us (n={})",
                self.recovered_blocks.load(Ordering::Relaxed),
                self.quarantined_blocks.load(Ordering::Relaxed),
                rec_mean,
                rec_p99,
                rec_n,
            ));
            let q = self.quarantined();
            if !q.is_empty() {
                let shown: Vec<String> = q.iter().take(16).map(|b| b.to_string()).collect();
                let more = if q.len() > 16 { ", …" } else { "" };
                s.push_str(&format!(
                    "\n  quarantine n={} blocks=[{}{more}]",
                    q.len(),
                    shown.join(", ")
                ));
            }
        }
        if let Some(f) = self.fleet() {
            s.push_str(&format!(
                "\n  fleet mode={} budget_bits={} deficit_bits={} last_deficit={} starved_grants={} wakeups={}",
                if f.degraded() { "degraded" } else { "ok" },
                f.budget_bits,
                f.deficit_bits,
                f.last_deficit_bits,
                f.starved_grants,
                f.wakeups,
            ));
        }
        if let Some(g) = self.guard_snapshot() {
            s.push_str(&format!(
                "\n  guards range_clamps={} abft_checks={} abft_trips={} recomputes={}",
                g.range_clamps, g.abft_checks, g.abft_trips, g.recomputes,
            ));
        }
        if let Some(i) = self.ingress() {
            s.push_str(&format!(
                "\n  ingress occupancy={} hwm={} cas_retries={} seal(full/deadline/drain)={}/{}/{} overloads={}",
                i.occupancy,
                i.occupancy_hwm,
                i.cas_retries,
                i.seal_full,
                i.seal_deadline,
                i.seal_drain,
                i.overloads,
            ));
        }
        let shards = self.shards.lock().unwrap();
        if !shards.is_empty() {
            s.push_str("\n  shard  scrubs   clean corrected detected zeroed refreshes");
            for (i, c) in shards.iter().enumerate() {
                s.push_str(&format!(
                    "\n  {:>5} {:>7} {:>7} {:>9} {:>8} {:>6} {:>9}",
                    i, c.scrubs, c.clean_scrubs, c.corrected, c.detected, c.zeroed, c.refreshes
                ));
            }
        }
        drop(shards);
        let sched = self.sched.lock().unwrap();
        if !sched.is_empty() {
            s.push_str("\n  shard  ber_upper  interval_s  deadline_in_s  passes overdue");
            for (i, g) in sched.iter().enumerate() {
                s.push_str(&format!(
                    "\n  {:>5} {:>10.3e} {:>11.3} {:>14.3} {:>7} {:>7}",
                    i, g.ber_upper, g.interval_secs, g.deadline_in_secs, g.passes, g.overdue
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.requests.load(Ordering::Relaxed), 12);
        assert!((m.mean_batch() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn latency_percentiles() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record_latency_us(i as f64);
        }
        let (_mean, p50, p99, n) = m.latency_summary();
        assert_eq!(n, 100);
        assert!((p50 - 50.5).abs() < 1.0);
        assert!(p99 >= 99.0);
    }

    #[test]
    fn latency_summary_under_concurrent_recorders() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let threads = 8;
        let per_thread = 500;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for i in 0..per_thread {
                        m.record_latency_us((t * per_thread + i) as f64);
                        m.record_batch(2);
                    }
                })
            })
            .collect();
        // summaries taken *while* recorders run must never panic and
        // never observe a partial count
        for _ in 0..50 {
            let (_, _, _, n) = m.latency_summary();
            assert!(n <= threads * per_thread);
        }
        for h in handles {
            h.join().unwrap();
        }
        let (mean, p50, _, n) = m.latency_summary();
        assert_eq!(n, threads * per_thread);
        // the union of the 8 ranges is 0..4000: mean/p50 ~ 1999.5
        assert!((mean - 1999.5).abs() < 1e-9, "mean = {mean}");
        assert!((p50 - 1999.5).abs() < 1.0, "p50 = {p50}");
        assert_eq!(m.requests.load(Ordering::Relaxed), 2 * (threads * per_thread) as u64);
        assert_eq!(m.batches.load(Ordering::Relaxed), (threads * per_thread) as u64);
    }

    #[test]
    fn shard_counters_under_concurrent_recorders() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let threads = 6;
        let per_thread = 400;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let m = m.clone();
                std::thread::spawn(move || {
                    let shard = t % 3; // three shards, two writers each
                    let stats = DecodeStats {
                        corrected: 1,
                        detected: 0,
                        zeroed: 0,
                    };
                    for i in 0..per_thread {
                        if i % 2 == 0 {
                            m.record_shard_scrub(shard, &stats);
                        } else {
                            m.record_shard_scrub(shard, &DecodeStats::default());
                        }
                        m.record_shard_refresh(shard);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let c = m.shard_counters();
        assert_eq!(c.len(), 3);
        for (i, shard) in c.iter().enumerate() {
            assert_eq!(shard.scrubs, 2 * per_thread as u64, "shard {i}");
            assert_eq!(shard.clean_scrubs, per_thread as u64, "shard {i}");
            assert_eq!(shard.corrected, per_thread as u64, "shard {i}");
            assert_eq!(shard.refreshes, 2 * per_thread as u64, "shard {i}");
        }
        assert_eq!(m.delta_refreshes.load(Ordering::Relaxed), (threads * per_thread) as u64);
    }

    #[test]
    fn shard_schedule_gauges_roundtrip_and_render() {
        let m = Metrics::new();
        assert!(m.shard_schedules().is_empty());
        let gauges = vec![
            ShardSchedule {
                ber_lower: 0.0,
                ber_upper: 2.5e-7,
                interval_secs: 3.2,
                deadline_in_secs: 1.1,
                passes: 9,
                overdue: 0,
            },
            ShardSchedule {
                ber_lower: 1e-6,
                ber_upper: 8e-6,
                interval_secs: 0.1,
                deadline_in_secs: -0.4,
                passes: 40,
                overdue: 2,
            },
        ];
        m.set_shard_schedules(gauges.clone());
        assert_eq!(m.shard_schedules(), gauges);
        let report = m.report();
        assert!(report.contains("ber_upper"), "{report}");
        assert!(report.contains("overdue"), "{report}");
        // wholesale replacement, not accumulation
        m.set_shard_schedules(gauges[..1].to_vec());
        assert_eq!(m.shard_schedules().len(), 1);
    }

    /// Ingress gauges read through `Metrics` while producers and a
    /// dispatcher hammer the ring: snapshots must stay internally
    /// consistent mid-flight and settle to conserved totals.
    #[test]
    fn ingress_gauges_under_concurrent_recorders() {
        use crate::coordinator::ingress::{IngressRing, PushError, RingConfig};
        use std::sync::mpsc::channel;
        use std::time::Duration;

        let m = Arc::new(Metrics::new());
        assert!(m.ingress().is_none(), "locked baseline has no gauges");
        let ring = Arc::new(IngressRing::new(RingConfig {
            depth: 4,
            cap: 8,
            dim: 1,
            max_wait: Duration::from_millis(1),
        }));
        m.set_ingress(ring.stats());
        let producers = 4;
        let per = 250u64;
        let handles: Vec<_> = (0..producers)
            .map(|p| {
                let ring = ring.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        let (tx, _rx) = channel();
                        loop {
                            match ring.push(p * 1000 + i, &[0.0], tx.clone()) {
                                Ok(()) => break,
                                Err(PushError::Overloaded) => std::thread::yield_now(),
                                Err(e) => panic!("{e}"),
                            }
                        }
                    }
                })
            })
            .collect();
        let dispatcher = {
            let ring = ring.clone();
            std::thread::spawn(move || {
                let mut served = 0u64;
                while let Some(b) = ring.next_sealed() {
                    served += b.count() as u64;
                }
                served
            })
        };
        // snapshots taken while recorders run never tear: the gauge can
        // momentarily lead the high-water mark (increment precedes the
        // fetch_max) by at most one lagging producer each, but neither
        // can exceed the ring's admission capacity
        for _ in 0..50 {
            let i = m.ingress().unwrap();
            assert!(i.occupancy <= 4 * 8);
            assert!(i.occupancy_hwm <= 4 * 8);
        }
        for h in handles {
            h.join().unwrap();
        }
        ring.close();
        assert_eq!(dispatcher.join().unwrap(), producers * per);
        let i = m.ingress().unwrap();
        assert_eq!(i.occupancy, 0, "all reservations recycled");
        assert!(i.occupancy_hwm >= 1);
        assert!(i.seal_full + i.seal_deadline + i.seal_drain >= 1);
        assert!(m.report().contains("ingress occupancy="), "{}", m.report());
    }

    #[test]
    fn guard_gauges_attach_and_render() {
        let m = Metrics::new();
        assert!(m.guard_snapshot().is_none(), "unguarded baseline has no gauges");
        assert!(!m.report().contains("guards"), "{}", m.report());
        let stats = Arc::new(GuardStats::default());
        m.set_guards(stats.clone());
        stats.absorb(&GuardReport {
            abft_checks: 5,
            abft_trips: 2,
            recomputes: 2,
            range_clamps: 7,
        });
        let g = m.guard_snapshot().unwrap();
        assert_eq!(g.range_clamps, 7);
        assert_eq!(g.abft_checks, 5);
        assert_eq!(g.abft_trips, 2);
        assert_eq!(g.recomputes, 2);
        let report = m.report();
        assert!(report.contains("guards range_clamps=7"), "{report}");
        assert!(report.contains("abft_trips=2"), "{report}");
    }

    #[test]
    fn recovery_gauges_accumulate_and_render() {
        let m = Metrics::new();
        assert!(!m.report().contains("recovery"), "{}", m.report());
        assert!(m.quarantined().is_empty());
        m.record_recovery(&[7, 2, 5], &[9], 420.0);
        m.record_recovery(&[], &[4, 9], 180.0);
        assert_eq!(m.recovered_blocks.load(Ordering::Relaxed), 3);
        assert_eq!(m.quarantined_blocks.load(Ordering::Relaxed), 3);
        assert_eq!(m.quarantined(), vec![4, 9], "list dedups identities");
        let (mean, _p99, n) = m.recovery_summary();
        assert_eq!(n, 2);
        assert!((mean - 300.0).abs() < 1e-9);
        let report = m.report();
        assert!(report.contains("recovery recovered=3 quarantined=3"), "{report}");
        assert!(report.contains("quarantine n=2 blocks=[4, 9]"), "{report}");
        // a later escalation that recovers a quarantined block clears it
        m.record_recovery(&[9], &[], 100.0);
        assert_eq!(m.quarantined(), vec![4]);
    }

    #[test]
    fn fleet_gauges_attach_and_render_degraded_mode() {
        let m = Metrics::new();
        assert!(m.fleet().is_none(), "no fleet gauges before first wakeup");
        assert!(!m.report().contains("fleet"), "{}", m.report());
        let healthy = FleetGauge {
            budget_bits: 4096,
            deficit_bits: 512,
            last_deficit_bits: 0,
            starved_grants: 1,
            wakeups: 10,
        };
        assert!(!healthy.degraded(), "stale deficit alone is not degraded");
        m.set_fleet(healthy);
        assert_eq!(m.fleet(), Some(healthy));
        let report = m.report();
        assert!(report.contains("fleet mode=ok budget_bits=4096"), "{report}");
        // an overcommitted wakeup flips the lane to degraded
        m.set_fleet(FleetGauge {
            budget_bits: 4096,
            deficit_bits: 1536,
            last_deficit_bits: 1024,
            starved_grants: 1,
            wakeups: 11,
        });
        let report = m.report();
        assert!(report.contains("fleet mode=degraded"), "{report}");
        assert!(report.contains("deficit_bits=1536"), "{report}");
        assert!(report.contains("last_deficit=1024"), "{report}");
    }

    #[test]
    fn shard_counters_grow_on_demand() {
        let m = Metrics::new();
        let stats = DecodeStats {
            corrected: 2,
            detected: 1,
            zeroed: 0,
        };
        m.record_shard_scrub(3, &stats);
        m.record_shard_scrub(3, &DecodeStats::default()); // clean pass
        m.record_shard_refresh(3);
        m.record_shard_refresh(0);
        let c = m.shard_counters();
        assert_eq!(c.len(), 4);
        assert_eq!(c[3].scrubs, 2);
        assert_eq!(c[3].clean_scrubs, 1, "only the error-free pass is clean");
        assert_eq!(c[3].corrected, 2);
        assert_eq!(c[3].detected, 1);
        assert_eq!(c[3].refreshes, 1);
        assert_eq!(c[0].refreshes, 1);
        assert_eq!(m.delta_refreshes.load(Ordering::Relaxed), 2);
        assert!(m.report().contains("shard"));
    }
}
