//! Serving metrics: request counters, latency series, memory-protection
//! event counters (corrected / detected / scrub passes).

use crate::util::stats::Series;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batch_sizes_sum: AtomicU64,
    pub corrected: AtomicU64,
    pub detected: AtomicU64,
    pub scrubs: AtomicU64,
    pub faults_injected: AtomicU64,
    pub weight_refreshes: AtomicU64,
    latency_us: Mutex<Series>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_sizes_sum
            .fetch_add(size as u64, Ordering::Relaxed);
        self.requests.fetch_add(size as u64, Ordering::Relaxed);
    }

    pub fn record_latency_us(&self, us: f64) {
        self.latency_us.lock().unwrap().push(us);
    }

    pub fn latency_summary(&self) -> (f64, f64, f64, usize) {
        let s = self.latency_us.lock().unwrap();
        (s.mean(), s.p(50.0), s.p(99.0), s.len())
    }

    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_sizes_sum.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn report(&self) -> String {
        let (mean, p50, p99, n) = self.latency_summary();
        format!(
            "requests={} batches={} mean_batch={:.1} latency(mean/p50/p99)={:.0}/{:.0}/{:.0}us (n={}) corrected={} detected={} scrubs={} faults={} refreshes={}",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch(),
            mean,
            p50,
            p99,
            n,
            self.corrected.load(Ordering::Relaxed),
            self.detected.load(Ordering::Relaxed),
            self.scrubs.load(Ordering::Relaxed),
            self.faults_injected.load(Ordering::Relaxed),
            self.weight_refreshes.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.requests.load(Ordering::Relaxed), 12);
        assert!((m.mean_batch() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn latency_percentiles() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record_latency_us(i as f64);
        }
        let (_mean, p50, p99, n) = m.latency_summary();
        assert_eq!(n, 100);
        assert!((p50 - 50.5).abs() < 1.0);
        assert!(p99 >= 99.0);
    }
}
