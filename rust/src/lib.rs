//! zsecc — In-Place Zero-Space Memory Protection for CNN (NeurIPS 2019).
//!
//! Three-layer reproduction: this crate is Layer 3 — the memory-protection
//! subsystem (ECC codes, fault injection, scrubbing), the model/artifact
//! loaders, the PJRT runtime that executes the AOT-compiled JAX/Pallas
//! inference graphs, a thread-based serving coordinator, and the harness
//! that regenerates every table and figure of the paper's evaluation.
//!
//! Layout:
//! * [`ecc`] — the paper's contribution: in-place zero-space ECC plus the
//!   baselines (SEC-DED (72,64), parity-zero, unprotected) and the
//!   future-work BCH-style extension. The `Protection` trait exposes
//!   block-range decode/scrub (`decode_span`/`scrub_span`,
//!   `decode_range`/`scrub_range`) so disjoint windows of one stored
//!   image can be processed independently — and in parallel. The hot
//!   path rides `ecc::tile`: a word-parallel (bitsliced) engine that
//!   syndromes 64 blocks at once and proves clean 512-byte tiles with
//!   one OR-reduction, degrading clean decodes to copies and clean
//!   scrubs to no-ops.
//! * [`memory`] — encoded weight memory: fault injection + scrubbing.
//!   `MemoryBank` is the whole-buffer store (Table-2 render, examples);
//!   `ShardedBank` splits the same stored image into S block-aligned
//!   shards scrubbed/decoded over the persistent worker pool
//!   (`memory::pool`: long-lived parked threads, shared injector +
//!   stealable per-worker queues, scope-style borrow API, per-worker
//!   scratch arenas), with per-shard `DecodeStats`, dirty tracking for
//!   incremental refresh, and copy-on-write trial resets (only
//!   fault-touched code blocks are copied back from pristine).
//!   `memory::scheduler` closes the telemetry → scheduling loop: an
//!   online per-shard bit-error-rate estimator (exponentially weighted
//!   error arrivals, Wilson confidence bounds) drives per-shard scrub
//!   deadlines — hot shards clamp to the base interval, provably-clean
//!   shards decay toward a configured maximum.
//! * [`quant`] — int8 weight buffers and per-layer dequantization,
//!   including the fused `decode_dequant_range` used by the scrub
//!   epoch's per-shard delta path (no full-buffer i8 intermediate).
//! * [`model`] — artifact manifests, weight/dataset loaders, plus
//!   [`model::recovery`]: the MILR-style recovery tier. Given layer
//!   shapes and a calibration sidecar persisted by `zsecc calibrate`
//!   (`<model>.recovery.json`), detected-uncorrectable weight blocks
//!   are reconstructed by solving the layer equation `Y = XW` for the
//!   implicated rows (least-squares), snapped to the quantization
//!   grid and verified against the held-out calibration residual —
//!   zero stored redundancy. The front-door detector is `ecc`'s
//!   sixth strategy, `milr` (plaintext probe, block 8).
//! * [`runtime`] — PJRT CPU client wrapper (HLO text -> executable),
//!   plus [`runtime::guard`]: compute-path protection (ABFT
//!   checksummed matmul with bitwise recompute-on-mismatch, calibrated
//!   activation range envelopes with clamp-and-count) for the guarded
//!   software executor, the serve front door, and the campaign's
//!   activation/accumulator fault sites.
//! * [`coordinator`] — request router, dynamic batcher, sharded
//!   protected weight store, metrics (global + per-shard). The scrub
//!   loop ships `WeightUpdate::Deltas` (offset + f32 window per dirty
//!   shard) over the refresh channel; a full buffer crosses only when
//!   every shard is dirty. Under `--recovery milr` the scrub loop
//!   escalates detected-uncorrectable blocks to the recovery tier on
//!   the shared pool — reconstructed blocks are written back and
//!   re-shipped, failed ones land in a typed quarantine gauge.
//!   See rust/README.md for the data-flow diagram.
//! * [`harness`] — Table 1 / Table 2 / Fig 1 / Fig 3 / Fig 4 + ablations,
//!   all fault-injection experiments riding on `harness::campaign`: a
//!   parallel Monte-Carlo campaign engine with adaptive
//!   (confidence-targeted) trial counts, five deterministic fault
//!   models, and a resumable checkpoint ledger (bit-identical resume).
//!   Cells and the unconditional head of each cell's trials pipeline
//!   over the shared worker pool; trials recycle copy-on-write-reset
//!   banks instead of re-encoding. `harness::scrubsim` replays
//!   time-varying fault scenarios (rate ramps, hotspot migration)
//!   against the adaptive scrub scheduler at equal scrub bandwidth.
//!   `harness::closedloop` closes the loop end to end: a model served
//!   under a live scheduler while the stateful `memory::fault::Wear`
//!   aging process drifts, scored per epoch by real accuracy and swept
//!   over {fixed, adaptive} × scrub budgets into the
//!   accuracy-vs-scrub-joules frontier.
//! * [`util`] — substrates the offline build denies us as crates: JSON,
//!   PRNG, CLI parsing, stats, ASCII plots, a bench timer.

pub mod coordinator;
pub mod ecc;
pub mod harness;
pub mod memory;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod util;

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory: honours `ZSECC_ARTIFACTS`, else walks
/// up from the current dir looking for `artifacts/index.json`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("ZSECC_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("index.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return ARTIFACTS_DIR.into();
        }
    }
}
