//! zsecc — In-Place Zero-Space Memory Protection for CNN (NeurIPS 2019).
//!
//! Three-layer reproduction: this crate is Layer 3 — the memory-protection
//! subsystem (ECC codes, fault injection, scrubbing), the model/artifact
//! loaders, the PJRT runtime that executes the AOT-compiled JAX/Pallas
//! inference graphs, a thread-based serving coordinator, and the harness
//! that regenerates every table and figure of the paper's evaluation.
//!
//! Layout:
//! * [`ecc`] — the paper's contribution: in-place zero-space ECC plus the
//!   baselines (SEC-DED (72,64), parity-zero, unprotected) and the
//!   future-work BCH-style extension.
//! * [`memory`] — encoded weight memory: fault injection + scrubbing.
//! * [`quant`] — int8 weight buffers and per-layer dequantization.
//! * [`model`] — artifact manifests, weight/dataset loaders.
//! * [`runtime`] — PJRT CPU client wrapper (HLO text -> executable).
//! * [`coordinator`] — request router, dynamic batcher, protected
//!   weight store, metrics.
//! * [`harness`] — Table 1 / Table 2 / Fig 1 / Fig 3 / Fig 4 + ablations.
//! * [`util`] — substrates the offline build denies us as crates: JSON,
//!   PRNG, CLI parsing, stats, ASCII plots, a bench timer.

pub mod coordinator;
pub mod ecc;
pub mod harness;
pub mod memory;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod util;

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory: honours `ZSECC_ARTIFACTS`, else walks
/// up from the current dir looking for `artifacts/index.json`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("ZSECC_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("index.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return ARTIFACTS_DIR.into();
        }
    }
}
