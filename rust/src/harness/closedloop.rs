//! Closed-loop accuracy-vs-scrub-energy simulation under drifting
//! wear faults.
//!
//! Every other harness measures protection in *storage* terms
//! (residual uncorrectable blocks, wrong weights). This one closes the
//! loop the paper's reliability argument actually cares about: a real
//! model is served from a [`ShardedBank`] while a [`Wear`] aging
//! process drifts — per-cell stuck-at damage accumulates inside a wear
//! window tick after tick, and the worn region's transient rate is
//! elevated, so the scheduler's Wilson BER estimator chases a moving
//! target — and each simulated epoch is scored by **end-to-end
//! accuracy** of the decoded weights through an [`EpochScorer`] (the
//! PJRT evaluator when artifacts exist, the campaign's synthetic dense
//! head otherwise).
//!
//! One discrete tick (= one virtual second):
//!
//! ```text
//!   wear.advance            damage drifts (stuck set grows)
//!   wear.strike_positions   stuck cells re-assert + transients land
//!   bank.inject_positions   the store reads back the damaged state
//!   sched.step_plan         ONE dispatch law: due shards through the
//!     (or FleetConfig        fleet arbiter under this cell's bit
//!      ::planner().plan)     budget — exactly what production runs
//!   bank.scrub_subset       granted shards scrub; bits are the
//!   sched.record_pass        energy spent (joules proxy)
//! ```
//!
//! At each epoch boundary the bank is decoded once (the inference
//! path's read, correcting single-error blocks in flight) and the
//! scorer turns the decoded weights into an accuracy. Sweeping scrub
//! policy {fixed, adaptive} × per-tick pass budgets at equal bandwidth
//! yields the **accuracy-vs-scrub-joules frontier**; the
//! deterministic acceptance gate ([`verdict`]) requires the adaptive
//! policy to dominate fixed at every equal-budget point — at least the
//! accuracy for at most the energy — and is the `[closedloop ok]` line
//! nightly CI greps for.
//!
//! Why adaptive dominates here and not under a uniform process: the
//! wear process is window-localized, and the damage the policy can
//! actually prevent is an in-window transient collecting a *partner*
//! flip in the same code block before a scrub separates them (two
//! uncorrected flips in one SEC block are permanent wrong weights).
//! Both policies see the *identical* damage stream — [`Wear`] consumes
//! randomness independently of the image contents — so a pair the
//! adaptive policy's 1-tick hot cadence lets form (both flips in one
//! tick) also forms under fixed, while fixed's longer hot-shard period
//! lets strictly more pairs survive. Stuck-at pairs, by contrast, are
//! permanent under any policy; they set the drifting accuracy floor
//! both policies share.
//!
//! Everything is deterministic in the config seed and independent of
//! worker count or wall-clock, so the sweep checkpoints into a
//! fingerprinted resumable ledger (same idiom as the campaign engine)
//! and a resumed run reproduces the interrupted one byte for byte.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

use crate::coordinator::FleetConfig;
use crate::ecc::strategy_by_name;
use crate::memory::{SchedulerConfig, ScrubPolicy, ScrubScheduler, ShardedBank, Wear, WearParams};
use crate::runtime::guard::DenseModel;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::plot;
use crate::util::rng::Rng;

/// Which dispatch law plans each tick's scrub passes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Planner {
    /// [`ScrubScheduler::step_plan`] — the single-model stepping of the
    /// shared arbitration law (no deferral counters).
    Sched,
    /// [`FleetConfig::planner`] — the full fleet arbiter with deferral
    /// tracking and the starvation guarantee, driven as a fleet of one.
    Fleet,
}

impl Planner {
    pub fn tag(&self) -> &'static str {
        match self {
            Planner::Sched => "sched",
            Planner::Fleet => "fleet",
        }
    }

    pub fn parse(text: &str) -> anyhow::Result<Planner> {
        match text {
            "sched" => Ok(Planner::Sched),
            "fleet" => Ok(Planner::Fleet),
            _ => anyhow::bail!("unknown planner '{text}' (sched | fleet)"),
        }
    }
}

/// Closed-loop sweep knobs. `budgets` are scrub passes per tick; each
/// is converted to a bit budget over the widest shard so every cell of
/// the sweep is an equal-bandwidth comparison.
#[derive(Clone, Debug)]
pub struct LoopConfig {
    pub strategy: String,
    pub n_weights: usize,
    pub shards: usize,
    pub epochs: u64,
    pub ticks_per_epoch: u64,
    /// Adaptive upper clamp, in ticks.
    pub max_interval_ticks: u64,
    /// Pool workers for the scrub fan-out (decode output is
    /// worker-count independent, so this is excluded from the ledger
    /// fingerprint).
    pub workers: usize,
    pub planner: Planner,
    /// Deferral cap when `planner` is [`Planner::Fleet`].
    pub starve_after: u32,
    pub wear: WearParams,
    pub seed: u64,
    pub budgets: Vec<u64>,
}

impl Default for LoopConfig {
    fn default() -> Self {
        LoopConfig {
            strategy: "in-place".into(),
            n_weights: 64 * 1024,
            shards: 16,
            epochs: 6,
            ticks_per_epoch: 30,
            max_interval_ticks: 16,
            workers: 2,
            planner: Planner::Sched,
            starve_after: 4,
            wear: WearParams::default(),
            seed: 42,
            budgets: vec![1, 2, 4],
        }
    }
}

impl LoopConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.epochs >= 1, "closed loop needs at least one epoch");
        anyhow::ensure!(
            self.ticks_per_epoch >= 1,
            "closed loop needs at least one tick per epoch"
        );
        anyhow::ensure!(!self.budgets.is_empty(), "budget sweep must not be empty");
        for pair in self.budgets.windows(2) {
            anyhow::ensure!(
                pair[0] < pair[1],
                "budgets must be strictly increasing (got {} then {})",
                pair[0],
                pair[1]
            );
        }
        anyhow::ensure!(
            self.budgets[0] >= 1,
            "every budget needs at least 1 pass/tick"
        );
        self.wear.validate()
    }

    /// Identity of the sweep a ledger belongs to. Excludes `workers`
    /// (results are worker-count independent) and the policy set (cells
    /// are keyed individually, so a fixed-only run can be resumed into
    /// a both-policies run).
    pub fn fingerprint(&self, scorer: &str) -> String {
        let budgets: Vec<String> = self.budgets.iter().map(|b| b.to_string()).collect();
        format!(
            "closedloop-v1|scorer={scorer}|strategy={}|n={}|shards={}|epochs={}|ticks={}|maxint={}|planner={}|starve={}|seed={}|{}|budgets={}",
            self.strategy,
            self.n_weights,
            self.shards,
            self.epochs,
            self.ticks_per_epoch,
            self.max_interval_ticks,
            self.planner.tag(),
            self.starve_after,
            self.seed,
            self.wear.tag(),
            budgets.join(",")
        )
    }
}

/// Scores one epoch's decoded weights by end-to-end accuracy. The
/// scorer owns the clean weight image the protected bank stores.
pub trait EpochScorer {
    /// Identity entering the ledger fingerprint (e.g. `synthetic`,
    /// `pjrt:squeezenet_s`).
    fn name(&self) -> String;
    /// The clean int8 weights the bank protects.
    fn weights(&self) -> &[i8];
    /// Accuracy in [0, 1] of a decoded weight image.
    fn score(&mut self, decoded: &[i8]) -> anyhow::Result<f64>;
}

/// Artifact-free scorer: the campaign engine's synthetic dense head
/// (`[n/16 x 16]` over the dequantized synthetic WOT image), scored as
/// argmax agreement with the clean model on one deterministic batch.
/// What CI and the nightly frontier run.
pub struct SyntheticScorer {
    weights: Vec<i8>,
    x: Vec<f32>,
    dim: usize,
    clean_argmax: Vec<usize>,
}

impl SyntheticScorer {
    /// Columns of the synthetic dense head (the campaign's geometry).
    const CLASSES: usize = 16;
    /// Rows of the fixed scoring batch: accuracy quantizes to 1/64.
    const BATCH: usize = 64;
    /// The int8 pipeline's dequantization scale for synthetic heads.
    const SCALE: f32 = 0.02;

    pub fn new(n_weights: usize) -> anyhow::Result<SyntheticScorer> {
        anyhow::ensure!(
            n_weights >= Self::CLASSES && n_weights % Self::CLASSES == 0,
            "closed-loop scoring needs n_weights to be a multiple of {} (got {n_weights})",
            Self::CLASSES
        );
        let weights = crate::harness::ablation::synth_wot(n_weights, 42);
        let dim = n_weights / Self::CLASSES;
        let mut rng = Rng::new(4242);
        let x: Vec<f32> = (0..Self::BATCH * dim).map(|_| rng.f64() as f32).collect();
        let clean = Self::head(&weights, dim)?.forward(&x, Self::BATCH);
        let clean_argmax = argmax_rows(&clean, Self::CLASSES);
        Ok(SyntheticScorer {
            weights,
            x,
            dim,
            clean_argmax,
        })
    }

    fn head(q: &[i8], dim: usize) -> anyhow::Result<DenseModel> {
        let w: Vec<f32> = q.iter().map(|&v| f32::from(v) * Self::SCALE).collect();
        DenseModel::from_flat(&w, &[(dim, Self::CLASSES)])
    }
}

impl EpochScorer for SyntheticScorer {
    fn name(&self) -> String {
        "synthetic".into()
    }

    fn weights(&self) -> &[i8] {
        &self.weights
    }

    fn score(&mut self, decoded: &[i8]) -> anyhow::Result<f64> {
        anyhow::ensure!(
            decoded.len() == self.weights.len(),
            "decoded image holds {} weights, scorer expects {}",
            decoded.len(),
            self.weights.len()
        );
        let logits = Self::head(decoded, self.dim)?.forward(&self.x, Self::BATCH);
        let agree = argmax_rows(&logits, Self::CLASSES)
            .iter()
            .zip(&self.clean_argmax)
            .filter(|(a, b)| a == b)
            .count();
        Ok(agree as f64 / Self::BATCH as f64)
    }
}

/// Row-wise argmax of a `[rows x classes]` logit matrix. Ties resolve
/// to the lowest index, deterministically.
fn argmax_rows(logits: &[f32], classes: usize) -> Vec<usize> {
    logits
        .chunks_exact(classes)
        .map(|row| {
            let mut best = 0;
            for (c, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = c;
                }
            }
            best
        })
        .collect()
}

/// One (policy, budget) cell of the sweep.
#[derive(Clone, Debug, PartialEq)]
pub struct CellOutcome {
    pub policy: ScrubPolicy,
    pub budget_passes: u64,
    /// End-to-end accuracy at each epoch boundary.
    pub epoch_acc: Vec<f64>,
    /// Total stored bits scrubbed — the energy (joules) proxy.
    pub bits_scrubbed: u64,
    pub scrub_passes: u64,
    pub faults_struck: u64,
    /// Stuck cells accumulated by the wear process when the clock
    /// stopped (identical across cells by construction).
    pub stuck_cells: u64,
    pub residual_uncorrectable: u64,
    pub residual_wrong_weights: u64,
}

impl CellOutcome {
    fn key_of(policy: ScrubPolicy, budget: u64) -> String {
        format!("{}|{budget}", policy.tag())
    }

    pub fn key(&self) -> String {
        Self::key_of(self.policy, self.budget_passes)
    }

    pub fn mean_acc(&self) -> f64 {
        if self.epoch_acc.is_empty() {
            return 0.0;
        }
        self.epoch_acc.iter().sum::<f64>() / self.epoch_acc.len() as f64
    }

    pub fn min_acc(&self) -> f64 {
        self.epoch_acc.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("policy", s(self.policy.tag())),
            ("budget_passes", num(self.budget_passes as f64)),
            ("epoch_acc", arr(self.epoch_acc.iter().map(|&a| num(a)))),
            ("bits_scrubbed", num(self.bits_scrubbed as f64)),
            ("scrub_passes", num(self.scrub_passes as f64)),
            ("faults_struck", num(self.faults_struck as f64)),
            ("stuck_cells", num(self.stuck_cells as f64)),
            (
                "residual_uncorrectable",
                num(self.residual_uncorrectable as f64),
            ),
            (
                "residual_wrong_weights",
                num(self.residual_wrong_weights as f64),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<CellOutcome> {
        let f = |k: &str| -> anyhow::Result<f64> {
            v.req(k)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("ledger cell field '{k}' must be a number"))
        };
        let policy = ScrubPolicy::parse(
            v.req("policy")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("ledger cell field 'policy' must be a string"))?,
        )?;
        let epoch_acc = v
            .req("epoch_acc")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("ledger cell field 'epoch_acc' must be an array"))?
            .iter()
            .map(|a| {
                a.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("epoch accuracies must be numbers"))
            })
            .collect::<anyhow::Result<Vec<f64>>>()?;
        Ok(CellOutcome {
            policy,
            budget_passes: f("budget_passes")? as u64,
            epoch_acc,
            bits_scrubbed: f("bits_scrubbed")? as u64,
            scrub_passes: f("scrub_passes")? as u64,
            faults_struck: f("faults_struck")? as u64,
            stuck_cells: f("stuck_cells")? as u64,
            residual_uncorrectable: f("residual_uncorrectable")? as u64,
            residual_wrong_weights: f("residual_wrong_weights")? as u64,
        })
    }
}

/// The finished sweep: cells in budget-major, fixed-before-adaptive
/// order (whichever of those the policy set produced).
#[derive(Clone, Debug)]
pub struct LoopReport {
    pub fingerprint: String,
    pub cells: Vec<CellOutcome>,
}

impl LoopReport {
    fn pair(&self, budget: u64) -> (Option<&CellOutcome>, Option<&CellOutcome>) {
        let find = |p: ScrubPolicy| {
            self.cells
                .iter()
                .find(|c| c.policy == p && c.budget_passes == budget)
        };
        (find(ScrubPolicy::Fixed), find(ScrubPolicy::Adaptive))
    }

    fn budgets(&self) -> Vec<u64> {
        let mut budgets: Vec<u64> = self.cells.iter().map(|c| c.budget_passes).collect();
        budgets.sort_unstable();
        budgets.dedup();
        budgets
    }

    /// JSON record: the raw cells (with their per-epoch accuracy
    /// traces) plus the derived frontier — one point per budget pairing
    /// each policy's mean accuracy with the energy it actually spent.
    pub fn to_json(&self) -> Json {
        let frontier = self.budgets().into_iter().map(|b| {
            let (fixed, adaptive) = self.pair(b);
            let acc = |c: Option<&CellOutcome>| match c {
                Some(c) => num(c.mean_acc()),
                None => Json::Null,
            };
            let bits = |c: Option<&CellOutcome>| match c {
                Some(c) => num(c.bits_scrubbed as f64),
                None => Json::Null,
            };
            obj(vec![
                ("budget_passes", num(b as f64)),
                ("fixed_acc", acc(fixed)),
                ("adaptive_acc", acc(adaptive)),
                ("fixed_bits", bits(fixed)),
                ("adaptive_bits", bits(adaptive)),
            ])
        });
        obj(vec![
            ("fingerprint", s(&self.fingerprint)),
            ("cells", arr(self.cells.iter().map(|c| c.to_json()))),
            ("frontier", arr(frontier)),
        ])
    }
}

/// Human-readable sweep table.
pub fn render(report: &LoopReport) -> String {
    let headers = [
        "budget",
        "policy",
        "passes",
        "bits-scrubbed",
        "mean-acc",
        "min-acc",
        "final-acc",
        "stuck",
        "resid-uncorr",
        "resid-wrong",
    ];
    let rows: Vec<Vec<String>> = report
        .cells
        .iter()
        .map(|c| {
            vec![
                format!("{}/tick", c.budget_passes),
                c.policy.tag().to_string(),
                c.scrub_passes.to_string(),
                c.bits_scrubbed.to_string(),
                format!("{:.4}", c.mean_acc()),
                format!("{:.4}", c.min_acc()),
                format!("{:.4}", c.epoch_acc.last().copied().unwrap_or(0.0)),
                c.stuck_cells.to_string(),
                c.residual_uncorrectable.to_string(),
                c.residual_wrong_weights.to_string(),
            ]
        })
        .collect();
    plot::table(&headers, &rows)
}

/// The deterministic acceptance gate: at every budget where both
/// policies ran, adaptive must reach **at least** fixed's mean epoch
/// accuracy while spending **at most** fixed's scrub energy — the
/// adaptive frontier dominates the fixed one pointwise. Returns the
/// `[closedloop ok]` line; a violated inequality is an error (the CLI
/// exits nonzero, which is what CI gates on).
pub fn verdict(report: &LoopReport) -> anyhow::Result<String> {
    let mut compared = 0usize;
    for b in report.budgets() {
        let (Some(fixed), Some(adaptive)) = report.pair(b) else {
            continue;
        };
        anyhow::ensure!(
            adaptive.mean_acc() >= fixed.mean_acc(),
            "[closedloop FAIL] adaptive mean accuracy {:.4} < fixed {:.4} at {b} passes/tick",
            adaptive.mean_acc(),
            fixed.mean_acc()
        );
        anyhow::ensure!(
            adaptive.bits_scrubbed <= fixed.bits_scrubbed,
            "[closedloop FAIL] adaptive scrubbed {} bits > fixed {} at {b} passes/tick",
            adaptive.bits_scrubbed,
            fixed.bits_scrubbed
        );
        compared += 1;
    }
    anyhow::ensure!(
        compared > 0,
        "[closedloop FAIL] no budget ran both policies; nothing to compare"
    );
    Ok(format!(
        "[closedloop ok] adaptive dominates fixed at all {compared} equal-budget \
         frontier points (accuracy >= at energy <=)"
    ))
}

/// Run one (policy, budget) cell: the full tick/epoch loop of the
/// module docs over a fresh bank, scheduler and wear process.
pub fn run_cell(
    cfg: &LoopConfig,
    scorer: &mut dyn EpochScorer,
    policy: ScrubPolicy,
    budget_passes: u64,
) -> anyhow::Result<CellOutcome> {
    anyhow::ensure!(budget_passes >= 1, "budget must be at least 1 pass/tick");
    let weights = scorer.weights().to_vec();
    anyhow::ensure!(
        weights.len() == cfg.n_weights,
        "scorer holds {} weights, config says {}",
        weights.len(),
        cfg.n_weights
    );
    let mut bank = ShardedBank::new(
        strategy_by_name(&cfg.strategy)?,
        &weights,
        cfg.shards,
        cfg.workers,
    )?;
    let nshards = bank.num_shards();
    let shard_bits: Vec<u64> = (0..nshards).map(|i| bank.shard_bits(i)).collect();
    // Equal-bandwidth budgets: passes are priced at the widest shard,
    // so every cell of the sweep may spend the same stored bits/tick.
    let pass_bits = shard_bits.iter().copied().max().unwrap_or(0);
    anyhow::ensure!(pass_bits > 0, "bank has no stored bits to scrub");
    let budget_bits = budget_passes * pass_bits;
    let tick = Duration::from_secs(1);
    let sched_cfg = match policy {
        // fixed at the bandwidth-implied period: budget passes/tick
        // over S shards = each shard every S/budget ticks
        ScrubPolicy::Fixed => {
            SchedulerConfig::fixed(tick * (nshards.div_ceil(budget_passes as usize) as u32))
        }
        ScrubPolicy::Adaptive => {
            SchedulerConfig::adaptive(tick, tick * (cfg.max_interval_ticks as u32))
        }
    };
    let mut sched = ScrubScheduler::new(sched_cfg, &shard_bits, Duration::ZERO);
    let mut planner = match cfg.planner {
        Planner::Sched => None,
        Planner::Fleet => {
            let mut arb = FleetConfig {
                budget_bits: Some(budget_bits),
                starve_after: cfg.starve_after,
            }
            .planner();
            let slot = arb.register(nshards);
            Some((arb, slot))
        }
    };
    // The wear process is seeded from the config alone — never the
    // policy or budget — so every cell faces the identical damage
    // stream and the sweep isolates the scrub response.
    let mut wear = Wear::new(cfg.wear, cfg.seed)?;
    let mut cell = CellOutcome {
        policy,
        budget_passes,
        epoch_acc: Vec::with_capacity(cfg.epochs as usize),
        bits_scrubbed: 0,
        scrub_passes: 0,
        faults_struck: 0,
        stuck_cells: 0,
        residual_uncorrectable: 0,
        residual_wrong_weights: 0,
    };
    let mut decoded = vec![0i8; weights.len()];
    for epoch in 0..cfg.epochs {
        for et in 0..cfg.ticks_per_epoch {
            let t = epoch * cfg.ticks_per_epoch + et;
            let now = tick * (t as u32);
            wear.advance(bank.total_bits());
            let strikes = wear.strike_positions(bank.image());
            cell.faults_struck += bank.inject_positions(&strikes);
            let chosen: Vec<usize> = match &mut planner {
                None => sched.step_plan(now, Some(budget_bits)),
                Some((arb, slot)) => arb
                    .plan(&[(*slot, &sched)], now)
                    .into_iter()
                    .map(|g| g.shard)
                    .collect(),
            };
            for &(i, stats) in &bank.scrub_subset(&chosen) {
                cell.bits_scrubbed += sched.shard_bits(i);
                sched.record_pass(i, &stats, now);
                cell.scrub_passes += 1;
            }
        }
        // Epoch boundary: the inference path's protected read (single
        // errors corrected in flight), scored end to end.
        bank.read(&mut decoded);
        cell.epoch_acc.push(scorer.score(&decoded)?);
    }
    cell.stuck_cells = wear.stuck_cells();
    let outcome = bank.read_outcome(&mut decoded);
    cell.residual_uncorrectable = if outcome.overflow {
        outcome.stats.detected
    } else {
        outcome.detected_blocks.len() as u64
    };
    cell.residual_wrong_weights = decoded
        .iter()
        .zip(&weights)
        .filter(|(a, b)| a != b)
        .count() as u64;
    Ok(cell)
}

/// Run the sweep: `policies` × `cfg.budgets`, checkpointing each
/// finished cell into the ledger (when given) so an interrupted sweep
/// resumes where it stopped. With `resume`, an existing ledger's cells
/// are trusted verbatim after a fingerprint match — re-running a
/// completed sweep touches nothing and reproduces the ledger byte for
/// byte.
pub fn run(
    cfg: &LoopConfig,
    scorer: &mut dyn EpochScorer,
    policies: &[ScrubPolicy],
    ledger_path: Option<&Path>,
    resume: bool,
) -> anyhow::Result<LoopReport> {
    cfg.validate()?;
    anyhow::ensure!(!policies.is_empty(), "no scrub policies selected");
    let fingerprint = cfg.fingerprint(&scorer.name());
    let mut ledger = match ledger_path {
        Some(path) if resume && path.exists() => Ledger::load(path, &fingerprint)?,
        _ => Ledger {
            fingerprint: fingerprint.clone(),
            cells: BTreeMap::new(),
        },
    };
    for &budget in &cfg.budgets {
        for &policy in policies {
            let key = CellOutcome::key_of(policy, budget);
            if ledger.cells.contains_key(&key) {
                continue;
            }
            let cell = run_cell(cfg, scorer, policy, budget)?;
            ledger.cells.insert(key, cell);
            if let Some(path) = ledger_path {
                ledger.save(path)?;
            }
        }
    }
    let mut cells = Vec::new();
    for &budget in &cfg.budgets {
        for policy in [ScrubPolicy::Fixed, ScrubPolicy::Adaptive] {
            if let Some(c) = ledger.cells.get(&CellOutcome::key_of(policy, budget)) {
                cells.push(c.clone());
            }
        }
    }
    Ok(LoopReport { fingerprint, cells })
}

// -------------------------------------------------------------- ledger --

/// Resumable checkpoint of the sweep — the campaign engine's ledger
/// idiom: a fingerprint hard-gating resume, cells keyed
/// `policy|budget`, write-to-temp + rename persistence, and no
/// wall-clock anywhere so the bytes are a pure function of the config.
struct Ledger {
    fingerprint: String,
    cells: BTreeMap<String, CellOutcome>,
}

impl Ledger {
    fn load(path: &Path, fingerprint: &str) -> anyhow::Result<Ledger> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading ledger {}: {e}", path.display()))?;
        let v = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing ledger {}: {e}", path.display()))?;
        let fp = v
            .req("fingerprint")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("ledger 'fingerprint' must be a string"))?;
        anyhow::ensure!(
            fp == fingerprint,
            "ledger {} belongs to a different sweep (fingerprint mismatch:\n  ledger: {fp}\n  config: {fingerprint})",
            path.display()
        );
        let mut cells = BTreeMap::new();
        for (k, cv) in v
            .req("cells")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("ledger 'cells' must be an object"))?
        {
            cells.insert(k.clone(), CellOutcome::from_json(cv)?);
        }
        Ok(Ledger {
            fingerprint: fingerprint.to_string(),
            cells,
        })
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("fingerprint", s(&self.fingerprint)),
            (
                "cells",
                Json::Obj(
                    self.cells
                        .iter()
                        .map(|(k, c)| (k.clone(), c.to_json()))
                        .collect(),
                ),
            ),
        ])
    }

    fn save(&self, path: &Path) -> anyhow::Result<()> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json().to_string())
            .map_err(|e| anyhow::anyhow!("writing ledger {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| anyhow::anyhow!("publishing ledger {}: {e}", path.display()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Small but physically meaningful: an 8-shard bank whose wear
    /// window sits inside one shard, hot transients landing a few
    /// flips per tick — the regime where pair formation separates the
    /// policies within a couple of simulated minutes.
    fn test_cfg() -> LoopConfig {
        LoopConfig {
            n_weights: 4 * 1024,
            shards: 8,
            epochs: 2,
            ticks_per_epoch: 24,
            max_interval_ticks: 8,
            workers: 1,
            wear: WearParams {
                transient_rate: 0.0,
                wear_rate: 2e-5,
                accel: 1.0,
                window_start: 0.25,
                window_frac: 0.10,
                max_stuck_frac: 0.05,
                hot_rate: 8e-4,
            },
            budgets: vec![1],
            ..LoopConfig::default()
        }
    }

    #[test]
    fn cells_are_deterministic() {
        let cfg = test_cfg();
        let mut scorer = SyntheticScorer::new(cfg.n_weights).unwrap();
        let a = run_cell(&cfg, &mut scorer, ScrubPolicy::Adaptive, 1).unwrap();
        let b = run_cell(&cfg, &mut scorer, ScrubPolicy::Adaptive, 1).unwrap();
        assert_eq!(a, b, "same config + seed must reproduce the cell exactly");
        assert!(a.faults_struck > 0, "the wear process must actually strike");
        assert!(a.scrub_passes > 0, "the planner must actually grant passes");
    }

    #[test]
    fn fleet_planner_is_the_same_law() {
        // A fleet of one under the arbiter grants the same passes the
        // scheduler's own stepping grants — the "one law" claim,
        // observed end to end through cell outcomes. The budget covers
        // every shard, so the arbiter's starvation guarantee (which
        // single-model stepping deliberately omits) never has to fire
        // and the two dispatch paths must coincide exactly.
        let cfg = test_cfg();
        let budget = cfg.shards as u64;
        let mut scorer = SyntheticScorer::new(cfg.n_weights).unwrap();
        let sched = run_cell(&cfg, &mut scorer, ScrubPolicy::Adaptive, budget).unwrap();
        let fleet_cfg = LoopConfig {
            planner: Planner::Fleet,
            ..test_cfg()
        };
        let fleet = run_cell(&fleet_cfg, &mut scorer, ScrubPolicy::Adaptive, budget).unwrap();
        assert_eq!(sched.epoch_acc, fleet.epoch_acc);
        assert_eq!(sched.bits_scrubbed, fleet.bits_scrubbed);
        assert_eq!(sched.scrub_passes, fleet.scrub_passes);
    }

    #[test]
    fn adaptive_dominates_fixed_under_localized_wear() {
        let cfg = test_cfg();
        let mut scorer = SyntheticScorer::new(cfg.n_weights).unwrap();
        let report = run(
            &cfg,
            &mut scorer,
            &[ScrubPolicy::Fixed, ScrubPolicy::Adaptive],
            None,
            false,
        )
        .unwrap();
        assert_eq!(report.cells.len(), 2);
        let (fixed, adaptive) = (report.pair(1).0.unwrap(), report.pair(1).1.unwrap());
        // Pair-formation physics: fixed's 8-tick hot period lets
        // in-window transients collect partners; adaptive's 1-tick
        // cadence separates them. Strictly fewer permanent wrong
        // weights, at no extra energy, at no accuracy loss.
        assert!(
            adaptive.residual_wrong_weights < fixed.residual_wrong_weights,
            "adaptive {} vs fixed {} residual wrong weights",
            adaptive.residual_wrong_weights,
            fixed.residual_wrong_weights
        );
        assert!(adaptive.bits_scrubbed <= fixed.bits_scrubbed);
        assert!(adaptive.mean_acc() >= fixed.mean_acc());
        let line = verdict(&report).unwrap();
        assert!(line.starts_with("[closedloop ok]"), "{line}");
    }

    #[test]
    fn ledger_resume_is_byte_identical() {
        let dir = std::env::temp_dir().join(format!("zsecc-closedloop-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let fresh = dir.join("fresh.json");
        let staged = dir.join("staged.json");
        let cfg = test_cfg();
        let mut scorer = SyntheticScorer::new(cfg.n_weights).unwrap();
        let both = [ScrubPolicy::Fixed, ScrubPolicy::Adaptive];
        run(&cfg, &mut scorer, &both, Some(&fresh), false).unwrap();
        // Interrupted sweep: only the fixed cell lands, then a resumed
        // run completes the adaptive cell on top of it.
        run(&cfg, &mut scorer, &both[..1], Some(&staged), false).unwrap();
        run(&cfg, &mut scorer, &both, Some(&staged), true).unwrap();
        let a = std::fs::read(&fresh).unwrap();
        let b = std::fs::read(&staged).unwrap();
        assert_eq!(a, b, "resumed ledger must match a fresh run byte for byte");
        // A different config must refuse the ledger outright.
        let other = LoopConfig {
            seed: 43,
            ..test_cfg()
        };
        let err = run(&other, &mut scorer, &both, Some(&fresh), true).unwrap_err();
        assert!(err.to_string().contains("fingerprint mismatch"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn report_json_carries_cells_and_frontier() {
        let cfg = test_cfg();
        let mut scorer = SyntheticScorer::new(cfg.n_weights).unwrap();
        let report = run(
            &cfg,
            &mut scorer,
            &[ScrubPolicy::Fixed, ScrubPolicy::Adaptive],
            None,
            false,
        )
        .unwrap();
        let v = Json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(
            v.req("fingerprint").unwrap().as_str().unwrap(),
            report.fingerprint
        );
        let cells = v.req("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        // cells round-trip through the ledger codec
        for c in cells {
            CellOutcome::from_json(c).unwrap();
        }
        let frontier = v.req("frontier").unwrap().as_arr().unwrap();
        assert_eq!(frontier.len(), 1);
        let point = &frontier[0];
        assert_eq!(point.req("budget_passes").unwrap().as_f64(), Some(1.0));
        assert!(point.req("fixed_acc").unwrap().as_f64().is_some());
        assert!(point.req("adaptive_acc").unwrap().as_f64().is_some());
        // the rendered table mentions every budget once per policy
        let table = render(&report);
        assert_eq!(table.matches("1/tick").count(), 2, "{table}");
    }
}
