//! Table 1: accuracy and weight-magnitude distribution of the 8-bit
//! quantized model zoo — the observation motivating in-place ECC
//! (>99% of weights in [-64, 63] for well-trained CNNs).
//!
//! Accuracies (float32 / int8) come from the manifest (python measured
//! them at train time); the int8 accuracy is *re-measured* through the
//! rust PJRT path on the pre-WOT artifact as a cross-language check, and
//! the distribution bands are computed from the pre-WOT int8 buffer.

use std::path::Path;
use std::sync::Arc;

use crate::model::{load_weights, EvalSet, Manifest};
use crate::quant::{distribution_bands, dequantize_into};
use crate::runtime::{accuracy, Runtime};
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::plot;

#[derive(Clone, Debug)]
pub struct Row {
    pub model: String,
    pub n_weights: usize,
    pub float_acc: f64,
    pub int8_acc: f64,
    /// int8 accuracy re-measured via the rust PJRT path (pre-WOT HLO).
    pub int8_acc_rust: Option<f64>,
    pub band0: f64, // |q| in [0, 32)
    pub band1: f64, // [32, 64)
    pub band2: f64, // [64, 128]
}

pub fn run(
    artifacts: &Path,
    models: &[String],
    remeasure: bool,
) -> anyhow::Result<Vec<Row>> {
    let rt = if remeasure { Some(Runtime::cpu()?) } else { None };
    let ds = if remeasure {
        Some(Arc::new(EvalSet::load(&artifacts.join("dataset.eval.bin"))?))
    } else {
        None
    };
    let mut rows = Vec::new();
    for model in models {
        let man = Manifest::load_model(artifacts, model)?;
        let q = load_weights(&man.prewot_path(), man.num_weights)?;
        let (b0, b1, b2) = distribution_bands(&q);
        let int8_acc_rust = match (&rt, &ds) {
            (Some(rt), Some(ds)) => {
                let batch = *man.batches.iter().max().unwrap();
                let exe = rt.load(&man.hlo_prewot_path(batch)?, batch, &man)?;
                let mut f = vec![0f32; q.len()];
                dequantize_into(&q, &man.layers_prewot(), &mut f);
                let wbuf = rt.bind_weights(&f)?;
                Some(accuracy(rt, &exe, &wbuf, ds)?)
            }
            _ => None,
        };
        rows.push(Row {
            model: model.clone(),
            n_weights: man.num_weights,
            float_acc: man.float_acc,
            int8_acc: man.int8_acc,
            int8_acc_rust,
            band0: b0,
            band1: b1,
            band2: b2,
        });
    }
    Ok(rows)
}

pub fn render(rows: &[Row]) -> String {
    let headers = [
        "Model",
        "#weights",
        "Float32 acc",
        "Int8 acc",
        "Int8 acc (rust)",
        "[0,32) %",
        "[32,64) %",
        "[64,128] %",
    ];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.model.clone(),
                format!("{}", r.n_weights),
                format!("{:.2}", r.float_acc * 100.0),
                format!("{:.2}", r.int8_acc * 100.0),
                r.int8_acc_rust
                    .map(|a| format!("{:.2}", a * 100.0))
                    .unwrap_or_else(|| "-".into()),
                format!("{:.2}", r.band0 * 100.0),
                format!("{:.2}", r.band1 * 100.0),
                format!("{:.2}", r.band2 * 100.0),
            ]
        })
        .collect();
    format!(
        "Table 1: accuracy and weight distribution of 8-bit quantized models\n{}",
        plot::table(&headers, &body)
    )
}

pub fn to_json(rows: &[Row]) -> Json {
    arr(rows.iter().map(|r| {
        obj(vec![
            ("model", s(&r.model)),
            ("n_weights", num(r.n_weights as f64)),
            ("float_acc", num(r.float_acc)),
            ("int8_acc", num(r.int8_acc)),
            (
                "int8_acc_rust",
                r.int8_acc_rust.map(num).unwrap_or(Json::Null),
            ),
            ("band_0_32", num(r.band0)),
            ("band_32_64", num(r.band1)),
            ("band_64_128", num(r.band2)),
        ])
    }))
}
