//! Table 2: accuracy drop under memory fault rates x protection
//! strategies (the paper's headline experiment).
//!
//! For every (model, strategy, fault-rate) cell we run `trials`
//! independent fault injections and report mean ± std of the accuracy
//! drop relative to the fault-free int8 model, plus the ECC-HW column
//! and the exact space overhead of the stored image.

use std::path::Path;
use std::sync::Arc;

use crate::ecc::strategy_by_name;
use crate::harness::eval::{cell_seed, EvalCtx};
use crate::memory::{FaultModel, MemoryBank};
use crate::model::EvalSet;
use crate::runtime::Runtime;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::plot;
use crate::util::stats;

pub const PAPER_RATES: [f64; 4] = [1e-6, 1e-5, 1e-4, 1e-3];
pub const PAPER_STRATEGIES: [&str; 4] = ["faulty", "zero", "ecc", "in-place"];
pub const PAPER_MODELS: [&str; 3] = ["vgg16_s", "resnet18_s", "squeezenet_s"];

#[derive(Clone, Debug)]
pub struct Cell {
    pub model: String,
    pub strategy: String,
    pub rate: f64,
    pub drops: Vec<f64>, // percentage points, one per trial
    pub corrected: u64,
    pub detected: u64,
}

#[derive(Clone, Debug)]
pub struct Table2 {
    pub cells: Vec<Cell>,
    pub base_acc: std::collections::BTreeMap<String, f64>,
    pub trials: usize,
}

pub struct Config {
    pub models: Vec<String>,
    pub strategies: Vec<String>,
    pub rates: Vec<f64>,
    pub trials: usize,
    pub batch: usize,
    pub fault_model: FaultModel,
    /// Shard/worker geometry of the per-trial protected store. Purely a
    /// decode-throughput knob: every setting produces bit-identical
    /// trial outputs (the shard-equivalence proptests pin this down).
    pub shards: usize,
    pub decode_workers: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            models: PAPER_MODELS.iter().map(|s| s.to_string()).collect(),
            strategies: PAPER_STRATEGIES.iter().map(|s| s.to_string()).collect(),
            rates: PAPER_RATES.to_vec(),
            trials: 10,
            batch: 256,
            fault_model: FaultModel::Uniform,
            shards: 8,
            decode_workers: 4,
        }
    }
}

pub fn run(artifacts: &Path, cfg: &Config, verbose: bool) -> anyhow::Result<Table2> {
    let rt = Runtime::cpu()?;
    let ds = Arc::new(EvalSet::load(&artifacts.join("dataset.eval.bin"))?);
    let mut cells = Vec::new();
    let mut base_acc = std::collections::BTreeMap::new();
    for model in &cfg.models {
        let mut ctx = EvalCtx::load(artifacts, model, cfg.batch, rt.clone(), ds.clone())?;
        ctx.shards = cfg.shards;
        ctx.decode_workers = cfg.decode_workers;
        base_acc.insert(model.clone(), ctx.base_acc);
        if verbose {
            eprintln!("[{model}] fault-free int8 acc = {:.4}", ctx.base_acc);
        }
        for strategy in &cfg.strategies {
            for &rate in &cfg.rates {
                let mut cell = Cell {
                    model: model.clone(),
                    strategy: strategy.clone(),
                    rate,
                    drops: Vec::with_capacity(cfg.trials),
                    corrected: 0,
                    detected: 0,
                };
                for t in 0..cfg.trials {
                    let seed = cell_seed(model, strategy, rate, t as u64);
                    let (acc, corr, det) =
                        ctx.faulty_trial(strategy, cfg.fault_model, rate, seed)?;
                    cell.drops.push((ctx.base_acc - acc) * 100.0);
                    cell.corrected += corr;
                    cell.detected += det;
                }
                if verbose {
                    eprintln!(
                        "[{model}] {strategy:>8} rate={rate:>7.0e} drop={}",
                        stats::mean_std_str(&cell.drops)
                    );
                }
                cells.push(cell);
            }
        }
    }
    Ok(Table2 {
        cells,
        base_acc,
        trials: cfg.trials,
    })
}

impl Table2 {
    /// Render the paper-shaped table.
    pub fn render(&self, cfg: &Config) -> String {
        let mut rows = Vec::new();
        for model in &cfg.models {
            for strategy in &cfg.strategies {
                let strat = strategy_by_name(strategy).unwrap();
                // measured overhead straight from a real encode
                let image = MemoryBank::new(
                    strategy_by_name(strategy).unwrap(),
                    &vec![0i8; 64],
                )
                .unwrap();
                let mut row = vec![
                    model.clone(),
                    strategy.clone(),
                    if strat.ecc_hw() { "Y" } else { "N" }.to_string(),
                    format!("{:.1}", image.overhead() * 100.0),
                ];
                for &rate in &cfg.rates {
                    let cell = self
                        .cells
                        .iter()
                        .find(|c| {
                            &c.model == model && &c.strategy == strategy && c.rate == rate
                        })
                        .unwrap();
                    row.push(stats::mean_std_str(&cell.drops));
                }
                rows.push(row);
            }
        }
        let mut headers = vec!["Model", "Strategy", "ECC HW", "Overhead %"];
        let rate_hdrs: Vec<String> = cfg.rates.iter().map(|r| format!("{r:.0e}")).collect();
        headers.extend(rate_hdrs.iter().map(|s| s.as_str()));
        format!(
            "Table 2: accuracy drop (%) under memory fault rates ({} trials)\n{}",
            self.trials,
            plot::table(&headers, &rows)
        )
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("trials", num(self.trials as f64)),
            (
                "base_acc",
                Json::Obj(
                    self.base_acc
                        .iter()
                        .map(|(k, v)| (k.clone(), num(*v)))
                        .collect(),
                ),
            ),
            (
                "cells",
                arr(self.cells.iter().map(|c| {
                    obj(vec![
                        ("model", s(&c.model)),
                        ("strategy", s(&c.strategy)),
                        ("rate", num(c.rate)),
                        ("drop_mean", num(stats::mean(&c.drops))),
                        ("drop_std", num(stats::std(&c.drops))),
                        ("drops", arr(c.drops.iter().map(|d| num(*d)))),
                        ("corrected", num(c.corrected as f64)),
                        ("detected", num(c.detected as f64)),
                    ])
                })),
            ),
        ])
    }

    /// The paper's qualitative claims, as machine-checkable predicates —
    /// used by the integration test and printed after the table.
    pub fn shape_checks(&self, cfg: &Config) -> Vec<(String, bool)> {
        let mean_drop = |m: &str, st: &str, r: f64| -> f64 {
            self.cells
                .iter()
                .find(|c| c.model == m && c.strategy == st && c.rate == r)
                .map(|c| stats::mean(&c.drops))
                .unwrap_or(f64::NAN)
        };
        let mut checks = Vec::new();
        let hi = *cfg
            .rates
            .last()
            .unwrap_or(&1e-3);
        for m in &cfg.models {
            // 1. at the highest rate protection helps: faulty >> ecc
            checks.push((
                format!("{m}: faulty drop > ecc drop at {hi:.0e}"),
                mean_drop(m, "faulty", hi) > mean_drop(m, "ecc", hi),
            ));
            // 2. in-place ≈ ecc at every rate (within 2 percentage points
            //    or both tiny) — the headline equivalence
            let mut ok = true;
            for &r in &cfg.rates {
                let a = mean_drop(m, "in-place", r);
                let b = mean_drop(m, "ecc", r);
                if (a - b).abs() > 2.0 && a.max(b) > 0.5 {
                    ok = false;
                }
            }
            checks.push((format!("{m}: in-place ≈ ecc at all rates"), ok));
            // 3. zero is between faulty and ecc at the highest rate
            checks.push((
                format!("{m}: ecc <= zero <= faulty ordering at {hi:.0e}"),
                mean_drop(m, "ecc", hi) <= mean_drop(m, "zero", hi) + 0.5
                    && mean_drop(m, "zero", hi) <= mean_drop(m, "faulty", hi) + 0.5,
            ));
        }
        checks
    }
}
