//! Table 2: accuracy drop under memory fault rates x protection
//! strategies (the paper's headline experiment).
//!
//! Since the campaign engine landed, this module is a thin consumer of
//! [`harness::campaign`](crate::harness::campaign): it builds a
//! fixed-trial-count campaign over the paper grid (one fault model),
//! runs it through the PJRT-backed [`campaign::EvalRunner`], and
//! reshapes the report into the paper's table. For every (model,
//! strategy, fault-rate) cell the campaign runs `trials` independent
//! fault injections; we report mean ± std of the accuracy drop
//! relative to the fault-free int8 model, plus the ECC-HW column and
//! the exact space overhead of the stored image.

use std::path::Path;

use crate::ecc::strategy_by_name;
use crate::harness::campaign::{self, TrialPolicy};
use crate::memory::{FaultModel, MemoryBank};
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::plot;
use crate::util::stats;

pub const PAPER_RATES: [f64; 4] = [1e-6, 1e-5, 1e-4, 1e-3];
pub const PAPER_STRATEGIES: [&str; 4] = ["faulty", "zero", "ecc", "in-place"];
pub const PAPER_MODELS: [&str; 3] = ["vgg16_s", "resnet18_s", "squeezenet_s"];

#[derive(Clone, Debug)]
pub struct Cell {
    pub model: String,
    pub strategy: String,
    pub rate: f64,
    pub drops: Vec<f64>, // percentage points, one per trial
    pub corrected: u64,
    pub detected: u64,
}

#[derive(Clone, Debug)]
pub struct Table2 {
    pub cells: Vec<Cell>,
    pub base_acc: std::collections::BTreeMap<String, f64>,
    pub trials: usize,
}

pub struct Config {
    pub models: Vec<String>,
    pub strategies: Vec<String>,
    pub rates: Vec<f64>,
    pub trials: usize,
    pub batch: usize,
    pub fault_model: FaultModel,
    /// Shard/worker geometry of the per-trial protected store. Purely a
    /// decode-throughput knob: every setting produces bit-identical
    /// trial outputs (the shard-equivalence proptests pin this down).
    pub shards: usize,
    pub decode_workers: usize,
    /// Parallel campaign cell workers. Each model's PJRT context is
    /// mutex-serialized, so values > 1 pay off across models.
    pub jobs: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            models: PAPER_MODELS.iter().map(|s| s.to_string()).collect(),
            strategies: PAPER_STRATEGIES.iter().map(|s| s.to_string()).collect(),
            rates: PAPER_RATES.to_vec(),
            trials: 10,
            batch: 256,
            fault_model: FaultModel::Uniform,
            shards: 8,
            decode_workers: 4,
            jobs: 1,
        }
    }
}

pub fn run(artifacts: &Path, cfg: &Config, verbose: bool) -> anyhow::Result<Table2> {
    let runner = campaign::EvalRunner::load(
        artifacts,
        &cfg.models,
        cfg.batch,
        cfg.shards,
        cfg.decode_workers,
    )?;
    if verbose {
        for (model, acc) in runner.base_acc() {
            eprintln!("[{model}] fault-free int8 acc = {acc:.4}");
        }
    }
    let ccfg = campaign::Config {
        models: cfg.models.clone(),
        strategies: cfg.strategies.clone(),
        rates: cfg.rates.clone(),
        fault_models: vec![cfg.fault_model],
        sites: vec![crate::memory::FaultSite::Weights],
        guards: vec![crate::runtime::GuardMode::Off],
        policy: TrialPolicy::fixed(cfg.trials),
        jobs: cfg.jobs,
        ledger: None,
        resume: false,
        stop_after: None,
        runner_tag: format!("pjrt:batch{}", cfg.batch),
        verbose,
    };
    let report = campaign::run(&ccfg, &runner)?;
    let cells = report
        .cells
        .iter()
        .map(|c| Cell {
            model: c.spec.model.clone(),
            strategy: c.spec.strategy.clone(),
            rate: c.spec.rate,
            drops: c.drops.clone(),
            corrected: c.corrected,
            detected: c.detected,
        })
        .collect();
    Ok(Table2 {
        cells,
        base_acc: runner.base_acc().clone(),
        trials: cfg.trials,
    })
}

impl Table2 {
    /// Render the paper-shaped table.
    pub fn render(&self, cfg: &Config) -> String {
        // Static per-strategy columns (ECC-HW flag, measured overhead of
        // a real encode) computed once per strategy, not once per row.
        let mut strat_cols = std::collections::BTreeMap::new();
        for strategy in &cfg.strategies {
            let strat = strategy_by_name(strategy).unwrap();
            let ecc_hw = if strat.ecc_hw() { "Y" } else { "N" }.to_string();
            let image = MemoryBank::new(strat, &[0i8; 64]).unwrap();
            strat_cols.insert(
                strategy.clone(),
                (ecc_hw, format!("{:.1}", image.overhead() * 100.0)),
            );
        }
        let mut rows = Vec::new();
        for model in &cfg.models {
            for strategy in &cfg.strategies {
                let (ecc_hw, overhead) = &strat_cols[strategy];
                let mut row = vec![
                    model.clone(),
                    strategy.clone(),
                    ecc_hw.clone(),
                    overhead.clone(),
                ];
                for &rate in &cfg.rates {
                    let cell = self
                        .cells
                        .iter()
                        .find(|c| {
                            &c.model == model && &c.strategy == strategy && c.rate == rate
                        })
                        .unwrap();
                    row.push(stats::mean_std_str(&cell.drops));
                }
                rows.push(row);
            }
        }
        let mut headers = vec!["Model", "Strategy", "ECC HW", "Overhead %"];
        let rate_hdrs: Vec<String> = cfg.rates.iter().map(|r| format!("{r:.0e}")).collect();
        headers.extend(rate_hdrs.iter().map(|s| s.as_str()));
        format!(
            "Table 2: accuracy drop (%) under memory fault rates ({} trials)\n{}",
            self.trials,
            plot::table(&headers, &rows)
        )
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("trials", num(self.trials as f64)),
            (
                "base_acc",
                Json::Obj(
                    self.base_acc
                        .iter()
                        .map(|(k, v)| (k.clone(), num(*v)))
                        .collect(),
                ),
            ),
            (
                "cells",
                arr(self.cells.iter().map(|c| {
                    obj(vec![
                        ("model", s(&c.model)),
                        ("strategy", s(&c.strategy)),
                        ("rate", num(c.rate)),
                        ("drop_mean", num(stats::mean(&c.drops))),
                        ("drop_std", num(stats::std(&c.drops))),
                        ("drops", arr(c.drops.iter().map(|d| num(*d)))),
                        ("corrected", num(c.corrected as f64)),
                        ("detected", num(c.detected as f64)),
                    ])
                })),
            ),
        ])
    }

    /// The paper's qualitative claims, as machine-checkable predicates —
    /// used by the integration test and printed after the table.
    pub fn shape_checks(&self, cfg: &Config) -> Vec<(String, bool)> {
        let mean_drop = |m: &str, st: &str, r: f64| -> f64 {
            self.cells
                .iter()
                .find(|c| c.model == m && c.strategy == st && c.rate == r)
                .map(|c| stats::mean(&c.drops))
                .unwrap_or(f64::NAN)
        };
        let mut checks = Vec::new();
        let hi = *cfg
            .rates
            .last()
            .unwrap_or(&1e-3);
        for m in &cfg.models {
            // 1. at the highest rate protection helps: faulty >> ecc
            checks.push((
                format!("{m}: faulty drop > ecc drop at {hi:.0e}"),
                mean_drop(m, "faulty", hi) > mean_drop(m, "ecc", hi),
            ));
            // 2. in-place ≈ ecc at every rate (within 2 percentage points
            //    or both tiny) — the headline equivalence
            let mut ok = true;
            for &r in &cfg.rates {
                let a = mean_drop(m, "in-place", r);
                let b = mean_drop(m, "ecc", r);
                if (a - b).abs() > 2.0 && a.max(b) > 0.5 {
                    ok = false;
                }
            }
            checks.push((format!("{m}: in-place ≈ ecc at all rates"), ok));
            // 3. zero is between faulty and ecc at the highest rate
            checks.push((
                format!("{m}: ecc <= zero <= faulty ordering at {hi:.0e}"),
                mean_drop(m, "ecc", hi) <= mean_drop(m, "zero", hi) + 0.5
                    && mean_drop(m, "zero", hi) <= mean_drop(m, "faulty", hi) + 0.5,
            ));
        }
        checks
    }
}
