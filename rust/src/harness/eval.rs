//! Shared evaluation machinery: one loaded (model, executable, dataset)
//! context on which protected-memory accuracy experiments run.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use crate::ecc::strategy_by_name;
use crate::memory::{FaultInjector, FaultModel, ShardedBank};
use crate::model::{load_weights, EvalSet, Manifest, RecoverySet};
use crate::quant::dequantize_into;
use crate::runtime::guard::{Calibration, DenseModel, Envelope, GuardMode, LayerEnvelope};
use crate::runtime::{accuracy, Executable, Runtime};
use crate::util::rng::Rng;

/// Stable per-cell seed so every trial is reproducible and independent
/// across (model, strategy, rate, trial). Kept for the examples and
/// ad-hoc drivers; campaign cells seed trials from their own cell key
/// — fault model included — via
/// [`campaign::trial_seed`](crate::harness::campaign::trial_seed), so
/// the two sequences are unrelated.
pub fn cell_seed(model: &str, strategy: &str, rate: f64, trial: u64) -> u64 {
    // FNV-1a over the cell key.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in model
        .bytes()
        .chain(strategy.bytes())
        .chain(format!("{rate:e}").bytes())
    {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ trial.wrapping_mul(0x9E3779B97F4A7C15)
}

/// A loaded evaluation context for one model.
pub struct EvalCtx {
    pub man: Manifest,
    pub weights: Vec<i8>,
    pub rt: Arc<Runtime>,
    pub exe: Executable,
    pub ds: Arc<EvalSet>,
    /// Fault-free accuracy of the int8 (post-WOT) model, measured
    /// through the exact rust path; Table-2 drops subtract this.
    pub base_acc: f64,
    /// Shard/worker geometry of the per-trial protected store (decode
    /// output is identical for every setting; workers only add speed).
    /// Read when a strategy's bank is first built — change it before
    /// the first trial.
    pub shards: usize,
    pub decode_workers: usize,
    // scratch
    qbuf: Vec<i8>,
    fbuf: Vec<f32>,
    /// One reusable protected store per strategy: trials reset it
    /// copy-on-write (only fault-touched blocks are copied back) instead
    /// of re-encoding the whole weight image every trial.
    banks: BTreeMap<String, crate::memory::ShardedBank>,
}

impl EvalCtx {
    pub fn load(
        artifacts: &Path,
        model: &str,
        batch: usize,
        rt: Arc<Runtime>,
        ds: Arc<EvalSet>,
    ) -> anyhow::Result<EvalCtx> {
        let man = Manifest::load_model(artifacts, model)?;
        let weights = load_weights(&man.weights_path(), man.num_weights)?;
        let exe = rt.load_model(&man, batch)?;
        let mut ctx = EvalCtx {
            qbuf: vec![0i8; weights.len()],
            fbuf: vec![0f32; weights.len()],
            man,
            weights,
            rt,
            exe,
            ds,
            base_acc: 0.0,
            shards: 8,
            decode_workers: ShardedBank::auto_workers(),
            banks: BTreeMap::new(),
        };
        ctx.base_acc = ctx.accuracy_of(&ctx.weights.clone())?;
        Ok(ctx)
    }

    /// Accuracy of an arbitrary int8 weight buffer through PJRT.
    pub fn accuracy_of(&mut self, q: &[i8]) -> anyhow::Result<f64> {
        dequantize_into(q, &self.man.layers, &mut self.fbuf);
        let wbuf = self.rt.bind_weights(&self.fbuf)?;
        accuracy(&self.rt, &self.exe, &wbuf, &self.ds)
    }

    /// One Table-2 trial: inject `rate` faults into the (cached,
    /// pristine-reset) `strategy` bank, decode, measure accuracy.
    /// Returns (accuracy, corrected, detected). The bank is encoded
    /// once per strategy and reset copy-on-write between trials — a
    /// trial's cost is injection + decode, not a re-encode.
    pub fn faulty_trial(
        &mut self,
        strategy: &str,
        model: FaultModel,
        rate: f64,
        seed: u64,
    ) -> anyhow::Result<(f64, u64, u64)> {
        use std::collections::btree_map::Entry;
        let bank = match self.banks.entry(strategy.to_string()) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(e) => {
                let strat = strategy_by_name(strategy)?;
                e.insert(ShardedBank::new(strat, &self.weights, self.shards, self.decode_workers)?)
            }
        };
        let mut q = std::mem::take(&mut self.qbuf);
        bank.reset(); // copy-on-write: only the previous trial's faulted blocks
        bank.inject(model, rate, seed);
        let stats = bank.read(&mut q);
        let acc = self.accuracy_of(&q)?;
        self.qbuf = q;
        Ok((acc, stats.corrected, stats.detected))
    }

    /// Record the model's serve-time envelopes — the `input` plane over
    /// the whole eval set and the `logits` plane over the clean int8
    /// model's outputs — widened by `margin`. The result is what
    /// `zsecc calibrate` persists into the manifest's `guards` section.
    pub fn calibrate(&mut self, margin: f64) -> anyhow::Result<Calibration> {
        dequantize_into(&self.weights, &self.man.layers, &mut self.fbuf);
        let wbuf = self.rt.bind_weights(&self.fbuf)?;
        let mut input = Envelope::empty();
        for v in self.ds.batch(0, self.ds.n) {
            input.observe(*v);
        }
        let mut logits = Envelope::empty();
        let b = self.exe.batch;
        let mut batches = 0usize;
        let mut at = 0usize;
        // Whole batches only: the ragged tail would just re-observe
        // padded copies of images already in the envelope.
        while at + b <= self.ds.n {
            for v in self.exe.run(&self.rt, &wbuf, self.ds.batch(at, b))? {
                logits.observe(v);
            }
            at += b;
            batches += 1;
        }
        anyhow::ensure!(batches > 0, "eval set smaller than one batch; cannot calibrate");
        Ok(Calibration {
            margin,
            batches,
            layers: vec![
                LayerEnvelope {
                    name: "input".to_string(),
                    env: input.widen(margin),
                },
                LayerEnvelope {
                    name: "logits".to_string(),
                    env: logits.widen(margin),
                },
            ],
        })
    }

    /// Capture the recovery tier's calibration sidecar: per dense
    /// layer, the input plane and the checkpointed pre-ReLU output on
    /// clean weights — the `Y = X · W` equations
    /// [`recover_blocks`](crate::model::recover_blocks) inverts. Only a
    /// pure dense-chain manifest has those equations; a model with conv
    /// layers returns `None` and its recovery tier stays unarmed.
    pub fn calibrate_recovery(&mut self, batch: usize) -> anyhow::Result<Option<RecoverySet>> {
        let mut dims = Vec::with_capacity(self.man.layers.len());
        for l in &self.man.layers {
            match l.shape[..] {
                [r, c] => dims.push((r, c)),
                _ => return Ok(None),
            }
        }
        anyhow::ensure!(
            self.ds.dim == dims[0].0,
            "dataset dim {} does not feed the first dense layer ({} rows)",
            self.ds.dim,
            dims[0].0
        );
        let batch = batch.min(self.ds.n).max(1);
        dequantize_into(&self.weights, &self.man.layers, &mut self.fbuf);
        let model = DenseModel::from_flat(&self.fbuf, &dims)?;
        let names: Vec<String> = self.man.layers.iter().map(|l| l.name.clone()).collect();
        Ok(Some(RecoverySet::capture(
            &model,
            &names,
            self.ds.batch(0, batch),
            batch,
        )))
    }

    /// One activation-site trial through PJRT: transient single-bit
    /// strikes land in each image batch *after* it leaves the (assumed
    /// clean) store, and range supervision — when the guard mode asks
    /// for it — clamps the struck batch into the manifest's calibrated
    /// `input` envelope before execution. Returns (accuracy, clamped).
    ///
    /// ABFT modes are refused here: the executable is an opaque compiled
    /// graph, so the checksum relation cannot be carried through it for
    /// a general model — accumulator strikes and ABFT sweeps run on the
    /// software compute path (`campaign --synthetic`).
    pub fn activation_trial(
        &mut self,
        guard: GuardMode,
        rate: f64,
        seed: u64,
    ) -> anyhow::Result<(f64, u64)> {
        anyhow::ensure!(
            !guard.abft(),
            "guard mode '{}' needs ABFT, which cannot wrap the opaque PJRT \
             executable for model '{}'; run this cell with --synthetic",
            guard.tag(),
            self.man.model
        );
        let env = if guard.range() {
            let calib = self.man.guards.as_ref().ok_or_else(|| {
                anyhow::anyhow!(
                    "model '{}' has no calibrated envelopes; run `zsecc calibrate` first",
                    self.man.model
                )
            })?;
            Some(calib.input_envelope().ok_or_else(|| {
                anyhow::anyhow!("calibration for '{}' misses the 'input' envelope", self.man.model)
            })?)
        } else {
            None
        };
        dequantize_into(&self.weights, &self.man.layers, &mut self.fbuf);
        let wbuf = self.rt.bind_weights(&self.fbuf)?;
        let b = self.exe.batch;
        let dim = self.exe.input_dim;
        let bits = (b * dim * 32) as u64;
        let mut rng = Rng::new(seed);
        let mut staged = vec![0f32; b * dim];
        let mut clamped = 0u64;
        let mut correct = 0usize;
        let mut at = 0usize;
        while at < self.ds.n {
            let take = b.min(self.ds.n - at);
            staged[..take * dim].copy_from_slice(self.ds.batch(at, take));
            for i in take..b {
                staged[i * dim..(i + 1) * dim].copy_from_slice(self.ds.image(at));
            }
            for _ in 0..FaultInjector::flip_count(bits, rate) {
                let pos = rng.below(bits);
                let v = &mut staged[(pos / 32) as usize];
                *v = f32::from_bits(v.to_bits() ^ (1u32 << (pos % 32)));
            }
            if let Some(env) = &env {
                clamped += env.clamp_count(&mut staged);
            }
            let preds = self.exe.predict(&self.rt, &wbuf, &staged)?;
            for i in 0..take {
                if preds[i] == self.ds.labels[at + i] as usize {
                    correct += 1;
                }
            }
            at += take;
        }
        Ok((correct as f64 / self.ds.n as f64, clamped))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_seed_varies_per_axis() {
        let s0 = cell_seed("m", "ecc", 1e-4, 0);
        assert_ne!(s0, cell_seed("m", "ecc", 1e-4, 1));
        assert_ne!(s0, cell_seed("m", "ecc", 1e-3, 0));
        assert_ne!(s0, cell_seed("m", "zero", 1e-4, 0));
        assert_ne!(s0, cell_seed("n", "ecc", 1e-4, 0));
        assert_eq!(s0, cell_seed("m", "ecc", 1e-4, 0), "stable");
    }
}
