//! Figure 1: distribution of large weights (outside [-64, 63]) over the
//! byte positions of 8-byte blocks, computed on the *pre-WOT* buffers.
//! The paper's point: the distribution is close to uniform, so in-place
//! ECC cannot rely on large weights landing at a fixed position — which
//! is exactly what WOT then enforces.

use std::path::Path;

use crate::model::{load_weights, Manifest};
use crate::quant::large_position_histogram;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::plot;

#[derive(Clone, Debug)]
pub struct Fig1 {
    pub model: String,
    pub pre_wot: [u64; 8],
    pub post_wot: [u64; 8],
}

pub fn run(artifacts: &Path, models: &[String]) -> anyhow::Result<Vec<Fig1>> {
    let mut out = Vec::new();
    for model in models {
        let man = Manifest::load_model(artifacts, model)?;
        let pre = load_weights(&man.prewot_path(), man.num_weights)?;
        let post = load_weights(&man.weights_path(), man.num_weights)?;
        out.push(Fig1 {
            model: model.clone(),
            pre_wot: large_position_histogram(&pre),
            post_wot: large_position_histogram(&post),
        });
    }
    Ok(out)
}

pub fn render(figs: &[Fig1]) -> String {
    let mut out = String::new();
    for f in figs {
        let labels: Vec<String> = (0..8).map(|i| format!("byte {i}")).collect();
        out.push_str(&plot::bar_chart(
            &format!("Fig 1 ({}): large-weight positions, pre-WOT", f.model),
            &labels,
            &f.pre_wot.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            40,
        ));
        out.push_str(&plot::bar_chart(
            &format!("Fig 1 ({}): after WOT (positions 0..6 must be 0)", f.model),
            &labels,
            &f.post_wot.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            40,
        ));
        let viol: u64 = f.post_wot[..7].iter().sum();
        out.push_str(&format!(
            "   post-WOT violations in positions 0..6: {viol} (must be 0)\n\n"
        ));
    }
    out
}

/// Uniformity check: is each pre-WOT position within `tol` relative
/// deviation of the mean? (The paper's "close to uniform".)
pub fn is_roughly_uniform(h: &[u64; 8], tol: f64) -> bool {
    let mean = h.iter().sum::<u64>() as f64 / 8.0;
    if mean == 0.0 {
        return true;
    }
    h.iter()
        .all(|&v| ((v as f64) - mean).abs() / mean <= tol)
}

pub fn to_json(figs: &[Fig1]) -> Json {
    arr(figs.iter().map(|f| {
        obj(vec![
            ("model", s(&f.model)),
            ("pre_wot", arr(f.pre_wot.iter().map(|&v| num(v as f64)))),
            ("post_wot", arr(f.post_wot.iter().map(|&v| num(v as f64)))),
        ])
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniformity_check() {
        assert!(is_roughly_uniform(&[10, 11, 9, 10, 10, 12, 9, 10], 0.3));
        assert!(!is_roughly_uniform(&[0, 0, 0, 0, 0, 0, 0, 80], 0.3));
        assert!(is_roughly_uniform(&[0; 8], 0.3), "empty is fine");
    }
}
