//! Ablations beyond the paper's tables (DESIGN.md section 4, last rows):
//!
//! 1. **QATT vs ADMM** — the paper's section-4.1 comparison: ADMM fails
//!    to drive large values out of positions 0..6 and pays a lossy final
//!    clamp. Rendered from the build-time logs.
//! 2. **Code strength** (future-work, section 6): in-place SEC-DED vs
//!    the zero-space BCH-16 extension — fraction of 64/128-bit blocks
//!    with *unrecovered* weight damage vs fault rate, on synthetic
//!    constraint-satisfying buffers.
//! 3. **Burst faults** — multi-cell upsets break SEC-DED's single-error
//!    assumption; BCH-16 survives 2-bit bursts.
//! 4. **Scrub interval** — latent-error accumulation: k injection rounds
//!    with/without scrubbing between them.
//! 5. **Fault-model sweep** — the campaign engine driving every
//!    deterministic fault model (uniform / burst / row-burst / stuck-at
//!    / hotspot) across strategies on synthetic buffers, with adaptive
//!    (confidence-targeted) trial counts.

use std::path::Path;

use crate::ecc::{strategy_by_name, Protection};
use crate::harness::campaign::{self, SyntheticRunner, TrialPolicy};
use crate::harness::fig34::{load_log, WotLog};
use crate::memory::{FaultInjector, FaultModel};
use crate::util::plot;
use crate::util::rng::Rng;
use crate::util::stats;

// ---------------------------------------------------------- synthetic --

/// Synthetic weights satisfying the standard WOT constraint.
pub fn synth_wot(n: usize, seed: u64) -> Vec<i8> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            if i % 8 == 7 {
                (rng.below(256) as i64 - 128) as i8
            } else {
                (rng.below(128) as i64 - 64) as i8
            }
        })
        .collect()
}

/// Synthetic weights satisfying the *extended* constraint (BCH-16).
pub fn synth_ext(n: usize, seed: u64) -> Vec<i8> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            if i % 16 == 15 {
                (rng.below(256) as i64 - 128) as i8
            } else {
                (rng.below(64) as i64 - 32) as i8
            }
        })
        .collect()
}

/// Fraction of weights decoded wrong after injecting at `rate`.
pub fn weight_error_rate(
    strat: &dyn Protection,
    weights: &[i8],
    model: FaultModel,
    rate: f64,
    trials: usize,
    seed: u64,
) -> anyhow::Result<f64> {
    let clean = strat.encode(weights)?;
    let mut wrong = 0u64;
    let mut out = vec![0i8; weights.len()];
    for t in 0..trials {
        let mut enc = clean.clone();
        let mut inj = FaultInjector::new(model, seed ^ (t as u64).wrapping_mul(0x9E37));
        inj.inject(&mut enc, rate);
        strat.decode(&enc, &mut out);
        wrong += out
            .iter()
            .zip(weights)
            .filter(|(a, b)| a != b)
            .count() as u64;
    }
    Ok(wrong as f64 / (weights.len() * trials) as f64)
}

// ------------------------------------------------------------ reports --

pub fn render_admm_vs_qatt(artifacts: &Path) -> anyhow::Result<String> {
    let qatt: WotLog = load_log(&artifacts.join("squeezenet_s.wot_log.json"))?;
    let admm: WotLog = load_log(&artifacts.join("squeezenet_s.admm_log.json"))?;
    let mut out = String::from("== Ablation: QATT vs ADMM (squeezenet_s) ==\n");
    out.push_str(&format!(
        "{:<28} {:>14} {:>14}\n",
        "", "QATT (paper)", "ADMM (rejected)"
    ));
    out.push_str(&format!(
        "{:<28} {:>14} {:>14}\n",
        "violations at end (pre-clamp)",
        qatt.n_large.last().copied().unwrap_or(f64::NAN),
        admm.n_large.last().copied().unwrap_or(f64::NAN),
    ));
    out.push_str(&format!(
        "{:<28} {:>14.4} {:>14.4}\n",
        "final accuracy (post-clamp)", qatt.final_acc, admm.final_acc
    ));
    out.push_str(&format!(
        "{:<28} {:>14.4} {:>14}\n",
        "int8 baseline", qatt.int8_acc, ""
    ));
    out.push_str(
        "(paper section 4.1: ADMM 'cannot help reduce the number of large values';\n QATT recovers baseline accuracy while satisfying the constraint.)\n",
    );
    Ok(out)
}

pub struct CodeStrengthRow {
    pub rate: f64,
    pub inplace_err: f64,
    pub ecc_err: f64,
    pub bch_err: f64,
    pub faulty_err: f64,
}

pub fn code_strength(rates: &[f64], n: usize, trials: usize) -> anyhow::Result<Vec<CodeStrengthRow>> {
    let w8 = synth_wot(n, 42);
    let w16 = synth_ext(n, 42);
    let inplace = strategy_by_name("in-place")?;
    let ecc = strategy_by_name("ecc")?;
    let bch = strategy_by_name("bch16")?;
    let faulty = strategy_by_name("faulty")?;
    rates
        .iter()
        .map(|&rate| {
            Ok(CodeStrengthRow {
                rate,
                inplace_err: weight_error_rate(&*inplace, &w8, FaultModel::Uniform, rate, trials, 1)?,
                ecc_err: weight_error_rate(&*ecc, &w8, FaultModel::Uniform, rate, trials, 2)?,
                bch_err: weight_error_rate(&*bch, &w16, FaultModel::Uniform, rate, trials, 3)?,
                faulty_err: weight_error_rate(&*faulty, &w8, FaultModel::Uniform, rate, trials, 4)?,
            })
        })
        .collect()
}

pub fn render_code_strength(rows: &[CodeStrengthRow]) -> String {
    let headers = ["fault rate", "faulty", "in-place(SEC-DED)", "ecc(72,64)", "bch16(DEC)"];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{:.0e}", r.rate),
                format!("{:.3e}", r.faulty_err),
                format!("{:.3e}", r.inplace_err),
                format!("{:.3e}", r.ecc_err),
                format!("{:.3e}", r.bch_err),
            ]
        })
        .collect();
    format!(
        "== Ablation: weight error rate after decode (uniform flips) ==\n{}",
        plot::table(&headers, &body)
    )
}

pub struct BurstRow {
    pub len: u32,
    pub inplace_err: f64,
    pub bch_err: f64,
}

pub fn burst(rates_len: &[u32], rate: f64, n: usize, trials: usize) -> anyhow::Result<Vec<BurstRow>> {
    let w8 = synth_wot(n, 7);
    let w16 = synth_ext(n, 7);
    let inplace = strategy_by_name("in-place")?;
    let bch = strategy_by_name("bch16")?;
    rates_len
        .iter()
        .map(|&len| {
            let m = FaultModel::Burst { len };
            Ok(BurstRow {
                len,
                inplace_err: weight_error_rate(&*inplace, &w8, m, rate, trials, 11)?,
                bch_err: weight_error_rate(&*bch, &w16, m, rate, trials, 12)?,
            })
        })
        .collect()
}

pub fn render_burst(rows: &[BurstRow], rate: f64) -> String {
    let headers = ["burst len", "in-place(SEC-DED)", "bch16(DEC)"];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.len),
                format!("{:.3e}", r.inplace_err),
                format!("{:.3e}", r.bch_err),
            ]
        })
        .collect();
    format!(
        "== Ablation: burst faults at rate {rate:.0e} (multi-cell upsets) ==\n{}",
        plot::table(&headers, &body)
    )
}

pub struct ScrubRow {
    pub rounds: usize,
    pub with_scrub_err: f64,
    pub without_scrub_err: f64,
}

/// Inject `rounds` batches of faults; scrubbing between batches keeps
/// single errors from pairing up into uncorrectable doubles.
pub fn scrub_study(rounds_list: &[usize], rate: f64, n: usize) -> anyhow::Result<Vec<ScrubRow>> {
    let w = synth_wot(n, 99);
    let strat = strategy_by_name("in-place")?;
    let mut out_rows = Vec::new();
    for &rounds in rounds_list {
        let mut err = [0f64; 2]; // [with, without]
        for (mode, e) in err.iter_mut().enumerate() {
            let mut enc = strat.encode(&w)?;
            let mut inj = FaultInjector::new(FaultModel::Uniform, 1234 + rounds as u64);
            for _ in 0..rounds {
                inj.inject(&mut enc, rate);
                if mode == 0 {
                    strat.scrub(&mut enc);
                }
            }
            let mut out = vec![0i8; w.len()];
            strat.decode(&enc, &mut out);
            *e = out.iter().zip(&w).filter(|(a, b)| a != b).count() as f64 / w.len() as f64;
        }
        out_rows.push(ScrubRow {
            rounds,
            with_scrub_err: err[0],
            without_scrub_err: err[1],
        });
    }
    Ok(out_rows)
}

pub fn render_scrub(rows: &[ScrubRow], rate: f64) -> String {
    let headers = ["fault rounds", "scrub each round", "no scrub"];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.rounds),
                format!("{:.3e}", r.with_scrub_err),
                format!("{:.3e}", r.without_scrub_err),
            ]
        })
        .collect();
    format!(
        "== Ablation: scrubbing vs latent-error accumulation (rate {rate:.0e}/round) ==\n{}",
        plot::table(&headers, &body)
    )
}

/// Campaign-driven sweep: every fault model x every strategy at one
/// rate, on the synthetic corruption proxy, with adaptive trial counts
/// (stop once the 95% CI half-width on the mean corruption reaches
/// 0.05 pp, between 4 and 24 trials per cell).
pub fn fault_model_campaign(
    rate: f64,
    n_weights: usize,
    jobs: usize,
) -> anyhow::Result<campaign::Report> {
    let cfg = campaign::Config {
        models: vec!["synthetic".to_string()],
        strategies: ["faulty", "ecc", "in-place", "bch16"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        rates: vec![rate],
        fault_models: vec![
            FaultModel::Uniform,
            FaultModel::Burst { len: 4 },
            FaultModel::RowBurst {
                row_bits: 512,
                len: 4,
            },
            FaultModel::StuckAt { bit: 1 },
            FaultModel::Hotspot { frac: 0.05 },
        ],
        sites: vec![crate::memory::FaultSite::Weights],
        guards: vec![crate::runtime::GuardMode::Off],
        policy: TrialPolicy::adaptive(4, 24, 0.05, 0.95),
        jobs,
        ledger: None,
        resume: false,
        stop_after: None,
        runner_tag: format!("synthetic:n{n_weights}"),
        verbose: false,
    };
    campaign::run(&cfg, &SyntheticRunner::new(n_weights, 8, 2))
}

/// Pivot the campaign report: strategies down, fault models across,
/// "mean ± std (n=trials)" in each cell.
pub fn render_fault_models(report: &campaign::Report, rate: f64) -> String {
    let mut faults: Vec<String> = Vec::new();
    let mut strategies: Vec<String> = Vec::new();
    for c in &report.cells {
        let tag = c.spec.fault.tag();
        if !faults.contains(&tag) {
            faults.push(tag);
        }
        if !strategies.contains(&c.spec.strategy) {
            strategies.push(c.spec.strategy.clone());
        }
    }
    let mut headers = vec!["strategy"];
    headers.extend(faults.iter().map(|f| f.as_str()));
    let rows: Vec<Vec<String>> = strategies
        .iter()
        .map(|strategy| {
            let mut row = vec![strategy.clone()];
            for fault in &faults {
                let cell = report
                    .cells
                    .iter()
                    .find(|c| &c.spec.strategy == strategy && c.spec.fault.tag() == *fault);
                row.push(match cell {
                    Some(c) => format!("{} (n={})", stats::mean_std_str(&c.drops), c.trials()),
                    None => "-".to_string(),
                });
            }
            row
        })
        .collect();
    format!(
        "== Ablation: weight corruption (pp) by fault model at rate {rate:.0e} (adaptive trials) ==\n{}",
        plot::table(&headers, &rows)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bch_beats_secded_at_high_rate() {
        let rows = code_strength(&[3e-3], 64 * 128, 4).unwrap();
        let r = &rows[0];
        assert!(r.bch_err < r.inplace_err, "DEC must beat SEC at 3e-3");
        assert!(r.inplace_err < r.faulty_err, "SEC must beat no protection");
    }

    #[test]
    fn burst2_kills_secded_not_bch() {
        let rows = burst(&[2], 1e-3, 64 * 128, 4).unwrap();
        assert!(rows[0].bch_err < rows[0].inplace_err * 0.5 + 1e-9);
    }

    #[test]
    fn fault_model_campaign_covers_grid_within_bounds() {
        let report = fault_model_campaign(1e-3, 64 * 16, 2).unwrap();
        assert!(report.complete);
        assert_eq!(report.cells.len(), 4 * 5, "4 strategies x 5 fault models");
        for c in &report.cells {
            assert!(
                (4..=24).contains(&c.trials()),
                "{}: {} trials outside bounds",
                c.spec.key(),
                c.trials()
            );
            // adaptive stop means: either the target was met or the cell
            // exhausted its budget
            if c.trials() < 24 {
                assert!(c.half_width <= 0.05 + 1e-12, "{}", c.spec.key());
            }
        }
        // unprotected uniform damage must exceed SEC-DED-protected damage
        let faulty = report
            .cell("synthetic", "faulty", 1e-3, &FaultModel::Uniform)
            .unwrap();
        let inplace = report
            .cell("synthetic", "in-place", 1e-3, &FaultModel::Uniform)
            .unwrap();
        assert!(stats::mean(&faulty.drops) > stats::mean(&inplace.drops));
        // the render pivots without panicking and names every model
        let table = render_fault_models(&report, 1e-3);
        for tag in ["uniform", "burst:4", "rowburst:512:4", "stuckat:1", "hotspot:0.05"] {
            assert!(table.contains(tag), "missing column {tag}");
        }
    }

    #[test]
    fn scrubbing_reduces_accumulation() {
        let rows = scrub_study(&[8], 2e-4, 64 * 64).unwrap();
        assert!(
            rows[0].with_scrub_err <= rows[0].without_scrub_err,
            "with {} vs without {}",
            rows[0].with_scrub_err,
            rows[0].without_scrub_err
        );
    }
}
