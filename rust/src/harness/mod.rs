//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (see DESIGN.md section 4 for the experiment index).
//!
//! Each module exposes a `run(...) -> Report` used both by the `zsecc`
//! CLI subcommands and by the corresponding bench binaries; reports
//! print the paper-shaped rows and can be dumped as JSON.
//!
//! [`campaign`] is the shared engine under the fault-injection
//! experiments: a parallel Monte-Carlo campaign over (model × strategy
//! × rate × fault-model) cells with adaptive (confidence-targeted)
//! trial counts and a resumable checkpoint ledger. `table2` is a thin
//! consumer of it; `ablation` drives it over the expanded fault-model
//! set on synthetic buffers. [`scrubsim`] replays *time-varying*
//! scenarios (rate ramps, hotspot migration) against the adaptive
//! scrub scheduler at equal scrub bandwidth vs fixed-interval.
//! [`closedloop`] closes the loop end to end: a model served under a
//! live scheduler while a wear process drifts, scored per epoch by
//! real accuracy, swept into the accuracy-vs-scrub-joules frontier.

pub mod ablation;
pub mod campaign;
pub mod closedloop;
pub mod eval;
pub mod fig1;
pub mod fig34;
pub mod scrubsim;
pub mod table1;
pub mod table2;

pub use eval::EvalCtx;
