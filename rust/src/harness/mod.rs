//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (see DESIGN.md section 4 for the experiment index).
//!
//! Each module exposes a `run(...) -> Report` used both by the `zsecc`
//! CLI subcommands and by the corresponding bench binaries; reports
//! print the paper-shaped rows and can be dumped as JSON.

pub mod ablation;
pub mod eval;
pub mod fig1;
pub mod fig34;
pub mod table1;
pub mod table2;

pub use eval::EvalCtx;
