//! Parallel Monte-Carlo fault-injection campaign engine.
//!
//! A campaign is a grid of cells — (model × strategy × fault-rate ×
//! fault-model × fault-site × guard-mode) — evaluated by independent
//! fault-injection trials. The default axes (`weights` site, guards
//! `off`) reproduce the classic storage campaign bit-for-bit — ledger
//! keys, fingerprints and trial seeds are unchanged, so existing
//! ledgers resume. The compute sites (`activations`, `accumulators`)
//! strike transiently during inference and are answered by the
//! compute-path guards ([`crate::runtime::guard`]); their trial seeds
//! deliberately exclude the guard mode, so guards-on and guards-off
//! cells face *identical* fault sequences and the reported residuals
//! compare at exactly equal injected faults. The recovery axis
//! (`--recovery off|milr`) follows the same discipline: it escalates
//! detected-uncorrectable weight blocks to algebraic layer
//! reconstruction ([`crate::model::recovery`]) and is excluded from
//! trial seeds, so recovery-on and recovery-off cells replay identical
//! strikes.
//! Instead of a fixed trial count, each cell runs until the Student-t
//! confidence interval on its mean accuracy drop is tight enough
//! (`ci_target` half-width at `confidence`), bounded by
//! `[min_trials, max_trials]`; with no target set it runs exactly
//! `min_trials` trials (the classic Table-2 mode).
//!
//! Cells fan out over the same persistent worker pool the sharded
//! store uses ([`run_jobs`](crate::memory::run_jobs) — parked threads,
//! no per-cell spawn/join), and the first `min_trials` trials of each
//! cell fan out too (they run unconditionally, so parallelism cannot
//! change the stopping decision; only the adaptive tail is
//! sequential). Trials reuse per-strategy banks with copy-on-write
//! resets instead of re-encoding, so a trial's cost is injection +
//! decode. Each completed cell is checkpointed to a JSON ledger, so an
//! interrupted campaign resumed with the same configuration replays
//! nothing — and its final report is **byte-identical** to an
//! uninterrupted run: trial seeds derive only from the cell key and
//! trial index, early stopping depends only on the (deterministic)
//! drop sequence, and the canonical report excludes wall-clock.
//! `tests/campaign.rs` pins the identity down.
//!
//! Two [`TrialRunner`]s ship: [`EvalRunner`] executes real models
//! through PJRT (one `EvalCtx` per model, mutex-serialized), and
//! [`SyntheticRunner`] uses decoded-weight corruption on synthetic WOT
//! buffers as the drop proxy — artifact-free, which is what the CI
//! smoke campaign and the integration tests run.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

use crate::harness::eval::EvalCtx;
use crate::memory::{run_jobs, FaultInjector, FaultModel, FaultSite, ShardedBank};
use crate::model::{recover_blocks, DenseShape, EvalSet, RecoveryMode, RecoverySet};
use crate::runtime::guard::{
    residual_pp, ComputeFault, ComputeFaults, DenseModel, GuardMode, GuardReport,
};
use crate::runtime::Runtime;
use crate::util::json::{arr, num, num_or_null, obj, s, Json};
use crate::util::plot;
use crate::util::rng::Rng;
use crate::util::stats;

// ---------------------------------------------------------------- grid --

/// One grid cell: a (model, strategy, rate, fault-model, fault-site,
/// guard-mode, recovery-mode) combination. For compute sites the
/// strategy is inert (no storage decode happens) and the fault model is
/// always the uniform transient strike — fault-model geometry describes
/// stored images; keep `--fault-model uniform` for compute-site sweeps.
/// The recovery mode only changes weights-site trials: with `milr`,
/// detected-uncorrectable blocks are escalated to algebraic layer
/// reconstruction before the decoded buffer is scored.
#[derive(Clone, Debug, PartialEq)]
pub struct CellSpec {
    pub model: String,
    pub strategy: String,
    pub rate: f64,
    pub fault: FaultModel,
    pub site: FaultSite,
    pub guard: GuardMode,
    pub recovery: RecoveryMode,
}

impl CellSpec {
    /// Stable ledger key. Default axes (weights site, guards off,
    /// recovery off) keep the pre-site four-part key, so old ledgers
    /// resume unchanged.
    pub fn key(&self) -> String {
        let mut k = format!(
            "{}|{}|{:e}|{}",
            self.model,
            self.strategy,
            self.rate,
            self.fault.tag()
        );
        if self.site != FaultSite::Weights || self.guard != GuardMode::Off {
            k.push('|');
            k.push_str(self.site.tag());
            k.push('|');
            k.push_str(self.guard.tag());
        }
        if self.recovery != RecoveryMode::Off {
            k.push_str("|recovery=");
            k.push_str(self.recovery.tag());
        }
        k
    }

    /// The trial-seed domain: like [`CellSpec::key`] but guard- and
    /// recovery-blind, so answered and unanswered cells of the same
    /// site draw *identical* fault sequences — guard and recovery
    /// comparisons are at exactly equal injected faults.
    pub fn seed_key(&self) -> String {
        let mut k = format!(
            "{}|{}|{:e}|{}",
            self.model,
            self.strategy,
            self.rate,
            self.fault.tag()
        );
        if self.site != FaultSite::Weights {
            k.push('|');
            k.push_str(self.site.tag());
        }
        k
    }
}

/// Stable per-trial seed: FNV-1a over the cell's seed key, whitened by
/// the trial index. Depends on nothing else — the backbone of resume
/// identity, cross-cell independence and equal-faults guard
/// comparisons.
pub fn trial_seed(spec: &CellSpec, trial: u64) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in spec.seed_key().bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ trial.wrapping_mul(0x9E3779B97F4A7C15)
}

/// When a cell's trial loop stops.
#[derive(Clone, Copy, Debug)]
pub struct TrialPolicy {
    pub min_trials: usize,
    pub max_trials: usize,
    /// Target CI half-width on the mean drop (percentage points); with
    /// `None` every cell runs exactly `min_trials` trials.
    pub ci_target: Option<f64>,
    /// Confidence level of the interval (see `stats::t_critical`).
    pub confidence: f64,
}

impl TrialPolicy {
    /// The classic fixed-count mode (Table 2's 10 trials/cell).
    pub fn fixed(n: usize) -> TrialPolicy {
        TrialPolicy {
            min_trials: n.max(1),
            max_trials: n.max(1),
            ci_target: None,
            confidence: 0.95,
        }
    }

    /// Adaptive mode: stop once the half-width reaches `target`, never
    /// before `min` trials, never after `max`.
    pub fn adaptive(min: usize, max: usize, target: f64, confidence: f64) -> TrialPolicy {
        let min = min.max(1);
        TrialPolicy {
            min_trials: min,
            max_trials: max.max(min),
            ci_target: Some(target),
            confidence,
        }
    }
}

/// Campaign configuration: the grid, the stopping policy, and the
/// execution/checkpoint knobs.
pub struct Config {
    pub models: Vec<String>,
    pub strategies: Vec<String>,
    pub rates: Vec<f64>,
    pub fault_models: Vec<FaultModel>,
    /// Fault sites to sweep; `[Weights]` is the classic storage
    /// campaign (and keeps ledgers byte-compatible with pre-site runs).
    pub sites: Vec<FaultSite>,
    /// Guard modes to sweep; `[Off]` preserves classic behaviour.
    /// Guards only change compute-site trials — a weights-site cell
    /// runs the storage path regardless of guard mode.
    pub guards: Vec<GuardMode>,
    /// Recovery modes to sweep; `[Off]` preserves classic behaviour.
    /// Recovery only changes weights-site trials — it escalates
    /// detected-uncorrectable stored blocks, of which compute sites
    /// have none.
    pub recovery: Vec<RecoveryMode>,
    pub policy: TrialPolicy,
    /// Parallel cell workers (1 = serial in grid order).
    pub jobs: usize,
    /// Checkpoint ledger path; `None` disables checkpointing.
    pub ledger: Option<PathBuf>,
    /// Load completed cells from the ledger instead of re-running them.
    pub resume: bool,
    /// Stop after computing this many *new* cells — the interruption
    /// hook the resume tests and smoke runs use; the report is then
    /// marked incomplete.
    pub stop_after: Option<usize>,
    /// Names the trial runner (and its salient parameters); a ledger
    /// written under a different tag refuses to resume.
    pub runner_tag: String,
    /// Log per-cell completion lines to stderr.
    pub verbose: bool,
}

impl Config {
    /// The cell grid in canonical (reporting) order.
    pub fn grid(&self) -> Vec<CellSpec> {
        let mut cells = Vec::new();
        for model in &self.models {
            for strategy in &self.strategies {
                for &rate in &self.rates {
                    for &fault in &self.fault_models {
                        for &site in &self.sites {
                            for &guard in &self.guards {
                                for &recovery in &self.recovery {
                                    cells.push(CellSpec {
                                        model: model.clone(),
                                        strategy: strategy.clone(),
                                        rate,
                                        fault,
                                        site,
                                        guard,
                                        recovery,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        cells
    }

    /// Everything that must match for a ledger to be resumable into
    /// this campaign. Execution knobs (jobs, stop_after, verbose,
    /// ledger path) deliberately excluded: they cannot change results.
    fn fingerprint(&self) -> String {
        let rates: Vec<String> = self.rates.iter().map(|r| format!("{r:e}")).collect();
        let faults: Vec<String> = self.fault_models.iter().map(|f| f.tag()).collect();
        let mut fp = format!(
            "v1|runner={}|models={}|strategies={}|rates={}|faults={}|min={}|max={}|ci={:?}|conf={}",
            self.runner_tag,
            self.models.join(","),
            self.strategies.join(","),
            rates.join(","),
            faults.join(","),
            self.policy.min_trials,
            self.policy.max_trials,
            self.policy.ci_target,
            self.policy.confidence,
        );
        // Default axes stay out of the fingerprint so pre-site ledgers
        // remain resumable; any non-default sweep is identity-bearing.
        if self.sites != [FaultSite::Weights] || self.guards != [GuardMode::Off] {
            let sites: Vec<&str> = self.sites.iter().map(|s| s.tag()).collect();
            let guards: Vec<&str> = self.guards.iter().map(|g| g.tag()).collect();
            fp.push_str(&format!(
                "|sites={}|guards={}",
                sites.join(","),
                guards.join(",")
            ));
        }
        if self.recovery != [RecoveryMode::Off] {
            let modes: Vec<&str> = self.recovery.iter().map(|r| r.tag()).collect();
            fp.push_str(&format!("|recovery={}", modes.join(",")));
        }
        fp
    }
}

// -------------------------------------------------------------- runner --

/// One trial's measurements.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrialOutcome {
    /// Degradation vs the fault-free baseline, percentage points:
    /// accuracy drop for weights-site trials, magnitude-weighted output
    /// residual ([`residual_pp`]) for compute-site trials — the latter
    /// so clamping a corrupted activation *reduces* the metric even
    /// when the prediction flips either way.
    pub drop_pp: f64,
    pub corrected: u64,
    pub detected: u64,
    /// Out-of-envelope activations clamped by the range guard.
    pub clamped: u64,
    /// Detected-uncorrectable blocks reconstructed by the recovery tier
    /// (always 0 with recovery off).
    pub recovered: u64,
    /// Detected-uncorrectable blocks the recovery tier had to
    /// quarantine (underdetermined, singular, or failed verification).
    pub unrecovered: u64,
}

/// Runs one fault-injection trial of a cell. Implementations must be
/// deterministic in `(spec, seed)` — resume identity depends on it —
/// and `Sync`: trials of different cells run concurrently, and so do
/// the first `min_trials` trials *within* a cell (they run
/// unconditionally, so parallelism cannot change a stopping decision).
pub trait TrialRunner: Sync {
    fn run_trial(&self, spec: &CellSpec, trial: u64, seed: u64) -> anyhow::Result<TrialOutcome>;
}

/// PJRT-backed runner: one loaded [`EvalCtx`] per model. Each context
/// is mutex-serialized (PJRT execution stays on one thread at a time),
/// so campaign parallelism pays off across models; the injection/decode
/// half of a trial is already parallel inside `ShardedBank`.
pub struct EvalRunner {
    ctxs: BTreeMap<String, Mutex<EvalCtx>>,
    base_acc: BTreeMap<String, f64>,
}

impl EvalRunner {
    pub fn load(
        artifacts: &Path,
        models: &[String],
        batch: usize,
        shards: usize,
        decode_workers: usize,
    ) -> anyhow::Result<EvalRunner> {
        let rt = Runtime::cpu()?;
        let ds = Arc::new(EvalSet::load(&artifacts.join("dataset.eval.bin"))?);
        let mut ctxs = BTreeMap::new();
        let mut base_acc = BTreeMap::new();
        for model in models {
            let mut ctx = EvalCtx::load(artifacts, model, batch, rt.clone(), ds.clone())?;
            ctx.shards = shards;
            ctx.decode_workers = decode_workers;
            base_acc.insert(model.clone(), ctx.base_acc);
            ctxs.insert(model.clone(), Mutex::new(ctx));
        }
        Ok(EvalRunner { ctxs, base_acc })
    }

    /// Fault-free int8 accuracy per loaded model.
    pub fn base_acc(&self) -> &BTreeMap<String, f64> {
        &self.base_acc
    }
}

impl TrialRunner for EvalRunner {
    fn run_trial(&self, spec: &CellSpec, _trial: u64, seed: u64) -> anyhow::Result<TrialOutcome> {
        let ctx = self
            .ctxs
            .get(&spec.model)
            .ok_or_else(|| anyhow::anyhow!("model '{}' not loaded in this campaign", spec.model))?;
        if spec.recovery != RecoveryMode::Off {
            anyhow::bail!(
                "recovery mode '{}' needs the synthetic runner's captured calibration \
                 set; sweep --recovery with --synthetic",
                spec.recovery.tag()
            );
        }
        let mut ctx = ctx.lock().unwrap();
        let base = ctx.base_acc;
        match spec.site {
            FaultSite::Weights => {
                let (acc, corrected, detected) =
                    ctx.faulty_trial(&spec.strategy, spec.fault, spec.rate, seed)?;
                Ok(TrialOutcome {
                    drop_pp: (base - acc) * 100.0,
                    corrected,
                    detected,
                    ..TrialOutcome::default()
                })
            }
            FaultSite::Activations => {
                let (acc, clamped) = ctx.activation_trial(spec.guard, spec.rate, seed)?;
                Ok(TrialOutcome {
                    drop_pp: (base - acc) * 100.0,
                    clamped,
                    ..TrialOutcome::default()
                })
            }
            FaultSite::Accumulators => anyhow::bail!(
                "fault site 'accumulators' strikes inside the opaque PJRT executable; \
                 sweep it with the software compute path (--synthetic)"
            ),
        }
    }
}

/// Artifact-free runner for tests, CI smoke campaigns and ablations:
/// the "accuracy drop" proxy is the percentage of weights decoded
/// wrong from a [`ShardedBank`] after injection. Deterministic per
/// seed, no PJRT, no artifacts. The two synthetic weight buffers (WOT
/// for the paper strategies, extended-WOT for `bch16`) are generated
/// once and shared across all trials, and the protected banks are
/// recycled through a per-strategy freelist: a released bank has been
/// copy-on-write reset to pristine, so a steady-state trial costs
/// injection + decode — never a re-encode, never a full image copy.
pub struct SyntheticRunner {
    n_weights: usize,
    shards: usize,
    workers: usize,
    wot: OnceLock<Vec<i8>>,
    ext: OnceLock<Vec<i8>>,
    /// Reset banks awaiting reuse, keyed by strategy; depth tracks peak
    /// same-strategy trial concurrency.
    banks: Mutex<BTreeMap<String, Vec<ShardedBank>>>,
    /// Lazily-built software compute path for the activation and
    /// accumulator fault sites: a dense head over the dequantized
    /// synthetic WOT weights, one fixed calibrated input batch, and its
    /// clean logits.
    compute: OnceLock<SynthCompute>,
    /// Lazily-captured recovery calibration (X plane + checkpointed
    /// pre-activation Y) over the same dense head geometry, plus the
    /// solver's shape table — what `--recovery milr` cells escalate to.
    recovery_calib: OnceLock<(RecoverySet, Vec<DenseShape>)>,
}

struct SynthCompute {
    model: DenseModel,
    x: Vec<f32>,
    batch: usize,
    clean: Vec<f32>,
}

impl SyntheticRunner {
    /// `n_weights` should be a multiple of 16 so `bch16` cells work too.
    pub fn new(n_weights: usize, shards: usize, workers: usize) -> SyntheticRunner {
        SyntheticRunner {
            n_weights,
            shards,
            workers,
            wot: OnceLock::new(),
            ext: OnceLock::new(),
            banks: Mutex::new(BTreeMap::new()),
            compute: OnceLock::new(),
            recovery_calib: OnceLock::new(),
        }
    }

    /// Columns of the synthetic dense head.
    const CLASSES: usize = 16;
    /// Rows of the fixed input batch the compute-site trials strike.
    const BATCH: usize = 32;

    /// The shared compute path: a single dense layer shaped
    /// `[n_weights/16 x 16]` over the dequantized synthetic WOT image,
    /// calibrated on (and evaluated against) one deterministic batch.
    fn compute_path(&self) -> anyhow::Result<&SynthCompute> {
        anyhow::ensure!(
            self.n_weights >= Self::CLASSES && self.n_weights % Self::CLASSES == 0,
            "compute-site cells need n_weights to be a multiple of {} (got {})",
            Self::CLASSES,
            self.n_weights
        );
        let q = self
            .wot
            .get_or_init(|| crate::harness::ablation::synth_wot(self.n_weights, 42));
        Ok(self.compute.get_or_init(|| {
            let dim = self.n_weights / Self::CLASSES;
            // The same dequantization scale the int8 pipeline uses for
            // small synthetic heads; exact value only shifts magnitudes.
            let w: Vec<f32> = q.iter().map(|&v| v as f32 * 0.02).collect();
            let mut model = DenseModel::from_flat(&w, &[(dim, Self::CLASSES)])
                .expect("synthetic dense head has a valid shape by construction");
            let mut rng = Rng::new(4242);
            let x: Vec<f32> = (0..Self::BATCH * dim).map(|_| rng.f64() as f32).collect();
            model.calibrate(&x, Self::BATCH, 0.05);
            let clean = model.forward(&x, Self::BATCH);
            SynthCompute {
                model,
                x,
                batch: Self::BATCH,
                clean,
            }
        }))
    }

    /// The recovery tier's calibration set: the same `[n_weights/16 x
    /// 16]` dense head over the synthetic WOT image, with the input
    /// plane and checkpointed pre-ReLU outputs captured on clean
    /// weights — exactly what the extended `zsecc calibrate` persists
    /// as a `.recovery.json` sidecar for real models.
    fn recovery_path(&self) -> anyhow::Result<&(RecoverySet, Vec<DenseShape>)> {
        anyhow::ensure!(
            self.n_weights >= Self::CLASSES && self.n_weights % Self::CLASSES == 0,
            "recovery cells need n_weights to be a multiple of {} (got {})",
            Self::CLASSES,
            self.n_weights
        );
        let q = self
            .wot
            .get_or_init(|| crate::harness::ablation::synth_wot(self.n_weights, 42));
        Ok(self.recovery_calib.get_or_init(|| {
            let dim = self.n_weights / Self::CLASSES;
            let scale = 0.02f32;
            let w: Vec<f32> = q.iter().map(|&v| v as f32 * scale).collect();
            let model = DenseModel::from_flat(&w, &[(dim, Self::CLASSES)])
                .expect("synthetic dense head has a valid shape by construction");
            // centered inputs keep the normal equations well-conditioned
            let mut rng = Rng::new(777);
            let x: Vec<f32> = (0..Self::BATCH * dim)
                .map(|_| (rng.f64() * 2.0 - 1.0) as f32)
                .collect();
            let set = RecoverySet::capture(&model, &["head".to_string()], &x, Self::BATCH);
            let shapes = vec![DenseShape {
                name: "head".into(),
                offset: 0,
                rows: dim,
                cols: Self::CLASSES,
                scale,
            }];
            (set, shapes)
        }))
    }
}

impl Default for SyntheticRunner {
    fn default() -> Self {
        SyntheticRunner::new(64 * 64, 8, 2)
    }
}

impl TrialRunner for SyntheticRunner {
    fn run_trial(&self, spec: &CellSpec, _trial: u64, seed: u64) -> anyhow::Result<TrialOutcome> {
        use crate::harness::ablation::{synth_ext, synth_wot};
        if spec.site != FaultSite::Weights {
            anyhow::ensure!(
                spec.recovery == RecoveryMode::Off,
                "recovery escalates stored-block corruption; compute sites have no \
                 stored blocks — keep --recovery off for compute-site sweeps"
            );
            return self.compute_trial(spec, seed);
        }
        anyhow::ensure!(
            spec.recovery == RecoveryMode::Off || spec.strategy != "bch16",
            "the recovery calibration covers the WOT image; bch16 cells use the \
             extended buffer — exclude bch16 from --recovery sweeps"
        );
        let w: &[i8] = if spec.strategy == "bch16" {
            self.ext.get_or_init(|| synth_ext(self.n_weights, 42))
        } else {
            self.wot.get_or_init(|| synth_wot(self.n_weights, 42))
        };
        // a recycled (pristine-reset) bank when one is free, else encode
        let recycled = {
            let mut banks = self.banks.lock().unwrap();
            banks.get_mut(&spec.strategy).and_then(|v| v.pop())
        };
        let mut bank = match recycled {
            Some(b) => b,
            None => ShardedBank::new(
                crate::ecc::strategy_by_name(&spec.strategy)?,
                w,
                self.shards,
                self.workers,
            )?,
        };
        bank.inject(spec.fault, spec.rate, seed);
        let mut out = crate::memory::pool::lease_i8(w.len());
        let (st, recovered, unrecovered) = if spec.recovery == RecoveryMode::Milr {
            let (calib, shapes) = self.recovery_path()?;
            let outc = bank.read_outcome(&mut out);
            let bb = bank.strategy().block_bytes();
            let (mut rec, mut unrec) = (0u64, 0u64);
            if !outc.detected_blocks.is_empty() {
                let ro = recover_blocks(
                    calib,
                    shapes,
                    &out,
                    &outc.detected_blocks,
                    bb,
                    bank.strategy().quant_grid(),
                );
                unrec = ro.quarantined.len() as u64;
                for rb in &ro.recovered {
                    // write back through the verified path, and patch
                    // the served buffer the trial scores
                    match bank.apply_recovery(rb.block, &rb.weights) {
                        Ok(()) => {
                            out[rb.block * bb..(rb.block + 1) * bb]
                                .copy_from_slice(&rb.weights);
                            rec += 1;
                        }
                        Err(_) => unrec += 1,
                    }
                }
            }
            (outc.stats, rec, unrec)
        } else {
            (bank.read(&mut out), 0, 0)
        };
        let wrong = out.iter().zip(w).filter(|(a, b)| a != b).count();
        bank.reset(); // copy-on-write: only fault-touched blocks copied back
        {
            let mut banks = self.banks.lock().unwrap();
            banks.entry(spec.strategy.clone()).or_default().push(bank);
        }
        Ok(TrialOutcome {
            drop_pp: 100.0 * wrong as f64 / w.len() as f64,
            corrected: st.corrected,
            detected: st.detected,
            recovered,
            unrecovered,
            ..TrialOutcome::default()
        })
    }
}

impl SyntheticRunner {
    /// One compute-site trial: draw `flip_count` transient single-bit
    /// strikes into the activation (or accumulator) buffer of the
    /// shared dense head, run it under the cell's guard mode, and score
    /// the magnitude-weighted residual against the cached clean logits.
    /// Seeds exclude the guard mode (see [`CellSpec::seed_key`]), so
    /// guards-on and guards-off cells face identical strikes.
    fn compute_trial(&self, spec: &CellSpec, seed: u64) -> anyhow::Result<TrialOutcome> {
        let sc = self.compute_path()?;
        let elems = match spec.site {
            FaultSite::Activations => sc.model.activation_elems(0, sc.batch),
            FaultSite::Accumulators => sc.model.accumulator_elems(0, sc.batch),
            FaultSite::Weights => unreachable!("weights site takes the storage path"),
        };
        let bits = (elems * 32) as u64;
        let mut rng = Rng::new(seed);
        let mut faults = ComputeFaults::default();
        let list = match spec.site {
            FaultSite::Activations => &mut faults.activations,
            _ => &mut faults.accumulators,
        };
        for _ in 0..FaultInjector::flip_count(bits, spec.rate) {
            let pos = rng.below(bits);
            list.push(ComputeFault {
                layer: 0,
                index: (pos / 32) as usize,
                bit: (pos % 32) as u32,
            });
        }
        let mut report = GuardReport::default();
        let y = sc
            .model
            .forward_guarded(&sc.x, sc.batch, spec.guard, &faults, &mut report);
        Ok(TrialOutcome {
            drop_pp: residual_pp(&y, &sc.clean),
            corrected: report.recomputes,
            detected: report.abft_trips,
            clamped: report.range_clamps,
            ..TrialOutcome::default()
        })
    }
}

// ------------------------------------------------------------- results --

/// One completed cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub spec: CellSpec,
    /// Accuracy drop per trial (percentage points).
    pub drops: Vec<f64>,
    pub corrected: u64,
    pub detected: u64,
    /// Range-guard clamps summed over the cell's trials (compute sites
    /// only; always 0 for weights-site cells).
    pub clamped: u64,
    /// Blocks reconstructed by the recovery tier, summed over trials
    /// (always 0 with recovery off).
    pub recovered: u64,
    /// Blocks the recovery tier quarantined, summed over trials.
    pub unrecovered: u64,
    /// CI half-width on the mean drop at the policy's confidence
    /// (infinite when a single trial cannot bound it).
    pub half_width: f64,
    /// Wall-clock of the cell's trial loop (excluded from canonical
    /// JSON — timing is not part of resume identity).
    pub wall_ms: f64,
}

impl CellResult {
    pub fn trials(&self) -> usize {
        self.drops.len()
    }

    fn to_json(&self, timing: bool) -> Json {
        let mut fields = vec![
            ("model", s(&self.spec.model)),
            ("strategy", s(&self.spec.strategy)),
            ("rate", num(self.spec.rate)),
            ("fault_model", s(&self.spec.fault.tag())),
            ("site", s(self.spec.site.tag())),
            ("guard", s(self.spec.guard.tag())),
            ("recovery", s(self.spec.recovery.tag())),
            ("trials", num(self.drops.len() as f64)),
            ("drop_mean", num(stats::mean(&self.drops))),
            ("drop_std", num(stats::std(&self.drops))),
            ("ci_half_width", num_or_null(self.half_width)),
            ("drops", arr(self.drops.iter().map(|d| num(*d)))),
            ("corrected", num(self.corrected as f64)),
            ("detected", num(self.detected as f64)),
            ("clamped", num(self.clamped as f64)),
            ("recovered", num(self.recovered as f64)),
            ("unrecovered", num(self.unrecovered as f64)),
        ];
        if timing {
            fields.push(("wall_ms", num(self.wall_ms)));
        }
        obj(fields)
    }

    fn from_json(v: &Json) -> anyhow::Result<CellResult> {
        let f = |k: &str| -> anyhow::Result<f64> {
            v.req(k)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("ledger cell field '{k}' must be a number"))
        };
        let st = |k: &str| -> anyhow::Result<String> {
            Ok(v.req(k)?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("ledger cell field '{k}' must be a string"))?
                .to_string())
        };
        let drops = v
            .req("drops")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("ledger cell field 'drops' must be an array"))?
            .iter()
            .map(|d| {
                d.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("ledger drop entries must be numbers"))
            })
            .collect::<anyhow::Result<Vec<f64>>>()?;
        let half_width = match v.req("ci_half_width")? {
            Json::Null => f64::INFINITY,
            other => other
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("'ci_half_width' must be a number or null"))?,
        };
        // Pre-site ledgers carry neither field: default to the classic
        // storage campaign axes they were written under.
        let site = match v.get("site").and_then(|x| x.as_str()) {
            Some(tag) => FaultSite::parse(tag)?,
            None => FaultSite::Weights,
        };
        let guard = match v.get("guard").and_then(|x| x.as_str()) {
            Some(tag) => GuardMode::parse(tag)?,
            None => GuardMode::Off,
        };
        let recovery = match v.get("recovery").and_then(|x| x.as_str()) {
            Some(tag) => RecoveryMode::parse(tag)?,
            None => RecoveryMode::Off,
        };
        Ok(CellResult {
            spec: CellSpec {
                model: st("model")?,
                strategy: st("strategy")?,
                rate: f("rate")?,
                fault: FaultModel::parse(&st("fault_model")?)?,
                site,
                guard,
                recovery,
            },
            drops,
            corrected: f("corrected")? as u64,
            detected: f("detected")? as u64,
            clamped: v.get("clamped").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64,
            recovered: v.get("recovered").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64,
            unrecovered: v.get("unrecovered").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64,
            half_width,
            wall_ms: v.get("wall_ms").and_then(|x| x.as_f64()).unwrap_or(0.0),
        })
    }
}

/// A finished (or interrupted) campaign, cells in canonical grid order.
#[derive(Clone, Debug)]
pub struct Report {
    pub cells: Vec<CellResult>,
    pub policy: TrialPolicy,
    /// False when the campaign stopped (`stop_after`) before every
    /// grid cell completed; resume to finish.
    pub complete: bool,
    pub wall_secs: f64,
}

impl Report {
    pub fn cell(
        &self,
        model: &str,
        strategy: &str,
        rate: f64,
        fault: &FaultModel,
    ) -> Option<&CellResult> {
        self.cells.iter().find(|c| {
            c.spec.model == model
                && c.spec.strategy == strategy
                && c.spec.rate == rate
                && c.spec.fault == *fault
        })
    }

    pub fn total_trials(&self) -> usize {
        self.cells.iter().map(|c| c.trials()).sum()
    }

    /// Canonical JSON: deterministic for a given (config, runner) —
    /// the resume-identity surface. Excludes all wall-clock fields.
    pub fn canonical_json(&self) -> Json {
        self.json_inner(false)
    }

    /// Full JSON including per-cell and total wall-clock.
    pub fn to_json(&self) -> Json {
        self.json_inner(true)
    }

    fn json_inner(&self, timing: bool) -> Json {
        let mut fields = vec![
            ("complete", Json::Bool(self.complete)),
            ("confidence", num(self.policy.confidence)),
            (
                "ci_target",
                num_or_null(self.policy.ci_target.unwrap_or(f64::INFINITY)),
            ),
            ("min_trials", num(self.policy.min_trials as f64)),
            ("max_trials", num(self.policy.max_trials as f64)),
            ("total_trials", num(self.total_trials() as f64)),
            ("cells", arr(self.cells.iter().map(|c| c.to_json(timing)))),
        ];
        if timing {
            fields.push(("wall_secs", num(self.wall_secs)));
        }
        obj(fields)
    }

    /// Paper-shaped summary table.
    pub fn render(&self) -> String {
        let headers = [
            "model",
            "strategy",
            "fault",
            "site",
            "guard",
            "recovery",
            "rate",
            "trials",
            "drop (pp)",
            "ci-hw",
            "corrected",
            "detected",
            "clamped",
            "recovered",
            "unrec",
        ];
        let rows: Vec<Vec<String>> = self
            .cells
            .iter()
            .map(|c| {
                vec![
                    c.spec.model.clone(),
                    c.spec.strategy.clone(),
                    c.spec.fault.tag(),
                    c.spec.site.tag().to_string(),
                    c.spec.guard.tag().to_string(),
                    c.spec.recovery.tag().to_string(),
                    format!("{:.0e}", c.spec.rate),
                    c.trials().to_string(),
                    stats::mean_std_str(&c.drops),
                    if c.half_width.is_finite() {
                        format!("{:.3}", c.half_width)
                    } else {
                        "n/a".to_string()
                    },
                    c.corrected.to_string(),
                    c.detected.to_string(),
                    c.clamped.to_string(),
                    c.recovered.to_string(),
                    c.unrecovered.to_string(),
                ]
            })
            .collect();
        format!(
            "Campaign: {} cells, {} trials, {:.1}s{}\n{}",
            self.cells.len(),
            self.total_trials(),
            self.wall_secs,
            if self.complete {
                ""
            } else {
                " (INCOMPLETE — rerun with --resume to finish)"
            },
            plot::table(&headers, &rows)
        )
    }
}

// -------------------------------------------------------------- ledger --

struct Ledger {
    fingerprint: String,
    cells: BTreeMap<String, CellResult>,
}

impl Ledger {
    fn load(path: &Path, fingerprint: &str) -> anyhow::Result<Ledger> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading ledger {}: {e}", path.display()))?;
        let v = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing ledger {}: {e}", path.display()))?;
        let fp = v
            .req("fingerprint")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("ledger 'fingerprint' must be a string"))?;
        anyhow::ensure!(
            fp == fingerprint,
            "ledger {} belongs to a different campaign (fingerprint mismatch:\n  ledger: {fp}\n  config: {fingerprint})",
            path.display()
        );
        let mut cells = BTreeMap::new();
        for (k, cv) in v
            .req("cells")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("ledger 'cells' must be an object"))?
        {
            cells.insert(k.clone(), CellResult::from_json(cv)?);
        }
        Ok(Ledger {
            fingerprint: fingerprint.to_string(),
            cells,
        })
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("fingerprint", s(&self.fingerprint)),
            (
                "cells",
                Json::Obj(
                    self.cells
                        .iter()
                        .map(|(k, c)| (k.clone(), c.to_json(true)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Write-to-temp + rename so an interruption mid-write never
    /// leaves a truncated ledger behind.
    fn save(&self, path: &Path) -> anyhow::Result<()> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json().to_string())
            .map_err(|e| anyhow::anyhow!("writing ledger {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| anyhow::anyhow!("publishing ledger {}: {e}", path.display()))?;
        Ok(())
    }
}

// -------------------------------------------------------------- engine --

/// Run one cell's trial loop until the policy says stop.
///
/// The first `min_trials` trials run unconditionally whatever the
/// stopping rule later decides, so they fan out over the worker pool
/// (`jobs` wide); the adaptive tail stays sequential because each
/// extra trial depends on the CI of its prefix. Results are collected
/// in trial order, so the drops sequence — and hence every stopping
/// decision — is identical to a fully serial loop.
fn run_cell(
    spec: &CellSpec,
    policy: &TrialPolicy,
    runner: &dyn TrialRunner,
    jobs: usize,
) -> anyhow::Result<CellResult> {
    let t0 = std::time::Instant::now();
    let mut drops = Vec::with_capacity(policy.min_trials);
    let (mut corrected, mut detected, mut clamped) = (0u64, 0u64, 0u64);
    let (mut recovered, mut unrecovered) = (0u64, 0u64);
    let prelude = policy.min_trials.min(policy.max_trials).max(1) as u64;
    let outcomes = run_jobs((0..prelude).collect(), jobs, |t| {
        runner.run_trial(spec, t, trial_seed(spec, t))
    });
    for out in outcomes {
        let out = out?;
        drops.push(out.drop_pp);
        corrected += out.corrected;
        detected += out.detected;
        clamped += out.clamped;
        recovered += out.recovered;
        unrecovered += out.unrecovered;
    }
    loop {
        let n = drops.len();
        if n >= policy.max_trials {
            break;
        }
        if n >= policy.min_trials {
            match policy.ci_target {
                None => break,
                Some(target) => {
                    if stats::mean_ci_half_width(&drops, policy.confidence) <= target {
                        break;
                    }
                }
            }
        }
        let t = n as u64;
        let out = runner.run_trial(spec, t, trial_seed(spec, t))?;
        drops.push(out.drop_pp);
        corrected += out.corrected;
        detected += out.detected;
        clamped += out.clamped;
        recovered += out.recovered;
        unrecovered += out.unrecovered;
    }
    Ok(CellResult {
        spec: spec.clone(),
        half_width: stats::mean_ci_half_width(&drops, policy.confidence),
        drops,
        corrected,
        detected,
        clamped,
        recovered,
        unrecovered,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

/// Run a campaign: fan pending cells over `jobs` workers, checkpoint
/// each completed cell to the ledger, and assemble the report in grid
/// order. With `resume`, cells already in the ledger are loaded, not
/// re-run.
pub fn run(cfg: &Config, runner: &dyn TrialRunner) -> anyhow::Result<Report> {
    let t0 = std::time::Instant::now();
    let grid = cfg.grid();
    anyhow::ensure!(!grid.is_empty(), "campaign grid is empty");
    let fingerprint = cfg.fingerprint();
    let mut done: BTreeMap<String, CellResult> = BTreeMap::new();
    if cfg.resume {
        let path = cfg
            .ledger
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("resume requires a ledger path"))?;
        if path.exists() {
            done = Ledger::load(path, &fingerprint)?.cells;
        }
    }
    let pending: Vec<CellSpec> = grid
        .iter()
        .filter(|c| !done.contains_key(&c.key()))
        .take(cfg.stop_after.unwrap_or(usize::MAX))
        .cloned()
        .collect();

    let shared = Mutex::new(Ledger {
        fingerprint,
        cells: done,
    });
    let policy = cfg.policy;
    let jobs = cfg.jobs.max(1);
    let outcomes = run_jobs(pending, jobs, |spec| -> anyhow::Result<()> {
        let cell = run_cell(&spec, &policy, runner, jobs)?;
        if cfg.verbose {
            eprintln!(
                "[campaign] {:<12} {:>8} rate={:>7.0e} {:<14} {:>12}/{:<5} trials={:<3} drop={} hw={:.3}",
                spec.model,
                spec.strategy,
                spec.rate,
                spec.fault.tag(),
                spec.site.tag(),
                spec.guard.tag(),
                cell.trials(),
                stats::mean_std_str(&cell.drops),
                cell.half_width,
            );
        }
        let mut ledger = shared.lock().unwrap();
        ledger.cells.insert(spec.key(), cell);
        if let Some(path) = &cfg.ledger {
            ledger.save(path)?;
        }
        Ok(())
    });
    for outcome in outcomes {
        outcome?;
    }

    let ledger = shared.into_inner().unwrap();
    let mut cells = Vec::with_capacity(grid.len());
    let mut complete = true;
    for spec in &grid {
        match ledger.cells.get(&spec.key()) {
            Some(c) => cells.push(c.clone()),
            None => complete = false,
        }
    }
    Ok(Report {
        cells,
        policy: cfg.policy,
        complete,
        wall_secs: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(policy: TrialPolicy) -> Config {
        Config {
            models: vec!["m".into()],
            strategies: vec!["a".into(), "b".into()],
            rates: vec![1e-3],
            fault_models: vec![FaultModel::Uniform, FaultModel::Burst { len: 2 }],
            sites: vec![FaultSite::Weights],
            guards: vec![GuardMode::Off],
            recovery: vec![RecoveryMode::Off],
            policy,
            jobs: 1,
            ledger: None,
            resume: false,
            stop_after: None,
            runner_tag: "test".into(),
            verbose: false,
        }
    }

    /// Zero-variance runner: every trial reports the same drop.
    struct ConstRunner(f64);
    impl TrialRunner for ConstRunner {
        fn run_trial(&self, _s: &CellSpec, _t: u64, _seed: u64) -> anyhow::Result<TrialOutcome> {
            Ok(TrialOutcome {
                drop_pp: self.0,
                corrected: 1,
                ..TrialOutcome::default()
            })
        }
    }

    /// High-variance runner: drops alternate 0 / 10 pp, so no sane CI
    /// target is ever met.
    struct AlternatingRunner;
    impl TrialRunner for AlternatingRunner {
        fn run_trial(&self, _s: &CellSpec, t: u64, _seed: u64) -> anyhow::Result<TrialOutcome> {
            Ok(TrialOutcome {
                drop_pp: (t % 2) as f64 * 10.0,
                ..TrialOutcome::default()
            })
        }
    }

    #[test]
    fn grid_is_canonical_order() {
        let g = cfg(TrialPolicy::fixed(1)).grid();
        assert_eq!(g.len(), 4);
        assert_eq!(g[0].strategy, "a");
        assert_eq!(g[0].fault, FaultModel::Uniform);
        assert_eq!(g[1].fault, FaultModel::Burst { len: 2 });
        assert_eq!(g[2].strategy, "b");
    }

    #[test]
    fn trial_seed_varies_per_axis_and_is_stable() {
        let spec = CellSpec {
            model: "m".into(),
            strategy: "ecc".into(),
            rate: 1e-4,
            fault: FaultModel::Uniform,
            site: FaultSite::Weights,
            guard: GuardMode::Off,
            recovery: RecoveryMode::Off,
        };
        let s0 = trial_seed(&spec, 0);
        assert_eq!(s0, trial_seed(&spec, 0));
        assert_ne!(s0, trial_seed(&spec, 1));
        let mut other = spec.clone();
        other.fault = FaultModel::Burst { len: 2 };
        assert_ne!(s0, trial_seed(&other, 0), "fault model is in the seed");
        let mut other = spec.clone();
        other.rate = 1e-3;
        assert_ne!(s0, trial_seed(&other, 0));
        let mut other = spec.clone();
        other.site = FaultSite::Activations;
        assert_ne!(s0, trial_seed(&other, 0), "fault site is in the seed");
    }

    #[test]
    fn default_axes_keep_classic_keys_and_guard_stays_out_of_seeds() {
        let classic = CellSpec {
            model: "m".into(),
            strategy: "ecc".into(),
            rate: 1e-4,
            fault: FaultModel::Uniform,
            site: FaultSite::Weights,
            guard: GuardMode::Off,
            recovery: RecoveryMode::Off,
        };
        // Pre-site ledgers keyed cells as model|strategy|rate|fault;
        // the default axes must reproduce that byte-for-byte.
        assert_eq!(classic.key(), "m|ecc|1e-4|uniform");
        assert_eq!(classic.seed_key(), "m|ecc|1e-4|uniform");

        let mut guarded = classic.clone();
        guarded.site = FaultSite::Activations;
        guarded.guard = GuardMode::Full;
        let mut unguarded = guarded.clone();
        unguarded.guard = GuardMode::Off;
        // Distinct ledger cells, identical fault sequences.
        assert_ne!(guarded.key(), unguarded.key());
        assert_eq!(guarded.seed_key(), unguarded.seed_key());
        assert_eq!(trial_seed(&guarded, 3), trial_seed(&unguarded, 3));

        // Recovery follows the same discipline: a distinct ledger key,
        // the same fault sequence as its recovery-off sibling.
        let mut recovering = classic.clone();
        recovering.recovery = RecoveryMode::Milr;
        assert_eq!(recovering.key(), "m|ecc|1e-4|uniform|recovery=milr");
        assert_eq!(recovering.seed_key(), classic.seed_key());
        assert_eq!(trial_seed(&recovering, 5), trial_seed(&classic, 5));
    }

    #[test]
    fn compute_site_cells_are_deterministic_and_guards_reduce_residual() {
        // 2e-3 over 32x64 activations = ~131 strikes per trial: enough
        // that some land in exponent bits (big, detectable corruption)
        // whatever the seed draws, so the comparative asserts below
        // hold by construction rather than by luck.
        let runner = SyntheticRunner::new(64 * 16, 4, 1);
        let spec = CellSpec {
            model: "synthetic".into(),
            strategy: "none".into(),
            rate: 2e-3,
            fault: FaultModel::Uniform,
            site: FaultSite::Activations,
            guard: GuardMode::Off,
            recovery: RecoveryMode::Off,
        };
        let seed = trial_seed(&spec, 0);
        let off = runner.run_trial(&spec, 0, seed).unwrap();
        let again = runner.run_trial(&spec, 0, seed).unwrap();
        assert_eq!(off.drop_pp, again.drop_pp, "trials are seed-deterministic");
        assert!(off.drop_pp > 0.0, "unguarded strikes must corrupt output");

        let mut full = spec.clone();
        full.guard = GuardMode::Full;
        let on = runner.run_trial(&full, 0, trial_seed(&full, 0)).unwrap();
        assert!(
            on.drop_pp < off.drop_pp,
            "guards must reduce the residual at equal faults (off={} on={})",
            off.drop_pp,
            on.drop_pp
        );
        assert!(on.clamped > 0, "range guard clamps out-of-envelope strikes");
        assert!(on.detected > 0 && on.corrected > 0, "ABFT repairs the rest");

        let mut acc = spec.clone();
        acc.site = FaultSite::Accumulators;
        let acc_off = runner.run_trial(&acc, 0, trial_seed(&acc, 0)).unwrap();
        acc.guard = GuardMode::Abft;
        let abft = runner.run_trial(&acc, 0, trial_seed(&acc, 0)).unwrap();
        assert!(
            abft.drop_pp < acc_off.drop_pp,
            "ABFT recompute must shrink the accumulator-site residual"
        );
        assert!(abft.detected > 0 && abft.corrected > 0);
    }

    #[test]
    fn fixed_policy_runs_exact_trial_count() {
        let report = run(&cfg(TrialPolicy::fixed(5)), &ConstRunner(1.0)).unwrap();
        assert!(report.complete);
        for c in &report.cells {
            assert_eq!(c.trials(), 5);
            assert_eq!(c.corrected, 5);
            assert_eq!(c.half_width, 0.0, "zero-variance sample");
        }
    }

    #[test]
    fn adaptive_policy_stops_at_min_on_zero_variance() {
        let report = run(
            &cfg(TrialPolicy::adaptive(3, 50, 0.5, 0.95)),
            &ConstRunner(2.0),
        )
        .unwrap();
        for c in &report.cells {
            assert_eq!(c.trials(), 3, "zero variance meets any target at min");
        }
    }

    #[test]
    fn adaptive_policy_runs_to_max_when_target_unreachable() {
        let report = run(
            &cfg(TrialPolicy::adaptive(3, 7, 0.5, 0.95)),
            &AlternatingRunner,
        )
        .unwrap();
        for c in &report.cells {
            assert_eq!(c.trials(), 7, "unreachable target must hit the max bound");
            assert!(c.half_width > 0.5);
        }
    }

    #[test]
    fn cell_json_roundtrip() {
        let cell = CellResult {
            spec: CellSpec {
                model: "m".into(),
                strategy: "in-place".into(),
                rate: 1e-3,
                fault: FaultModel::RowBurst {
                    row_bits: 512,
                    len: 4,
                },
                site: FaultSite::Activations,
                guard: GuardMode::Full,
                recovery: RecoveryMode::Off,
            },
            drops: vec![0.0, 0.125, 3.5],
            corrected: 17,
            detected: 3,
            clamped: 9,
            recovered: 4,
            unrecovered: 2,
            half_width: 1.25,
            wall_ms: 12.5,
        };
        let back = CellResult::from_json(&cell.to_json(true)).unwrap();
        assert_eq!(back.spec, cell.spec);
        assert_eq!(back.drops, cell.drops);
        assert_eq!((back.corrected, back.detected, back.clamped), (17, 3, 9));
        assert_eq!((back.recovered, back.unrecovered), (4, 2));
        assert_eq!(back.half_width, 1.25);
        // A pre-site ledger cell (no site/guard/clamped/recovery
        // fields) loads with the classic defaults.
        let mut old = cell.to_json(true);
        if let Json::Obj(m) = &mut old {
            m.remove("site");
            m.remove("guard");
            m.remove("clamped");
            m.remove("recovery");
            m.remove("recovered");
            m.remove("unrecovered");
        }
        let back = CellResult::from_json(&old).unwrap();
        assert_eq!(back.spec.site, FaultSite::Weights);
        assert_eq!(back.spec.guard, GuardMode::Off);
        assert_eq!(back.spec.recovery, RecoveryMode::Off);
        assert_eq!(back.clamped, 0);
        assert_eq!((back.recovered, back.unrecovered), (0, 0));
        // infinite half-width survives as null
        let single = CellResult {
            half_width: f64::INFINITY,
            drops: vec![1.0],
            ..cell
        };
        let back = CellResult::from_json(&single.to_json(false)).unwrap();
        assert!(back.half_width.is_infinite());
        assert_eq!(back.wall_ms, 0.0, "canonical cell carries no timing");
    }

    #[test]
    fn fingerprint_ignores_execution_knobs_only() {
        let a = cfg(TrialPolicy::fixed(5));
        let mut b = cfg(TrialPolicy::fixed(5));
        b.jobs = 7;
        b.stop_after = Some(1);
        b.verbose = true;
        assert_eq!(a.fingerprint(), b.fingerprint());
        let mut c = cfg(TrialPolicy::fixed(6));
        assert_ne!(a.fingerprint(), c.fingerprint());
        c = cfg(TrialPolicy::fixed(5));
        c.rates = vec![1e-4];
        assert_ne!(a.fingerprint(), c.fingerprint());
        c = cfg(TrialPolicy::fixed(5));
        c.runner_tag = "other".into();
        assert_ne!(a.fingerprint(), c.fingerprint());
        // Default site/guard axes leave the fingerprint untouched (old
        // ledgers resume); a real sweep is identity-bearing.
        c = cfg(TrialPolicy::fixed(5));
        assert_eq!(a.fingerprint(), c.fingerprint());
        assert!(!a.fingerprint().contains("sites="));
        c.sites = vec![FaultSite::Weights, FaultSite::Activations];
        assert_ne!(a.fingerprint(), c.fingerprint());
        c = cfg(TrialPolicy::fixed(5));
        c.guards = vec![GuardMode::Off, GuardMode::Full];
        assert_ne!(a.fingerprint(), c.fingerprint());
        c = cfg(TrialPolicy::fixed(5));
        assert!(!a.fingerprint().contains("recovery="));
        c.recovery = vec![RecoveryMode::Off, RecoveryMode::Milr];
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn milr_recovery_reduces_synthetic_drop_at_equal_faults() {
        // Scan trials of the zero-redundancy milr strategy, scoring
        // each with and without the recovery tier. Seeds exclude the
        // recovery mode, so each pair faces identical strikes. At 2e-4
        // over 2048x8 stored bits ~3 flips land per trial: some trials
        // carry no probe-visible flip (skipped), some carry silent
        // corruption in the implicated columns (verification rejects
        // the solve and quarantines), and at least one trial must
        // recover a block and strictly shrink the accuracy drop.
        let runner = SyntheticRunner::new(2048, 4, 2);
        let spec = CellSpec {
            model: "synthetic".into(),
            strategy: "milr".into(),
            rate: 2e-4,
            fault: FaultModel::Uniform,
            site: FaultSite::Weights,
            guard: GuardMode::Off,
            recovery: RecoveryMode::Off,
        };
        let mut rec_spec = spec.clone();
        rec_spec.recovery = RecoveryMode::Milr;

        let mut detections = 0u64;
        let mut strict: Option<(u64, TrialOutcome)> = None;
        for t in 0..32 {
            let off = runner.run_trial(&spec, t, trial_seed(&spec, t)).unwrap();
            assert_eq!(off.recovered, 0, "recovery off must never recover");
            if off.detected == 0 {
                continue;
            }
            detections += 1;
            let on = runner
                .run_trial(&rec_spec, t, trial_seed(&rec_spec, t))
                .unwrap();
            assert_eq!(
                on.detected, off.detected,
                "trial {t}: equal faults must implicate the same blocks"
            );
            if on.recovered > 0 && on.drop_pp < off.drop_pp {
                strict = Some((t, on));
                break;
            }
        }
        assert!(detections > 0, "the scan must hit probe-visible strikes");
        let (t, on) =
            strict.expect("no trial in 0..32 strictly improved under recovery");
        // Deterministic: the winning cell replays identically.
        let again = runner
            .run_trial(&rec_spec, t, trial_seed(&rec_spec, t))
            .unwrap();
        assert_eq!(again.drop_pp, on.drop_pp);
        assert_eq!(again.recovered, on.recovered);
        assert_eq!(again.unrecovered, on.unrecovered);
    }
}
