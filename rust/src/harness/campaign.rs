//! Parallel Monte-Carlo fault-injection campaign engine.
//!
//! A campaign is a grid of cells — (model × strategy × fault-rate ×
//! fault-model) — evaluated by independent fault-injection trials.
//! Instead of a fixed trial count, each cell runs until the Student-t
//! confidence interval on its mean accuracy drop is tight enough
//! (`ci_target` half-width at `confidence`), bounded by
//! `[min_trials, max_trials]`; with no target set it runs exactly
//! `min_trials` trials (the classic Table-2 mode).
//!
//! Cells fan out over the same persistent worker pool the sharded
//! store uses ([`run_jobs`](crate::memory::run_jobs) — parked threads,
//! no per-cell spawn/join), and the first `min_trials` trials of each
//! cell fan out too (they run unconditionally, so parallelism cannot
//! change the stopping decision; only the adaptive tail is
//! sequential). Trials reuse per-strategy banks with copy-on-write
//! resets instead of re-encoding, so a trial's cost is injection +
//! decode. Each completed cell is checkpointed to a JSON ledger, so an
//! interrupted campaign resumed with the same configuration replays
//! nothing — and its final report is **byte-identical** to an
//! uninterrupted run: trial seeds derive only from the cell key and
//! trial index, early stopping depends only on the (deterministic)
//! drop sequence, and the canonical report excludes wall-clock.
//! `tests/campaign.rs` pins the identity down.
//!
//! Two [`TrialRunner`]s ship: [`EvalRunner`] executes real models
//! through PJRT (one `EvalCtx` per model, mutex-serialized), and
//! [`SyntheticRunner`] uses decoded-weight corruption on synthetic WOT
//! buffers as the drop proxy — artifact-free, which is what the CI
//! smoke campaign and the integration tests run.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

use crate::harness::eval::EvalCtx;
use crate::memory::{run_jobs, FaultModel, ShardedBank};
use crate::model::EvalSet;
use crate::runtime::Runtime;
use crate::util::json::{arr, num, num_or_null, obj, s, Json};
use crate::util::plot;
use crate::util::stats;

// ---------------------------------------------------------------- grid --

/// One grid cell: a (model, strategy, rate, fault-model) combination.
#[derive(Clone, Debug, PartialEq)]
pub struct CellSpec {
    pub model: String,
    pub strategy: String,
    pub rate: f64,
    pub fault: FaultModel,
}

impl CellSpec {
    /// Stable ledger key; also the seed domain of the cell's trials.
    pub fn key(&self) -> String {
        format!(
            "{}|{}|{:e}|{}",
            self.model,
            self.strategy,
            self.rate,
            self.fault.tag()
        )
    }
}

/// Stable per-trial seed: FNV-1a over the cell key, whitened by the
/// trial index. Depends on nothing else — the backbone of resume
/// identity and cross-cell independence.
pub fn trial_seed(spec: &CellSpec, trial: u64) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in spec.key().bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ trial.wrapping_mul(0x9E3779B97F4A7C15)
}

/// When a cell's trial loop stops.
#[derive(Clone, Copy, Debug)]
pub struct TrialPolicy {
    pub min_trials: usize,
    pub max_trials: usize,
    /// Target CI half-width on the mean drop (percentage points); with
    /// `None` every cell runs exactly `min_trials` trials.
    pub ci_target: Option<f64>,
    /// Confidence level of the interval (see `stats::t_critical`).
    pub confidence: f64,
}

impl TrialPolicy {
    /// The classic fixed-count mode (Table 2's 10 trials/cell).
    pub fn fixed(n: usize) -> TrialPolicy {
        TrialPolicy {
            min_trials: n.max(1),
            max_trials: n.max(1),
            ci_target: None,
            confidence: 0.95,
        }
    }

    /// Adaptive mode: stop once the half-width reaches `target`, never
    /// before `min` trials, never after `max`.
    pub fn adaptive(min: usize, max: usize, target: f64, confidence: f64) -> TrialPolicy {
        let min = min.max(1);
        TrialPolicy {
            min_trials: min,
            max_trials: max.max(min),
            ci_target: Some(target),
            confidence,
        }
    }
}

/// Campaign configuration: the grid, the stopping policy, and the
/// execution/checkpoint knobs.
pub struct Config {
    pub models: Vec<String>,
    pub strategies: Vec<String>,
    pub rates: Vec<f64>,
    pub fault_models: Vec<FaultModel>,
    pub policy: TrialPolicy,
    /// Parallel cell workers (1 = serial in grid order).
    pub jobs: usize,
    /// Checkpoint ledger path; `None` disables checkpointing.
    pub ledger: Option<PathBuf>,
    /// Load completed cells from the ledger instead of re-running them.
    pub resume: bool,
    /// Stop after computing this many *new* cells — the interruption
    /// hook the resume tests and smoke runs use; the report is then
    /// marked incomplete.
    pub stop_after: Option<usize>,
    /// Names the trial runner (and its salient parameters); a ledger
    /// written under a different tag refuses to resume.
    pub runner_tag: String,
    /// Log per-cell completion lines to stderr.
    pub verbose: bool,
}

impl Config {
    /// The cell grid in canonical (reporting) order.
    pub fn grid(&self) -> Vec<CellSpec> {
        let mut cells = Vec::new();
        for model in &self.models {
            for strategy in &self.strategies {
                for &rate in &self.rates {
                    for &fault in &self.fault_models {
                        cells.push(CellSpec {
                            model: model.clone(),
                            strategy: strategy.clone(),
                            rate,
                            fault,
                        });
                    }
                }
            }
        }
        cells
    }

    /// Everything that must match for a ledger to be resumable into
    /// this campaign. Execution knobs (jobs, stop_after, verbose,
    /// ledger path) deliberately excluded: they cannot change results.
    fn fingerprint(&self) -> String {
        let rates: Vec<String> = self.rates.iter().map(|r| format!("{r:e}")).collect();
        let faults: Vec<String> = self.fault_models.iter().map(|f| f.tag()).collect();
        format!(
            "v1|runner={}|models={}|strategies={}|rates={}|faults={}|min={}|max={}|ci={:?}|conf={}",
            self.runner_tag,
            self.models.join(","),
            self.strategies.join(","),
            rates.join(","),
            faults.join(","),
            self.policy.min_trials,
            self.policy.max_trials,
            self.policy.ci_target,
            self.policy.confidence,
        )
    }
}

// -------------------------------------------------------------- runner --

/// One trial's measurements.
#[derive(Clone, Copy, Debug)]
pub struct TrialOutcome {
    /// Accuracy drop vs the fault-free baseline, percentage points.
    pub drop_pp: f64,
    pub corrected: u64,
    pub detected: u64,
}

/// Runs one fault-injection trial of a cell. Implementations must be
/// deterministic in `(spec, seed)` — resume identity depends on it —
/// and `Sync`: trials of different cells run concurrently, and so do
/// the first `min_trials` trials *within* a cell (they run
/// unconditionally, so parallelism cannot change a stopping decision).
pub trait TrialRunner: Sync {
    fn run_trial(&self, spec: &CellSpec, trial: u64, seed: u64) -> anyhow::Result<TrialOutcome>;
}

/// PJRT-backed runner: one loaded [`EvalCtx`] per model. Each context
/// is mutex-serialized (PJRT execution stays on one thread at a time),
/// so campaign parallelism pays off across models; the injection/decode
/// half of a trial is already parallel inside `ShardedBank`.
pub struct EvalRunner {
    ctxs: BTreeMap<String, Mutex<EvalCtx>>,
    base_acc: BTreeMap<String, f64>,
}

impl EvalRunner {
    pub fn load(
        artifacts: &Path,
        models: &[String],
        batch: usize,
        shards: usize,
        decode_workers: usize,
    ) -> anyhow::Result<EvalRunner> {
        let rt = Runtime::cpu()?;
        let ds = Arc::new(EvalSet::load(&artifacts.join("dataset.eval.bin"))?);
        let mut ctxs = BTreeMap::new();
        let mut base_acc = BTreeMap::new();
        for model in models {
            let mut ctx = EvalCtx::load(artifacts, model, batch, rt.clone(), ds.clone())?;
            ctx.shards = shards;
            ctx.decode_workers = decode_workers;
            base_acc.insert(model.clone(), ctx.base_acc);
            ctxs.insert(model.clone(), Mutex::new(ctx));
        }
        Ok(EvalRunner { ctxs, base_acc })
    }

    /// Fault-free int8 accuracy per loaded model.
    pub fn base_acc(&self) -> &BTreeMap<String, f64> {
        &self.base_acc
    }
}

impl TrialRunner for EvalRunner {
    fn run_trial(&self, spec: &CellSpec, _trial: u64, seed: u64) -> anyhow::Result<TrialOutcome> {
        let ctx = self
            .ctxs
            .get(&spec.model)
            .ok_or_else(|| anyhow::anyhow!("model '{}' not loaded in this campaign", spec.model))?;
        let mut ctx = ctx.lock().unwrap();
        let base = ctx.base_acc;
        let (acc, corrected, detected) =
            ctx.faulty_trial(&spec.strategy, spec.fault, spec.rate, seed)?;
        Ok(TrialOutcome {
            drop_pp: (base - acc) * 100.0,
            corrected,
            detected,
        })
    }
}

/// Artifact-free runner for tests, CI smoke campaigns and ablations:
/// the "accuracy drop" proxy is the percentage of weights decoded
/// wrong from a [`ShardedBank`] after injection. Deterministic per
/// seed, no PJRT, no artifacts. The two synthetic weight buffers (WOT
/// for the paper strategies, extended-WOT for `bch16`) are generated
/// once and shared across all trials, and the protected banks are
/// recycled through a per-strategy freelist: a released bank has been
/// copy-on-write reset to pristine, so a steady-state trial costs
/// injection + decode — never a re-encode, never a full image copy.
pub struct SyntheticRunner {
    n_weights: usize,
    shards: usize,
    workers: usize,
    wot: OnceLock<Vec<i8>>,
    ext: OnceLock<Vec<i8>>,
    /// Reset banks awaiting reuse, keyed by strategy; depth tracks peak
    /// same-strategy trial concurrency.
    banks: Mutex<BTreeMap<String, Vec<ShardedBank>>>,
}

impl SyntheticRunner {
    /// `n_weights` should be a multiple of 16 so `bch16` cells work too.
    pub fn new(n_weights: usize, shards: usize, workers: usize) -> SyntheticRunner {
        SyntheticRunner {
            n_weights,
            shards,
            workers,
            wot: OnceLock::new(),
            ext: OnceLock::new(),
            banks: Mutex::new(BTreeMap::new()),
        }
    }
}

impl Default for SyntheticRunner {
    fn default() -> Self {
        SyntheticRunner::new(64 * 64, 8, 2)
    }
}

impl TrialRunner for SyntheticRunner {
    fn run_trial(&self, spec: &CellSpec, _trial: u64, seed: u64) -> anyhow::Result<TrialOutcome> {
        use crate::harness::ablation::{synth_ext, synth_wot};
        let w: &[i8] = if spec.strategy == "bch16" {
            self.ext.get_or_init(|| synth_ext(self.n_weights, 42))
        } else {
            self.wot.get_or_init(|| synth_wot(self.n_weights, 42))
        };
        // a recycled (pristine-reset) bank when one is free, else encode
        let recycled = {
            let mut banks = self.banks.lock().unwrap();
            banks.get_mut(&spec.strategy).and_then(|v| v.pop())
        };
        let mut bank = match recycled {
            Some(b) => b,
            None => ShardedBank::new(
                crate::ecc::strategy_by_name(&spec.strategy)?,
                w,
                self.shards,
                self.workers,
            )?,
        };
        bank.inject(spec.fault, spec.rate, seed);
        let mut out = crate::memory::pool::lease_i8(w.len());
        let st = bank.read(&mut out);
        let wrong = out.iter().zip(w).filter(|(a, b)| a != b).count();
        bank.reset(); // copy-on-write: only fault-touched blocks copied back
        {
            let mut banks = self.banks.lock().unwrap();
            banks.entry(spec.strategy.clone()).or_default().push(bank);
        }
        Ok(TrialOutcome {
            drop_pp: 100.0 * wrong as f64 / w.len() as f64,
            corrected: st.corrected,
            detected: st.detected,
        })
    }
}

// ------------------------------------------------------------- results --

/// One completed cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub spec: CellSpec,
    /// Accuracy drop per trial (percentage points).
    pub drops: Vec<f64>,
    pub corrected: u64,
    pub detected: u64,
    /// CI half-width on the mean drop at the policy's confidence
    /// (infinite when a single trial cannot bound it).
    pub half_width: f64,
    /// Wall-clock of the cell's trial loop (excluded from canonical
    /// JSON — timing is not part of resume identity).
    pub wall_ms: f64,
}

impl CellResult {
    pub fn trials(&self) -> usize {
        self.drops.len()
    }

    fn to_json(&self, timing: bool) -> Json {
        let mut fields = vec![
            ("model", s(&self.spec.model)),
            ("strategy", s(&self.spec.strategy)),
            ("rate", num(self.spec.rate)),
            ("fault_model", s(&self.spec.fault.tag())),
            ("trials", num(self.drops.len() as f64)),
            ("drop_mean", num(stats::mean(&self.drops))),
            ("drop_std", num(stats::std(&self.drops))),
            ("ci_half_width", num_or_null(self.half_width)),
            ("drops", arr(self.drops.iter().map(|d| num(*d)))),
            ("corrected", num(self.corrected as f64)),
            ("detected", num(self.detected as f64)),
        ];
        if timing {
            fields.push(("wall_ms", num(self.wall_ms)));
        }
        obj(fields)
    }

    fn from_json(v: &Json) -> anyhow::Result<CellResult> {
        let f = |k: &str| -> anyhow::Result<f64> {
            v.req(k)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("ledger cell field '{k}' must be a number"))
        };
        let st = |k: &str| -> anyhow::Result<String> {
            Ok(v.req(k)?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("ledger cell field '{k}' must be a string"))?
                .to_string())
        };
        let drops = v
            .req("drops")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("ledger cell field 'drops' must be an array"))?
            .iter()
            .map(|d| {
                d.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("ledger drop entries must be numbers"))
            })
            .collect::<anyhow::Result<Vec<f64>>>()?;
        let half_width = match v.req("ci_half_width")? {
            Json::Null => f64::INFINITY,
            other => other
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("'ci_half_width' must be a number or null"))?,
        };
        Ok(CellResult {
            spec: CellSpec {
                model: st("model")?,
                strategy: st("strategy")?,
                rate: f("rate")?,
                fault: FaultModel::parse(&st("fault_model")?)?,
            },
            drops,
            corrected: f("corrected")? as u64,
            detected: f("detected")? as u64,
            half_width,
            wall_ms: v.get("wall_ms").and_then(|x| x.as_f64()).unwrap_or(0.0),
        })
    }
}

/// A finished (or interrupted) campaign, cells in canonical grid order.
#[derive(Clone, Debug)]
pub struct Report {
    pub cells: Vec<CellResult>,
    pub policy: TrialPolicy,
    /// False when the campaign stopped (`stop_after`) before every
    /// grid cell completed; resume to finish.
    pub complete: bool,
    pub wall_secs: f64,
}

impl Report {
    pub fn cell(
        &self,
        model: &str,
        strategy: &str,
        rate: f64,
        fault: &FaultModel,
    ) -> Option<&CellResult> {
        self.cells.iter().find(|c| {
            c.spec.model == model
                && c.spec.strategy == strategy
                && c.spec.rate == rate
                && c.spec.fault == *fault
        })
    }

    pub fn total_trials(&self) -> usize {
        self.cells.iter().map(|c| c.trials()).sum()
    }

    /// Canonical JSON: deterministic for a given (config, runner) —
    /// the resume-identity surface. Excludes all wall-clock fields.
    pub fn canonical_json(&self) -> Json {
        self.json_inner(false)
    }

    /// Full JSON including per-cell and total wall-clock.
    pub fn to_json(&self) -> Json {
        self.json_inner(true)
    }

    fn json_inner(&self, timing: bool) -> Json {
        let mut fields = vec![
            ("complete", Json::Bool(self.complete)),
            ("confidence", num(self.policy.confidence)),
            (
                "ci_target",
                num_or_null(self.policy.ci_target.unwrap_or(f64::INFINITY)),
            ),
            ("min_trials", num(self.policy.min_trials as f64)),
            ("max_trials", num(self.policy.max_trials as f64)),
            ("total_trials", num(self.total_trials() as f64)),
            ("cells", arr(self.cells.iter().map(|c| c.to_json(timing)))),
        ];
        if timing {
            fields.push(("wall_secs", num(self.wall_secs)));
        }
        obj(fields)
    }

    /// Paper-shaped summary table.
    pub fn render(&self) -> String {
        let headers = [
            "model", "strategy", "fault", "rate", "trials", "drop (pp)", "ci-hw", "corrected",
            "detected",
        ];
        let rows: Vec<Vec<String>> = self
            .cells
            .iter()
            .map(|c| {
                vec![
                    c.spec.model.clone(),
                    c.spec.strategy.clone(),
                    c.spec.fault.tag(),
                    format!("{:.0e}", c.spec.rate),
                    c.trials().to_string(),
                    stats::mean_std_str(&c.drops),
                    if c.half_width.is_finite() {
                        format!("{:.3}", c.half_width)
                    } else {
                        "n/a".to_string()
                    },
                    c.corrected.to_string(),
                    c.detected.to_string(),
                ]
            })
            .collect();
        format!(
            "Campaign: {} cells, {} trials, {:.1}s{}\n{}",
            self.cells.len(),
            self.total_trials(),
            self.wall_secs,
            if self.complete {
                ""
            } else {
                " (INCOMPLETE — rerun with --resume to finish)"
            },
            plot::table(&headers, &rows)
        )
    }
}

// -------------------------------------------------------------- ledger --

struct Ledger {
    fingerprint: String,
    cells: BTreeMap<String, CellResult>,
}

impl Ledger {
    fn load(path: &Path, fingerprint: &str) -> anyhow::Result<Ledger> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading ledger {}: {e}", path.display()))?;
        let v = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing ledger {}: {e}", path.display()))?;
        let fp = v
            .req("fingerprint")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("ledger 'fingerprint' must be a string"))?;
        anyhow::ensure!(
            fp == fingerprint,
            "ledger {} belongs to a different campaign (fingerprint mismatch:\n  ledger: {fp}\n  config: {fingerprint})",
            path.display()
        );
        let mut cells = BTreeMap::new();
        for (k, cv) in v
            .req("cells")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("ledger 'cells' must be an object"))?
        {
            cells.insert(k.clone(), CellResult::from_json(cv)?);
        }
        Ok(Ledger {
            fingerprint: fingerprint.to_string(),
            cells,
        })
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("fingerprint", s(&self.fingerprint)),
            (
                "cells",
                Json::Obj(
                    self.cells
                        .iter()
                        .map(|(k, c)| (k.clone(), c.to_json(true)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Write-to-temp + rename so an interruption mid-write never
    /// leaves a truncated ledger behind.
    fn save(&self, path: &Path) -> anyhow::Result<()> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json().to_string())
            .map_err(|e| anyhow::anyhow!("writing ledger {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| anyhow::anyhow!("publishing ledger {}: {e}", path.display()))?;
        Ok(())
    }
}

// -------------------------------------------------------------- engine --

/// Run one cell's trial loop until the policy says stop.
///
/// The first `min_trials` trials run unconditionally whatever the
/// stopping rule later decides, so they fan out over the worker pool
/// (`jobs` wide); the adaptive tail stays sequential because each
/// extra trial depends on the CI of its prefix. Results are collected
/// in trial order, so the drops sequence — and hence every stopping
/// decision — is identical to a fully serial loop.
fn run_cell(
    spec: &CellSpec,
    policy: &TrialPolicy,
    runner: &dyn TrialRunner,
    jobs: usize,
) -> anyhow::Result<CellResult> {
    let t0 = std::time::Instant::now();
    let mut drops = Vec::with_capacity(policy.min_trials);
    let (mut corrected, mut detected) = (0u64, 0u64);
    let prelude = policy.min_trials.min(policy.max_trials).max(1) as u64;
    let outcomes = run_jobs((0..prelude).collect(), jobs, |t| {
        runner.run_trial(spec, t, trial_seed(spec, t))
    });
    for out in outcomes {
        let out = out?;
        drops.push(out.drop_pp);
        corrected += out.corrected;
        detected += out.detected;
    }
    loop {
        let n = drops.len();
        if n >= policy.max_trials {
            break;
        }
        if n >= policy.min_trials {
            match policy.ci_target {
                None => break,
                Some(target) => {
                    if stats::mean_ci_half_width(&drops, policy.confidence) <= target {
                        break;
                    }
                }
            }
        }
        let t = n as u64;
        let out = runner.run_trial(spec, t, trial_seed(spec, t))?;
        drops.push(out.drop_pp);
        corrected += out.corrected;
        detected += out.detected;
    }
    Ok(CellResult {
        spec: spec.clone(),
        half_width: stats::mean_ci_half_width(&drops, policy.confidence),
        drops,
        corrected,
        detected,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

/// Run a campaign: fan pending cells over `jobs` workers, checkpoint
/// each completed cell to the ledger, and assemble the report in grid
/// order. With `resume`, cells already in the ledger are loaded, not
/// re-run.
pub fn run(cfg: &Config, runner: &dyn TrialRunner) -> anyhow::Result<Report> {
    let t0 = std::time::Instant::now();
    let grid = cfg.grid();
    anyhow::ensure!(!grid.is_empty(), "campaign grid is empty");
    let fingerprint = cfg.fingerprint();
    let mut done: BTreeMap<String, CellResult> = BTreeMap::new();
    if cfg.resume {
        let path = cfg
            .ledger
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("resume requires a ledger path"))?;
        if path.exists() {
            done = Ledger::load(path, &fingerprint)?.cells;
        }
    }
    let pending: Vec<CellSpec> = grid
        .iter()
        .filter(|c| !done.contains_key(&c.key()))
        .take(cfg.stop_after.unwrap_or(usize::MAX))
        .cloned()
        .collect();

    let shared = Mutex::new(Ledger {
        fingerprint,
        cells: done,
    });
    let policy = cfg.policy;
    let jobs = cfg.jobs.max(1);
    let outcomes = run_jobs(pending, jobs, |spec| -> anyhow::Result<()> {
        let cell = run_cell(&spec, &policy, runner, jobs)?;
        if cfg.verbose {
            eprintln!(
                "[campaign] {:<12} {:>8} rate={:>7.0e} {:<14} trials={:<3} drop={} hw={:.3}",
                spec.model,
                spec.strategy,
                spec.rate,
                spec.fault.tag(),
                cell.trials(),
                stats::mean_std_str(&cell.drops),
                cell.half_width,
            );
        }
        let mut ledger = shared.lock().unwrap();
        ledger.cells.insert(spec.key(), cell);
        if let Some(path) = &cfg.ledger {
            ledger.save(path)?;
        }
        Ok(())
    });
    for outcome in outcomes {
        outcome?;
    }

    let ledger = shared.into_inner().unwrap();
    let mut cells = Vec::with_capacity(grid.len());
    let mut complete = true;
    for spec in &grid {
        match ledger.cells.get(&spec.key()) {
            Some(c) => cells.push(c.clone()),
            None => complete = false,
        }
    }
    Ok(Report {
        cells,
        policy: cfg.policy,
        complete,
        wall_secs: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(policy: TrialPolicy) -> Config {
        Config {
            models: vec!["m".into()],
            strategies: vec!["a".into(), "b".into()],
            rates: vec![1e-3],
            fault_models: vec![FaultModel::Uniform, FaultModel::Burst { len: 2 }],
            policy,
            jobs: 1,
            ledger: None,
            resume: false,
            stop_after: None,
            runner_tag: "test".into(),
            verbose: false,
        }
    }

    /// Zero-variance runner: every trial reports the same drop.
    struct ConstRunner(f64);
    impl TrialRunner for ConstRunner {
        fn run_trial(&self, _s: &CellSpec, _t: u64, _seed: u64) -> anyhow::Result<TrialOutcome> {
            Ok(TrialOutcome {
                drop_pp: self.0,
                corrected: 1,
                detected: 0,
            })
        }
    }

    /// High-variance runner: drops alternate 0 / 10 pp, so no sane CI
    /// target is ever met.
    struct AlternatingRunner;
    impl TrialRunner for AlternatingRunner {
        fn run_trial(&self, _s: &CellSpec, t: u64, _seed: u64) -> anyhow::Result<TrialOutcome> {
            Ok(TrialOutcome {
                drop_pp: (t % 2) as f64 * 10.0,
                corrected: 0,
                detected: 0,
            })
        }
    }

    #[test]
    fn grid_is_canonical_order() {
        let g = cfg(TrialPolicy::fixed(1)).grid();
        assert_eq!(g.len(), 4);
        assert_eq!(g[0].strategy, "a");
        assert_eq!(g[0].fault, FaultModel::Uniform);
        assert_eq!(g[1].fault, FaultModel::Burst { len: 2 });
        assert_eq!(g[2].strategy, "b");
    }

    #[test]
    fn trial_seed_varies_per_axis_and_is_stable() {
        let spec = CellSpec {
            model: "m".into(),
            strategy: "ecc".into(),
            rate: 1e-4,
            fault: FaultModel::Uniform,
        };
        let s0 = trial_seed(&spec, 0);
        assert_eq!(s0, trial_seed(&spec, 0));
        assert_ne!(s0, trial_seed(&spec, 1));
        let mut other = spec.clone();
        other.fault = FaultModel::Burst { len: 2 };
        assert_ne!(s0, trial_seed(&other, 0), "fault model is in the seed");
        let mut other = spec.clone();
        other.rate = 1e-3;
        assert_ne!(s0, trial_seed(&other, 0));
    }

    #[test]
    fn fixed_policy_runs_exact_trial_count() {
        let report = run(&cfg(TrialPolicy::fixed(5)), &ConstRunner(1.0)).unwrap();
        assert!(report.complete);
        for c in &report.cells {
            assert_eq!(c.trials(), 5);
            assert_eq!(c.corrected, 5);
            assert_eq!(c.half_width, 0.0, "zero-variance sample");
        }
    }

    #[test]
    fn adaptive_policy_stops_at_min_on_zero_variance() {
        let report = run(
            &cfg(TrialPolicy::adaptive(3, 50, 0.5, 0.95)),
            &ConstRunner(2.0),
        )
        .unwrap();
        for c in &report.cells {
            assert_eq!(c.trials(), 3, "zero variance meets any target at min");
        }
    }

    #[test]
    fn adaptive_policy_runs_to_max_when_target_unreachable() {
        let report = run(
            &cfg(TrialPolicy::adaptive(3, 7, 0.5, 0.95)),
            &AlternatingRunner,
        )
        .unwrap();
        for c in &report.cells {
            assert_eq!(c.trials(), 7, "unreachable target must hit the max bound");
            assert!(c.half_width > 0.5);
        }
    }

    #[test]
    fn cell_json_roundtrip() {
        let cell = CellResult {
            spec: CellSpec {
                model: "m".into(),
                strategy: "in-place".into(),
                rate: 1e-3,
                fault: FaultModel::RowBurst {
                    row_bits: 512,
                    len: 4,
                },
            },
            drops: vec![0.0, 0.125, 3.5],
            corrected: 17,
            detected: 3,
            half_width: 1.25,
            wall_ms: 12.5,
        };
        let back = CellResult::from_json(&cell.to_json(true)).unwrap();
        assert_eq!(back.spec, cell.spec);
        assert_eq!(back.drops, cell.drops);
        assert_eq!((back.corrected, back.detected), (17, 3));
        assert_eq!(back.half_width, 1.25);
        // infinite half-width survives as null
        let single = CellResult {
            half_width: f64::INFINITY,
            drops: vec![1.0],
            ..cell
        };
        let back = CellResult::from_json(&single.to_json(false)).unwrap();
        assert!(back.half_width.is_infinite());
        assert_eq!(back.wall_ms, 0.0, "canonical cell carries no timing");
    }

    #[test]
    fn fingerprint_ignores_execution_knobs_only() {
        let a = cfg(TrialPolicy::fixed(5));
        let mut b = cfg(TrialPolicy::fixed(5));
        b.jobs = 7;
        b.stop_after = Some(1);
        b.verbose = true;
        assert_eq!(a.fingerprint(), b.fingerprint());
        let mut c = cfg(TrialPolicy::fixed(6));
        assert_ne!(a.fingerprint(), c.fingerprint());
        c = cfg(TrialPolicy::fixed(5));
        c.rates = vec![1e-4];
        assert_ne!(a.fingerprint(), c.fingerprint());
        c = cfg(TrialPolicy::fixed(5));
        c.runner_tag = "other".into();
        assert_ne!(a.fingerprint(), c.fingerprint());
    }
}
