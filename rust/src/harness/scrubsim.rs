//! Time-varying fault scenarios driving the adaptive scrub scheduler
//! against the fixed-interval baseline at equal scrub bandwidth.
//!
//! The campaign engine measures *static* fault pressure; real memory
//! does not behave that way — rates ramp (temperature, altitude) and
//! damage migrates (a failing bank region). This harness replays such
//! scenarios tick by tick against a [`ShardedBank`] and a
//! [`ScrubScheduler`], dispatching a **fixed budget of scrub passes
//! per tick** under either policy, so the comparison isolates the
//! *allocation* of scrub bandwidth, never its amount:
//!
//! * `fixed` — every shard on one cadence (earliest-deadline dispatch
//!   of a single shared interval degenerates to round-robin);
//! * `adaptive` — per-shard deadlines from the online BER estimator;
//!   the hot shard clamps to a 1-tick interval and soaks up budget,
//!   provably-clean shards decay toward the max interval.
//!
//! After the last tick the bank is decoded once: weights decoded wrong
//! and blocks detected-uncorrectable are the **residual error** the
//! paper's reliability argument (Sec. 4, Fig. 4) ties to scrub
//! frequency. Under a hotspot scenario the adaptive policy's residual
//! is strictly below fixed-interval's at equal passes — the
//! deterministic acceptance test of the scheduler, and the `sched`
//! section of the `ecc_hotpath` bench ledger.
//!
//! Everything is deterministic in the scenario seed: virtual time (one
//! tick = one virtual second), per-tick injection seeds derived from
//! `seed ^ tick`, and the worker-count-independent scrub passes the
//! shard-equivalence proptests already pin down.
//!
//! The **fleet simulation** ([`run_fleet_sim`]) extends the same
//! machinery across model boundaries: several banks with independent
//! fault scenarios compete for one process-wide scrub budget, and the
//! arbitrated allocation ([`FleetArbitration`]) is compared against a
//! static per-model partition (`isolated`) and a naive rotation
//! (`roundrobin`) at equal total bandwidth and identical fault
//! streams. [`fleet_verdict`] is the deterministic acceptance gate the
//! CI smoke greps for.

use std::time::Duration;

use crate::ecc::strategy_by_name;
use crate::memory::{
    FaultModel, FleetArbitration, SchedulerConfig, ScrubPolicy, ScrubScheduler, ShardedBank,
};
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::plot;

/// One scenario phase: a fault model injected at `rate` (of stored
/// bits, per tick) for `ticks` virtual seconds.
#[derive(Clone, Debug)]
pub struct Phase {
    pub model: FaultModel,
    pub rate: f64,
    pub ticks: u32,
}

/// A time-varying fault scenario: phases played back to back.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub seed: u64,
    pub phases: Vec<Phase>,
}

impl Scenario {
    /// Rate ramp: uniform flips climbing two decades and falling back —
    /// the whole store heats up, then cools. Exercises global interval
    /// tightening/relaxation (no locality for the scheduler to exploit,
    /// so expect parity with fixed at equal bandwidth).
    pub fn ramp(seed: u64) -> Scenario {
        let rate_steps = [2e-6, 1e-5, 1e-4, 1e-5, 2e-6];
        Scenario {
            name: "ramp".into(),
            seed,
            phases: rate_steps
                .iter()
                .map(|&rate| Phase {
                    model: FaultModel::Uniform,
                    rate,
                    ticks: 24,
                })
                .collect(),
        }
    }

    /// Hotspot migration: all flips confined to a narrow window that
    /// jumps across the image between phases — the scenario the
    /// adaptive scheduler exists for. Residual errors are dominated by
    /// blocks collecting a second flip before their next scrub, so
    /// concentrating passes on the live hotspot beats spreading them
    /// evenly.
    pub fn hotspot_migration(seed: u64) -> Scenario {
        // Starts chosen so the 3%-wide window sits inside a single
        // shard at the default 16-shard split (shard width 6.25%): one
        // hot shard demands ~1 pass/tick, which together with 15 cold
        // shards at the max interval stays inside the 2-pass/tick
        // budget — the comparison probes scheduling, not overload.
        let starts = [0.07, 0.39, 0.825];
        Scenario {
            name: "migrate".into(),
            seed,
            phases: starts
                .iter()
                .map(|&start| Phase {
                    model: FaultModel::HotspotAt { start, frac: 0.03 },
                    rate: 2.5e-5,
                    ticks: 60,
                })
                .collect(),
        }
    }

    /// Scenario registry for the CLI / nightly campaign.
    pub fn by_name(name: &str, seed: u64) -> anyhow::Result<Scenario> {
        match name {
            "ramp" => Ok(Scenario::ramp(seed)),
            "migrate" => Ok(Scenario::hotspot_migration(seed)),
            _ => anyhow::bail!("unknown scenario '{name}' (ramp | migrate)"),
        }
    }

    pub fn total_ticks(&self) -> u64 {
        self.phases.iter().map(|p| u64::from(p.ticks)).sum()
    }

    /// The phase covering virtual second `tick`.
    fn phase_at(&self, tick: u64) -> &Phase {
        let mut t = tick;
        for p in &self.phases {
            if t < u64::from(p.ticks) {
                return p;
            }
            t -= u64::from(p.ticks);
        }
        self.phases.last().expect("scenario has no phases")
    }
}

/// Simulation knobs shared by both policies.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub strategy: String,
    pub n_weights: usize,
    pub shards: usize,
    /// Scrub passes dispatched per tick — the bandwidth both policies
    /// get; the fixed policy's implied per-shard period is
    /// `shards / budget` ticks.
    pub budget: usize,
    /// Adaptive upper clamp, in ticks.
    pub max_interval_ticks: u64,
    /// Pool workers for the per-shard scrub fan-out.
    pub workers: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            strategy: "in-place".into(),
            n_weights: 64 * 1024,
            shards: 16,
            budget: 2,
            max_interval_ticks: 16,
            workers: 2,
        }
    }
}

/// One policy's run over a scenario.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub policy: ScrubPolicy,
    pub scenario: String,
    pub scrub_passes: u64,
    pub faults_injected: u64,
    pub corrected: u64,
    /// Blocks still detected-uncorrectable at the final decode.
    pub residual_uncorrectable: u64,
    /// Weights decoded wrong at the final decode.
    pub residual_wrong_weights: u64,
    /// Per-tick, per-shard Wilson-upper BER trace (the nightly
    /// artifact the estimator's behavior is inspected through).
    pub ber_trace: Vec<Vec<f64>>,
}

impl SimResult {
    /// JSON record; `trace` controls whether the (large) per-tick BER
    /// trace is included.
    pub fn to_json(&self, trace: bool) -> Json {
        let mut fields = vec![
            ("policy", s(self.policy.tag())),
            ("scenario", s(&self.scenario)),
            ("scrub_passes", num(self.scrub_passes as f64)),
            ("faults_injected", num(self.faults_injected as f64)),
            ("corrected", num(self.corrected as f64)),
            ("residual_uncorrectable", num(self.residual_uncorrectable as f64)),
            ("residual_wrong_weights", num(self.residual_wrong_weights as f64)),
        ];
        if trace {
            fields.push((
                "ber_trace",
                arr(self.ber_trace.iter().map(|row| arr(row.iter().map(|&b| num(b))))),
            ));
        }
        obj(fields)
    }
}

/// Replay `scenario` under `policy` at the configured bandwidth.
pub fn run_sim(
    cfg: &SimConfig,
    scenario: &Scenario,
    policy: ScrubPolicy,
) -> anyhow::Result<SimResult> {
    anyhow::ensure!(cfg.budget >= 1, "scrub budget must be at least 1 pass/tick");
    let weights = crate::harness::ablation::synth_wot(cfg.n_weights, 42);
    let mut bank = ShardedBank::new(
        strategy_by_name(&cfg.strategy)?,
        &weights,
        cfg.shards,
        cfg.workers,
    )?;
    let nshards = bank.num_shards();
    let shard_bits: Vec<u64> = (0..nshards).map(|i| bank.shard_bits(i)).collect();
    let tick = Duration::from_secs(1);
    let sched_cfg = match policy {
        // fixed at the bandwidth-implied period: budget passes/tick
        // over S shards = each shard every S/budget ticks
        ScrubPolicy::Fixed => SchedulerConfig::fixed(tick * (nshards.div_ceil(cfg.budget) as u32)),
        ScrubPolicy::Adaptive => {
            SchedulerConfig::adaptive(tick, tick * (cfg.max_interval_ticks as u32))
        }
    };
    let mut sched = ScrubScheduler::new(sched_cfg, &shard_bits, Duration::ZERO);
    let mut result = SimResult {
        policy,
        scenario: scenario.name.clone(),
        scrub_passes: 0,
        faults_injected: 0,
        corrected: 0,
        residual_uncorrectable: 0,
        residual_wrong_weights: 0,
        ber_trace: Vec::with_capacity(scenario.total_ticks() as usize),
    };
    for t in 0..scenario.total_ticks() {
        let now = tick * (t as u32);
        let phase = scenario.phase_at(t);
        let seed = scenario.seed ^ (t + 1).wrapping_mul(0x9E3779B97F4A7C15);
        result.faults_injected += bank.inject(phase.model, phase.rate, seed);
        // Fixed bandwidth: always exactly `budget` passes, earliest
        // deadline first — under the fixed policy this is round-robin,
        // under adaptive it follows the estimator.
        let chosen = sched.most_urgent(cfg.budget.min(nshards));
        let per_shard = bank.scrub_subset(&chosen);
        for &(i, stats) in &per_shard {
            result.corrected += stats.corrected + stats.zeroed;
            sched.record_pass(i, &stats, now);
            result.scrub_passes += 1;
        }
        result.ber_trace.push((0..nshards).map(|i| sched.ber_bounds(i).1).collect());
    }
    let (uncorr, wrong) = final_residual(&mut bank, &weights);
    result.residual_uncorrectable = uncorr;
    result.residual_wrong_weights = wrong;
    Ok(result)
}

/// Residual error once the clock stops: **block identities still
/// detected-uncorrectable at a final decode**, plus weights decoded
/// wrong. Counting at the final decode (not accumulating scrub-pass
/// detections) matters because uncorrectable states can be transient —
/// a double-flipped block that loses one flip to a later strike is
/// corrected by the next pass and must not be charged to the residual.
/// If the per-pass block list overflowed its cap the event count is the
/// only (over-)estimate left, and overflow means the residual is huge
/// anyway.
fn final_residual(bank: &mut ShardedBank, weights: &[i8]) -> (u64, u64) {
    let mut out = vec![0i8; weights.len()];
    let outcome = bank.read_outcome(&mut out);
    let uncorr = if outcome.overflow {
        outcome.stats.detected
    } else {
        outcome.detected_blocks.len() as u64
    };
    let wrong = out
        .iter()
        .zip(weights)
        .filter(|(a, b)| a != b)
        .count() as u64;
    (uncorr, wrong)
}

/// Run both policies over a scenario and render the comparison.
pub fn compare(cfg: &SimConfig, scenario: &Scenario) -> anyhow::Result<(SimResult, SimResult)> {
    let fixed = run_sim(cfg, scenario, ScrubPolicy::Fixed)?;
    let adaptive = run_sim(cfg, scenario, ScrubPolicy::Adaptive)?;
    Ok((fixed, adaptive))
}

pub fn render(results: &[&SimResult]) -> String {
    let headers = [
        "scenario",
        "policy",
        "passes",
        "faults",
        "corrected",
        "resid-uncorr",
        "resid-wrong",
    ];
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                r.scenario.clone(),
                r.policy.tag().to_string(),
                r.scrub_passes.to_string(),
                r.faults_injected.to_string(),
                r.corrected.to_string(),
                r.residual_uncorrectable.to_string(),
                r.residual_wrong_weights.to_string(),
            ]
        })
        .collect();
    plot::table(&headers, &rows)
}

// ---------------------------------------------------------------------------
// Fleet simulation: many models, one scrub budget
// ---------------------------------------------------------------------------

/// One model lane in the fleet simulation: its own weights, bank and
/// fault scenario, competing for the shared scrub budget.
#[derive(Clone, Debug)]
pub struct FleetModel {
    pub name: String,
    pub n_weights: usize,
    pub scenario: Scenario,
}

/// Knobs shared by every allocation policy in a fleet comparison.
#[derive(Clone, Debug)]
pub struct FleetSimConfig {
    pub strategy: String,
    /// Shards per model bank.
    pub shards: usize,
    /// Scrub passes dispatched per tick **across the whole fleet** —
    /// the bandwidth every allocation policy gets. Overridden by
    /// `budget_gbps` when that is set.
    pub budget_passes: usize,
    /// Operator-facing alternative to `budget_passes`: a scrub
    /// bandwidth in GB/s, converted against the 1-second tick via
    /// [`crate::memory::gbps_to_bits_per_wakeup`] and rounded *down*
    /// to whole passes over the fleet's widest shard (a pass is never
    /// split). Must buy at least one pass.
    pub budget_gbps: Option<f64>,
    /// Adaptive upper clamp, in ticks.
    pub max_interval_ticks: u64,
    /// Pool workers for the per-shard scrub fan-out.
    pub workers: usize,
    /// Deferral cap for the arbitrated allocation's starvation guard.
    pub starve_after: u32,
}

impl Default for FleetSimConfig {
    fn default() -> Self {
        FleetSimConfig {
            strategy: "in-place".into(),
            shards: 8,
            budget_passes: 3,
            budget_gbps: None,
            max_interval_ticks: 16,
            workers: 2,
            starve_after: 4,
        }
    }
}

/// How the per-tick scrub budget is split across models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FleetAllocation {
    /// Static partition: every model runs its own scheduler on
    /// `budget / n_models` passes per tick — per-server scrub loops
    /// with fair shares, the pre-fleet baseline.
    Isolated,
    /// Naive rotation: each tick the whole budget goes to the next
    /// model in round-robin order, blind to urgency.
    RoundRobin,
    /// The fleet arbiter: one [`FleetArbitration`] ranking due shards
    /// across all models by Wilson-upper urgency under one budget.
    Arbitrated,
}

impl FleetAllocation {
    pub fn tag(&self) -> &'static str {
        match self {
            FleetAllocation::Isolated => "isolated",
            FleetAllocation::RoundRobin => "roundrobin",
            FleetAllocation::Arbitrated => "fleet",
        }
    }
}

/// One model's outcome under a fleet allocation.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FleetLaneResult {
    pub model: String,
    pub scrub_passes: u64,
    pub faults_injected: u64,
    pub corrected: u64,
    /// Blocks still detected-uncorrectable at the final decode.
    pub residual_uncorrectable: u64,
    /// Weights decoded wrong at the final decode.
    pub residual_wrong_weights: u64,
    /// Cumulative due-but-denied bits (arbitrated allocation only).
    pub deficit_bits: u64,
    /// Grants received through the starvation guard (arbitrated only).
    pub starved_grants: u64,
}

/// A whole fleet's run under one allocation policy.
#[derive(Clone, Debug)]
pub struct FleetSimResult {
    pub allocation: FleetAllocation,
    pub lanes: Vec<FleetLaneResult>,
    pub total_passes: u64,
    /// Worst inter-scrub gap over every (model, shard), in ticks,
    /// including the tail from the last pass to the end of the clock —
    /// the observable the starvation bound is asserted on.
    pub max_gap_ticks: u64,
}

impl FleetSimResult {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("allocation", s(self.allocation.tag())),
            ("total_passes", num(self.total_passes as f64)),
            ("max_gap_ticks", num(self.max_gap_ticks as f64)),
            (
                "lanes",
                arr(self.lanes.iter().map(|l| {
                    obj(vec![
                        ("model", s(&l.model)),
                        ("scrub_passes", num(l.scrub_passes as f64)),
                        ("faults_injected", num(l.faults_injected as f64)),
                        ("corrected", num(l.corrected as f64)),
                        ("residual_uncorrectable", num(l.residual_uncorrectable as f64)),
                        ("residual_wrong_weights", num(l.residual_wrong_weights as f64)),
                        ("deficit_bits", num(l.deficit_bits as f64)),
                        ("starved_grants", num(l.starved_grants as f64)),
                    ])
                })),
            ),
        ])
    }
}

/// The canonical fleet scenario: model `a` takes a stationary in-shard
/// hotspot while models `b` and `c` see only faint background flips —
/// the case fleet arbitration exists for. Bandwidth should chase the
/// hotspot across model boundaries without pushing any quiet model
/// past its residual budget.
pub fn fleet_models(seed: u64) -> Vec<FleetModel> {
    let quiet = |name: &str, seed: u64| FleetModel {
        name: name.into(),
        n_weights: 32 * 1024,
        scenario: Scenario {
            name: name.into(),
            seed,
            phases: vec![Phase {
                model: FaultModel::Uniform,
                rate: 2e-7,
                ticks: 120,
            }],
        },
    };
    vec![
        FleetModel {
            name: "a".into(),
            n_weights: 32 * 1024,
            scenario: Scenario {
                name: "a".into(),
                seed,
                phases: vec![Phase {
                    // the 5%-wide window at 30% sits inside shard 2 of
                    // the 8-shard split (25% .. 37.5%): one hot shard
                    model: FaultModel::HotspotAt { start: 0.30, frac: 0.05 },
                    rate: 4e-5,
                    ticks: 120,
                }],
            },
        },
        quiet("b", seed ^ 0x5EED_C01D),
        quiet("c", seed ^ 0xC01D_5EED),
    ]
}

/// Replay every model's scenario against one scrub budget split by
/// `alloc`. Fault streams are derived from each model's scenario seed
/// alone, so two runs with different allocations see bit-identical
/// injections — the comparison isolates bandwidth *allocation*.
pub fn run_fleet_sim(
    cfg: &FleetSimConfig,
    models: &[FleetModel],
    alloc: FleetAllocation,
) -> anyhow::Result<FleetSimResult> {
    anyhow::ensure!(!models.is_empty(), "fleet sim needs at least one model");
    let total_ticks = models[0].scenario.total_ticks();
    anyhow::ensure!(
        models.iter().all(|m| m.scenario.total_ticks() == total_ticks),
        "fleet models must share one clock"
    );
    let tick = Duration::from_secs(1);
    let mut banks = Vec::with_capacity(models.len());
    let mut scheds = Vec::with_capacity(models.len());
    let mut goldens = Vec::with_capacity(models.len());
    for (mi, m) in models.iter().enumerate() {
        let weights = crate::harness::ablation::synth_wot(m.n_weights, 42 + mi as u64);
        let bank = ShardedBank::new(
            strategy_by_name(&cfg.strategy)?,
            &weights,
            cfg.shards,
            cfg.workers,
        )?;
        let shard_bits: Vec<u64> = (0..bank.num_shards()).map(|i| bank.shard_bits(i)).collect();
        scheds.push(ScrubScheduler::new(
            SchedulerConfig::adaptive(tick, tick * (cfg.max_interval_ticks as u32)),
            &shard_bits,
            Duration::ZERO,
        ));
        banks.push(bank);
        goldens.push(weights);
    }
    // Arbitrated budget in bits: `budget_passes` passes over the
    // fleet's widest shard, so a grant is never denied for byte-count
    // rounding between models of different sizes.
    let pass_bits = banks
        .iter()
        .flat_map(|b| (0..b.num_shards()).map(|i| b.shard_bits(i)))
        .max()
        .unwrap_or(0);
    // A bandwidth-stated budget converts to whole passes over the
    // widest shard (rounding down: bandwidth is a cap, not a promise),
    // so every allocation policy still compares at equal whole-pass
    // bandwidth.
    let budget_passes = match cfg.budget_gbps {
        None => cfg.budget_passes,
        Some(gbps) => {
            let bits = crate::memory::gbps_to_bits_per_wakeup(gbps, tick);
            anyhow::ensure!(
                pass_bits > 0 && bits >= pass_bits,
                "--budget-gbps {gbps} buys {bits} bits/tick, less than one \
                 pass over the widest shard ({pass_bits} bits)"
            );
            (bits / pass_bits) as usize
        }
    };
    anyhow::ensure!(budget_passes >= 1, "scrub budget must be at least 1 pass/tick");
    if alloc == FleetAllocation::Isolated {
        anyhow::ensure!(
            budget_passes % models.len() == 0,
            "isolated allocation needs a budget divisible by the model count \
             ({} passes over {} models)",
            budget_passes,
            models.len()
        );
    }
    let mut fleet =
        FleetArbitration::new(Some(budget_passes as u64 * pass_bits), cfg.starve_after);
    let slots: Vec<usize> = banks.iter().map(|b| fleet.register(b.num_shards())).collect();
    let mut lanes: Vec<FleetLaneResult> = models
        .iter()
        .map(|m| FleetLaneResult { model: m.name.clone(), ..FleetLaneResult::default() })
        .collect();
    let mut last_scrub: Vec<Vec<u64>> =
        banks.iter().map(|b| vec![0u64; b.num_shards()]).collect();
    let mut max_gap = 0u64;
    let mut total_passes = 0u64;
    let mut rr_cursor = 0usize;
    for t in 0..total_ticks {
        let now = tick * (t as u32);
        for (mi, m) in models.iter().enumerate() {
            let phase = m.scenario.phase_at(t);
            let seed = m.scenario.seed ^ (t + 1).wrapping_mul(0x9E3779B97F4A7C15);
            lanes[mi].faults_injected += banks[mi].inject(phase.model, phase.rate, seed);
        }
        let grants: Vec<(usize, Vec<usize>)> = match alloc {
            FleetAllocation::Isolated => {
                let per = budget_passes / models.len();
                scheds
                    .iter()
                    .enumerate()
                    .map(|(mi, sc)| (mi, sc.most_urgent(per)))
                    .collect()
            }
            FleetAllocation::RoundRobin => {
                let mi = rr_cursor;
                rr_cursor = (rr_cursor + 1) % models.len();
                vec![(mi, scheds[mi].most_urgent(budget_passes))]
            }
            FleetAllocation::Arbitrated => {
                let refs: Vec<(usize, &ScrubScheduler)> =
                    slots.iter().copied().zip(scheds.iter()).collect();
                let planned = fleet.plan(&refs, now);
                let mut by_model: Vec<Vec<usize>> = vec![Vec::new(); models.len()];
                for g in planned {
                    by_model[g.model].push(g.shard);
                }
                by_model.into_iter().enumerate().collect()
            }
        };
        for (mi, chosen) in grants {
            if chosen.is_empty() {
                continue;
            }
            let per_shard = banks[mi].scrub_subset(&chosen);
            for &(i, stats) in &per_shard {
                lanes[mi].corrected += stats.corrected + stats.zeroed;
                scheds[mi].record_pass(i, &stats, now);
                lanes[mi].scrub_passes += 1;
                total_passes += 1;
                max_gap = max_gap.max(t - last_scrub[mi][i]);
                last_scrub[mi][i] = t;
            }
        }
    }
    for (mi, last) in last_scrub.iter().enumerate() {
        for &l in last {
            max_gap = max_gap.max(total_ticks - l);
        }
        let (uncorr, wrong) = final_residual(&mut banks[mi], &goldens[mi]);
        lanes[mi].residual_uncorrectable = uncorr;
        lanes[mi].residual_wrong_weights = wrong;
        if alloc == FleetAllocation::Arbitrated {
            let d = fleet.deficit(slots[mi]);
            lanes[mi].deficit_bits = d.deficit_bits;
            lanes[mi].starved_grants = d.starved_grants;
        }
    }
    Ok(FleetSimResult { allocation: alloc, lanes, total_passes, max_gap_ticks: max_gap })
}

/// Run all three allocations over the same fleet at equal bandwidth.
pub fn fleet_compare(
    cfg: &FleetSimConfig,
    models: &[FleetModel],
) -> anyhow::Result<(FleetSimResult, FleetSimResult, FleetSimResult)> {
    let iso = run_fleet_sim(cfg, models, FleetAllocation::Isolated)?;
    let rr = run_fleet_sim(cfg, models, FleetAllocation::RoundRobin)?;
    let arb = run_fleet_sim(cfg, models, FleetAllocation::Arbitrated)?;
    Ok((iso, rr, arb))
}

pub fn fleet_render(results: &[&FleetSimResult]) -> String {
    let headers = [
        "allocation",
        "model",
        "passes",
        "faults",
        "corrected",
        "resid-uncorr",
        "resid-wrong",
        "deficit-bits",
    ];
    let rows: Vec<Vec<String>> = results
        .iter()
        .flat_map(|r| {
            r.lanes.iter().map(move |l| {
                vec![
                    r.allocation.tag().to_string(),
                    l.model.clone(),
                    l.scrub_passes.to_string(),
                    l.faults_injected.to_string(),
                    l.corrected.to_string(),
                    l.residual_uncorrectable.to_string(),
                    l.residual_wrong_weights.to_string(),
                    l.deficit_bits.to_string(),
                ]
            })
        })
        .collect();
    plot::table(&headers, &rows)
}

/// Deterministic fleet acceptance gate. At equal total bandwidth and
/// identical fault streams the arbitrated allocation must
///
/// 1. keep every quiet model's residual no worse than its isolated
///    fair share (the per-model residual budget holds),
/// 2. strictly beat naive round-robin on the hot model (the budget
///    actually chases urgency across model boundaries), and
/// 3. never let any shard's inter-scrub gap exceed the starvation
///    bound `max_interval + starve_after + total_shards + 1` ticks.
///
/// Returns the `[fleet ok]` verdict line the CI smoke greps for; a
/// violated inequality becomes the error.
pub fn fleet_verdict(
    cfg: &FleetSimConfig,
    iso: &FleetSimResult,
    rr: &FleetSimResult,
    arb: &FleetSimResult,
) -> anyhow::Result<String> {
    let n = iso.lanes.len();
    anyhow::ensure!(
        rr.lanes.len() == n && arb.lanes.len() == n,
        "allocations ran different fleets"
    );
    for i in 0..n {
        anyhow::ensure!(
            iso.lanes[i].faults_injected == rr.lanes[i].faults_injected
                && iso.lanes[i].faults_injected == arb.lanes[i].faults_injected,
            "allocations saw different fault streams for model '{}'",
            iso.lanes[i].model
        );
    }
    anyhow::ensure!(
        arb.total_passes <= iso.total_passes && arb.total_passes <= rr.total_passes,
        "arbitrated allocation outspent the baselines: {} passes vs isolated {} / roundrobin {}",
        arb.total_passes,
        iso.total_passes,
        rr.total_passes
    );
    let hot = (0..n)
        .max_by_key(|&i| iso.lanes[i].faults_injected)
        .expect("fleet has lanes");
    anyhow::ensure!(
        arb.lanes[hot].residual_uncorrectable < rr.lanes[hot].residual_uncorrectable,
        "hot model '{}' must strictly beat round-robin: fleet {} vs roundrobin {}",
        iso.lanes[hot].model,
        arb.lanes[hot].residual_uncorrectable,
        rr.lanes[hot].residual_uncorrectable
    );
    for i in (0..n).filter(|&i| i != hot) {
        anyhow::ensure!(
            arb.lanes[i].residual_uncorrectable <= iso.lanes[i].residual_uncorrectable,
            "quiet model '{}' regressed past its isolated budget: fleet {} vs isolated {}",
            iso.lanes[i].model,
            arb.lanes[i].residual_uncorrectable,
            iso.lanes[i].residual_uncorrectable
        );
    }
    let bound =
        cfg.max_interval_ticks + u64::from(cfg.starve_after) + (cfg.shards * n) as u64 + 1;
    anyhow::ensure!(
        arb.max_gap_ticks <= bound,
        "starvation: a shard waited {} ticks between scrubs (bound {})",
        arb.max_gap_ticks,
        bound
    );
    Ok(format!(
        "[fleet ok] hot '{}' resid fleet={} < roundrobin={} (isolated={}); \
         quiet lanes within isolated budgets; max gap {} <= {} ticks at {} passes",
        iso.lanes[hot].model,
        arb.lanes[hot].residual_uncorrectable,
        rr.lanes[hot].residual_uncorrectable,
        iso.lanes[hot].residual_uncorrectable,
        arb.max_gap_ticks,
        bound,
        arb.total_passes
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_cover_the_clock() {
        let sc = Scenario::hotspot_migration(1);
        assert_eq!(sc.total_ticks(), 180);
        assert_eq!(sc.phase_at(0).model, sc.phases[0].model);
        assert_eq!(sc.phase_at(59).model, sc.phases[0].model);
        assert_eq!(sc.phase_at(60).model, sc.phases[1].model);
        assert_eq!(sc.phase_at(179).model, sc.phases[2].model);
        assert!(Scenario::by_name("nope", 1).is_err());
    }

    /// A bandwidth-stated fleet budget is exactly the whole-pass budget
    /// it converts to: bits/tick over the widest shard, rounded down.
    #[test]
    fn fleet_budget_gbps_equals_converted_passes() {
        let models = fleet_models(3);
        let by_passes = FleetSimConfig::default();
        // the widest shard of a 32 KiB in-place bank at 8 shards is
        // 4096 bytes = 32768 stored bits; 3.4 passes/tick rounds down
        // to the default 3
        let pass_bits = (32 * 1024 / 8) * 8;
        let gbps = 3.4 * pass_bits as f64 / 8e9;
        let by_gbps = FleetSimConfig {
            budget_gbps: Some(gbps),
            budget_passes: 999, // must be ignored
            ..FleetSimConfig::default()
        };
        for alloc in [FleetAllocation::RoundRobin, FleetAllocation::Arbitrated] {
            let a = run_fleet_sim(&by_passes, &models, alloc).unwrap();
            let b = run_fleet_sim(&by_gbps, &models, alloc).unwrap();
            assert_eq!(a.total_passes, b.total_passes, "{}", alloc.tag());
            assert_eq!(a.lanes, b.lanes, "{}", alloc.tag());
        }
        // a bandwidth below one pass per tick is a loud error
        let starved = FleetSimConfig {
            budget_gbps: Some(0.5 * pass_bits as f64 / 8e9),
            ..FleetSimConfig::default()
        };
        assert!(run_fleet_sim(&starved, &models, FleetAllocation::Arbitrated).is_err());
    }

    /// The tentpole acceptance test: under a seeded hotspot-migration
    /// scenario at equal total scrub passes, the adaptive policy's
    /// residual uncorrected-error count is strictly below
    /// fixed-interval's.
    #[test]
    fn adaptive_beats_fixed_at_equal_bandwidth_under_hotspots() {
        let cfg = SimConfig::default();
        let scenario = Scenario::hotspot_migration(7);
        let (fixed, adaptive) = compare(&cfg, &scenario).unwrap();
        assert_eq!(
            fixed.scrub_passes, adaptive.scrub_passes,
            "the comparison is only fair at equal scrub bandwidth"
        );
        assert_eq!(fixed.faults_injected, adaptive.faults_injected);
        assert!(
            adaptive.residual_uncorrectable < fixed.residual_uncorrectable,
            "adaptive must strictly beat fixed on uncorrectable residue: \
             adaptive {} vs fixed {}",
            adaptive.residual_uncorrectable,
            fixed.residual_uncorrectable
        );
        assert!(
            adaptive.residual_wrong_weights < fixed.residual_wrong_weights,
            "adaptive must strictly beat fixed on wrong weights: \
             adaptive {} vs fixed {}",
            adaptive.residual_wrong_weights,
            fixed.residual_wrong_weights
        );
    }

    /// Two-pass heal: a block that collects two flips is
    /// detected-uncorrectable on the first scrub pass; a later strike
    /// reverting one of them leaves a single flip the second pass
    /// corrects. The residual is measured at the *final* decode, so the
    /// transient must contribute nothing.
    #[test]
    fn transient_uncorrectable_blocks_leave_no_final_residual() {
        let weights = crate::harness::ablation::synth_wot(512, 42);
        let mut bank =
            ShardedBank::new(strategy_by_name("in-place").unwrap(), &weights, 2, 1).unwrap();
        // pass 1: two flips in block 0 — even-weight syndrome, detected
        bank.image_mut().flip_bit(2);
        bank.image_mut().flip_bit(11);
        let first = bank.scrub_outcome();
        assert_eq!(first.detected_blocks, vec![0], "double flip must be detected");
        // the transient resolves: a later strike reverts one flip …
        bank.image_mut().flip_bit(11);
        // … and pass 2 corrects the single survivor in place
        let second = bank.scrub_outcome();
        assert!(second.detected_blocks.is_empty());
        assert!(second.stats.corrected >= 1, "the survivor must be corrected");
        // final decode: the healed block is not charged to the residual
        let (uncorr, wrong) = final_residual(&mut bank, &weights);
        assert_eq!(uncorr, 0, "healed transients must not count");
        assert_eq!(wrong, 0);
    }

    /// Determinism: same scenario seed, same results, tick for tick.
    #[test]
    fn sim_is_deterministic_in_the_seed() {
        let cfg = SimConfig {
            n_weights: 16 * 1024,
            shards: 8,
            ..SimConfig::default()
        };
        let scenario = Scenario::ramp(3);
        let a = run_sim(&cfg, &scenario, ScrubPolicy::Adaptive).unwrap();
        let b = run_sim(&cfg, &scenario, ScrubPolicy::Adaptive).unwrap();
        assert_eq!(a.residual_wrong_weights, b.residual_wrong_weights);
        assert_eq!(a.faults_injected, b.faults_injected);
        assert_eq!(a.ber_trace, b.ber_trace);
    }

    /// The estimator visibly tracks a rate ramp: the mean Wilson-upper
    /// BER across shards is higher at the peak of the ramp than in the
    /// cold first phase, and falls again after the ramp subsides.
    #[test]
    fn ber_trace_follows_the_ramp() {
        let cfg = SimConfig {
            n_weights: 16 * 1024,
            shards: 8,
            budget: 4,
            ..SimConfig::default()
        };
        let scenario = Scenario::ramp(11);
        let r = run_sim(&cfg, &scenario, ScrubPolicy::Adaptive).unwrap();
        let mean_at = |t: usize| -> f64 {
            let row = &r.ber_trace[t];
            row.iter().sum::<f64>() / row.len() as f64
        };
        // phase layout: 24 ticks each of 2e-6, 1e-5, 1e-4, 1e-5, 2e-6
        let cold = mean_at(20);
        let peak = mean_at(68);
        let cooled = mean_at(119);
        assert!(peak > cold * 2.0, "peak {peak} vs cold {cold}");
        assert!(cooled < peak / 2.0, "cooled {cooled} vs peak {peak}");
    }

    #[test]
    fn json_record_carries_the_comparison() {
        let cfg = SimConfig {
            n_weights: 8 * 1024,
            shards: 4,
            ..SimConfig::default()
        };
        let scenario = Scenario::hotspot_migration(5);
        let r = run_sim(&cfg, &scenario, ScrubPolicy::Adaptive).unwrap();
        let j = r.to_json(true);
        assert_eq!(j.req("policy").unwrap().as_str(), Some("adaptive"));
        assert_eq!(
            j.req("ber_trace").unwrap().as_arr().unwrap().len(),
            scenario.total_ticks() as usize
        );
        let no_trace = r.to_json(false);
        assert!(no_trace.get("ber_trace").is_none());
        assert!(render(&[&r]).contains("adaptive"));
    }

    /// The fleet acceptance test from the issue: a hotspot on model
    /// `a` with models `b` and `c` quiet. At equal total bandwidth the
    /// arbitrated allocation must keep every quiet model at or below
    /// its isolated-fair-share residual while strictly beating naive
    /// round-robin on the hot model — the budget visibly chases
    /// urgency across model boundaries.
    #[test]
    fn fleet_arbitration_beats_roundrobin_without_hurting_quiet_models() {
        let cfg = FleetSimConfig::default();
        let models = fleet_models(7);
        let (iso, rr, arb) = fleet_compare(&cfg, &models).unwrap();
        // equal fault streams and bandwidth no greater than the baselines
        for i in 0..models.len() {
            assert_eq!(iso.lanes[i].faults_injected, arb.lanes[i].faults_injected);
            assert_eq!(iso.lanes[i].faults_injected, rr.lanes[i].faults_injected);
        }
        assert_eq!(iso.total_passes, rr.total_passes);
        assert!(
            arb.total_passes <= iso.total_passes,
            "arbitrated must not outspend the baselines: {} vs {}",
            arb.total_passes,
            iso.total_passes
        );
        // hot model: strictly better than blind rotation
        assert!(
            arb.lanes[0].residual_uncorrectable < rr.lanes[0].residual_uncorrectable,
            "fleet {} vs roundrobin {}",
            arb.lanes[0].residual_uncorrectable,
            rr.lanes[0].residual_uncorrectable
        );
        // quiet models: no worse than their isolated fair share
        for i in 1..models.len() {
            assert!(
                arb.lanes[i].residual_uncorrectable <= iso.lanes[i].residual_uncorrectable,
                "quiet lane {i}: fleet {} vs isolated {}",
                arb.lanes[i].residual_uncorrectable,
                iso.lanes[i].residual_uncorrectable
            );
        }
        // the verdict helper agrees and the CI marker is present
        let verdict = fleet_verdict(&cfg, &iso, &rr, &arb).unwrap();
        assert!(verdict.starts_with("[fleet ok]"), "{verdict}");
        assert!(fleet_render(&[&iso, &rr, &arb]).contains("roundrobin"));
    }

    /// Starvation-freedom observable: under the arbitrated allocation
    /// no shard's inter-scrub gap may exceed
    /// `max_interval + starve_after + total_shards + 1` ticks, even
    /// with a hot shard soaking up budget every wakeup.
    #[test]
    fn fleet_gaps_stay_within_the_starvation_bound() {
        let cfg = FleetSimConfig::default();
        let models = fleet_models(13);
        let arb = run_fleet_sim(&cfg, &models, FleetAllocation::Arbitrated).unwrap();
        let bound = cfg.max_interval_ticks
            + u64::from(cfg.starve_after)
            + (cfg.shards * models.len()) as u64
            + 1;
        assert!(
            arb.max_gap_ticks <= bound,
            "gap {} exceeds bound {}",
            arb.max_gap_ticks,
            bound
        );
    }

    /// Fleet determinism: same seeds, same lanes, pass for pass.
    #[test]
    fn fleet_sim_is_deterministic_in_the_seed() {
        let cfg = FleetSimConfig::default();
        let models = fleet_models(3);
        let a = run_fleet_sim(&cfg, &models, FleetAllocation::Arbitrated).unwrap();
        let b = run_fleet_sim(&cfg, &models, FleetAllocation::Arbitrated).unwrap();
        assert_eq!(a.lanes, b.lanes);
        assert_eq!(a.total_passes, b.total_passes);
        assert_eq!(a.max_gap_ticks, b.max_gap_ticks);
    }

    #[test]
    fn fleet_json_record_carries_every_lane() {
        let cfg = FleetSimConfig::default();
        let models = fleet_models(5);
        let arb = run_fleet_sim(&cfg, &models, FleetAllocation::Arbitrated).unwrap();
        let j = arb.to_json();
        assert_eq!(j.req("allocation").unwrap().as_str(), Some("fleet"));
        assert_eq!(j.req("lanes").unwrap().as_arr().unwrap().len(), models.len());
    }
}
