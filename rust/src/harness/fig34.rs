//! Figures 3 and 4: the WOT training dynamics, from `<m>.wot_log.json`
//! (written by python/compile/wot.py at build time).
//!
//! Fig 3 — number of large values in positions 0..6 of 8-byte blocks
//! *before* each throttling step (decays toward 0 as training adapts).
//! Fig 4 — eval accuracy before vs after throttling (the gap closes and
//! the post-throttle accuracy recovers the int8 baseline).

use std::path::Path;

use crate::model::Manifest;
use crate::util::json::Json;
use crate::util::plot;

#[derive(Clone, Debug)]
pub struct WotLog {
    pub model: String,
    pub steps: Vec<f64>,
    pub n_large: Vec<f64>,
    pub acc_before: Vec<f64>,
    pub acc_after: Vec<f64>,
    pub final_acc: f64,
    pub int8_acc: f64,
}

pub fn load_log(path: &Path) -> anyhow::Result<WotLog> {
    let j = Json::parse(&std::fs::read_to_string(path)?)?;
    let nums = |key: &str| -> anyhow::Result<Vec<f64>> {
        Ok(j.req(key)?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_f64())
            .collect())
    };
    Ok(WotLog {
        model: j
            .get("model")
            .and_then(|m| m.as_str())
            .unwrap_or("?")
            .to_string(),
        steps: nums("step")?,
        n_large: nums("n_large")?,
        acc_before: nums("acc_before")?,
        acc_after: nums("acc_after")?,
        final_acc: j.req("final_acc")?.as_f64().unwrap_or(0.0),
        int8_acc: j
            .get("int8_acc")
            .and_then(|v| v.as_f64())
            .unwrap_or(f64::NAN),
    })
}

pub fn run(artifacts: &Path, models: &[String]) -> anyhow::Result<Vec<WotLog>> {
    models
        .iter()
        .map(|m| {
            let man = Manifest::load_model(artifacts, m)?;
            load_log(&man.wot_log_path())
        })
        .collect()
}

pub fn render_fig3(logs: &[WotLog]) -> String {
    let mut out = String::new();
    for l in logs {
        out.push_str(&plot::line_plot(
            &format!(
                "Fig 3 ({}): large values in positions 0..6 before throttling",
                l.model
            ),
            &l.steps,
            &[("n_large", l.n_large.clone())],
            10,
            60,
        ));
        out.push_str(&format!(
            "   start={} end={} (paper: thousands -> ~0)\n\n",
            l.n_large.first().unwrap_or(&0.0),
            l.n_large.last().unwrap_or(&0.0)
        ));
    }
    out
}

pub fn render_fig4(logs: &[WotLog]) -> String {
    let mut out = String::new();
    for l in logs {
        out.push_str(&plot::line_plot(
            &format!("Fig 4 ({}): accuracy before/after throttling", l.model),
            &l.steps,
            &[
                ("before", l.acc_before.clone()),
                ("after", l.acc_after.clone()),
            ],
            12,
            60,
        ));
        out.push_str(&format!(
            "   int8 baseline={:.4}  final (after WOT, throttled)={:.4}\n\n",
            l.int8_acc, l.final_acc
        ));
    }
    out
}

/// Machine-checkable shape claims for the integration test.
pub fn shape_checks(logs: &[WotLog]) -> Vec<(String, bool)> {
    let mut checks = Vec::new();
    for l in logs {
        let first = *l.n_large.first().unwrap_or(&0.0);
        let last = *l.n_large.last().unwrap_or(&0.0);
        checks.push((
            format!("{}: Fig3 large-count decays (start {first} -> end {last})", l.model),
            last <= first * 0.2 || last <= 16.0,
        ));
        checks.push((
            format!(
                "{}: Fig4 final acc recovers int8 within 3 points ({:.3} vs {:.3})",
                l.model, l.final_acc, l.int8_acc
            ),
            l.final_acc >= l.int8_acc - 0.03,
        ));
    }
    checks
}
