//! MILR-style algebraic weight recovery: reconstruct detected-
//! uncorrectable blocks from the layer equation instead of serving them
//! corrupted.
//!
//! The idea (MILR, PAPERS.md): a dense layer computes `Y = X · W`, so a
//! corrupted entry of `W` is over-determined by a calibration batch of
//! inputs `X` and checkpointed pre-activation outputs `Y` — solve the
//! layer equation for exactly the implicated coordinates and write the
//! result back. This is the recovery-of-last-resort tier behind every
//! stored-ECC strategy's uncorrectable path, and the *only* correction
//! tier of the zero-redundancy [`crate::ecc::milr`] strategy.
//!
//! The ladder, end to end:
//!
//! 1. **detect** — a decode/scrub pass reports the uncorrectable block
//!    set ([`crate::ecc::DecodeOutcome`]).
//! 2. **correct** — the stored code already fixed what it could.
//! 3. **recover** — [`recover_blocks`] maps each block through the
//!    manifest's layer table to `(layer, row, col)` coordinates, groups
//!    unknowns by `(layer, column)` (one linear system per column,
//!    jointly over every implicated block), solves the normal equations
//!    of `Y[:,c] = X · W[:,c]` by partial-pivot Gaussian elimination in
//!    f64, and re-quantizes to int8 on the strategy's quantization
//!    grid ([`crate::ecc::QuantGrid`] — plain WOT for the period-8
//!    strategies, extended WOT for `bch16`).
//! 4. **quarantine** — blocks whose system is underdetermined, singular,
//!    or fails verification come back on [`RecoveryOutcome`]'s typed
//!    quarantine list, not as panics; the caller records them and keeps
//!    serving. Failures are per column group, so one poisoned column
//!    never sinks the rest of the implicated set.
//!
//! Verification is two-fold: the residual of the recovered column
//! against the checkpointed `Y` must sit at the numerical noise floor
//! (a wrong solve is off by whole quantization steps), *and* the caller
//! re-encodes the block and checks the syndrome goes clean
//! ([`crate::memory::ShardedBank::apply_recovery`]) — the milr probe
//! alone cannot see byte-7/low-bit corruption, the residual can.
//!
//! Calibration data (`X` per layer, pre-ReLU `Y` per layer) is captured
//! by an extended `zsecc calibrate` and persisted as a
//! `<model>.recovery.json` sidecar next to the manifest — it holds float
//! activation planes, far too large to inline into the manifest itself.

use crate::ecc::QuantGrid;
use crate::model::manifest::Layer;
use crate::runtime::guard::DenseModel;
use crate::util::json::{arr, num, obj, s, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------- mode --

/// Whether the recovery tier is armed (campaign axis, serve flag).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoveryMode {
    /// Detected-uncorrectable blocks are served as stored (the pre-PR-8
    /// behavior, and the ledger-compatible default).
    Off,
    /// Escalate to algebraic layer reconstruction.
    Milr,
}

impl RecoveryMode {
    /// Stable tag — ledger keys, JSON reports, CLI. `parse` accepts
    /// every string `tag` produces.
    pub fn tag(self) -> &'static str {
        match self {
            RecoveryMode::Off => "off",
            RecoveryMode::Milr => "milr",
        }
    }

    pub fn parse(text: &str) -> anyhow::Result<RecoveryMode> {
        match text {
            "off" => Ok(RecoveryMode::Off),
            "milr" => Ok(RecoveryMode::Milr),
            _ => anyhow::bail!("unknown recovery mode '{text}' (off | milr)"),
        }
    }
}

// ------------------------------------------------------------- dataset --

/// Calibration record of one dense layer: the input plane `x` (batch ×
/// rows) and the checkpointed pre-activation output `y = x · w`
/// (batch × cols), both captured on clean weights.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerCalib {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub x: Vec<f32>,
    pub y: Vec<f32>,
}

/// The persisted recovery calibration set (`<model>.recovery.json`).
#[derive(Clone, Debug, PartialEq)]
pub struct RecoverySet {
    /// Calibration batch size — the row count of every system; recovery
    /// of `k` joint unknowns in one column needs `batch >= k`.
    pub batch: usize,
    pub layers: Vec<LayerCalib>,
}

impl RecoverySet {
    /// Capture a recovery set from a guarded dense model on one clean
    /// batch: per layer, the input plane and the *pre-ReLU* matmul
    /// output (the exact `Y = X · W` relation the solver inverts).
    /// `names[l]` labels layer `l` (use the manifest layer names so the
    /// block map can find its calibration).
    pub fn capture(model: &DenseModel, names: &[String], x: &[f32], batch: usize) -> RecoverySet {
        assert_eq!(names.len(), model.layers.len(), "one name per layer");
        let mut layers = Vec::with_capacity(model.layers.len());
        let mut act = x.to_vec();
        for (l, layer) in model.layers.iter().enumerate() {
            let mut y = vec![0f32; batch * layer.cols];
            layer.matmul(&act, batch, &mut y);
            layers.push(LayerCalib {
                name: names[l].clone(),
                rows: layer.rows,
                cols: layer.cols,
                x: act.clone(),
                y: y.clone(),
            });
            if l + 1 < model.layers.len() {
                y.iter_mut().for_each(|v| *v = v.max(0.0));
            }
            act = y;
        }
        RecoverySet { batch, layers }
    }

    pub fn layer(&self, name: &str) -> Option<&LayerCalib> {
        self.layers.iter().find(|l| l.name == name)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("batch", num(self.batch as f64)),
            (
                "layers",
                arr(self.layers.iter().map(|l| {
                    obj(vec![
                        ("name", s(&l.name)),
                        ("rows", num(l.rows as f64)),
                        ("cols", num(l.cols as f64)),
                        ("x", arr(l.x.iter().map(|&v| num(f64::from(v))))),
                        ("y", arr(l.y.iter().map(|&v| num(f64::from(v))))),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<RecoverySet> {
        let batch = v
            .req("batch")?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("recovery 'batch' must be a number"))?;
        let mut layers = Vec::new();
        for lv in v
            .req("layers")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("recovery 'layers' must be an array"))?
        {
            let name = lv
                .req("name")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("recovery layer 'name' must be a string"))?
                .to_string();
            let rows = lv.req("rows")?.as_usize().unwrap_or(0);
            let cols = lv.req("cols")?.as_usize().unwrap_or(0);
            let plane = |k: &str, want: usize| -> anyhow::Result<Vec<f32>> {
                let xs: Vec<f32> = lv
                    .req(k)?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("recovery layer '{name}' '{k}' must be an array"))?
                    .iter()
                    .filter_map(|x| x.as_f64())
                    .map(|x| x as f32)
                    .collect();
                anyhow::ensure!(
                    xs.len() == want,
                    "recovery layer '{name}' '{k}' holds {} values, wants {want}",
                    xs.len()
                );
                Ok(xs)
            };
            let x = plane("x", batch * rows)?;
            let y = plane("y", batch * cols)?;
            layers.push(LayerCalib {
                name,
                rows,
                cols,
                x,
                y,
            });
        }
        anyhow::ensure!(!layers.is_empty(), "recovery set holds no layers");
        Ok(RecoverySet { batch, layers })
    }

    /// `<model>.recovery.json` next to the manifest.
    pub fn sidecar_path(dir: &Path, model: &str) -> PathBuf {
        dir.join(format!("{model}.recovery.json"))
    }

    /// Persist (write-to-temp + rename, like the manifest's guards key).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json().to_string())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| anyhow::anyhow!("publishing {}: {e}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<RecoverySet> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        RecoverySet::from_json(&Json::parse(&text)?)
    }
}

// ----------------------------------------------------------- block map --

/// One dense layer's geometry in the flat weight buffer — the shape the
/// solver understands. Derived from manifest [`Layer`]s (2-D shapes) or
/// built directly by synthetic runners.
#[derive(Clone, Debug)]
pub struct DenseShape {
    pub name: String,
    /// Element offset into the flat int8 buffer.
    pub offset: usize,
    pub rows: usize,
    pub cols: usize,
    /// Dequantization scale: `w_f32 = w_i8 * scale`.
    pub scale: f32,
}

impl DenseShape {
    pub fn size(&self) -> usize {
        self.rows * self.cols
    }
}

/// Convert a manifest layer table into solver shapes. Layers whose
/// shape is not 2-D are kept as placeholders with `rows = 0` — mapping
/// a block into one yields [`RecoveryError::NotDense`] rather than a
/// silent skip.
pub fn dense_shapes(layers: &[Layer]) -> Vec<DenseShape> {
    layers
        .iter()
        .map(|l| {
            let (rows, cols) = match l.shape[..] {
                [r, c] => (r, c),
                _ => (0, l.size),
            };
            DenseShape {
                name: l.name.clone(),
                offset: l.offset,
                rows,
                cols,
                scale: l.scale,
            }
        })
        .collect()
}

// -------------------------------------------------------------- errors --

/// Typed graceful-degradation signal: why a block could not be
/// recovered. Callers quarantine, they do not panic.
#[derive(Clone, Debug, PartialEq)]
pub enum RecoveryError {
    /// No calibration record for the layer the block lives in.
    NoCalibration(String),
    /// The block maps into a layer the solver has no equation for.
    NotDense(String),
    /// More joint unknowns in one column than calibration rows.
    Underdetermined {
        layer: String,
        col: usize,
        unknowns: usize,
        batch: usize,
    },
    /// The normal equations are rank-deficient (degenerate inputs).
    Singular { layer: String, col: usize },
    /// The recovered column does not reproduce the checkpointed `Y`.
    VerifyFailed {
        layer: String,
        col: usize,
        residual: f64,
    },
}

impl std::fmt::Display for RecoveryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecoveryError::NoCalibration(l) => {
                write!(f, "no recovery calibration for layer '{l}' (run `zsecc calibrate`)")
            }
            RecoveryError::NotDense(l) => {
                write!(f, "layer '{l}' is not a dense matrix — no layer equation to solve")
            }
            RecoveryError::Underdetermined {
                layer,
                col,
                unknowns,
                batch,
            } => write!(
                f,
                "layer '{layer}' column {col}: {unknowns} joint unknowns exceed the \
                 {batch}-row calibration batch"
            ),
            RecoveryError::Singular { layer, col } => {
                write!(f, "layer '{layer}' column {col}: normal equations are singular")
            }
            RecoveryError::VerifyFailed {
                layer,
                col,
                residual,
            } => write!(
                f,
                "layer '{layer}' column {col}: recovered weights miss the checkpointed \
                 outputs (residual {residual:.3e})"
            ),
        }
    }
}

impl std::error::Error for RecoveryError {}

// -------------------------------------------------------------- solver --

/// One recovered block: the int8 weights to hand to
/// [`crate::memory::ShardedBank::apply_recovery`].
#[derive(Clone, Debug, PartialEq)]
pub struct RecoveredBlock {
    pub block: usize,
    pub weights: Vec<i8>,
}

/// The recovery tier's answer: fully reconstructed blocks plus the
/// typed quarantine list for everything it could not vouch for. Never
/// a panic, never a partial block — a block is recovered only when
/// *every* column system it touches solved and verified.
#[derive(Clone, Debug, Default)]
pub struct RecoveryOutcome {
    /// Blocks whose every column solved and verified, in block order.
    pub recovered: Vec<RecoveredBlock>,
    /// Quarantined blocks, in block order, each with the first error
    /// that implicated it. The caller keeps serving the stored bytes
    /// for these and records them — graceful degradation, not a crash.
    pub quarantined: Vec<(usize, RecoveryError)>,
}

/// Solve the layer equations for every implicated block.
///
/// * `weights` — the current decoded flat int8 buffer; entries outside
///   the implicated blocks are trusted and move to the right-hand side.
/// * `blocks` — detected-uncorrectable block indices (each covers
///   `block_bytes` consecutive flat elements; every element of an
///   implicated block is treated as unknown).
///
/// Unknowns are grouped by `(layer, column)` and solved *jointly*
/// across blocks — two implicated blocks sharing a column become one
/// system, not two inconsistent ones. Each recovered column is verified
/// against the checkpointed `Y` before anything is accepted: a residual
/// above the noise floor (a wrong solve is off by whole quantization
/// steps) quarantines the column's blocks rather than handing back
/// plausible garbage. Failures are *per column group*: silent
/// corruption poisoning one column (e.g. flips the milr probe cannot
/// see) quarantines only the blocks sharing that column — every other
/// implicated block still recovers.
pub fn recover_blocks(
    set: &RecoverySet,
    shapes: &[DenseShape],
    weights: &[i8],
    blocks: &[usize],
    block_bytes: usize,
    grid: QuantGrid,
) -> RecoveryOutcome {
    let bb = block_bytes.max(1);
    let mut blist: Vec<usize> = blocks.to_vec();
    blist.sort_unstable();
    blist.dedup();
    // map blocks -> per-(layer, col) unknown row sets + member blocks
    let mut unknown: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
    let mut members: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
    let mut failed: BTreeMap<usize, RecoveryError> = BTreeMap::new();
    'blocks: for &b in &blist {
        // map the whole block before committing any unknowns: a block
        // that half-maps must not leave stray unknowns behind
        let mut coords = Vec::with_capacity(bb);
        for e in b * bb..(b + 1) * bb {
            let li = shapes
                .iter()
                .position(|sh| e >= sh.offset && e < sh.offset + sh.size().max(1));
            let li = match li {
                Some(li) if shapes[li].rows > 0 => li,
                Some(li) => {
                    failed.insert(b, RecoveryError::NotDense(shapes[li].name.clone()));
                    continue 'blocks;
                }
                None => {
                    failed.insert(b, RecoveryError::NotDense(format!("element {e}")));
                    continue 'blocks;
                }
            };
            let el = e - shapes[li].offset;
            coords.push((li, el / shapes[li].cols, el % shapes[li].cols));
        }
        for (li, row, col) in coords {
            let rows = unknown.entry((li, col)).or_default();
            if !rows.contains(&row) {
                rows.push(row);
            }
            let mem = members.entry((li, col)).or_default();
            if !mem.contains(&b) {
                mem.push(b);
            }
        }
    }
    // recovered flat values, keyed by element index
    let mut recovered: BTreeMap<usize, i8> = BTreeMap::new();
    for ((li, col), mut rows) in unknown {
        rows.sort_unstable();
        match solve_column(set, &shapes[li], weights, &rows, col, grid) {
            Ok(vals) => recovered.extend(vals),
            Err(e) => {
                for &b in &members[&(li, col)] {
                    failed.entry(b).or_insert_with(|| e.clone());
                }
            }
        }
    }
    let mut out = RecoveryOutcome::default();
    for b in blist {
        match failed.remove(&b) {
            Some(err) => out.quarantined.push((b, err)),
            None => out.recovered.push(RecoveredBlock {
                block: b,
                weights: (b * bb..(b + 1) * bb)
                    .map(|e| recovered.get(&e).copied().unwrap_or(weights[e]))
                    .collect(),
            }),
        }
    }
    out
}

/// Solve one `(layer, column)` system: least squares over the
/// calibration batch for the unknown `rows`, re-quantized onto the
/// strategy's quantization grid and verified against the checkpointed
/// `Y`. Returns the recovered `(flat element, value)` pairs, or the
/// typed reason the column cannot be trusted.
fn solve_column(
    set: &RecoverySet,
    sh: &DenseShape,
    weights: &[i8],
    rows: &[usize],
    col: usize,
    grid: QuantGrid,
) -> Result<Vec<(usize, i8)>, RecoveryError> {
    let calib = set
        .layer(&sh.name)
        .ok_or_else(|| RecoveryError::NoCalibration(sh.name.clone()))?;
    let k = rows.len();
    let bsz = set.batch;
    if bsz < k {
        return Err(RecoveryError::Underdetermined {
            layer: sh.name.clone(),
            col,
            unknowns: k,
            batch: bsz,
        });
    }
    let scale = f64::from(sh.scale);
    // rhs_b = Y[b, col] - sum_{d not unknown} X[b, d] * w[d, col]
    let mut a = vec![0f64; bsz * k]; // X restricted to unknown rows
    let mut rhs = vec![0f64; bsz];
    for b in 0..bsz {
        let xr = &calib.x[b * calib.rows..(b + 1) * calib.rows];
        let mut acc = f64::from(calib.y[b * calib.cols + col]);
        let mut next = 0usize;
        for (d, &xv) in xr.iter().enumerate() {
            if next < k && rows[next] == d {
                a[b * k + next] = f64::from(xv);
                next += 1;
            } else {
                let w = f64::from(weights[sh.offset + d * sh.cols + col]) * scale;
                acc -= f64::from(xv) * w;
            }
        }
        rhs[b] = acc;
    }
    // normal equations M z = g
    let mut m = vec![0f64; k * k];
    let mut g = vec![0f64; k];
    for b in 0..bsz {
        for i in 0..k {
            let ai = a[b * k + i];
            g[i] += ai * rhs[b];
            for j in 0..k {
                m[i * k + j] += ai * a[b * k + j];
            }
        }
    }
    let z = solve_gauss(&mut m, &mut g, k).ok_or(RecoveryError::Singular {
        layer: sh.name.clone(),
        col,
    })?;
    // re-quantize onto the strategy's int8 grid
    let vals: Vec<(usize, i8)> = rows
        .iter()
        .zip(&z)
        .map(|(&r, &zi)| {
            let e = sh.offset + r * sh.cols + col;
            let q = (zi / scale).round();
            let (lo, hi) = grid.bounds(e);
            (e, q.clamp(lo, hi) as i8)
        })
        .collect();
    // verify: the recovered column must reproduce the checkpointed Y
    // at the float noise floor — a wrong solve misses by whole
    // quantization steps
    let (mut res, mut mass) = (0f64, 0f64);
    for b in 0..bsz {
        let xr = &calib.x[b * calib.rows..(b + 1) * calib.rows];
        let mut yhat = 0f64;
        let mut next = 0usize;
        for (d, &xv) in xr.iter().enumerate() {
            let e = sh.offset + d * sh.cols + col;
            let q = if next < k && rows[next] == d {
                next += 1;
                vals[next - 1].1
            } else {
                weights[e]
            };
            let w = f64::from(q) * scale;
            yhat += f64::from(xv) * w;
            mass += f64::from(xv).abs() * w.abs();
        }
        res += (yhat - f64::from(calib.y[b * calib.cols + col])).abs();
    }
    if res > 1e-3 * mass + 1e-6 {
        return Err(RecoveryError::VerifyFailed {
            layer: sh.name.clone(),
            col,
            residual: res,
        });
    }
    Ok(vals)
}

/// Gaussian elimination with partial pivoting on `m` (k×k, row-major)
/// and `g` (k); returns the solution or `None` on a (near-)singular
/// pivot.
fn solve_gauss(m: &mut [f64], g: &mut [f64], k: usize) -> Option<Vec<f64>> {
    let scale = m
        .iter()
        .fold(0f64, |acc, &v| acc.max(v.abs()))
        .max(f64::MIN_POSITIVE);
    for p in 0..k {
        let (mut best, mut mag) = (p, m[p * k + p].abs());
        for r in p + 1..k {
            if m[r * k + p].abs() > mag {
                best = r;
                mag = m[r * k + p].abs();
            }
        }
        if mag <= 1e-12 * scale {
            return None;
        }
        if best != p {
            for c in 0..k {
                m.swap(p * k + c, best * k + c);
            }
            g.swap(p, best);
        }
        let piv = m[p * k + p];
        for r in p + 1..k {
            let f = m[r * k + p] / piv;
            if f == 0.0 {
                continue;
            }
            for c in p..k {
                m[r * k + c] -= f * m[p * k + c];
            }
            g[r] -= f * g[p];
        }
    }
    let mut z = vec![0f64; k];
    for p in (0..k).rev() {
        let mut acc = g[p];
        for c in p + 1..k {
            acc -= m[p * k + c] * z[c];
        }
        z[p] = acc / m[p * k + p];
    }
    Some(z)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::guard::DenseLayer;
    use crate::util::rng::Rng;

    /// A quantized dense model plus its exact calibration set: weights
    /// on the WOT grid, X random, Y = X · (W * scale) in f32 — the same
    /// arithmetic the serving forward pass uses.
    fn synth(
        rows: usize,
        cols: usize,
        batch: usize,
        scale: f32,
        seed: u64,
    ) -> (Vec<i8>, DenseShape, RecoverySet) {
        let mut rng = Rng::new(seed);
        let w: Vec<i8> = (0..rows * cols)
            .map(|i| {
                if i % 8 == 7 {
                    (rng.below(256) as i64 - 128) as i8
                } else {
                    (rng.below(128) as i64 - 64) as i8
                }
            })
            .collect();
        let wf: Vec<f32> = w.iter().map(|&v| f32::from(v) * scale).collect();
        let layer = DenseLayer::new(wf, rows, cols).unwrap();
        let x: Vec<f32> = (0..batch * rows)
            .map(|_| (rng.f64() * 2.0 - 1.0) as f32)
            .collect();
        let mut y = vec![0f32; batch * cols];
        layer.matmul(&x, batch, &mut y);
        let shape = DenseShape {
            name: "w".into(),
            offset: 0,
            rows,
            cols,
            scale,
        };
        let set = RecoverySet {
            batch,
            layers: vec![LayerCalib {
                name: "w".into(),
                rows,
                cols,
                x,
                y,
            }],
        };
        (w, shape, set)
    }

    #[test]
    fn recovers_a_corrupted_block_exactly() {
        let (w, shape, set) = synth(16, 8, 32, 0.02, 5);
        let mut bad = w.clone();
        // block 3 = elements 24..32 = row 3 of the 16x8 matrix, trashed
        for e in 24..32 {
            bad[e] = bad[e].wrapping_add(37);
        }
        let out = recover_blocks(&set, &[shape], &bad, &[3], 8, QuantGrid::WOT8);
        assert!(out.quarantined.is_empty());
        let rec = out.recovered;
        assert_eq!(rec.len(), 1);
        assert_eq!(rec[0].block, 3);
        assert_eq!(rec[0].weights, w[24..32], "exact reconstruction");
    }

    #[test]
    fn joint_recovery_of_blocks_sharing_columns() {
        // 8-column rows: blocks 2 and 6 are rows 2 and 6 — every column
        // has two joint unknowns, exercising the k=2 solve
        let (w, shape, set) = synth(8, 8, 24, 0.05, 7);
        let mut bad = w.clone();
        for e in (2 * 8..3 * 8).chain(6 * 8..7 * 8) {
            bad[e] ^= 0x55;
        }
        let out = recover_blocks(&set, &[shape], &bad, &[6, 2, 6], 8, QuantGrid::WOT8);
        assert!(out.quarantined.is_empty());
        let rec = out.recovered;
        assert_eq!(rec.len(), 2, "deduped, sorted");
        assert_eq!(rec[0].block, 2);
        assert_eq!(rec[0].weights, w[16..24]);
        assert_eq!(rec[1].weights, w[48..56]);
    }

    #[test]
    fn ragged_blocks_span_rows_and_still_recover() {
        // cols = 12: an 8-element block covers parts of two rows, so the
        // per-column systems have one unknown each but the block map
        // must split coordinates correctly
        let (w, shape, set) = synth(6, 12, 16, 0.03, 9);
        let mut bad = w.clone();
        for e in 8..16 {
            bad[e] = bad[e].wrapping_sub(19);
        }
        let out = recover_blocks(&set, &[shape], &bad, &[1], 8, QuantGrid::WOT8);
        assert!(out.quarantined.is_empty());
        assert_eq!(out.recovered[0].weights, w[8..16]);
    }

    #[test]
    fn underdetermined_and_missing_calibration_are_typed() {
        let (w, shape, mut set) = synth(16, 8, 2, 0.02, 11);
        // batch 2 < 3 joint unknowns per column (blocks 0, 1, 2 = rows 0..3)
        let out = recover_blocks(&set, &[shape.clone()], &w, &[0, 1, 2], 8, QuantGrid::WOT8);
        assert!(out.recovered.is_empty());
        assert_eq!(out.quarantined.len(), 3, "every implicated block quarantined");
        assert!(
            matches!(
                out.quarantined[0].1,
                RecoveryError::Underdetermined { unknowns: 3, batch: 2, .. }
            ),
            "{}",
            out.quarantined[0].1
        );
        set.layers[0].name = "other".into();
        let out = recover_blocks(&set, &[shape.clone()], &w, &[0], 8, QuantGrid::WOT8);
        assert!(matches!(out.quarantined[..], [(0, RecoveryError::NoCalibration(_))]));
        // a non-dense placeholder refuses with NotDense
        let flat = DenseShape {
            rows: 0,
            ..shape
        };
        let out = recover_blocks(&set, &[flat], &w, &[0], 8, QuantGrid::WOT8);
        assert!(matches!(out.quarantined[..], [(0, RecoveryError::NotDense(_))]));
    }

    #[test]
    fn degenerate_inputs_are_singular_not_wrong() {
        let (w, shape, mut set) = synth(8, 8, 16, 0.05, 13);
        // zero out the calibration column for row 4: block 4's unknowns
        // have no observable effect -> singular normal equations
        for b in 0..16 {
            set.layers[0].x[b * 8 + 4] = 0.0;
        }
        // recompute y to stay consistent with the zeroed inputs
        let wf: Vec<f32> = w.iter().map(|&v| f32::from(v) * 0.05).collect();
        let layer = DenseLayer::new(wf, 8, 8).unwrap();
        let mut y = vec![0f32; 16 * 8];
        layer.matmul(&set.layers[0].x, 16, &mut y);
        set.layers[0].y = y;
        let out = recover_blocks(&set, &[shape], &w, &[4], 8, QuantGrid::WOT8);
        assert!(out.recovered.is_empty());
        assert!(
            matches!(out.quarantined[..], [(4, RecoveryError::Singular { .. })]),
            "{:?}",
            out.quarantined
        );
    }

    #[test]
    fn inconsistent_calibration_fails_verification() {
        let (w, shape, mut set) = synth(16, 8, 32, 0.02, 15);
        // poison the checkpointed outputs: the solve cannot reproduce
        // them on the int8 grid and must refuse
        for v in &mut set.layers[0].y {
            *v += 1000.0 * (0.5 - (*v).signum() as f32 * 0.25);
        }
        // make the corruption non-affine so no exact solution exists
        set.layers[0].y[3] *= -7.0;
        let out = recover_blocks(&set, &[shape], &w, &[2], 8, QuantGrid::WOT8);
        assert!(
            out.recovered.is_empty(),
            "poisoned Y must not yield a 'recovered' block: {out:?}"
        );
        assert!(matches!(
            out.quarantined[..],
            [(2, RecoveryError::VerifyFailed { .. })] | [(2, RecoveryError::Singular { .. })]
        ));
    }

    #[test]
    fn partial_failure_quarantines_only_the_implicated_blocks() {
        // 16-column rows: block 0 covers row 0 / cols 0..8, block 5
        // covers row 2 / cols 8..16 — disjoint column groups. Poisoning
        // checkpointed column 3 must quarantine block 0 alone; block 5
        // still recovers exactly.
        let (w, shape, mut set) = synth(8, 16, 24, 0.02, 21);
        let mut bad = w.clone();
        for e in 0..8 {
            bad[e] = bad[e].wrapping_add(23);
        }
        for e in 40..48 {
            bad[e] = bad[e].wrapping_sub(17);
        }
        for b in 0..24 {
            set.layers[0].y[b * 16 + 3] = -1e3;
        }
        let out = recover_blocks(&set, &[shape], &bad, &[0, 5], 8, QuantGrid::WOT8);
        assert_eq!(out.recovered.len(), 1, "{:?}", out.quarantined);
        assert_eq!(out.recovered[0].block, 5);
        assert_eq!(out.recovered[0].weights, w[40..48], "exact reconstruction");
        assert!(matches!(
            out.quarantined[..],
            [(0, RecoveryError::VerifyFailed { .. })]
        ));
    }

    #[test]
    fn recovery_set_json_roundtrips_via_sidecar() {
        let (_, _, set) = synth(8, 8, 4, 0.05, 17);
        let back = RecoverySet::from_json(&set.to_json()).unwrap();
        assert_eq!(back, set);
        let dir = std::env::temp_dir().join("zsecc_recovery_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = RecoverySet::sidecar_path(&dir, "m");
        assert!(path.ends_with("m.recovery.json"));
        set.save(&path).unwrap();
        assert_eq!(RecoverySet::load(&path).unwrap(), set);
    }

    #[test]
    fn capture_records_pre_relu_planes() {
        let mut rng = Rng::new(19);
        let w: Vec<f32> = (0..16 * 8 + 8 * 4).map(|_| (rng.f64() - 0.5) as f32).collect();
        let model = DenseModel::from_flat(&w, &[(16, 8), (8, 4)]).unwrap();
        let x: Vec<f32> = (0..3 * 16).map(|_| rng.f64() as f32).collect();
        let set = RecoverySet::capture(&model, &["a".into(), "b".into()], &x, 3);
        assert_eq!(set.batch, 3);
        assert_eq!(set.layers[0].name, "a");
        assert_eq!(set.layers[0].x, x);
        // layer 1's input is ReLU(layer 0 pre-activation)
        let relu: Vec<f32> = set.layers[0].y.iter().map(|v| v.max(0.0)).collect();
        assert_eq!(set.layers[1].x, relu);
        // y really is X · W (check one element in f64)
        let mut want = 0f64;
        for d in 0..16 {
            want += f64::from(x[d]) * f64::from(w[d * 8]);
        }
        assert!((f64::from(set.layers[0].y[0]) - want).abs() < 1e-4);
    }

    #[test]
    fn dense_shapes_follow_the_manifest() {
        let layers = vec![
            Layer {
                name: "a".into(),
                shape: vec![4, 8],
                offset: 0,
                size: 32,
                scale: 0.5,
                scale_prewot: 0.5,
            },
            Layer {
                name: "b".into(),
                shape: vec![16],
                offset: 32,
                size: 16,
                scale: 0.25,
                scale_prewot: 0.25,
            },
        ];
        let shapes = dense_shapes(&layers);
        assert_eq!((shapes[0].rows, shapes[0].cols), (4, 8));
        assert_eq!(shapes[0].offset, 0);
        assert_eq!(shapes[1].rows, 0, "1-D layer is a NotDense placeholder");
    }
}
