//! Artifact loaders: model manifests, int8 weight buffers, eval dataset.

pub mod dataset;
pub mod manifest;

pub use dataset::EvalSet;
pub use manifest::{Layer, Manifest};

use std::path::Path;

/// Read a raw int8 weight buffer (`<model>.weights.bin` / `.prewot.bin`).
pub fn load_weights(path: &Path, expect_len: usize) -> anyhow::Result<Vec<i8>> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    anyhow::ensure!(
        bytes.len() == expect_len,
        "{}: expected {} weights, found {} bytes",
        path.display(),
        expect_len,
        bytes.len()
    );
    Ok(bytes.into_iter().map(|b| b as i8).collect())
}
