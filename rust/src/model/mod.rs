//! Artifact loaders (model manifests, int8 weight buffers, eval
//! dataset) plus the MILR recovery tier: [`recovery`] reconstructs
//! detected-uncorrectable weight blocks from the layer equation using a
//! persisted calibration sidecar (`<model>.recovery.json`).

pub mod dataset;
pub mod manifest;
pub mod recovery;

pub use dataset::EvalSet;
pub use manifest::{Layer, Manifest};
pub use recovery::{
    dense_shapes, recover_blocks, DenseShape, RecoveredBlock, RecoveryError, RecoveryMode,
    RecoveryOutcome, RecoverySet,
};

use std::path::Path;

/// Read a raw int8 weight buffer (`<model>.weights.bin` / `.prewot.bin`).
pub fn load_weights(path: &Path, expect_len: usize) -> anyhow::Result<Vec<i8>> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    anyhow::ensure!(
        bytes.len() == expect_len,
        "{}: expected {} weights, found {} bytes",
        path.display(),
        expect_len,
        bytes.len()
    );
    Ok(bytes.into_iter().map(|b| b as i8).collect())
}
