//! `<model>.manifest.json` — the contract between the python build path
//! and the rust runtime: layer table (offsets into the flat int8 buffer,
//! shapes, frozen dequantization scales), reference accuracies, and the
//! artifact file index.

use crate::runtime::guard::Calibration;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One protected tensor (conv/dense weight) in the flat buffer.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub shape: Vec<usize>,
    /// Element offset into the flat int8 buffer.
    pub offset: usize,
    /// Element count (always a multiple of 8: whole 64-bit blocks).
    pub size: usize,
    /// Frozen dequantization scale (post-WOT grid).
    pub scale: f32,
    /// Dequantization scale of the pre-WOT buffer (Table-1 path).
    pub scale_prewot: f32,
}

/// Parsed `<model>.manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub num_classes: usize,
    pub input_dim: usize,
    pub num_weights: usize,
    pub float_acc: f64,
    pub int8_acc: f64,
    pub wot_acc: f64,
    pub batches: Vec<usize>,
    pub pallas_batch: usize,
    pub layers: Vec<Layer>,
    /// File names relative to the artifacts dir.
    pub weights_file: String,
    pub prewot_file: String,
    pub wot_log_file: String,
    pub hlo: BTreeMap<usize, String>,
    pub hlo_pallas: BTreeMap<usize, String>,
    pub hlo_prewot: BTreeMap<usize, String>,
    /// Compute-path guard calibration (activation envelopes), written
    /// back by `zsecc calibrate`; absent until a calibration pass ran.
    pub guards: Option<Calibration>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

fn batch_map(j: &Json) -> anyhow::Result<BTreeMap<usize, String>> {
    let mut out = BTreeMap::new();
    if let Some(obj) = j.as_obj() {
        for (k, v) in obj {
            out.insert(
                k.parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("bad batch key '{k}'"))?,
                v.as_str()
                    .ok_or_else(|| anyhow::anyhow!("file name must be a string"))?
                    .to_string(),
            );
        }
    }
    Ok(out)
}

impl Manifest {
    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let j = Json::parse(&text)?;
        let layers_j = j.req("layers")?.as_arr().unwrap_or(&[]);
        let mut layers = Vec::with_capacity(layers_j.len());
        for l in layers_j {
            layers.push(Layer {
                name: l.req("name")?.as_str().unwrap_or("").to_string(),
                shape: l
                    .req("shape")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect(),
                offset: l.req("offset")?.as_usize().unwrap_or(0),
                size: l.req("size")?.as_usize().unwrap_or(0),
                scale: l.req("scale")?.as_f64().unwrap_or(0.0) as f32,
                scale_prewot: l.req("scale_prewot")?.as_f64().unwrap_or(0.0) as f32,
            });
        }
        let files = j.req("files")?;
        let man = Manifest {
            model: j.req("model")?.as_str().unwrap_or("").to_string(),
            num_classes: j.req("num_classes")?.as_usize().unwrap_or(0),
            input_dim: j.req("input_dim")?.as_usize().unwrap_or(0),
            num_weights: j.req("num_weights")?.as_usize().unwrap_or(0),
            float_acc: j.req("float_acc")?.as_f64().unwrap_or(0.0),
            int8_acc: j.req("int8_acc")?.as_f64().unwrap_or(0.0),
            wot_acc: j.req("wot_acc")?.as_f64().unwrap_or(0.0),
            batches: j
                .req("batches")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|b| b.as_usize())
                .collect(),
            pallas_batch: j.req("pallas_batch")?.as_usize().unwrap_or(0),
            layers,
            weights_file: files.req("weights")?.as_str().unwrap_or("").to_string(),
            prewot_file: files.req("prewot")?.as_str().unwrap_or("").to_string(),
            wot_log_file: files.req("wot_log")?.as_str().unwrap_or("").to_string(),
            hlo: batch_map(files.req("hlo")?)?,
            hlo_pallas: batch_map(files.req("hlo_pallas")?)?,
            hlo_prewot: batch_map(files.req("hlo_prewot")?)?,
            guards: match j.get("guards") {
                Some(Json::Null) | None => None,
                Some(g) => Some(Calibration::from_json(g)?),
            },
            dir: path.parent().unwrap_or(Path::new(".")).to_path_buf(),
        };
        man.validate()?;
        Ok(man)
    }

    /// Load by model name from an artifacts directory.
    pub fn load_model(dir: &Path, model: &str) -> anyhow::Result<Manifest> {
        Self::load(&dir.join(format!("{model}.manifest.json")))
    }

    /// Structural invariants the python exporter guarantees.
    pub fn validate(&self) -> anyhow::Result<()> {
        let mut at = 0usize;
        for l in &self.layers {
            anyhow::ensure!(
                l.offset == at,
                "layer {} offset {} != running total {at}",
                l.name,
                l.offset
            );
            anyhow::ensure!(l.size % 8 == 0, "layer {} size not block-aligned", l.name);
            anyhow::ensure!(
                l.size == l.shape.iter().product::<usize>(),
                "layer {} size/shape mismatch",
                l.name
            );
            anyhow::ensure!(l.scale > 0.0, "layer {} scale must be positive", l.name);
            at += l.size;
        }
        anyhow::ensure!(
            at == self.num_weights,
            "layers tile {} weights, manifest says {}",
            at,
            self.num_weights
        );
        Ok(())
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join(&self.weights_file)
    }
    pub fn prewot_path(&self) -> PathBuf {
        self.dir.join(&self.prewot_file)
    }
    pub fn wot_log_path(&self) -> PathBuf {
        self.dir.join(&self.wot_log_file)
    }
    pub fn hlo_path(&self, batch: usize) -> anyhow::Result<PathBuf> {
        self.hlo
            .get(&batch)
            .map(|f| self.dir.join(f))
            .ok_or_else(|| anyhow::anyhow!("no HLO artifact for batch {batch}"))
    }
    pub fn hlo_pallas_path(&self, batch: usize) -> anyhow::Result<PathBuf> {
        self.hlo_pallas
            .get(&batch)
            .map(|f| self.dir.join(f))
            .ok_or_else(|| anyhow::anyhow!("no pallas HLO artifact for batch {batch}"))
    }
    pub fn hlo_prewot_path(&self, batch: usize) -> anyhow::Result<PathBuf> {
        self.hlo_prewot
            .get(&batch)
            .map(|f| self.dir.join(f))
            .ok_or_else(|| anyhow::anyhow!("no prewot HLO artifact for batch {batch}"))
    }

    /// Persist a guard calibration into the manifest file (the
    /// `guards` key is replaced, everything else round-trips through
    /// the parser untouched). Write-to-temp + rename so an interrupted
    /// calibration never leaves a truncated manifest.
    pub fn save_guards(&self, calib: &Calibration) -> anyhow::Result<()> {
        let path = self.dir.join(format!("{}.manifest.json", self.model));
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let mut j = Json::parse(&text)?;
        match &mut j {
            Json::Obj(m) => {
                m.insert("guards".to_string(), calib.to_json());
            }
            _ => anyhow::bail!("manifest {} is not a JSON object", path.display()),
        }
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, j.to_string())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| anyhow::anyhow!("publishing {}: {e}", path.display()))?;
        Ok(())
    }

    /// Layers with prewot scales substituted (Table-1 path).
    pub fn layers_prewot(&self) -> Vec<Layer> {
        self.layers
            .iter()
            .map(|l| Layer {
                scale: l.scale_prewot,
                ..l.clone()
            })
            .collect()
    }
}

/// List model names from `index.json` in the artifacts dir.
pub fn list_models(dir: &Path) -> anyhow::Result<Vec<String>> {
    let text = std::fs::read_to_string(dir.join("index.json"))?;
    let j = Json::parse(&text)?;
    Ok(j.req("models")?
        .as_obj()
        .map(|m| m.keys().cloned().collect())
        .unwrap_or_default())
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "model": "m", "num_classes": 10, "img_size": 32, "input_dim": 3072,
      "num_weights": 16, "float_acc": 0.9, "int8_acc": 0.89, "wot_acc": 0.88,
      "batches": [1, 32], "pallas_batch": 32,
      "layers": [
        {"name": "a.w", "shape": [8], "offset": 0, "size": 8, "scale": 0.5, "scale_prewot": 0.6},
        {"name": "b.w", "shape": [2, 4], "offset": 8, "size": 8, "scale": 0.25, "scale_prewot": 0.3}
      ],
      "files": {"weights": "m.weights.bin", "prewot": "m.prewot.bin",
                "wot_log": "m.wot_log.json",
                "hlo": {"1": "m.b1.hlo.txt", "32": "m.b32.hlo.txt"},
                "hlo_pallas": {"32": "m.b32.pallas.hlo.txt"},
                "hlo_prewot": {"32": "m.prewot.b32.hlo.txt"}}
    }"#;

    #[test]
    fn parse_mini_manifest() {
        let dir = std::env::temp_dir().join("zsecc_man_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.manifest.json");
        std::fs::write(&p, MINI).unwrap();
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.model, "m");
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.layers[1].offset, 8);
        assert_eq!(m.hlo[&32], "m.b32.hlo.txt");
        assert!(m.hlo_path(1).unwrap().ends_with("m.b1.hlo.txt"));
        assert!(m.hlo_path(7).is_err());
        assert_eq!(m.layers_prewot()[0].scale, 0.6);
    }

    #[test]
    fn guards_calibration_roundtrips_through_the_manifest() {
        use crate::runtime::guard::{Envelope, LayerEnvelope};
        let dir = std::env::temp_dir().join("zsecc_man_guards");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.manifest.json");
        std::fs::write(&p, MINI).unwrap();
        let m = Manifest::load(&p).unwrap();
        assert!(m.guards.is_none(), "seed manifest carries no calibration");
        let calib = Calibration {
            margin: 0.05,
            batches: 2,
            layers: vec![
                LayerEnvelope {
                    name: "input".into(),
                    env: Envelope::new(0.0, 1.0),
                },
                LayerEnvelope {
                    name: "logits".into(),
                    env: Envelope::new(-8.0, 11.0),
                },
            ],
        };
        m.save_guards(&calib).unwrap();
        let back = Manifest::load(&p).unwrap();
        assert_eq!(back.guards.as_ref(), Some(&calib));
        // everything else survives the rewrite
        assert_eq!(back.layers.len(), 2);
        assert_eq!(back.hlo[&32], "m.b32.hlo.txt");
        // a malformed guards section is a load error, not a silent None
        let poisoned = MINI.replace(
            "\"model\": \"m\",",
            "\"model\": \"m\", \"guards\": {\"margin\": 0.1}, ",
        );
        std::fs::write(&p, poisoned).unwrap();
        assert!(Manifest::load(&p).is_err());
    }

    #[test]
    fn validation_rejects_gaps() {
        let bad = MINI.replace("\"offset\": 8", "\"offset\": 16");
        let dir = std::env::temp_dir().join("zsecc_man_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.manifest.json");
        std::fs::write(&p, bad).unwrap();
        assert!(Manifest::load(&p).is_err());
    }
}
