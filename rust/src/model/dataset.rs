//! `dataset.eval.bin` loader — the shared evaluation split.
//!
//! Layout (little-endian): u32 N, u32 D, f32[N*D] images, u8[N] labels.
//! Written by python/compile/data.py::write_eval_bin.

use std::path::Path;

pub struct EvalSet {
    pub n: usize,
    pub dim: usize,
    /// Row-major images, n x dim.
    pub images: Vec<f32>,
    pub labels: Vec<u8>,
}

impl EvalSet {
    pub fn load(path: &Path) -> anyhow::Result<EvalSet> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        anyhow::ensure!(bytes.len() >= 8, "dataset file truncated");
        let n = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
        let dim = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        let img_bytes = n * dim * 4;
        anyhow::ensure!(
            bytes.len() == 8 + img_bytes + n,
            "dataset file size mismatch: n={n} d={dim} len={}",
            bytes.len()
        );
        let mut images = vec![0f32; n * dim];
        for (i, chunk) in bytes[8..8 + img_bytes].chunks_exact(4).enumerate() {
            images[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        let labels = bytes[8 + img_bytes..].to_vec();
        Ok(EvalSet {
            n,
            dim,
            images,
            labels,
        })
    }

    /// Image row i.
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * self.dim..(i + 1) * self.dim]
    }

    /// Contiguous batch of images [at, at+batch) as a flat slice.
    pub fn batch(&self, at: usize, batch: usize) -> &[f32] {
        &self.images[at * self.dim..(at + batch) * self.dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_synthetic_file() {
        let dir = std::env::temp_dir().join("zsecc_ds_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("eval.bin");
        let n = 3usize;
        let d = 4usize;
        let mut bytes = Vec::new();
        bytes.extend((n as u32).to_le_bytes());
        bytes.extend((d as u32).to_le_bytes());
        for i in 0..(n * d) {
            bytes.extend((i as f32 * 0.5).to_le_bytes());
        }
        bytes.extend([7u8, 8, 9]);
        std::fs::write(&p, &bytes).unwrap();
        let ds = EvalSet::load(&p).unwrap();
        assert_eq!(ds.n, 3);
        assert_eq!(ds.dim, 4);
        assert_eq!(ds.image(1), &[2.0, 2.5, 3.0, 3.5]);
        assert_eq!(ds.labels, vec![7, 8, 9]);
        assert_eq!(ds.batch(1, 2).len(), 8);
    }

    #[test]
    fn rejects_truncation() {
        let dir = std::env::temp_dir().join("zsecc_ds_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("eval.bin");
        std::fs::write(&p, [1, 0, 0, 0, 2, 0, 0, 0, 9]).unwrap();
        assert!(EvalSet::load(&p).is_err());
    }
}
