//! Int8 weight buffers and per-layer dequantization.
//!
//! Mirrors python/compile/quantize.py (Eq. 1 of the paper, frozen
//! calibration scales): the stored int8 value `q` dequantizes to
//! `q * scale_l` for its layer's scale. The rust side only ever
//! *dequantizes* — quantization happened at build time.

use crate::ecc::{tile, CleanPath, DecodeStats, Encoded, Protection};
use crate::model::manifest::Layer;

/// WOT block geometry (must match python/compile/quantize.py).
pub const BLOCK: usize = 8;
pub const SMALL_LO: i8 = -64;
pub const SMALL_HI: i8 = 63;

/// Dequantize a flat int8 buffer into f32 using per-layer scales.
/// `out.len() == q.len()`; layers must tile the buffer exactly.
pub fn dequantize_into(q: &[i8], layers: &[Layer], out: &mut [f32]) {
    debug_assert_eq!(q.len(), out.len());
    for l in layers {
        let s = l.scale;
        let (a, b) = (l.offset, l.offset + l.size);
        for (o, &v) in out[a..b].iter_mut().zip(&q[a..b]) {
            *o = v as f32 * s;
        }
    }
}

/// Dequantize the window `[base, base + q.len())` of the flat weight
/// buffer: `q`/`out` hold only the window, `base` is its global element
/// offset, and each element uses the scale of the layer that owns it.
pub fn dequantize_range(q: &[i8], layers: &[Layer], base: usize, out: &mut [f32]) {
    debug_assert_eq!(q.len(), out.len());
    let end = base + q.len();
    for l in layers {
        let (a, b) = (l.offset.max(base), (l.offset + l.size).min(end));
        if a >= b {
            continue;
        }
        let s = l.scale;
        let (la, lb) = (a - base, b - base);
        for (o, &v) in out[la..lb].iter_mut().zip(&q[la..lb]) {
            *o = v as f32 * s;
        }
    }
}

/// Per-layer f32 dequant LUTs for the clean fast path: `plain[b]` is
/// the dequantized weight of stored byte `b`, and `restored[b]`
/// additionally folds in the in-place bit6 := bit7 sign copy — so a
/// clean tile dequantizes straight from the stored image, one table
/// load per weight, with no intermediate i8 buffer at all.
struct LayerLut {
    plain: [f32; 256],
    restored: [f32; 256],
}

impl LayerLut {
    fn new(scale: f32) -> LayerLut {
        let mut plain = [0f32; 256];
        let mut restored = [0f32; 256];
        for (b, (p, r)) in plain.iter_mut().zip(restored.iter_mut()).enumerate() {
            let v = b as u8;
            *p = (v as i8) as f32 * scale;
            let rv = (v & !0x40) | ((v >> 1) & 0x40);
            *r = (rv as i8) as f32 * scale;
        }
        LayerLut { plain, restored }
    }
}

/// Lazily-built LUT cache over the window's layers (tables are only
/// materialized for layers that actually see a clean tile). Scoped to
/// one `decode_dequant_range` call: a rebuild costs 512 multiplies per
/// touched layer, well under 1% of decoding a typical (>= 64 KiB)
/// shard — callers with many tiny shards should batch them into larger
/// windows rather than thread a cross-call cache through the API.
struct CleanLuts<'a> {
    path: CleanPath,
    layers: &'a [Layer],
    tables: Vec<Option<Box<LayerLut>>>,
}

impl<'a> CleanLuts<'a> {
    fn new(path: CleanPath, layers: &'a [Layer]) -> CleanLuts<'a> {
        CleanLuts {
            path,
            layers,
            tables: (0..layers.len()).map(|_| None).collect(),
        }
    }

    /// Dequantize a *clean* stored window (global byte offset `base`)
    /// directly into `out`, per-layer scales applied via the LUTs.
    fn dequant_clean(&mut self, data: &[u8], base: usize, out: &mut [f32]) {
        debug_assert_eq!(data.len(), out.len());
        let end = base + data.len();
        for (li, l) in self.layers.iter().enumerate() {
            let (a, b) = (l.offset.max(base), (l.offset + l.size).min(end));
            if a >= b {
                continue;
            }
            let lut = self.tables[li].get_or_insert_with(|| Box::new(LayerLut::new(l.scale)));
            let (la, lb) = (a - base, b - base);
            match self.path {
                CleanPath::Copy => {
                    for (o, &v) in out[la..lb].iter_mut().zip(&data[la..lb]) {
                        *o = lut.plain[v as usize];
                    }
                }
                CleanPath::SignRestore => {
                    // byte k of each 8-byte block: k < 7 carries an
                    // in-place check bit, k == 7 is the free byte
                    for (i, (o, &v)) in out[la..lb].iter_mut().zip(&data[la..lb]).enumerate() {
                        *o = if (a + i) % 8 == 7 {
                            lut.plain[v as usize]
                        } else {
                            lut.restored[v as usize]
                        };
                    }
                }
            }
        }
    }
}

/// Fused ECC decode + dequantize of the block-aligned window
/// `[start, end)` of a stored image into `out`
/// (`out.len() == end - start`) — the scrub epoch's per-shard refresh
/// path. Tiles proven clean by the word-parallel probe
/// (`Protection::tile_is_clean`) dequantize straight from the stored
/// bytes through the f32 LUTs (sign restore folded in for in-place);
/// only dirty tiles and the ragged tail decode into the reusable
/// `scratch` buffer first.
pub fn decode_dequant_range(
    strategy: &dyn Protection,
    enc: &Encoded,
    start: usize,
    end: usize,
    layers: &[Layer],
    scratch: &mut Vec<i8>,
    out: &mut [f32],
) -> DecodeStats {
    debug_assert_eq!(out.len(), end - start);
    // same alignment contract as decode_range: the SignRestore clean
    // path reads the block phase off the global byte offset
    debug_assert!(
        start % strategy.block_bytes() == 0
            && (end % strategy.block_bytes() == 0 || end == enc.data.len())
    );
    let (os, oe) = strategy.oob_window(start, end, enc.data.len(), enc.oob.len());
    let data = &enc.data[start..end];
    let oob = &enc.oob[os..oe];
    let opt = tile::TILE_BYTES / strategy.block_bytes() * strategy.oob_bytes_per_block();
    let mut luts = CleanLuts::new(strategy.clean_path(), layers);
    let mut stats = DecodeStats::default();
    let (mut d, mut o) = (0usize, 0usize);
    while data.len() - d >= tile::TILE_BYTES {
        let (dt, ot) = (&data[d..d + tile::TILE_BYTES], &oob[o..o + opt]);
        if strategy.tile_is_clean(dt, ot) {
            luts.dequant_clean(dt, start + d, &mut out[d..d + tile::TILE_BYTES]);
        } else {
            // dirty tile: decode_tile re-derives its lane mask (one
            // extra transpose per dirty tile — cheap next to the scalar
            // corrections it gates, and it keeps the trait free of
            // bitsliced-mask plumbing)
            scratch.clear();
            scratch.resize(tile::TILE_BYTES, 0);
            stats.add(&strategy.decode_tile(dt, ot, scratch));
            dequantize_range(scratch, layers, start + d, &mut out[d..d + tile::TILE_BYTES]);
        }
        d += tile::TILE_BYTES;
        o += opt;
    }
    if d < data.len() {
        scratch.clear();
        scratch.resize(data.len() - d, 0);
        stats.add(&strategy.decode_span(&data[d..], &oob[o..], scratch));
        dequantize_range(scratch, layers, start + d, &mut out[d..]);
    }
    stats
}

/// Weight-magnitude distribution over the paper's Table-1 bands:
/// fractions of |q| in [0,32), [32,64), [64,128].
pub fn distribution_bands(q: &[i8]) -> (f64, f64, f64) {
    let mut bands = [0u64; 3];
    for &v in q {
        let a = (v as i32).unsigned_abs();
        let idx = if a < 32 {
            0
        } else if a < 64 {
            1
        } else {
            2
        };
        bands[idx] += 1;
    }
    let n = q.len() as f64;
    (
        bands[0] as f64 / n,
        bands[1] as f64 / n,
        bands[2] as f64 / n,
    )
}

/// Histogram of large-value byte positions within 8-byte blocks — the
/// paper's Fig. 1 (computed over the pre-WOT buffer).
pub fn large_position_histogram(q: &[i8]) -> [u64; BLOCK] {
    let mut h = [0u64; BLOCK];
    for chunk in q.chunks_exact(BLOCK) {
        for (j, &v) in chunk.iter().enumerate() {
            if !(SMALL_LO..=SMALL_HI).contains(&v) {
                h[j] += 1;
            }
        }
    }
    h
}

/// WOT-constraint violations (large values at positions 0..6).
pub fn wot_violations(q: &[i8]) -> u64 {
    large_position_histogram(q)[..BLOCK - 1].iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layers2() -> Vec<Layer> {
        vec![
            Layer {
                name: "a".into(),
                shape: vec![8],
                offset: 0,
                size: 8,
                scale: 0.5,
                scale_prewot: 0.5,
            },
            Layer {
                name: "b".into(),
                shape: vec![8],
                offset: 8,
                size: 8,
                scale: 2.0,
                scale_prewot: 2.0,
            },
        ]
    }

    #[test]
    fn dequant_per_layer_scale() {
        let q: Vec<i8> = (0..16).map(|i| i as i8).collect();
        let mut out = vec![0f32; 16];
        dequantize_into(&q, &layers2(), &mut out);
        assert_eq!(out[2], 1.0); // 2 * 0.5
        assert_eq!(out[10], 20.0); // 10 * 2.0
    }

    #[test]
    fn dequant_range_matches_full_pass() {
        let q: Vec<i8> = (0..16).map(|i| i as i8).collect();
        let mut full = vec![0f32; 16];
        dequantize_into(&q, &layers2(), &mut full);
        // every window [a, b) must reproduce the matching slice, layer
        // boundary (at 8) included
        for (a, b) in [(0usize, 16usize), (0, 8), (8, 16), (4, 12), (6, 10)] {
            let mut win = vec![0f32; b - a];
            dequantize_range(&q[a..b], &layers2(), a, &mut win);
            assert_eq!(win, full[a..b], "window [{a},{b})");
        }
    }

    #[test]
    fn fused_decode_dequant_matches_two_pass() {
        use crate::ecc::strategy_by_name;
        let q: Vec<i8> = (0..16).map(|i| (i - 8) as i8).collect();
        let s = strategy_by_name("ecc").unwrap();
        let mut enc = s.encode(&q).unwrap();
        enc.flip_bit(3); // correctable single flip in block 0
        // reference: full decode then full dequantize
        let mut dec = vec![0i8; 16];
        s.decode(&enc, &mut dec);
        let mut full = vec![0f32; 16];
        dequantize_into(&dec, &layers2(), &mut full);
        // fused path over the two halves
        let mut scratch = Vec::new();
        let mut out = vec![0f32; 16];
        let mut stats = DecodeStats::default();
        stats.add(&decode_dequant_range(
            s.as_ref(), &enc, 0, 8, &layers2(), &mut scratch, &mut out[0..8],
        ));
        stats.add(&decode_dequant_range(
            s.as_ref(), &enc, 8, 16, &layers2(), &mut scratch, &mut out[8..16],
        ));
        assert_eq!(out, full);
        assert_eq!(stats.corrected, 1);
    }

    #[test]
    fn fused_clean_tile_lut_path_matches_two_pass() {
        use crate::ecc::{strategy_by_name, DecodeStats};
        use crate::util::rng::Rng;
        // 2 full tiles + a ragged 8-block tail, with a layer boundary
        // mid-block (element 700) so the sign-restore LUT path crosses
        // scale changes at non-block offsets; one correctable flip in
        // tile 1 keeps a dirty tile in the mix.
        let n = 2 * 512 + 64;
        let mut rng = Rng::new(23);
        let w: Vec<i8> = (0..n)
            .map(|i| {
                if i % 8 == 7 {
                    (rng.below(256) as i64 - 128) as i8
                } else {
                    (rng.below(128) as i64 - 64) as i8
                }
            })
            .collect();
        let layers = vec![
            Layer {
                name: "a".into(),
                shape: vec![700],
                offset: 0,
                size: 700,
                scale: 0.03,
                scale_prewot: 0.03,
            },
            Layer {
                name: "b".into(),
                shape: vec![n - 700],
                offset: 700,
                size: n - 700,
                scale: 1.75,
                scale_prewot: 1.75,
            },
        ];
        for name in ["faulty", "zero", "ecc", "in-place"] {
            let s = strategy_by_name(name).unwrap();
            let mut enc = s.encode(&w).unwrap();
            enc.flip_bit(64 * 64 + 321); // lands in tile 1
            // reference: full scalar decode, then full dequantize
            let mut dec = vec![0i8; n];
            let ref_stats = s.decode_span(&enc.data, &enc.oob, &mut dec);
            let mut full = vec![0f32; n];
            dequantize_into(&dec, &layers, &mut full);
            // fused path, whole window and split windows
            let mut scratch = Vec::new();
            let mut out = vec![0f32; n];
            let stats = decode_dequant_range(
                s.as_ref(), &enc, 0, n, &layers, &mut scratch, &mut out,
            );
            assert_eq!(out, full, "{name}: fused whole-window mismatch");
            assert_eq!(stats, ref_stats, "{name}: fused stats mismatch");
            let mut out2 = vec![0f32; n];
            let mut sum = DecodeStats::default();
            for (a, b) in [(0usize, 512usize), (512, 1088)] {
                sum.add(&decode_dequant_range(
                    s.as_ref(), &enc, a, b, &layers, &mut scratch, &mut out2[a..b],
                ));
            }
            assert_eq!(out2, full, "{name}: fused split-window mismatch");
            assert_eq!(sum, ref_stats, "{name}: fused split stats mismatch");
        }
    }

    #[test]
    fn bands_sum_to_one() {
        let q: Vec<i8> = (-128..=127).map(|v| v as i8).collect();
        let (a, b, c) = distribution_bands(&q);
        assert!((a + b + c - 1.0).abs() < 1e-12);
        // [0,32): values -31..=31 -> 63; [32,64): 64; rest: 129
        assert!((a - 63.0 / 256.0).abs() < 1e-12);
        assert!((b - 64.0 / 256.0).abs() < 1e-12);
    }

    #[test]
    fn fig1_histogram_counts_positions() {
        let mut q = vec![0i8; 24];
        q[0] = 127; // block 0 pos 0
        q[15] = -100; // block 1 pos 7
        q[17] = 64; // block 2 pos 1
        let h = large_position_histogram(&q);
        assert_eq!(h[0], 1);
        assert_eq!(h[1], 1);
        assert_eq!(h[7], 1);
        assert_eq!(wot_violations(&q), 2);
    }
}
