//! Int8 weight buffers and per-layer dequantization.
//!
//! Mirrors python/compile/quantize.py (Eq. 1 of the paper, frozen
//! calibration scales): the stored int8 value `q` dequantizes to
//! `q * scale_l` for its layer's scale. The rust side only ever
//! *dequantizes* — quantization happened at build time.

use crate::ecc::{DecodeStats, Encoded, Protection};
use crate::model::manifest::Layer;

/// WOT block geometry (must match python/compile/quantize.py).
pub const BLOCK: usize = 8;
pub const SMALL_LO: i8 = -64;
pub const SMALL_HI: i8 = 63;

/// Dequantize a flat int8 buffer into f32 using per-layer scales.
/// `out.len() == q.len()`; layers must tile the buffer exactly.
pub fn dequantize_into(q: &[i8], layers: &[Layer], out: &mut [f32]) {
    debug_assert_eq!(q.len(), out.len());
    for l in layers {
        let s = l.scale;
        let (a, b) = (l.offset, l.offset + l.size);
        for (o, &v) in out[a..b].iter_mut().zip(&q[a..b]) {
            *o = v as f32 * s;
        }
    }
}

/// Dequantize the window `[base, base + q.len())` of the flat weight
/// buffer: `q`/`out` hold only the window, `base` is its global element
/// offset, and each element uses the scale of the layer that owns it.
pub fn dequantize_range(q: &[i8], layers: &[Layer], base: usize, out: &mut [f32]) {
    debug_assert_eq!(q.len(), out.len());
    let end = base + q.len();
    for l in layers {
        let (a, b) = (l.offset.max(base), (l.offset + l.size).min(end));
        if a >= b {
            continue;
        }
        let s = l.scale;
        let (la, lb) = (a - base, b - base);
        for (o, &v) in out[la..lb].iter_mut().zip(&q[la..lb]) {
            *o = v as f32 * s;
        }
    }
}

/// Fused ECC decode + dequantize of the block-aligned window
/// `[start, end)` of a stored image: decodes into the reusable
/// `scratch` buffer (resized to the window, no full-buffer i8 pass) and
/// dequantizes into `out` (`out.len() == end - start`). This is the
/// scrub epoch's per-shard refresh path.
pub fn decode_dequant_range(
    strategy: &dyn Protection,
    enc: &Encoded,
    start: usize,
    end: usize,
    layers: &[Layer],
    scratch: &mut Vec<i8>,
    out: &mut [f32],
) -> DecodeStats {
    debug_assert_eq!(out.len(), end - start);
    scratch.clear();
    scratch.resize(end - start, 0);
    let stats = strategy.decode_range(enc, start, end, scratch);
    dequantize_range(scratch, layers, start, out);
    stats
}

/// Weight-magnitude distribution over the paper's Table-1 bands:
/// fractions of |q| in [0,32), [32,64), [64,128].
pub fn distribution_bands(q: &[i8]) -> (f64, f64, f64) {
    let mut bands = [0u64; 3];
    for &v in q {
        let a = (v as i32).unsigned_abs();
        let idx = if a < 32 {
            0
        } else if a < 64 {
            1
        } else {
            2
        };
        bands[idx] += 1;
    }
    let n = q.len() as f64;
    (
        bands[0] as f64 / n,
        bands[1] as f64 / n,
        bands[2] as f64 / n,
    )
}

/// Histogram of large-value byte positions within 8-byte blocks — the
/// paper's Fig. 1 (computed over the pre-WOT buffer).
pub fn large_position_histogram(q: &[i8]) -> [u64; BLOCK] {
    let mut h = [0u64; BLOCK];
    for chunk in q.chunks_exact(BLOCK) {
        for (j, &v) in chunk.iter().enumerate() {
            if !(SMALL_LO..=SMALL_HI).contains(&v) {
                h[j] += 1;
            }
        }
    }
    h
}

/// WOT-constraint violations (large values at positions 0..6).
pub fn wot_violations(q: &[i8]) -> u64 {
    large_position_histogram(q)[..BLOCK - 1].iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layers2() -> Vec<Layer> {
        vec![
            Layer {
                name: "a".into(),
                shape: vec![8],
                offset: 0,
                size: 8,
                scale: 0.5,
                scale_prewot: 0.5,
            },
            Layer {
                name: "b".into(),
                shape: vec![8],
                offset: 8,
                size: 8,
                scale: 2.0,
                scale_prewot: 2.0,
            },
        ]
    }

    #[test]
    fn dequant_per_layer_scale() {
        let q: Vec<i8> = (0..16).map(|i| i as i8).collect();
        let mut out = vec![0f32; 16];
        dequantize_into(&q, &layers2(), &mut out);
        assert_eq!(out[2], 1.0); // 2 * 0.5
        assert_eq!(out[10], 20.0); // 10 * 2.0
    }

    #[test]
    fn dequant_range_matches_full_pass() {
        let q: Vec<i8> = (0..16).map(|i| i as i8).collect();
        let mut full = vec![0f32; 16];
        dequantize_into(&q, &layers2(), &mut full);
        // every window [a, b) must reproduce the matching slice, layer
        // boundary (at 8) included
        for (a, b) in [(0usize, 16usize), (0, 8), (8, 16), (4, 12), (6, 10)] {
            let mut win = vec![0f32; b - a];
            dequantize_range(&q[a..b], &layers2(), a, &mut win);
            assert_eq!(win, full[a..b], "window [{a},{b})");
        }
    }

    #[test]
    fn fused_decode_dequant_matches_two_pass() {
        use crate::ecc::strategy_by_name;
        let q: Vec<i8> = (0..16).map(|i| (i - 8) as i8).collect();
        let s = strategy_by_name("ecc").unwrap();
        let mut enc = s.encode(&q).unwrap();
        enc.flip_bit(3); // correctable single flip in block 0
        // reference: full decode then full dequantize
        let mut dec = vec![0i8; 16];
        s.decode(&enc, &mut dec);
        let mut full = vec![0f32; 16];
        dequantize_into(&dec, &layers2(), &mut full);
        // fused path over the two halves
        let mut scratch = Vec::new();
        let mut out = vec![0f32; 16];
        let mut stats = DecodeStats::default();
        stats.add(&decode_dequant_range(
            s.as_ref(), &enc, 0, 8, &layers2(), &mut scratch, &mut out[0..8],
        ));
        stats.add(&decode_dequant_range(
            s.as_ref(), &enc, 8, 16, &layers2(), &mut scratch, &mut out[8..16],
        ));
        assert_eq!(out, full);
        assert_eq!(stats.corrected, 1);
    }

    #[test]
    fn bands_sum_to_one() {
        let q: Vec<i8> = (-128..=127).map(|v| v as i8).collect();
        let (a, b, c) = distribution_bands(&q);
        assert!((a + b + c - 1.0).abs() < 1e-12);
        // [0,32): values -31..=31 -> 63; [32,64): 64; rest: 129
        assert!((a - 63.0 / 256.0).abs() < 1e-12);
        assert!((b - 64.0 / 256.0).abs() < 1e-12);
    }

    #[test]
    fn fig1_histogram_counts_positions() {
        let mut q = vec![0i8; 24];
        q[0] = 127; // block 0 pos 0
        q[15] = -100; // block 1 pos 7
        q[17] = 64; // block 2 pos 1
        let h = large_position_histogram(&q);
        assert_eq!(h[0], 1);
        assert_eq!(h[1], 1);
        assert_eq!(h[7], 1);
        assert_eq!(wot_violations(&q), 2);
    }
}
