//! Int8 weight buffers and per-layer dequantization.
//!
//! Mirrors python/compile/quantize.py (Eq. 1 of the paper, frozen
//! calibration scales): the stored int8 value `q` dequantizes to
//! `q * scale_l` for its layer's scale. The rust side only ever
//! *dequantizes* — quantization happened at build time.

use crate::model::manifest::Layer;

/// WOT block geometry (must match python/compile/quantize.py).
pub const BLOCK: usize = 8;
pub const SMALL_LO: i8 = -64;
pub const SMALL_HI: i8 = 63;

/// Dequantize a flat int8 buffer into f32 using per-layer scales.
/// `out.len() == q.len()`; layers must tile the buffer exactly.
pub fn dequantize_into(q: &[i8], layers: &[Layer], out: &mut [f32]) {
    debug_assert_eq!(q.len(), out.len());
    for l in layers {
        let s = l.scale;
        let (a, b) = (l.offset, l.offset + l.size);
        for (o, &v) in out[a..b].iter_mut().zip(&q[a..b]) {
            *o = v as f32 * s;
        }
    }
}

/// Weight-magnitude distribution over the paper's Table-1 bands:
/// fractions of |q| in [0,32), [32,64), [64,128].
pub fn distribution_bands(q: &[i8]) -> (f64, f64, f64) {
    let mut bands = [0u64; 3];
    for &v in q {
        let a = (v as i32).unsigned_abs();
        let idx = if a < 32 {
            0
        } else if a < 64 {
            1
        } else {
            2
        };
        bands[idx] += 1;
    }
    let n = q.len() as f64;
    (
        bands[0] as f64 / n,
        bands[1] as f64 / n,
        bands[2] as f64 / n,
    )
}

/// Histogram of large-value byte positions within 8-byte blocks — the
/// paper's Fig. 1 (computed over the pre-WOT buffer).
pub fn large_position_histogram(q: &[i8]) -> [u64; BLOCK] {
    let mut h = [0u64; BLOCK];
    for chunk in q.chunks_exact(BLOCK) {
        for (j, &v) in chunk.iter().enumerate() {
            if !(SMALL_LO..=SMALL_HI).contains(&v) {
                h[j] += 1;
            }
        }
    }
    h
}

/// WOT-constraint violations (large values at positions 0..6).
pub fn wot_violations(q: &[i8]) -> u64 {
    large_position_histogram(q)[..BLOCK - 1].iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layers2() -> Vec<Layer> {
        vec![
            Layer {
                name: "a".into(),
                shape: vec![8],
                offset: 0,
                size: 8,
                scale: 0.5,
                scale_prewot: 0.5,
            },
            Layer {
                name: "b".into(),
                shape: vec![8],
                offset: 8,
                size: 8,
                scale: 2.0,
                scale_prewot: 2.0,
            },
        ]
    }

    #[test]
    fn dequant_per_layer_scale() {
        let q: Vec<i8> = (0..16).map(|i| i as i8).collect();
        let mut out = vec![0f32; 16];
        dequantize_into(&q, &layers2(), &mut out);
        assert_eq!(out[2], 1.0); // 2 * 0.5
        assert_eq!(out[10], 20.0); // 10 * 2.0
    }

    #[test]
    fn bands_sum_to_one() {
        let q: Vec<i8> = (-128..=127).map(|v| v as i8).collect();
        let (a, b, c) = distribution_bands(&q);
        assert!((a + b + c - 1.0).abs() < 1e-12);
        // [0,32): values -31..=31 -> 63; [32,64): 64; rest: 129
        assert!((a - 63.0 / 256.0).abs() < 1e-12);
        assert!((b - 64.0 / 256.0).abs() < 1e-12);
    }

    #[test]
    fn fig1_histogram_counts_positions() {
        let mut q = vec![0i8; 24];
        q[0] = 127; // block 0 pos 0
        q[15] = -100; // block 1 pos 7
        q[17] = 64; // block 2 pos 1
        let h = large_position_histogram(&q);
        assert_eq!(h[0], 1);
        assert_eq!(h[1], 1);
        assert_eq!(h[7], 1);
        assert_eq!(wot_violations(&q), 2);
    }
}
