//! zsecc CLI — the Layer-3 leader binary.
//!
//! Subcommands map one-to-one onto the paper's evaluation artifacts:
//!   table1, table2, fig1, fig3, fig4   reproduce the paper's numbers
//!   ablation                           QATT-vs-ADMM, BCH, burst, scrub
//!   calibrate                          record activation envelopes for guards
//!   serve                              protected inference serving demo
//!   info                               artifact inventory
//!
//! `--artifacts <dir>` overrides discovery (default: walk up for
//! ./artifacts with index.json, or $ZSECC_ARTIFACTS).

use std::path::PathBuf;
use std::time::Duration;

use zsecc::coordinator::{BatchPolicy, Server, ServerConfig};
use zsecc::harness::{ablation, campaign, closedloop, fig1, fig34, scrubsim, table1, table2};
use zsecc::memory::{FaultModel, FaultSite, ScrubPolicy, WearParams};
use zsecc::model::manifest::list_models;
use zsecc::model::{RecoveryMode, RecoverySet};
use zsecc::runtime::GuardMode;
use zsecc::util::cli::Args;
use zsecc::util::rng::Rng;

fn artifacts_from(args: &Args) -> PathBuf {
    args.str_opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(zsecc::artifacts_dir)
}

fn parse_rates(args: &Args) -> anyhow::Result<Vec<f64>> {
    match args.str_opt("rates") {
        None => Ok(table2::PAPER_RATES.to_vec()),
        Some(s) => s
            .split(',')
            .map(|r| {
                r.parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("bad rate '{r}'"))
            })
            .collect(),
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let artifacts = artifacts_from(&args);
    match args.cmd.as_deref() {
        Some("info") => {
            println!("artifacts: {}", artifacts.display());
            for m in list_models(&artifacts)? {
                let man = zsecc::model::Manifest::load_model(&artifacts, &m)?;
                println!(
                    "  {:<14} {:>9} weights  float={:.3} int8={:.3} wot={:.3}  batches={:?}",
                    man.model, man.num_weights, man.float_acc, man.int8_acc, man.wot_acc, man.batches
                );
            }
        }
        Some("table1") => {
            let models = args.list_or("models", &[]);
            let models = if models.is_empty() {
                list_models(&artifacts)?
            } else {
                models
            };
            let remeasure = !args.bool("no-remeasure");
            let rows = table1::run(&artifacts, &models, remeasure)?;
            println!("{}", table1::render(&rows));
            if args.bool("json") {
                println!("{}", table1::to_json(&rows));
            }
        }
        Some("table2") => {
            let mut cfg = table2::Config {
                trials: args.usize_or("trials", 10)?,
                batch: args.usize_or("batch", 256)?,
                rates: parse_rates(&args)?,
                shards: args.usize_or("shards", 8)?,
                decode_workers: args.usize_or("workers", 4)?,
                jobs: args.usize_or("jobs", 1)?,
                fault_model: FaultModel::parse(&args.str_or("fault-model", "uniform"))?,
                ..Default::default()
            };
            let models = args.list_or("models", &[]);
            if !models.is_empty() {
                cfg.models = models;
            }
            let strategies = args.list_or("strategies", &[]);
            if !strategies.is_empty() {
                cfg.strategies = strategies;
            }
            let t2 = table2::run(&artifacts, &cfg, args.bool("verbose"))?;
            println!("{}", t2.render(&cfg));
            println!("shape checks (paper's qualitative claims):");
            for (name, ok) in t2.shape_checks(&cfg) {
                println!("  [{}] {}", if ok { "ok" } else { "FAIL" }, name);
            }
            if args.bool("json") {
                println!("{}", t2.to_json());
            }
        }
        Some("fig1") => {
            let models = args.list_or("models", &["squeezenet_s"]);
            let figs = fig1::run(&artifacts, &models)?;
            println!("{}", fig1::render(&figs));
            if args.bool("json") {
                println!("{}", fig1::to_json(&figs));
            }
        }
        Some("fig3") | Some("fig4") => {
            let models = args.list_or("models", &[]);
            let models = if models.is_empty() {
                list_models(&artifacts)?
            } else {
                models
            };
            let logs = fig34::run(&artifacts, &models)?;
            if args.cmd.as_deref() == Some("fig3") {
                println!("{}", fig34::render_fig3(&logs));
            } else {
                println!("{}", fig34::render_fig4(&logs));
            }
            for (name, ok) in fig34::shape_checks(&logs) {
                println!("  [{}] {}", if ok { "ok" } else { "FAIL" }, name);
            }
        }
        Some("ablation") => {
            match ablation::render_admm_vs_qatt(&artifacts) {
                Ok(s) => println!("{s}"),
                Err(e) => println!("(admm log unavailable: {e})"),
            }
            let rates = [1e-4, 1e-3, 3e-3, 1e-2];
            let rows = ablation::code_strength(&rates, 64 * 256, 5)?;
            println!("{}", ablation::render_code_strength(&rows));
            let brows = ablation::burst(&[1, 2, 4], 1e-3, 64 * 256, 5)?;
            println!("{}", ablation::render_burst(&brows, 1e-3));
            let srows = ablation::scrub_study(&[1, 4, 16], 2e-4, 64 * 128)?;
            println!("{}", ablation::render_scrub(&srows, 2e-4));
            let sweep =
                ablation::fault_model_campaign(1e-3, 64 * 256, args.usize_or("jobs", 2)?)?;
            println!("{}", ablation::render_fault_models(&sweep, 1e-3));
        }
        Some("campaign") => run_campaign(&args, &artifacts)?,
        Some("calibrate") => {
            let batch = args.usize_or("batch", 256)?;
            let margin = args.f64_or("margin", 0.05)?;
            let models = args.list_or("models", &[]);
            let models = if models.is_empty() {
                list_models(&artifacts)?
            } else {
                models
            };
            let rt = zsecc::runtime::Runtime::cpu()?;
            let ds = std::sync::Arc::new(zsecc::model::EvalSet::load(
                &artifacts.join("dataset.eval.bin"),
            )?);
            for model in &models {
                let mut ctx =
                    zsecc::harness::EvalCtx::load(&artifacts, model, batch, rt.clone(), ds.clone())?;
                let calib = ctx.calibrate(margin)?;
                ctx.man.save_guards(&calib)?;
                println!(
                    "[{model}] calibrated over {} batches of {batch} (margin {margin}):",
                    calib.batches
                );
                for l in &calib.layers {
                    println!("  {:<8} [{:+.4}, {:+.4}]", l.name, l.env.lo, l.env.hi);
                }
                // Extended capture: the recovery tier's sidecar. Only a
                // pure dense-chain manifest has the Y = X·W equations
                // the MILR solver inverts; conv models skip with a note.
                match ctx.calibrate_recovery(batch)? {
                    Some(set) => {
                        let path = RecoverySet::sidecar_path(&artifacts, model);
                        set.save(&path)?;
                        println!(
                            "  recovery sidecar: {} layers, batch {} -> {}",
                            set.layers.len(),
                            set.batch,
                            path.display()
                        );
                    }
                    None => println!(
                        "  (recovery sidecar skipped: manifest is not a pure dense chain)"
                    ),
                }
            }
        }
        Some("scrubsim") => run_scrubsim(&args)?,
        Some("closedloop") => run_closedloop(&args, &artifacts)?,
        Some("serve") => {
            let model = args.str_or("model", "squeezenet_s");
            let secs = args.f64_or("seconds", 5.0)?;
            let rps = args.f64_or("rps", 200.0)?;
            let cfg = ServerConfig {
                strategy: args.str_or("strategy", "in-place"),
                policy: BatchPolicy {
                    max_batch: args.usize_or("batch", 32)?,
                    max_wait: Duration::from_millis(args.u64_or("max-wait-ms", 5)?),
                },
                scrub_interval: Some(Duration::from_millis(
                    args.u64_or("scrub-ms", 200)?,
                )),
                scrub_policy: ScrubPolicy::parse(&args.str_or("scrub-policy", "adaptive"))?,
                scrub_max_interval: Some(Duration::from_millis(
                    args.u64_or("scrub-max-ms", 16 * args.u64_or("scrub-ms", 200)?)?,
                )),
                fault_rate_per_interval: args.f64_or("fault-rate", 1e-7)?,
                fault_seed: args.u64_or("seed", 1)?,
                shards: args.usize_or("shards", 8)?,
                scrub_workers: args.usize_or("scrub-workers", 4)?,
                // The lock-free slab ring is the serving default; the
                // mutex batcher stays selectable as the baseline.
                ingress: zsecc::coordinator::IngressPolicy::parse(&args.str_or("ingress", "ring"))?,
                ring_depth: args.usize_or("ring-depth", 8)?,
                guard: GuardMode::parse(&args.str_or("guards", "off"))?,
                // start_pjrt fills this from the manifest's calibrated
                // envelopes (`zsecc calibrate`) when the mode needs it.
                guard_calibration: None,
                recovery: RecoveryMode::parse(&args.str_or("recovery", "off"))?,
                // start_pjrt fills this from the `<model>.recovery.json`
                // sidecar (`zsecc calibrate`) when the tier is armed.
                recovery_calibration: None,
                // Residual-error budget for the fleet arbiter: expected
                // new error bits per shard per scrub interval the model
                // is willing to tolerate.
                target_residual: args.f64_or("target-residual", 0.5)?,
                // start_pjrt replaces the default label with the model
                // name; an explicit flag wins.
                fleet_label: args.str_or("fleet-label", "model"),
                // Bandwidth-stated scrub budget for the private
                // fleet-of-one: GB/s converted to bits per wakeup
                // against --scrub-ms. Omitted = legacy unbounded.
                scrub_budget_gbps: args.f64_opt("budget-gbps")?,
            };
            // No validate() here: start_pjrt first fills the guard and
            // recovery calibrations from the manifest/sidecar, *then*
            // validates — an early check would refuse modes whose
            // calibration exists on disk.
            serve_demo(&artifacts, &model, cfg, secs, rps)?;
        }
        _ => {
            println!(
                "zsecc — In-Place Zero-Space Memory Protection for CNN (NeurIPS'19 reproduction)\n\
                 usage: zsecc <info|table1|table2|campaign|scrubsim|closedloop|fig1|fig3|fig4|ablation|serve> [flags]\n\
                 common flags: --artifacts DIR --models a,b --json\n\
                 table2:   --trials N --rates 1e-6,1e-5 --strategies faulty,ecc --batch B --jobs J --fault-model M --verbose\n\
                 campaign: --fault-model uniform,burst:4,stuckat:1,rowburst:8192:4,hotspot:0.05,hotspotat:0.4:0.05\n\
                 \x20         --site weights,activations,accumulators --guards off,range,abft,full\n\
                 \x20         --recovery off,milr (escalate uncorrectable blocks to algebraic reconstruction)\n\
                 \x20         --ci-target HW --confidence C --min-trials N --max-trials N --jobs J\n\
                 \x20         --ledger FILE --resume --out FILE --synthetic --n WEIGHTS --verbose\n\
                 calibrate: --models a,b --batch B --margin M   (writes envelopes into the manifest\n\
                 \x20         and the <model>.recovery.json sidecar for dense-chain models)\n\
                 scrubsim: --scenario ramp|migrate|fleet --scrub-policy fixed|adaptive|both --seed N\n\
                 \x20         --strategy S --n WEIGHTS --shards S --budget PASSES --max-interval TICKS\n\
                 \x20         --budget-gbps G (fleet: bandwidth-stated budget, overrides --budget)\n\
                 \x20         --starve-after K (fleet: deferral cap) --trace --out FILE --json\n\
                 closedloop: --scenario wear[:T:R:A:S:F:CAP:HOT] --scrub-policy fixed|adaptive|both\n\
                 \x20         --budgets 1,2,4 (passes/tick) --epochs N --ticks-per-epoch T --planner sched|fleet\n\
                 \x20         --strategy S --n WEIGHTS --shards S --max-interval TICKS --seed N\n\
                 \x20         --ledger FILE --resume --out FILE --json --synthetic (skip PJRT scoring)\n\
                 serve:    --model M --strategy S --seconds T --rps R --batch B --scrub-ms MS\n\
                 \x20         --scrub-policy fixed|adaptive --scrub-max-ms MS --fault-rate F --shards S --scrub-workers W\n\
                 \x20         --ingress ring|locked (lock-free slab ring vs mutex batcher) --ring-depth N\n\
                 \x20         --guards off|range --recovery off|milr (both need a prior `zsecc calibrate`)\n\
                 \x20         --target-residual BITS (per-shard residual budget for the fleet scrub arbiter)\n\
                 \x20         --budget-gbps G (scrub-bandwidth budget for the fleet-of-one arbiter)"
            );
        }
    }
    Ok(())
}

/// The `campaign` subcommand: a Monte-Carlo fault-injection campaign
/// over (model x strategy x rate x fault-model) cells with adaptive
/// trial counts and a resumable ledger. `--synthetic` swaps the
/// PJRT-backed runner for the artifact-free corruption proxy (what CI
/// smoke runs use).
fn run_campaign(args: &Args, artifacts: &std::path::Path) -> anyhow::Result<()> {
    let policy = {
        let min = args.usize_or("min-trials", 4)?;
        let max = args.usize_or("max-trials", 32)?;
        match args.f64_opt("ci-target")? {
            Some(target) => {
                anyhow::ensure!(
                    args.str_opt("trials").is_none(),
                    "--trials is the fixed-count mode; with --ci-target use --min-trials/--max-trials"
                );
                campaign::TrialPolicy::adaptive(min, max, target, args.f64_or("confidence", 0.95)?)
            }
            None => {
                anyhow::ensure!(
                    args.str_opt("min-trials").is_none() && args.str_opt("max-trials").is_none(),
                    "--min-trials/--max-trials only apply with --ci-target; \
                     use --trials N for a fixed count"
                );
                campaign::TrialPolicy::fixed(args.usize_or("trials", 10)?)
            }
        }
    };
    let fault_models = args
        .list_or("fault-model", &["uniform"])
        .iter()
        .map(|m| FaultModel::parse(m.as_str()))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let synthetic = args.bool("synthetic");
    let n_weights = args.usize_or("n", 64 * 256)?;
    let batch = args.usize_or("batch", 256)?;
    let shards = args.usize_or("shards", 8)?;
    let workers = args.usize_or("workers", if synthetic { 2 } else { 4 })?;
    let mut models = args.list_or("models", &[]);
    if models.is_empty() {
        models = if synthetic {
            vec!["synthetic".to_string()]
        } else {
            list_models(artifacts)?
        };
    }
    let stop_after = match args.usize_or("stop-after", 0)? {
        0 => None,
        n => Some(n),
    };
    // `--site` and `--sites` are synonyms (one axis value is the common
    // case); same for `--guard`/`--guards`.
    let sites = match args.str_opt("sites").or_else(|| args.str_opt("site")) {
        None => vec![FaultSite::Weights],
        Some(s) => s
            .split(',')
            .map(FaultSite::parse)
            .collect::<anyhow::Result<Vec<_>>>()?,
    };
    let guards = match args.str_opt("guards").or_else(|| args.str_opt("guard")) {
        None => vec![GuardMode::Off],
        Some(s) => s
            .split(',')
            .map(GuardMode::parse)
            .collect::<anyhow::Result<Vec<_>>>()?,
    };
    let recovery = match args.str_opt("recovery") {
        None => vec![RecoveryMode::Off],
        Some(s) => s
            .split(',')
            .map(RecoveryMode::parse)
            .collect::<anyhow::Result<Vec<_>>>()?,
    };
    let cfg = campaign::Config {
        models,
        strategies: args.list_or("strategies", &table2::PAPER_STRATEGIES),
        rates: parse_rates(args)?,
        fault_models,
        sites,
        guards,
        recovery,
        policy,
        jobs: args.usize_or("jobs", 2)?,
        ledger: args.str_opt("ledger").map(PathBuf::from),
        resume: args.bool("resume"),
        stop_after,
        runner_tag: if synthetic {
            format!("synthetic:n{n_weights}")
        } else {
            format!("pjrt:batch{batch}")
        },
        verbose: args.bool("verbose"),
    };
    let report = if synthetic {
        let runner = campaign::SyntheticRunner::new(n_weights, shards, workers);
        campaign::run(&cfg, &runner)?
    } else {
        let runner = campaign::EvalRunner::load(artifacts, &cfg.models, batch, shards, workers)?;
        campaign::run(&cfg, &runner)?
    };
    println!("{}", report.render());
    print_guard_comparisons(&report);
    print_recovery_comparisons(&report);
    if let Some(out) = args.str_opt("out") {
        std::fs::write(out, report.canonical_json().to_string())?;
        println!("(canonical JSON written to {out})");
    }
    if args.bool("json") {
        println!("{}", report.to_json());
    }
    Ok(())
}

/// For every guarded cell that has an unguarded sibling (same model,
/// strategy, rate, fault model, and site — and, because guard modes are
/// excluded from trial seeds, the *same* injected fault sequence),
/// print the mean-residual comparison. CI greps for `[guards ok]`.
fn print_guard_comparisons(report: &campaign::Report) {
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let sibling_key = |s: &campaign::CellSpec| {
        format!("{}|{}|{:e}|{}|{}", s.model, s.strategy, s.rate, s.fault.tag(), s.site.tag())
    };
    let mut off = std::collections::BTreeMap::new();
    for c in &report.cells {
        if c.spec.guard == GuardMode::Off && !c.drops.is_empty() {
            off.insert(sibling_key(&c.spec), mean(&c.drops));
        }
    }
    for c in &report.cells {
        if c.spec.guard == GuardMode::Off || c.drops.is_empty() {
            continue;
        }
        if let Some(&base) = off.get(&sibling_key(&c.spec)) {
            println!(
                "guards: {} site={} rate={:e} {}={:.4}pp off={:.4}pp clamped={} [{}]",
                c.spec.model,
                c.spec.site.tag(),
                c.spec.rate,
                c.spec.guard.tag(),
                mean(&c.drops),
                base,
                c.clamped,
                if mean(&c.drops) < base { "guards ok" } else { "guards FAIL" }
            );
        }
    }
}

/// For every recovery-armed cell that has a recovery-off sibling (same
/// model, strategy, rate, fault model, site, and guard — and, because
/// recovery modes are excluded from trial seeds, the *same* injected
/// fault sequence), print the mean-residual comparison. CI greps for
/// `[recovery ok]` (strictly lower residual drop at equal faults) and
/// fails on `[recovery FAIL]`; a cell whose solves never fired (0
/// blocks recovered) prints `[recovery idle]`.
fn print_recovery_comparisons(report: &campaign::Report) {
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let sibling_key = |s: &campaign::CellSpec| {
        format!(
            "{}|{}|{:e}|{}|{}|{}",
            s.model,
            s.strategy,
            s.rate,
            s.fault.tag(),
            s.site.tag(),
            s.guard.tag()
        )
    };
    let mut off = std::collections::BTreeMap::new();
    for c in &report.cells {
        if c.spec.recovery == RecoveryMode::Off && !c.drops.is_empty() {
            off.insert(sibling_key(&c.spec), mean(&c.drops));
        }
    }
    for c in &report.cells {
        if c.spec.recovery == RecoveryMode::Off || c.drops.is_empty() {
            continue;
        }
        if let Some(&base) = off.get(&sibling_key(&c.spec)) {
            let m = mean(&c.drops);
            let verdict = if c.recovered == 0 {
                "recovery idle"
            } else if m < base {
                "recovery ok"
            } else {
                "recovery FAIL"
            };
            println!(
                "recovery: {} strategy={} rate={:e} {}={:.4}pp off={:.4}pp \
                 recovered={} quarantined={} [{}]",
                c.spec.model,
                c.spec.strategy,
                c.spec.rate,
                c.spec.recovery.tag(),
                m,
                base,
                c.recovered,
                c.unrecovered,
                verdict
            );
        }
    }
}

/// The `scrubsim` subcommand: replay a time-varying fault scenario
/// (rate ramp / hotspot migration) against the scrub scheduler,
/// comparing the fixed and adaptive policies at equal scrub bandwidth.
/// Artifact-free and deterministic in `--seed`; `--out` writes a JSON
/// record including the per-shard BER traces (the nightly campaign's
/// build artifact).
fn run_scrubsim(args: &Args) -> anyhow::Result<()> {
    if args.str_or("scenario", "migrate") == "fleet" {
        return run_fleet_scrubsim(args);
    }
    let cfg = scrubsim::SimConfig {
        strategy: args.str_or("strategy", "in-place"),
        n_weights: args.usize_or("n", 64 * 1024)?,
        shards: args.usize_or("shards", 16)?,
        budget: args.usize_or("budget", 2)?,
        max_interval_ticks: args.u64_or("max-interval", 16)?,
        workers: args.usize_or("workers", 2)?,
    };
    let seed = args.u64_or("seed", 7)?;
    let scenario = scrubsim::Scenario::by_name(&args.str_or("scenario", "migrate"), seed)?;
    let policy = args.str_or("scrub-policy", "both");
    let results: Vec<scrubsim::SimResult> = match policy.as_str() {
        "both" => {
            let (fixed, adaptive) = scrubsim::compare(&cfg, &scenario)?;
            vec![fixed, adaptive]
        }
        p => vec![scrubsim::run_sim(&cfg, &scenario, ScrubPolicy::parse(p)?)?],
    };
    let refs: Vec<&scrubsim::SimResult> = results.iter().collect();
    println!(
        "scrubsim: scenario={} seed={seed} strategy={} shards={} budget={}/tick ticks={}",
        scenario.name,
        cfg.strategy,
        cfg.shards,
        cfg.budget,
        scenario.total_ticks()
    );
    println!("{}", scrubsim::render(&refs));
    if let [fixed, adaptive] = refs.as_slice() {
        if fixed.policy == ScrubPolicy::Fixed && adaptive.policy == ScrubPolicy::Adaptive {
            println!(
                "adaptive vs fixed residual (uncorrectable blocks): {} vs {} [{}]",
                adaptive.residual_uncorrectable,
                fixed.residual_uncorrectable,
                if adaptive.residual_uncorrectable <= fixed.residual_uncorrectable {
                    "ok"
                } else {
                    "ADAPTIVE WORSE"
                }
            );
        }
    }
    let trace = args.bool("trace") || args.str_opt("out").is_some();
    let record = zsecc::util::json::obj(vec![
        ("scenario", zsecc::util::json::s(&scenario.name)),
        ("seed", zsecc::util::json::num(seed as f64)),
        ("results", zsecc::util::json::arr(results.iter().map(|r| r.to_json(trace)))),
    ]);
    if let Some(out) = args.str_opt("out") {
        std::fs::write(out, record.to_string())?;
        println!("(JSON written to {out})");
    }
    if args.bool("json") {
        println!("{record}");
    }
    Ok(())
}

/// `scrubsim --scenario fleet`: several models with independent fault
/// scenarios competing for one process-wide scrub budget. Runs the
/// isolated / round-robin / arbitrated allocations at equal total
/// bandwidth and identical fault streams, prints the comparison, and
/// ends with the `[fleet ok]` verdict line CI greps for (a violated
/// inequality exits nonzero instead).
fn run_fleet_scrubsim(args: &Args) -> anyhow::Result<()> {
    let cfg = scrubsim::FleetSimConfig {
        strategy: args.str_or("strategy", "in-place"),
        shards: args.usize_or("shards", 8)?,
        budget_passes: args.usize_or("budget", 3)?,
        // Bandwidth-stated alternative: GB/s against the 1 s tick,
        // rounded down to whole passes over the widest shard. Overrides
        // --budget when present.
        budget_gbps: args.f64_opt("budget-gbps")?,
        max_interval_ticks: args.u64_or("max-interval", 16)?,
        workers: args.usize_or("workers", 2)?,
        starve_after: args.u64_or("starve-after", 4)? as u32,
    };
    let seed = args.u64_or("seed", 7)?;
    let models = scrubsim::fleet_models(seed);
    let ticks = models[0].scenario.total_ticks();
    let stated = match cfg.budget_gbps {
        Some(gbps) => format!("{gbps} GB/s"),
        None => format!("{}/tick", cfg.budget_passes),
    };
    println!(
        "scrubsim: scenario=fleet seed={seed} strategy={} models={} shards={}/model \
         budget={stated} starve-after={} ticks={ticks}",
        cfg.strategy,
        models.len(),
        cfg.shards,
        cfg.starve_after
    );
    let (iso, rr, arb) = scrubsim::fleet_compare(&cfg, &models)?;
    println!("{}", scrubsim::fleet_render(&[&iso, &rr, &arb]));
    let record = zsecc::util::json::obj(vec![
        ("scenario", zsecc::util::json::s("fleet")),
        ("seed", zsecc::util::json::num(seed as f64)),
        (
            "results",
            zsecc::util::json::arr([&iso, &rr, &arb].iter().map(|r| r.to_json())),
        ),
    ]);
    if let Some(out) = args.str_opt("out") {
        std::fs::write(out, record.to_string())?;
        println!("(JSON written to {out})");
    }
    if args.bool("json") {
        println!("{record}");
    }
    // Verdict last so the pass/fail line is the tail of the output.
    println!("{}", scrubsim::fleet_verdict(&cfg, &iso, &rr, &arb)?);
    Ok(())
}

/// Scores closed-loop epochs through the PJRT evaluator — the real
/// model, real dataset accuracy path.
struct PjrtScorer {
    model: String,
    ctx: zsecc::harness::EvalCtx,
}

impl closedloop::EpochScorer for PjrtScorer {
    fn name(&self) -> String {
        format!("pjrt:{}", self.model)
    }

    fn weights(&self) -> &[i8] {
        &self.ctx.weights
    }

    fn score(&mut self, decoded: &[i8]) -> anyhow::Result<f64> {
        self.ctx.accuracy_of(decoded)
    }
}

/// `zsecc closedloop`: the accuracy-vs-scrub-joules frontier sweep —
/// a model served under a live scrub scheduler while a wear process
/// drifts, scored per epoch by end-to-end accuracy, {fixed, adaptive}
/// × pass budgets at equal bandwidth. Ends with the `[closedloop ok]`
/// verdict line nightly CI greps for (a dominated adaptive frontier
/// exits nonzero instead). Scores through PJRT when artifacts are
/// loadable, the campaign's synthetic dense head otherwise.
fn run_closedloop(args: &Args, artifacts: &std::path::Path) -> anyhow::Result<()> {
    let mut cfg = closedloop::LoopConfig {
        strategy: args.str_or("strategy", "in-place"),
        n_weights: args.usize_or("n", 64 * 1024)?,
        shards: args.usize_or("shards", 16)?,
        epochs: args.u64_or("epochs", 6)?,
        ticks_per_epoch: args.u64_or("ticks-per-epoch", 30)?,
        max_interval_ticks: args.u64_or("max-interval", 16)?,
        workers: args.usize_or("workers", 2)?,
        planner: closedloop::Planner::parse(&args.str_or("planner", "sched"))?,
        starve_after: args.u64_or("starve-after", 4)? as u32,
        wear: WearParams::parse(&args.str_or("scenario", "wear"))?,
        seed: args.u64_or("seed", 42)?,
        budgets: args
            .list_or("budgets", &["1", "2", "4"])
            .iter()
            .map(|b| {
                b.parse::<u64>()
                    .map_err(|_| anyhow::anyhow!("bad budget '{b}' (passes/tick)"))
            })
            .collect::<anyhow::Result<Vec<u64>>>()?,
    };
    let policies = match args.str_or("scrub-policy", "both").as_str() {
        "both" => vec![ScrubPolicy::Fixed, ScrubPolicy::Adaptive],
        p => vec![ScrubPolicy::parse(p)?],
    };
    let pjrt = if args.bool("synthetic") {
        None
    } else {
        let model = args.str_or("model", "squeezenet_s");
        let load = || -> anyhow::Result<PjrtScorer> {
            let rt = zsecc::runtime::Runtime::cpu()?;
            let ds = std::sync::Arc::new(zsecc::model::EvalSet::load(
                &artifacts.join("dataset.eval.bin"),
            )?);
            let ctx = zsecc::harness::EvalCtx::load(
                artifacts,
                &model,
                args.usize_or("batch", 256)?,
                rt,
                ds,
            )?;
            Ok(PjrtScorer { model: model.clone(), ctx })
        };
        match load() {
            Ok(scorer) => Some(scorer),
            Err(e) => {
                println!("(PJRT scoring unavailable: {e}; falling back to the synthetic head)");
                None
            }
        }
    };
    let mut scorer: Box<dyn closedloop::EpochScorer> = match pjrt {
        Some(scorer) => {
            // The bank protects the real model's weights; the config's
            // synthetic size no longer applies.
            cfg.n_weights = scorer.ctx.weights.len();
            Box::new(scorer)
        }
        None => Box::new(closedloop::SyntheticScorer::new(cfg.n_weights)?),
    };
    println!(
        "closedloop: scorer={} planner={} {} seed={} epochs={}x{} ticks shards={} budgets={:?}",
        scorer.name(),
        cfg.planner.tag(),
        cfg.wear.tag(),
        cfg.seed,
        cfg.epochs,
        cfg.ticks_per_epoch,
        cfg.shards,
        cfg.budgets
    );
    let ledger = args.str_opt("ledger").map(std::path::PathBuf::from);
    let report = closedloop::run(
        &cfg,
        scorer.as_mut(),
        &policies,
        ledger.as_deref(),
        args.bool("resume"),
    )?;
    println!("{}", closedloop::render(&report));
    let record = report.to_json();
    if let Some(out) = args.str_opt("out") {
        std::fs::write(out, record.to_string())?;
        println!("(JSON written to {out})");
    }
    if args.bool("json") {
        println!("{record}");
    }
    // Verdict last so the pass/fail line is the tail of the output;
    // single-policy runs have no frontier pair to judge.
    if policies.len() == 2 {
        println!("{}", closedloop::verdict(&report)?);
    }
    Ok(())
}

/// Poisson open-loop serving demo: drives the coordinator at `rps` for
/// `secs`, prints throughput / latency / protection counters.
fn serve_demo(
    artifacts: &std::path::Path,
    model: &str,
    cfg: ServerConfig,
    secs: f64,
    rps: f64,
) -> anyhow::Result<()> {
    let ds = zsecc::model::EvalSet::load(&artifacts.join("dataset.eval.bin"))?;
    println!(
        "serving {model} with strategy={} batch={} scrub={:?} fault-rate={}/interval",
        cfg.strategy, cfg.policy.max_batch, cfg.scrub_interval, cfg.fault_rate_per_interval
    );
    let srv = Server::start_pjrt(artifacts, model, &cfg)?;
    let mut rng = Rng::new(7);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    let mut sent = 0u64;
    let mut correct = 0u64;
    let mut answered = 0u64;
    while t0.elapsed().as_secs_f64() < secs {
        let idx = rng.below(ds.n as u64) as usize;
        let rx = srv.submit(ds.image(idx).to_vec())?;
        pending.push((rx, ds.labels[idx] as usize));
        sent += 1;
        // Drain ready responses opportunistically.
        pending.retain(|(rx, label)| match rx.try_recv() {
            Ok(resp) => {
                answered += 1;
                if resp.pred == *label {
                    correct += 1;
                }
                false
            }
            Err(_) => true,
        });
        std::thread::sleep(Duration::from_secs_f64(rng.exp(rps)));
    }
    for (rx, label) in pending {
        if let Ok(resp) = rx.recv_timeout(Duration::from_secs(30)) {
            answered += 1;
            if resp.pred == label {
                correct += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "sent={sent} answered={answered} accuracy={:.4} throughput={:.1} req/s",
        correct as f64 / answered.max(1) as f64,
        answered as f64 / wall
    );
    println!("metrics: {}", srv.metrics.report());
    srv.shutdown();
    Ok(())
}
