//! xoshiro256++ PRNG (Blackman & Vigna) — fast, high-quality, seedable.
//!
//! Used by the fault injector and the workload generators. Determinism
//! matters: every experiment cell records its seed so Table-2 trials are
//! exactly reproducible.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// splitmix64, the recommended seeder for xoshiro.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, bound) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// `k` distinct indices in [0, n), k <= n. O(k) expected when k << n
    /// (hash-set rejection), O(n) partial Fisher-Yates otherwise.
    pub fn distinct(&mut self, n: u64, k: u64) -> Vec<u64> {
        assert!(k <= n, "cannot draw {k} distinct from {n}");
        if k == 0 {
            return Vec::new();
        }
        if k * 4 <= n {
            let mut seen = std::collections::HashSet::with_capacity(k as usize);
            let mut out = Vec::with_capacity(k as usize);
            while out.len() < k as usize {
                let v = self.below(n);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        } else {
            // Partial Fisher-Yates over a dense index vector.
            let mut idx: Vec<u64> = (0..n).collect();
            for i in 0..k as usize {
                let j = i as u64 + self.below(n - i as u64);
                idx.swap(i, j as usize);
            }
            idx.truncate(k as usize);
            idx
        }
    }

    /// Standard normal via Box-Muller (used by workload generators).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn distinct_unique_and_complete() {
        let mut r = Rng::new(3);
        // sparse regime
        let v = r.distinct(1_000_000, 100);
        let s: std::collections::HashSet<_> = v.iter().collect();
        assert_eq!(s.len(), 100);
        // dense regime: k == n must be a permutation
        let mut v = r.distinct(64, 64);
        v.sort_unstable();
        assert_eq!(v, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(17);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }
}
