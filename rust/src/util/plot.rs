//! ASCII rendering of the paper's figures (bar charts + line series).
//!
//! The harness prints figures to stdout and writes the raw series to
//! JSON next to them, so both a human and a plotting script can consume
//! the reproduction.

/// Horizontal bar chart (Fig. 1 style: one bar per category).
pub fn bar_chart(title: &str, labels: &[String], values: &[f64], width: usize) -> String {
    assert_eq!(labels.len(), values.len());
    let max = values.iter().cloned().fold(f64::MIN, f64::max).max(1e-12);
    let lw = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = format!("== {title} ==\n");
    for (l, v) in labels.iter().zip(values) {
        let n = ((v / max) * width as f64).round() as usize;
        out.push_str(&format!(
            "{:>lw$} | {}{} {:.1}\n",
            l,
            "#".repeat(n),
            " ".repeat(width - n),
            v,
            lw = lw
        ));
    }
    out
}

/// Multi-series line plot on a character grid (Fig. 3 / Fig. 4 style).
/// Each series is (name, points); x is the shared index of the points.
pub fn line_plot(
    title: &str,
    xs: &[f64],
    series: &[(&str, Vec<f64>)],
    height: usize,
    width: usize,
) -> String {
    let marks = ['*', 'o', '+', 'x', '@', '%'];
    let ymin = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().cloned())
        .fold(f64::MAX, f64::min);
    let ymax = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().cloned())
        .fold(f64::MIN, f64::max);
    let span = (ymax - ymin).max(1e-12);
    let xmin = xs.first().cloned().unwrap_or(0.0);
    let xmax = xs.last().cloned().unwrap_or(1.0);
    let xspan = (xmax - xmin).max(1e-12);

    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (x, y) in xs.iter().zip(ys) {
            let c = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let r = (((ymax - y) / span) * (height - 1) as f64).round() as usize;
            grid[r.min(height - 1)][c.min(width - 1)] = marks[si % marks.len()];
        }
    }
    let mut out = format!("== {title} ==\n");
    for (i, row) in grid.iter().enumerate() {
        let yval = ymax - span * i as f64 / (height - 1) as f64;
        out.push_str(&format!("{:>10.3} |{}\n", yval, row.iter().collect::<String>()));
    }
    out.push_str(&format!(
        "{:>10} +{}\n{:>10}  x: {:.0} .. {:.0}\n",
        "",
        "-".repeat(width),
        "",
        xmin,
        xmax
    ));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", marks[si % marks.len()], name));
    }
    out
}

/// Fixed-width table printer (Table 1 / Table 2 style).
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (c, w) in cells.iter().zip(widths) {
            line.push_str(&format!(" {:<w$} |", c, w = w));
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    out.push('|');
    for w in &widths {
        out.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
    }
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bar_chart_renders() {
        let s = bar_chart(
            "t",
            &["a".into(), "bb".into()],
            &[1.0, 2.0],
            10,
        );
        assert!(s.contains("bb | ##########"));
        assert!(s.contains("a | #####"));
    }

    #[test]
    fn line_plot_renders() {
        let xs = [0.0, 1.0, 2.0];
        let s = line_plot("t", &xs, &[("up", vec![0.0, 1.0, 2.0])], 5, 20);
        assert!(s.contains("*"));
        assert!(s.contains("up"));
    }

    #[test]
    fn table_renders_aligned() {
        let s = table(
            &["model", "acc"],
            &[vec!["vgg".into(), "0.9".into()], vec!["rn".into(), "0.85".into()]],
        );
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }
}
