//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `bench(name, iters_hint, f)` warms up, auto-scales the iteration
//! count toward a target measurement time, reports ns/iter with spread,
//! and returns the stats so bench binaries can also emit JSON/CSV.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub ns_per_iter: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub samples: usize,
}

impl BenchResult {
    pub fn throughput_str(&self, bytes_per_iter: usize) -> String {
        let gbps = bytes_per_iter as f64 / self.ns_per_iter; // bytes/ns == GB/s
        format!("{:.2} GB/s", gbps)
    }
}

/// Run `f` repeatedly; auto-calibrate so each sample takes >= ~20ms,
/// collect `samples` samples, report median ns/iter.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let mut iters: u64 = 1;
    loop {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        let el = t.elapsed().as_nanos() as f64;
        if el > 20_000_000.0 || iters >= 1 << 24 {
            break;
        }
        iters *= 4;
    }
    let samples = 7;
    let mut per_iter = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t.elapsed().as_nanos() as f64 / iters as f64);
    }
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let res = BenchResult {
        name: name.to_string(),
        iters,
        ns_per_iter: per_iter[samples / 2],
        min_ns: per_iter[0],
        max_ns: per_iter[samples - 1],
        samples,
    };
    println!(
        "{:<48} {:>12.1} ns/iter  (min {:.1}, max {:.1}, {} iters)",
        res.name, res.ns_per_iter, res.min_ns, res.max_ns, res.iters
    );
    res
}

/// One-shot wall-clock measurement for expensive end-to-end cells.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t = Instant::now();
    let v = f();
    (v, t.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs() {
        let mut acc = 0u64;
        let r = bench("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.ns_per_iter >= 0.0);
        assert!(r.iters >= 1);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
