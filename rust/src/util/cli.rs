//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `zsecc <subcommand> [--flag] [--key value]...`
//! Flags may be given as `--key=value` or `--key value`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub cmd: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl Iterator<Item = String>) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = argv.peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag
                    // (then it's a boolean switch).
                    match it.peek() {
                        Some(n) if !n.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(stripped.to_string(), "true".into());
                        }
                    }
                }
            } else if out.cmd.is_none() {
                out.cmd = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> anyhow::Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a float, got '{v}'")),
        }
    }

    /// Optional float flag: absent is `None`, malformed is an error
    /// (distinguishes "no target" from "bad target" for `--ci-target`).
    pub fn f64_opt(&self, key: &str) -> anyhow::Result<Option<f64>> {
        match self.str_opt(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key} expects a float, got '{v}'")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.str_opt(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list flag.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.str_opt(key) {
            Some(v) => v.split(',').filter(|s| !s.is_empty()).map(String::from).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["table2", "--rates", "1e-4,1e-3", "--trials=5", "--verbose"]);
        assert_eq!(a.cmd.as_deref(), Some("table2"));
        assert_eq!(a.str_opt("rates"), Some("1e-4,1e-3"));
        assert_eq!(a.usize_or("trials", 10).unwrap(), 5);
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn boolean_before_flag() {
        let a = parse(&["x", "--a", "--b", "v"]);
        assert!(a.bool("a"));
        assert_eq!(a.str_opt("b"), Some("v"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.usize_or("n", 1).is_err());
    }

    #[test]
    fn optional_float() {
        let a = parse(&["x", "--ci-target", "0.25"]);
        assert_eq!(a.f64_opt("ci-target").unwrap(), Some(0.25));
        assert_eq!(a.f64_opt("absent").unwrap(), None);
        let b = parse(&["x", "--ci-target", "abc"]);
        assert!(b.f64_opt("ci-target").is_err());
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["x", "--models", "vgg16_s,resnet18_s"]);
        assert_eq!(a.list_or("models", &[]), vec!["vgg16_s", "resnet18_s"]);
        assert_eq!(a.list_or("other", &["d"]), vec!["d"]);
    }
}
