//! Minimal JSON parser/serializer (serde_json is unavailable offline).
//!
//! Supports the full JSON grammar needed by the artifact manifests and
//! experiment reports: objects, arrays, strings (with escapes), numbers,
//! booleans, null. Numbers are parsed as f64 (adequate: offsets/sizes in
//! our manifests stay far below 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- typed accessors ------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: required field lookups with contextual errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key '{key}' in JSON object"))
    }

    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            at: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.at != p.b.len() {
            anyhow::bail!("trailing garbage at byte {}", p.at);
        }
        Ok(v)
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Serialization goes through `Display` (so `.to_string()` works via
/// the blanket `ToString`); output is canonical — object keys sorted
/// (BTreeMap), no whitespace — which the campaign ledger relies on for
/// byte-identical resume comparisons.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    at: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.at < self.b.len() && matches!(self.b[self.at], b' ' | b'\t' | b'\n' | b'\r') {
            self.at += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.at)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek()? != c {
            anyhow::bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.at,
                self.b[self.at] as char
            );
        }
        self.at += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.at..].starts_with(s.as_bytes()) {
            self.at += s.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.at)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => anyhow::bail!("unexpected '{}' at byte {}", c as char, self.at),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.at += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.at += 1;
                }
                b'}' => {
                    self.at += 1;
                    return Ok(Json::Obj(m));
                }
                c => anyhow::bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.at += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.at += 1;
                }
                b']' => {
                    self.at += 1;
                    return Ok(Json::Arr(a));
                }
                c => anyhow::bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.at += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.at += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.at + 4 > self.b.len() {
                                anyhow::bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.at..self.at + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.at += 4;
                            // Surrogate pairs: only BMP needed for our files,
                            // but handle pairs for completeness.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.at) == Some(&b'\\')
                                    && self.b.get(self.at + 1) == Some(&b'u')
                                {
                                    let hex2 = std::str::from_utf8(
                                        &self.b[self.at + 2..self.at + 6],
                                    )?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.at += 6;
                                    char::from_u32(
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00),
                                    )
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow::anyhow!("bad codepoint"))?);
                        }
                        c => anyhow::bail!("bad escape '\\{}'", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: re-decode from the byte slice.
                    let start = self.at - 1;
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                    s.push_str(chunk);
                    self.at = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.at;
        if self.peek()? == b'-' {
            self.at += 1;
        }
        while self.at < self.b.len()
            && matches!(self.b[self.at], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.at += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.at])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

/// Builder helpers for report emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
/// Finite numbers serialize as numbers; NaN/inf (not representable in
/// JSON) become null. Used for optional statistics like CI half-widths.
pub fn num_or_null(n: f64) -> Json {
    if n.is_finite() {
        Json::Num(n)
    } else {
        Json::Null
    }
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny \"q\""}, "d": true, "e": null}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(
            v.get("b").unwrap().get("c").unwrap().as_str(),
            Some("x\ny \"q\"")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn non_finite_numbers_become_null() {
        assert_eq!(num_or_null(f64::INFINITY), Json::Null);
        assert_eq!(num_or_null(f64::NAN), Json::Null);
        assert_eq!(num_or_null(2.5), Json::Num(2.5));
        assert_eq!(Json::Bool(true).as_bool(), Some(true));
        assert_eq!(Json::Null.as_bool(), None);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn nested_deep() {
        let src = "[".repeat(50) + &"]".repeat(50);
        assert!(Json::parse(&src).is_ok());
    }
}
