//! Descriptive statistics for experiment cells and latency reports.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0 for n < 2).
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Mean ± std formatted like the paper's Table 2 ("0.35 ± 0.06").
pub fn mean_std_str(xs: &[f64]) -> String {
    format!("{:.2} ± {:.2}", mean(xs), std(xs))
}

/// Online accumulator for latency series (keeps raw samples; our series
/// are small enough that exact percentiles beat streaming sketches).
#[derive(Default, Clone)]
pub struct Series {
    pub xs: Vec<f64>,
}

impl Series {
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }
    pub fn mean(&self) -> f64 {
        mean(&self.xs)
    }
    pub fn std(&self) -> f64 {
        std(&self.xs)
    }
    pub fn p(&self, q: f64) -> f64 {
        percentile(&self.xs, q)
    }
    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        // sample std of this classic set is ~2.138
        assert!((std(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn degenerate() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
