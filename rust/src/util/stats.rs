//! Descriptive statistics for experiment cells and latency reports.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0 for n < 2).
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Mean ± std formatted like the paper's Table 2 ("0.35 ± 0.06").
pub fn mean_std_str(xs: &[f64]) -> String {
    format!("{:.2} ± {:.2}", mean(xs), std(xs))
}

// ------------------------------------------------- confidence intervals --

/// Two-sided Student-t critical values for df 1..=30, then anchors at
/// df 40/60/120 and the normal quantile; standard table values.
const T_TABLE_90: [f64; 30] = [
    6.314, 2.920, 2.353, 2.132, 2.015, 1.943, 1.895, 1.860, 1.833, 1.812, 1.796, 1.782, 1.771,
    1.761, 1.753, 1.746, 1.740, 1.734, 1.729, 1.725, 1.721, 1.717, 1.714, 1.711, 1.708, 1.706,
    1.703, 1.701, 1.699, 1.697,
];
const T_TAIL_90: [(f64, f64); 4] =
    [(40.0, 1.684), (60.0, 1.671), (120.0, 1.658), (f64::INFINITY, 1.645)];
const T_TABLE_95: [f64; 30] = [
    12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179, 2.160,
    2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064, 2.060, 2.056,
    2.052, 2.048, 2.045, 2.042,
];
const T_TAIL_95: [(f64, f64); 4] =
    [(40.0, 2.021), (60.0, 2.000), (120.0, 1.980), (f64::INFINITY, 1.960)];
const T_TABLE_99: [f64; 30] = [
    63.657, 9.925, 5.841, 4.604, 4.032, 3.707, 3.499, 3.355, 3.250, 3.169, 3.106, 3.055, 3.012,
    2.977, 2.947, 2.921, 2.898, 2.878, 2.861, 2.845, 2.831, 2.819, 2.807, 2.797, 2.787, 2.779,
    2.771, 2.763, 2.756, 2.750,
];
const T_TAIL_99: [(f64, f64); 4] =
    [(40.0, 2.704), (60.0, 2.660), (120.0, 2.617), (f64::INFINITY, 2.576)];

/// Two-sided Student-t critical value `t*` such that a t-distributed
/// variable with `df` degrees of freedom lies in `[-t*, t*]` with the
/// given probability. Supported confidence levels: 0.90, 0.95, 0.99
/// (the nearest supported level is used). Exact table values for
/// df 1..=30; linear interpolation in 1/df against the 40/60/120/normal
/// anchors beyond (error < 1e-3 there).
pub fn t_critical(df: usize, confidence: f64) -> f64 {
    let (table, tail) = if confidence >= 0.97 {
        (&T_TABLE_99, &T_TAIL_99)
    } else if confidence >= 0.925 {
        (&T_TABLE_95, &T_TAIL_95)
    } else {
        (&T_TABLE_90, &T_TAIL_90)
    };
    let df = df.max(1);
    if df <= 30 {
        return table[df - 1];
    }
    // interpolate in x = 1/df between (30, t30) and the tail anchors
    let x = 1.0 / df as f64;
    let mut prev = (30.0, table[29]);
    for &(d, t) in tail {
        let (x0, x1) = (1.0 / prev.0, 1.0 / d);
        if x >= x1 {
            return t + (prev.1 - t) * (x - x1) / (x0 - x1);
        }
        prev = (d, t);
    }
    tail[tail.len() - 1].1
}

/// Half-width of the two-sided `confidence` Student-t interval on the
/// mean of `xs`: `t* · s / sqrt(n)`. A single sample (or none) cannot
/// bound the mean — returns infinity; a zero-variance sample returns 0.
pub fn mean_ci_half_width(xs: &[f64], confidence: f64) -> f64 {
    if xs.len() < 2 {
        return f64::INFINITY;
    }
    let s = std(xs);
    if s == 0.0 {
        return 0.0;
    }
    t_critical(xs.len() - 1, confidence) * s / (xs.len() as f64).sqrt()
}

/// Two-sided normal quantile `z*` for the given confidence level —
/// the `df → ∞` limit of [`t_critical`] (1.645 / 1.960 / 2.576 for
/// 90 / 95 / 99%).
pub fn normal_z(confidence: f64) -> f64 {
    t_critical(usize::MAX, confidence)
}

/// Wilson score interval for a Bernoulli proportion: `k` successes out
/// of `n` trials at the given confidence. Unlike the Wald interval it
/// never collapses to zero width on `k == 0` — exactly what an online
/// bit-error-rate estimator needs: a shard that has shown no error
/// still carries an upper bound that shrinks as clean evidence
/// accumulates. Accepts fractional (exponentially weighted) effective
/// counts; `n <= 0` returns the vacuous `(0, 1)`.
pub fn wilson_interval(k: f64, n: f64, confidence: f64) -> (f64, f64) {
    if n <= 0.0 {
        return (0.0, 1.0);
    }
    let z = normal_z(confidence);
    let p = (k / n).clamp(0.0, 1.0);
    let z2 = z * z;
    let denom = 1.0 + z2 / n;
    let center = (p + z2 / (2.0 * n)) / denom;
    let half = z * (p * (1.0 - p) / n + z2 / (4.0 * n * n)).sqrt() / denom;
    ((center - half).max(0.0), (center + half).min(1.0))
}

/// Online accumulator for latency series (keeps raw samples; our series
/// are small enough that exact percentiles beat streaming sketches).
#[derive(Default, Clone)]
pub struct Series {
    pub xs: Vec<f64>,
}

impl Series {
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }
    pub fn mean(&self) -> f64 {
        mean(&self.xs)
    }
    pub fn std(&self) -> f64 {
        std(&self.xs)
    }
    pub fn p(&self, q: f64) -> f64 {
        percentile(&self.xs, q)
    }
    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        // sample std of this classic set is ~2.138
        assert!((std(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn degenerate() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn t_critical_matches_table_fixtures() {
        // classic two-sided table values, exact in the df<=30 regime
        assert_eq!(t_critical(1, 0.95), 12.706);
        assert_eq!(t_critical(4, 0.95), 2.776);
        assert_eq!(t_critical(7, 0.95), 2.365);
        assert_eq!(t_critical(30, 0.95), 2.042);
        assert_eq!(t_critical(4, 0.90), 2.132);
        assert_eq!(t_critical(10, 0.99), 3.169);
        // df 0 is clamped to 1
        assert_eq!(t_critical(0, 0.95), 12.706);
        // tail interpolation: monotone, bracketed by its anchors
        let t45 = t_critical(45, 0.95);
        assert!(t45 > 2.000 && t45 < 2.021, "t(45) = {t45}");
        // ...and converges to the normal quantile for huge df
        assert!((t_critical(1_000_000, 0.95) - 1.960).abs() < 1e-3);
        assert!((t_critical(1_000_000, 0.99) - 2.576).abs() < 1e-3);
        // unsupported levels snap to the nearest supported one
        assert_eq!(t_critical(5, 0.94), t_critical(5, 0.95));
    }

    #[test]
    fn ci_half_width_known_value() {
        // n=8, s=2.13809..., t(7, 95%)=2.365 -> hw = t*s/sqrt(8) ≈ 1.7878
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let hw = mean_ci_half_width(&xs, 0.95);
        assert!((hw - 1.7878).abs() < 1e-3, "hw = {hw}");
        // wider at higher confidence
        assert!(mean_ci_half_width(&xs, 0.99) > hw);
        assert!(mean_ci_half_width(&xs, 0.90) < hw);
    }

    #[test]
    fn wilson_matches_published_values() {
        // classic fixture: 10/100 at 95% -> (0.0552, 0.1744)
        let (lo, hi) = wilson_interval(10.0, 100.0, 0.95);
        assert!((lo - 0.0552).abs() < 1e-3, "lo = {lo}");
        assert!((hi - 0.1744).abs() < 1e-3, "hi = {hi}");
        // zero successes: lower bound 0, upper ~ z^2 / (n + z^2)
        let (lo, hi) = wilson_interval(0.0, 1000.0, 0.95);
        assert_eq!(lo, 0.0);
        let z2 = normal_z(0.95).powi(2);
        assert!((hi - z2 / (1000.0 + z2)).abs() < 1e-6, "hi = {hi}");
        // all successes mirrors zero successes
        let (lo, hi) = wilson_interval(1000.0, 1000.0, 0.95);
        assert!(hi > 1.0 - 1e-9, "hi = {hi}");
        assert!((lo - 1000.0 / (1000.0 + z2)).abs() < 1e-6, "lo = {lo}");
        // no evidence is the vacuous interval
        assert_eq!(wilson_interval(0.0, 0.0, 0.95), (0.0, 1.0));
        // more evidence tightens, higher confidence widens
        let (_, hi_small) = wilson_interval(1.0, 100.0, 0.95);
        let (_, hi_big) = wilson_interval(10.0, 1000.0, 0.95);
        assert!(hi_big < hi_small);
        let (_, hi99) = wilson_interval(10.0, 1000.0, 0.99);
        assert!(hi99 > hi_big);
    }

    #[test]
    fn normal_z_anchors() {
        assert!((normal_z(0.90) - 1.645).abs() < 1e-3);
        assert!((normal_z(0.95) - 1.960).abs() < 1e-3);
        assert!((normal_z(0.99) - 2.576).abs() < 1e-3);
    }

    #[test]
    fn ci_half_width_degenerate() {
        // one sample (or none) cannot bound the mean
        assert!(mean_ci_half_width(&[3.0], 0.95).is_infinite());
        assert!(mean_ci_half_width(&[], 0.95).is_infinite());
        // zero variance pins the mean exactly
        assert_eq!(mean_ci_half_width(&[2.0, 2.0, 2.0], 0.95), 0.0);
    }
}
