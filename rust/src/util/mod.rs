//! In-tree substrates replacing unavailable crates (offline build):
//! JSON codec (serde), PRNG (rand), CLI parsing (clap), statistics and
//! timing (criterion), ASCII plotting.

pub mod cli;
pub mod json;
pub mod plot;
pub mod rng;
pub mod stats;
pub mod timer;
