//! Adaptive per-shard scrub scheduling driven by an online
//! bit-error-rate estimator.
//!
//! The serving loop used to scrub every shard on one fixed interval —
//! wasting clean-tile passes on cold shards while under-protecting
//! hotspots (exactly the non-uniform fault models the campaign engine
//! injects). This module closes the telemetry → scheduling loop:
//!
//! ```text
//!             DecodeStats per scrub pass
//!   ShardedBank ----------------------------> BerEstimator (per shard)
//!        ^                                         | EW error counts,
//!        | scrub_subset(due shards)                | Wilson upper bound
//!        |                                         v
//!   ScrubScheduler <------------------------- deadline = f(BER, budget)
//!             earliest-deadline-first dispatch
//! ```
//!
//! **Estimator.** Every scrub pass over a shard yields a `DecodeStats`.
//! The estimator folds the pass into exponentially weighted counts of
//! *newly arrived* error bits (`corrected + zeroed` plus the *increase*
//! in detected-uncorrectable blocks — a block that is already
//! uncorrectable is re-detected by every subsequent pass, and more
//! scrubbing cannot help it, so only fresh detections count as arrival
//! signal) over exponentially weighted bit·seconds of exposure. The
//! Wilson score interval ([`crate::util::stats::wilson_interval`]) on
//! those effective counts gives a confidence-bounded BER: a shard with
//! no observed error still has a non-zero upper bound that shrinks as
//! clean evidence accumulates — "provably clean" is an accumulating
//! statement, not a single lucky pass.
//!
//! **Scheduler.** Each shard carries its own next-scrub deadline. The
//! adaptive policy sizes the interval so the *expected number of new
//! error bits arriving between scrubs* (Wilson-upper BER × shard bits ×
//! interval) stays at the configured residual budget, clamped to
//! `[base_interval, max_interval]`; a clean pass additionally grows the
//! interval by at least the `growth` factor, so with injection disabled
//! every shard's interval decays monotonically to the maximum. Hot
//! shards clamp to the base interval and soak up scrub bandwidth;
//! deadlines are served earliest-first.
//!
//! Time is passed in by the caller as a [`Duration`] since an arbitrary
//! epoch — the serving loop uses wall clock, the simulation harness
//! ([`crate::harness::scrubsim`]) uses virtual ticks, which is what
//! makes the scheduler's behavior deterministically testable.

use std::time::Duration;

use crate::ecc::DecodeStats;
use crate::util::stats;

/// Which scrub scheduling policy the serving loop runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScrubPolicy {
    /// Every shard on one fixed interval (the pre-scheduler behavior).
    Fixed,
    /// Per-shard deadlines from the online BER estimator.
    Adaptive,
}

impl ScrubPolicy {
    /// Stable tag (CLI flag values, JSON reports).
    pub fn tag(&self) -> &'static str {
        match self {
            ScrubPolicy::Fixed => "fixed",
            ScrubPolicy::Adaptive => "adaptive",
        }
    }

    /// Parse a `--scrub-policy` value; accepts every string `tag`
    /// produces.
    pub fn parse(text: &str) -> anyhow::Result<ScrubPolicy> {
        match text {
            "fixed" => Ok(ScrubPolicy::Fixed),
            "adaptive" => Ok(ScrubPolicy::Adaptive),
            _ => anyhow::bail!("unknown scrub policy '{text}' (fixed | adaptive)"),
        }
    }
}

/// Scheduler knobs. `fixed`/`adaptive` constructors carry sensible
/// defaults; everything is public for the simulation harness.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    pub policy: ScrubPolicy,
    /// The fixed policy's period; the adaptive policy's starting
    /// interval and lower clamp.
    pub base_interval: Duration,
    /// Adaptive upper clamp: provably-clean shards decay toward this.
    pub max_interval: Duration,
    /// Target expected *new* error bits per shard per interval — the
    /// residual-error budget the deadline is derived from.
    pub target_residual: f64,
    /// Confidence of the Wilson upper bound (see `stats::normal_z`).
    pub confidence: f64,
    /// Exponential retain factor per pass in (0, 1): how much of the
    /// previous evidence a new pass keeps. Smaller forgets (and thus
    /// re-adapts) faster.
    pub decay: f64,
    /// Minimum multiplicative interval growth after a clean pass
    /// (>= 1); guarantees monotone decay to `max_interval` on clean
    /// streaks whatever the Wilson bound does.
    pub growth: f64,
}

impl SchedulerConfig {
    /// The classic fixed-interval loop expressed as a scheduler.
    pub fn fixed(interval: Duration) -> SchedulerConfig {
        SchedulerConfig {
            policy: ScrubPolicy::Fixed,
            base_interval: interval,
            max_interval: interval,
            target_residual: 0.5,
            confidence: 0.95,
            decay: 0.7,
            growth: 1.5,
        }
    }

    /// Adaptive scheduling between `base` (hot clamp) and `max`
    /// (clean decay target).
    pub fn adaptive(base: Duration, max: Duration) -> SchedulerConfig {
        SchedulerConfig {
            policy: ScrubPolicy::Adaptive,
            base_interval: base,
            max_interval: max.max(base),
            target_residual: 0.5,
            confidence: 0.95,
            decay: 0.7,
            growth: 1.5,
        }
    }

    /// Override the per-model residual budget (expected new error bits
    /// per shard per interval) — the knob `ServerConfig::target_residual`
    /// feeds through. Non-finite or non-positive values keep the
    /// default.
    pub fn with_target_residual(mut self, target: f64) -> SchedulerConfig {
        if target.is_finite() && target > 0.0 {
            self.target_residual = target;
        }
        self
    }
}

/// Per-shard estimator + deadline state.
#[derive(Clone, Debug)]
struct ShardSched {
    /// Stored bits exposed to faults (BER denominator).
    bits: u64,
    /// Current scrub interval.
    interval: Duration,
    /// Next scrub deadline (same epoch as the caller's `now`).
    deadline: Duration,
    /// When the shard was last scrubbed (creation time before the
    /// first pass — exposure starts when the bank goes live).
    last_pass: Duration,
    /// Exponentially weighted newly-arrived error bits.
    ew_errors: f64,
    /// Exponentially weighted bit·seconds of exposure.
    ew_bitsecs: f64,
    /// Detected-uncorrectable count of the previous pass: re-detected
    /// blocks are not new arrivals.
    last_detected: u64,
    passes: u64,
    /// Passes that started later than deadline + half the base
    /// interval — the "scheduler cannot keep up" signal.
    overdue: u64,
}

/// Read-only per-shard snapshot for metrics/reporting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardSchedule {
    /// Wilson lower bound on the per-bit-per-second error rate.
    pub ber_lower: f64,
    /// Wilson upper bound — what the deadline is derived from.
    pub ber_upper: f64,
    /// Current scrub interval in seconds.
    pub interval_secs: f64,
    /// Deadline relative to the caller's `now` (negative = overdue by
    /// that many seconds).
    pub deadline_in_secs: f64,
    /// Cumulative scrub passes recorded for this shard.
    pub passes: u64,
    /// Cumulative late passes (past deadline by more than half the
    /// base interval).
    pub overdue: u64,
}

/// Deadline-based per-shard scrub scheduler (see module docs).
pub struct ScrubScheduler {
    cfg: SchedulerConfig,
    shards: Vec<ShardSched>,
}

impl ScrubScheduler {
    /// A scheduler over shards of the given stored-bit sizes. Every
    /// shard starts due at `now` — the first pass calibrates the
    /// estimator — with an *optimistic* interval at the max: a clean
    /// first pass keeps it there (no cold-start stampede of the whole
    /// fleet growing from the base interval), while a first pass that
    /// sees errors re-derives the interval from the evidence and
    /// clamps hot shards straight to the base.
    pub fn new(cfg: SchedulerConfig, shard_bits: &[u64], now: Duration) -> ScrubScheduler {
        let shards = shard_bits
            .iter()
            .map(|&bits| ShardSched {
                bits,
                interval: cfg.max_interval,
                deadline: now,
                last_pass: now,
                ew_errors: 0.0,
                ew_bitsecs: 0.0,
                last_detected: 0,
                passes: 0,
                overdue: 0,
            })
            .collect();
        ScrubScheduler { cfg, shards }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn policy(&self) -> ScrubPolicy {
        self.cfg.policy
    }

    /// Shards whose deadline has passed, in shard-index order (the
    /// consumer scrubs them all this wakeup; use [`Self::most_urgent`]
    /// when dispatch order matters).
    pub fn due(&self, now: Duration) -> Vec<usize> {
        (0..self.shards.len())
            .filter(|&i| self.shards[i].deadline <= now)
            .collect()
    }

    /// The `k` shards with the earliest deadlines whether or not they
    /// are due yet — the fixed-bandwidth dispatch the simulation
    /// harness uses to compare policies at equal scrub passes per tick.
    pub fn most_urgent(&self, k: usize) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.shards.len()).collect();
        order.sort_by_key(|&i| (self.shards[i].deadline, i));
        order.truncate(k);
        order
    }

    /// Earliest deadline across all shards — what the serving loop
    /// sleeps until.
    pub fn next_deadline(&self) -> Duration {
        self.shards
            .iter()
            .map(|s| s.deadline)
            .min()
            .unwrap_or_default()
    }

    pub fn interval(&self, idx: usize) -> Duration {
        self.shards[idx].interval
    }

    /// Stored bits shard `idx` exposes (what its scrub pass costs the
    /// fleet budget).
    pub fn shard_bits(&self, idx: usize) -> u64 {
        self.shards[idx].bits
    }

    pub fn deadline(&self, idx: usize) -> Duration {
        self.shards[idx].deadline
    }

    /// Wilson `(lower, upper)` bounds on shard `idx`'s per-bit-per-
    /// second error rate at the configured confidence. `(0, 1)` before
    /// any evidence.
    pub fn ber_bounds(&self, idx: usize) -> (f64, f64) {
        let s = &self.shards[idx];
        stats::wilson_interval(s.ew_errors, s.ew_bitsecs, self.cfg.confidence)
    }

    /// Snapshot of shard `idx` relative to `now` (for metrics gauges).
    pub fn snapshot(&self, idx: usize, now: Duration) -> ShardSchedule {
        let s = &self.shards[idx];
        let (ber_lower, ber_upper) = self.ber_bounds(idx);
        ShardSchedule {
            ber_lower,
            ber_upper,
            interval_secs: s.interval.as_secs_f64(),
            deadline_in_secs: s.deadline.as_secs_f64() - now.as_secs_f64(),
            passes: s.passes,
            overdue: s.overdue,
        }
    }

    /// Record a completed scrub pass over shard `idx` and re-derive
    /// its interval and deadline. `now` must not precede the shard's
    /// previous pass.
    pub fn record_pass(&mut self, idx: usize, pass: &DecodeStats, now: Duration) {
        let cfg = self.cfg;
        let s = &mut self.shards[idx];
        // Newly arrived error bits: corrections and zeroings are fresh
        // by construction (the pass repaired them); detections are new
        // only beyond the previous pass's count.
        let new_err = pass.corrected + pass.zeroed + pass.detected.saturating_sub(s.last_detected);
        s.last_detected = pass.detected;
        // Fold unconditionally: a pass with zero elapsed exposure still
        // contributes its error evidence (the Wilson interval stays
        // vacuous until bit·seconds accrue), so arrivals seen by an
        // instant first pass are never silently dropped.
        let elapsed = now.saturating_sub(s.last_pass).as_secs_f64();
        s.ew_errors = cfg.decay * s.ew_errors + new_err as f64;
        s.ew_bitsecs = cfg.decay * s.ew_bitsecs + s.bits as f64 * elapsed;
        if now > s.deadline + cfg.base_interval / 2 {
            s.overdue += 1;
        }
        s.last_pass = now;
        s.passes += 1;
        if cfg.policy == ScrubPolicy::Adaptive {
            let mut next = derive_interval(&cfg, s.bits, s.ew_errors, s.ew_bitsecs);
            if new_err == 0 {
                // Clean pass: never shrink, grow by at least `growth` —
                // the monotone decay-to-max guarantee.
                next = next.max(s.interval.mul_f64(cfg.growth));
            }
            s.interval = next.clamp(cfg.base_interval, cfg.max_interval);
        }
        s.deadline = now + s.interval;
    }

    /// One discrete time step of the scheduler's dispatch law: which
    /// shards scrub *now*, spending at most `budget_bits` (None = no
    /// cap). Due shards become [`ScrubDemand`]s and route through the
    /// same [`arbitrate`] planner the fleet control loop runs — the
    /// closed-loop simulation and the serve path share one law, so a
    /// policy the sim certifies is the policy production executes.
    /// Shards the budget cannot place simply stay due and compete again
    /// next step (single-model stepping keeps no deferral counters; the
    /// starvation bound belongs to [`FleetArbitration`]).
    pub fn step_plan(&self, now: Duration, budget_bits: Option<u64>) -> Vec<usize> {
        let demands: Vec<ScrubDemand> = self
            .due(now)
            .into_iter()
            .map(|i| ScrubDemand {
                model: 0,
                shard: i,
                bits: self.shard_bits(i),
                ber_upper: self.ber_bounds(i).1,
                lateness_secs: (now.as_secs_f64() - self.deadline(i).as_secs_f64()).max(0.0),
                deferrals: 0,
            })
            .collect();
        arbitrate(&demands, budget_bits.unwrap_or(u64::MAX), u32::MAX)
            .into_iter()
            .map(|g| g.shard)
            .collect()
    }
}

/// The adaptive interval that keeps expected new-error arrivals at the
/// residual budget — `target_residual / (wilson_upper · bits)` with
/// every degenerate denominator guarded. A zero-bit shard (shard
/// geometry can leave an empty tail shard) exposes nothing: it idles at
/// the maximum interval instead of letting its vacuous evidence
/// hot-clamp it. A zero or non-finite arrival-rate bound likewise falls
/// back to the maximum rather than dividing into a NaN deadline.
fn derive_interval(
    cfg: &SchedulerConfig,
    bits: u64,
    ew_errors: f64,
    ew_bitsecs: f64,
) -> Duration {
    if bits == 0 {
        return cfg.max_interval;
    }
    let (_, ber_hi) = stats::wilson_interval(ew_errors, ew_bitsecs, cfg.confidence);
    let err_per_sec = ber_hi * bits as f64;
    if err_per_sec.is_finite() && err_per_sec > 0.0 {
        Duration::from_secs_f64(
            (cfg.target_residual / err_per_sec).min(cfg.max_interval.as_secs_f64()),
        )
    } else {
        cfg.max_interval
    }
}

// ------------------------------------------------------------- fleet --

/// One due shard's demand on the fleet scrub budget: everything the
/// cross-model arbiter ranks on. Built by [`FleetArbitration::plan`]
/// from each model's [`ScrubScheduler`]; public (and plain data) so the
/// arbitration invariants are provable on synthetic demand sets without
/// standing up banks.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScrubDemand {
    /// Registration slot of the owning model.
    pub model: usize,
    pub shard: usize,
    /// Stored bits a pass over this shard costs the budget.
    pub bits: u64,
    /// Wilson upper bound on the shard's error arrival rate — the
    /// urgency signal.
    pub ber_upper: f64,
    /// Seconds past the shard's deadline (0 when exactly due).
    pub lateness_secs: f64,
    /// Consecutive wakeups this shard has been due but not granted.
    pub deferrals: u32,
}

impl ScrubDemand {
    /// Urgency score: expected error bits already accrued past the
    /// deadline — Wilson-upper arrival rate × exposed bits, scaled up
    /// by how late the shard already is. Deterministic total order via
    /// the `(model, shard)` tie-break in [`arbitrate`].
    pub fn urgency(&self) -> f64 {
        self.ber_upper.max(f64::MIN_POSITIVE) * self.bits as f64 * (1.0 + self.lateness_secs)
    }
}

/// One scrub pass granted by the arbiter this wakeup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FleetGrant {
    pub model: usize,
    pub shard: usize,
    /// Granted through the starvation guarantee (deferral cap), not by
    /// outranking the field on urgency.
    pub starved: bool,
}

/// Pick which due shards scrub this wakeup, spending at most
/// `budget_bits` of scrub bandwidth.
///
/// Two classes, in order:
///
/// 1. **Starved** (`deferrals >= starve_after`): served
///    most-deferred-first regardless of urgency. As long as
///    `budget_bits` covers the largest single shard, every wakeup
///    grants at least the front starved candidate, so no due shard
///    waits more than `starve_after + total_shards` wakeups — the
///    starvation-freedom bound the proptests pin.
/// 2. **Urgent**: remaining budget goes greedy by
///    [`ScrubDemand::urgency`], skipping candidates that no longer
///    fit (first-fit over the ranked order).
///
/// Granted bits never exceed `budget_bits` (conservation) — a shard
/// that does not fit is deferred, never partially scrubbed.
pub fn arbitrate(demands: &[ScrubDemand], budget_bits: u64, starve_after: u32) -> Vec<FleetGrant> {
    let mut starved: Vec<&ScrubDemand> = Vec::new();
    let mut urgent: Vec<&ScrubDemand> = Vec::new();
    for d in demands {
        if d.deferrals >= starve_after {
            starved.push(d);
        } else {
            urgent.push(d);
        }
    }
    starved.sort_by(|a, b| {
        b.deferrals
            .cmp(&a.deferrals)
            .then(a.model.cmp(&b.model))
            .then(a.shard.cmp(&b.shard))
    });
    urgent.sort_by(|a, b| {
        b.urgency()
            .partial_cmp(&a.urgency())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.model.cmp(&b.model))
            .then(a.shard.cmp(&b.shard))
    });
    let mut grants = Vec::new();
    let mut left = budget_bits;
    for (class, starved_class) in [(starved, true), (urgent, false)] {
        for d in class {
            if d.bits <= left {
                left -= d.bits;
                grants.push(FleetGrant {
                    model: d.model,
                    shard: d.shard,
                    starved: starved_class,
                });
            }
        }
    }
    grants
}

/// Convert an operator-facing scrub-bandwidth budget in GB/s (decimal
/// gigabytes, as bandwidth is always quoted) into the stored-bit budget
/// one arbiter wakeup may spend: `gbps x 1e9 bytes x 8 bits x wakeup
/// seconds`, rounded to nearest. Non-finite or non-positive inputs map
/// to 0 (an explicit "no bandwidth" rather than a surprise huge cast).
/// This is the first step of deriving the fleet budget from a
/// machine-level bandwidth fraction instead of a raw bit count.
pub fn gbps_to_bits_per_wakeup(gbps: f64, wakeup: Duration) -> u64 {
    if !gbps.is_finite() || gbps <= 0.0 {
        return 0;
    }
    (gbps * 1e9 * 8.0 * wakeup.as_secs_f64()).round() as u64
}

/// Per-model budget-deficit gauges (degraded-mode observability): how
/// much due scrub work the arbiter could *not* place, per model.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ModelDeficit {
    /// Cumulative bits of due-but-denied scrub demand.
    pub deficit_bits: u64,
    /// Denied bits at the most recent wakeup (0 = keeping up now).
    pub last_deficit_bits: u64,
    /// Grants this model received through the starvation guarantee.
    pub starved_grants: u64,
}

/// Cross-model scrub arbitration state: per-shard deferral counters and
/// per-model deficit accounting over a shared bit budget. The live
/// fleet control loop ([`crate::coordinator::fleet`]) and the scrubsim
/// harness drive the *same* planner, which is what makes the
/// starvation/conservation guarantees deterministically testable.
#[derive(Clone, Debug)]
pub struct FleetArbitration {
    /// Scrub bits the whole fleet may spend per wakeup; `None` scrubs
    /// every due shard (a fleet of one degenerates to the old
    /// per-server loop).
    budget_bits: Option<u64>,
    starve_after: u32,
    deferrals: Vec<Vec<u32>>,
    deficits: Vec<ModelDeficit>,
    wakeups: u64,
}

impl FleetArbitration {
    /// `starve_after` is clamped to >= 1: with a cap of 0 every due
    /// shard is "starved" and urgency ranking never happens.
    pub fn new(budget_bits: Option<u64>, starve_after: u32) -> FleetArbitration {
        FleetArbitration {
            budget_bits,
            starve_after: starve_after.max(1),
            deferrals: Vec::new(),
            deficits: Vec::new(),
            wakeups: 0,
        }
    }

    /// Register a model; returns its slot (the `model` field of every
    /// demand/grant).
    pub fn register(&mut self, num_shards: usize) -> usize {
        self.deferrals.push(vec![0; num_shards]);
        self.deficits.push(ModelDeficit::default());
        self.deferrals.len() - 1
    }

    pub fn budget_bits(&self) -> Option<u64> {
        self.budget_bits
    }

    pub fn starve_after(&self) -> u32 {
        self.starve_after
    }

    pub fn wakeups(&self) -> u64 {
        self.wakeups
    }

    pub fn num_models(&self) -> usize {
        self.deferrals.len()
    }

    pub fn deficit(&self, model: usize) -> ModelDeficit {
        self.deficits[model]
    }

    /// Plan one wakeup: collect every registered scheduler's due shards
    /// as demands, arbitrate them under the budget, and fold the
    /// outcome back into the deferral/deficit books. `scheds[i]` pairs
    /// a registration slot with its scheduler; a retired model is
    /// simply absent. Grants come back grouped as the caller passed the
    /// models, ready for per-bank `scrub_subset` dispatch.
    pub fn plan(&mut self, scheds: &[(usize, &ScrubScheduler)], now: Duration) -> Vec<FleetGrant> {
        let mut demands: Vec<ScrubDemand> = Vec::new();
        for &(slot, sched) in scheds {
            for shard in sched.due(now) {
                let (_, ber_upper) = sched.ber_bounds(shard);
                demands.push(ScrubDemand {
                    model: slot,
                    shard,
                    bits: sched.shard_bits(shard),
                    ber_upper,
                    lateness_secs: now.saturating_sub(sched.deadline(shard)).as_secs_f64(),
                    deferrals: self.deferrals[slot][shard],
                });
            }
        }
        let grants = match self.budget_bits {
            // Unbounded: everything due is granted, ranked all the same
            // so dispatch order stays urgency-first.
            None => arbitrate(&demands, u64::MAX, self.starve_after),
            Some(b) => arbitrate(&demands, b, self.starve_after),
        };
        self.wakeups += 1;
        for def in self.deficits.iter_mut() {
            def.last_deficit_bits = 0;
        }
        let granted: std::collections::BTreeSet<(usize, usize)> =
            grants.iter().map(|g| (g.model, g.shard)).collect();
        for d in &demands {
            if granted.contains(&(d.model, d.shard)) {
                continue;
            }
            self.deferrals[d.model][d.shard] = self.deferrals[d.model][d.shard].saturating_add(1);
            let def = &mut self.deficits[d.model];
            def.deficit_bits = def.deficit_bits.saturating_add(d.bits);
            def.last_deficit_bits = def.last_deficit_bits.saturating_add(d.bits);
        }
        for g in &grants {
            self.deferrals[g.model][g.shard] = 0;
            if g.starved {
                self.deficits[g.model].starved_grants += 1;
            }
        }
        grants
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn secs(n: u64) -> Duration {
        Duration::from_secs(n)
    }

    fn errs(corrected: u64, detected: u64) -> DecodeStats {
        DecodeStats {
            corrected,
            detected,
            zeroed: 0,
        }
    }

    #[test]
    fn policy_tags_roundtrip() {
        for p in [ScrubPolicy::Fixed, ScrubPolicy::Adaptive] {
            assert_eq!(ScrubPolicy::parse(p.tag()).unwrap(), p);
        }
        assert!(ScrubPolicy::parse("eager").is_err());
    }

    #[test]
    fn fixed_policy_keeps_one_cadence() {
        let cfg = SchedulerConfig::fixed(secs(10));
        let mut sched = ScrubScheduler::new(cfg, &[1 << 20, 1 << 20], Duration::ZERO);
        assert_eq!(sched.due(Duration::ZERO), vec![0, 1], "all due at start");
        sched.record_pass(0, &errs(100, 3), secs(0));
        sched.record_pass(1, &DecodeStats::default(), secs(0));
        // however different the evidence, fixed keeps the base interval
        assert_eq!(sched.interval(0), secs(10));
        assert_eq!(sched.interval(1), secs(10));
        assert_eq!(sched.deadline(0), secs(10));
        assert!(sched.due(secs(9)).is_empty());
        assert_eq!(sched.due(secs(10)), vec![0, 1]);
    }

    #[test]
    fn clean_shards_decay_to_max_interval() {
        // The acceptance guarantee: with fault injection disabled,
        // every shard's interval decays (monotonically grows) to the
        // configured maximum — here from the worst starting point, a
        // shard clamped hot by an initial error shower.
        let cfg = SchedulerConfig::adaptive(secs(1), secs(64));
        let mut sched = ScrubScheduler::new(cfg, &[1 << 16, 1 << 22], Duration::ZERO);
        for idx in 0..sched.num_shards() {
            sched.record_pass(idx, &errs(400, 0), secs(1));
            assert_eq!(sched.interval(idx), secs(1), "shard {idx}: hot clamp");
            let mut now = secs(1);
            let mut prev = Duration::ZERO;
            for _ in 0..24 {
                now += sched.interval(idx);
                sched.record_pass(idx, &DecodeStats::default(), now);
                assert!(
                    sched.interval(idx) >= prev,
                    "shard {idx}: interval must never shrink on clean passes"
                );
                prev = sched.interval(idx);
            }
            assert_eq!(
                sched.interval(idx),
                secs(64),
                "shard {idx}: clean shard must reach the max interval"
            );
            // ...and the BER upper bound keeps shrinking as the error
            // evidence decays and clean exposure accumulates
            let (_, hi) = sched.ber_bounds(idx);
            assert!(hi < 1e-3, "clean shard upper bound: {hi}");
        }
    }

    #[test]
    fn zero_bit_shard_never_hot_clamps() {
        // An empty shard exposes no bits. Before the denominator guard
        // its pinned 1-bit exposure made the Wilson upper bound hover
        // near 1, so `target / (ber_hi * bits)` dragged it to the hot
        // clamp — an empty shard soaking up scrub bandwidth forever.
        let cfg = SchedulerConfig::adaptive(secs(1), secs(64));
        let mut sched = ScrubScheduler::new(cfg, &[0, 1 << 20], Duration::ZERO);
        let mut now = Duration::ZERO;
        for _ in 0..4 {
            now += secs(1);
            sched.record_pass(0, &DecodeStats::default(), now);
            assert_eq!(
                sched.interval(0),
                secs(64),
                "no bits, no evidence, no hot clamp"
            );
        }
        // Even a (nonsensical) error report against an empty shard must
        // not divide its way into a hot deadline.
        sched.record_pass(0, &errs(3, 0), now + secs(1));
        assert_eq!(sched.interval(0), secs(64));
        let (lo, hi) = sched.ber_bounds(0);
        assert_eq!((lo, hi), (0.0, 1.0), "vacuous evidence stays vacuous");
        // The populated neighbour still adapts normally.
        sched.record_pass(1, &errs(400, 0), secs(1));
        assert_eq!(sched.interval(1), secs(1), "real shards still hot-clamp");
    }

    #[test]
    fn hot_shard_clamps_to_base_interval() {
        let cfg = SchedulerConfig::adaptive(secs(1), secs(64));
        let mut sched = ScrubScheduler::new(cfg, &[1 << 20], Duration::ZERO);
        let mut now = secs(1);
        for _ in 0..6 {
            sched.record_pass(0, &errs(500, 10), now);
            now += sched.interval(0);
        }
        assert_eq!(
            sched.interval(0),
            secs(1),
            "a shard showering errors must sit at the hot clamp"
        );
        let (lo, hi) = sched.ber_bounds(0);
        assert!(lo > 0.0 && hi > lo, "error evidence must lift both bounds");
    }

    #[test]
    fn redetected_uncorrectables_are_not_new_arrivals() {
        let cfg = SchedulerConfig::adaptive(secs(1), secs(32));
        let mut sched = ScrubScheduler::new(cfg, &[1 << 20], Duration::ZERO);
        // A pass that finds 5 uncorrectable blocks...
        sched.record_pass(0, &errs(0, 5), secs(1));
        let hot = sched.interval(0);
        assert!(hot < secs(32), "fresh detections must tighten the interval");
        // ...then the same 5 re-detected every pass with nothing new:
        // the shard must cool back down (arrival rate is zero).
        let mut now = secs(1);
        for _ in 0..20 {
            now += sched.interval(0);
            sched.record_pass(0, &errs(0, 5), now);
        }
        assert_eq!(
            sched.interval(0),
            secs(32),
            "a statically-damaged shard must not hog scrub bandwidth"
        );
    }

    #[test]
    fn adaptation_recovers_after_a_hot_phase() {
        let cfg = SchedulerConfig::adaptive(secs(1), secs(16));
        let mut sched = ScrubScheduler::new(cfg, &[1 << 20], Duration::ZERO);
        let mut now = Duration::ZERO;
        for _ in 0..5 {
            now += sched.interval(0);
            sched.record_pass(0, &errs(200, 0), now);
        }
        assert_eq!(sched.interval(0), secs(1));
        for _ in 0..24 {
            now += sched.interval(0);
            sched.record_pass(0, &DecodeStats::default(), now);
        }
        assert_eq!(
            sched.interval(0),
            secs(16),
            "evidence decay must let a cooled shard relax again"
        );
    }

    #[test]
    fn due_and_urgent_order_by_deadline() {
        let cfg = SchedulerConfig::adaptive(secs(1), secs(64));
        let mut sched = ScrubScheduler::new(cfg, &[1 << 20, 1 << 20, 1 << 20], Duration::ZERO);
        // shard 1 hot (deadline now+1), shards 0/2 clean (later)
        sched.record_pass(0, &DecodeStats::default(), secs(1));
        sched.record_pass(1, &errs(300, 0), secs(1));
        sched.record_pass(2, &DecodeStats::default(), secs(1));
        assert_eq!(sched.most_urgent(2), vec![1, 0]);
        assert_eq!(sched.next_deadline(), sched.deadline(1));
        let due = sched.due(secs(2));
        assert_eq!(due, vec![1], "only the hot shard is due after 1s");
        assert!(sched.due(Duration::ZERO).is_empty());
    }

    fn demand(model: usize, shard: usize, bits: u64, ber: f64, late: f64, def: u32) -> ScrubDemand {
        ScrubDemand {
            model,
            shard,
            bits,
            ber_upper: ber,
            lateness_secs: late,
            deferrals: def,
        }
    }

    #[test]
    fn arbitrate_conserves_the_bit_budget() {
        let demands: Vec<ScrubDemand> = (0..6)
            .map(|i| demand(i % 2, i, 1000, 1e-6 * (i + 1) as f64, i as f64, 0))
            .collect();
        for budget in [0u64, 999, 1000, 2500, 6000] {
            let grants = arbitrate(&demands, budget, 4);
            let spent: u64 = grants.iter().map(|_| 1000u64).sum();
            assert!(spent <= budget, "budget {budget}: spent {spent}");
        }
        // full budget grants everything
        assert_eq!(arbitrate(&demands, 6000, 4).len(), 6);
    }

    #[test]
    fn arbitrate_ranks_by_urgency_then_serves_starved_first() {
        // model 1's shard is far hotter; at budget for one pass it wins
        let d = vec![
            demand(0, 0, 1000, 1e-7, 0.0, 0),
            demand(1, 0, 1000, 1e-3, 0.0, 0),
        ];
        let g = arbitrate(&d, 1000, 4);
        assert_eq!(g, vec![FleetGrant { model: 1, shard: 0, starved: false }]);
        // ...unless the cold one has hit the deferral cap: starvation
        // freedom outranks urgency
        let d = vec![
            demand(0, 0, 1000, 1e-7, 0.0, 4),
            demand(1, 0, 1000, 1e-3, 0.0, 0),
        ];
        let g = arbitrate(&d, 1000, 4);
        assert_eq!(g, vec![FleetGrant { model: 0, shard: 0, starved: true }]);
    }

    #[test]
    fn arbitrate_lateness_breaks_equal_rates() {
        let d = vec![
            demand(0, 0, 1000, 1e-5, 0.0, 0),
            demand(0, 1, 1000, 1e-5, 30.0, 0),
        ];
        let g = arbitrate(&d, 1000, 4);
        assert_eq!((g[0].model, g[0].shard), (0, 1), "later shard first");
    }

    #[test]
    fn planner_accounts_deficits_and_bounds_waits() {
        // two 4-shard models, every shard 1000 bits, budget = one pass
        // per wakeup: 7 of 8 due shards are denied every wakeup, yet
        // the deferral cap must cycle every shard through within
        // starve_after + total_shards wakeups.
        let cfg = SchedulerConfig::fixed(secs(1));
        let bits = [1000u64; 4];
        let mut scheds = vec![
            ScrubScheduler::new(cfg, &bits, Duration::ZERO),
            ScrubScheduler::new(cfg, &bits, Duration::ZERO),
        ];
        let mut fleet = FleetArbitration::new(Some(1000), 3);
        let a = fleet.register(4);
        let b = fleet.register(4);
        assert_eq!((a, b), (0, 1));
        let mut last_scrub = [[0u64; 4]; 2];
        let clean = DecodeStats::default();
        for wakeup in 1..=40u64 {
            let now = secs(wakeup);
            let grants = {
                let refs: Vec<(usize, &ScrubScheduler)> =
                    vec![(a, &scheds[0]), (b, &scheds[1])];
                fleet.plan(&refs, now)
            };
            assert_eq!(grants.len(), 1, "budget fits exactly one pass");
            for g in grants {
                scheds[g.model].record_pass(g.shard, &clean, now);
                let waited = wakeup - last_scrub[g.model][g.shard];
                assert!(
                    waited <= 3 + 8 + 1,
                    "shard ({}, {}) waited {waited} wakeups",
                    g.model,
                    g.shard
                );
                last_scrub[g.model][g.shard] = wakeup;
            }
        }
        // demand is 8x the budget: both models must be carrying deficit
        for m in [a, b] {
            let d = fleet.deficit(m);
            assert!(d.deficit_bits > 0, "model {m} deficit: {d:?}");
            assert!(d.starved_grants > 0, "model {m} starved grants");
        }
        assert_eq!(fleet.wakeups(), 40);
    }

    #[test]
    fn planner_without_budget_grants_everything_due() {
        let cfg = SchedulerConfig::fixed(secs(1));
        let sched = ScrubScheduler::new(cfg, &[500, 500, 500], Duration::ZERO);
        let mut fleet = FleetArbitration::new(None, 4);
        let m = fleet.register(3);
        let grants = fleet.plan(&[(m, &sched)], Duration::ZERO);
        assert_eq!(grants.len(), 3);
        assert_eq!(fleet.deficit(m), ModelDeficit::default());
    }

    #[test]
    fn step_plan_is_the_fleet_law_for_one_model() {
        let cfg = SchedulerConfig::fixed(secs(1));
        let mut sched = ScrubScheduler::new(cfg, &[600, 600, 600], Duration::ZERO);
        // uncapped: every due shard granted, exactly `due`'s set
        assert_eq!(sched.step_plan(Duration::ZERO, None), vec![0, 1, 2]);
        // nothing due -> nothing planned
        for i in 0..3 {
            sched.record_pass(i, &DecodeStats::default(), Duration::ZERO);
        }
        assert!(sched.step_plan(secs(0), Some(u64::MAX)).is_empty());
        // capped at one shard's bits: exactly one grant, and it matches
        // what the fleet arbiter would grant for the same demand set
        let now = secs(1);
        sched.record_pass(0, &errs(40, 0), Duration::ZERO); // shard 0 urgent
        let plan = sched.step_plan(now, Some(600));
        assert_eq!(plan.len(), 1);
        let mut fleet = FleetArbitration::new(Some(600), u32::MAX);
        let m = fleet.register(3);
        let grants = fleet.plan(&[(m, &sched)], now);
        assert_eq!(
            plan,
            grants.iter().map(|g| g.shard).collect::<Vec<_>>(),
            "sim stepping and the fleet planner must agree"
        );
        // budget below the smallest shard: due work stays due
        assert!(sched.step_plan(now, Some(100)).is_empty());
        assert_eq!(sched.due(now).len(), 3);
    }

    #[test]
    fn gbps_conversion_is_pinned() {
        // 1 GB/s for a 1-second wakeup is exactly 8e9 stored bits
        assert_eq!(
            gbps_to_bits_per_wakeup(1.0, Duration::from_secs(1)),
            8_000_000_000
        );
        // 0.25 GB/s at a 200 ms wakeup: 0.25e9 * 8 * 0.2 = 4e8
        assert_eq!(
            gbps_to_bits_per_wakeup(0.25, Duration::from_millis(200)),
            400_000_000
        );
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert_eq!(gbps_to_bits_per_wakeup(bad, Duration::from_secs(1)), 0);
        }
    }

    #[test]
    fn overdue_passes_are_counted() {
        let cfg = SchedulerConfig::adaptive(secs(2), secs(64));
        let mut sched = ScrubScheduler::new(cfg, &[1 << 20], Duration::ZERO);
        // first pass at t=10: deadline was 0, slack is 1s -> overdue
        sched.record_pass(0, &DecodeStats::default(), secs(10));
        let snap = sched.snapshot(0, secs(10));
        assert_eq!(snap.overdue, 1);
        assert_eq!(snap.passes, 1);
        assert!(snap.deadline_in_secs > 0.0);
        // a punctual pass adds nothing
        let next = sched.deadline(0);
        sched.record_pass(0, &DecodeStats::default(), next);
        assert_eq!(sched.snapshot(0, next).overdue, 1);
    }
}
